"""Trip-count-aware static analysis of optimized HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any model
with scanned layers (all of ours) under-reports FLOPs/bytes by the trip
count; the same bias hits collective bytes for collectives inside the layer
scan (sequence-parallel all-gathers).  This module parses the post-SPMD HLO
text, builds the computation call graph with multipliers (while trip counts
from ``known_trip_count``) and produces trip-aware totals:

* flops:       2*M*N*K per dot (MXU work — elementwise is negligible);
* hbm bytes:   fusion-boundary traffic (result + operands of top-level
               instructions; fusion-internal computations touch VMEM only);
* collectives: per-kind bytes and counts for all-gather / all-reduce /
               reduce-scatter / all-to-all / collective-permute.

Shapes in post-SPMD HLO are per-device, so all numbers are per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DT = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s*->")
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT.get(dt, 4)
    return total


def _type_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # operands + attributes


@dataclass
class Computation:
    name: str
    params: dict = field(default_factory=dict)     # name -> type str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)     # instr name -> type str


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _COMP_HDR.match(line)
        if hdr and line.endswith("{"):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            for pm in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[a-z0-9]+"
                                  r"\[[0-9,]*\](?:\{[^}]*\})?))", hdr.group(2)):
                cur.params[pm.group(1)] = pm.group(2)
                cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        if opcode == "parameter":       # e.g. %p = f32[..] parameter(0)
            cur.params[name] = type_str
        ins = Instr(name, type_str, opcode, rest)
        cur.instrs.append(ins)
        cur.shapes[name] = type_str
    return comps


def _called_comps(ins: Instr) -> list[tuple[str, str]]:
    """(role, computation) pairs referenced by this instruction."""
    out = []
    for key in ("body", "condition", "to_apply", "calls"):
        for m in re.finditer(rf"{key}=%?([\w.\-]+)", ins.rest):
            out.append((key, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
    if m:
        for c in m.group(1).split(","):
            out.append(("branch", c.strip().lstrip("%")))
    return out


def _trip_count(ins: Instr) -> int:
    m = re.search(r'known_trip_count[^0-9]*"?(\d+)"?', ins.rest)
    if m:
        return int(m.group(1))
    m = re.search(r'trip_count[^0-9]*(\d+)', ins.rest)
    return int(m.group(1)) if m else 1


def _multipliers(comps: dict[str, Computation], *,
                 unit_trips: bool = False) -> tuple[dict, set]:
    """Computation -> execution count; plus the set of fusion-called comps
    (whose traffic is VMEM-internal)."""
    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name or entry is None:
            pass
    # ENTRY is the computation whose name is not referenced by any other
    referenced = set()
    for c in comps.values():
        for ins in c.instrs:
            for _, callee in _called_comps(ins):
                referenced.add(callee)
    entries = [n for n in comps if n not in referenced]
    mult = {n: 0 for n in comps}
    fusion_called: set[str] = set()
    stack = [(e, 1) for e in entries]
    seen_depth = 0
    while stack:
        name, k = stack.pop()
        if name not in comps or k == 0:
            continue
        mult[name] = mult.get(name, 0) + k
        comp = comps[name]
        for ins in comp.instrs:
            calls = _called_comps(ins)
            if not calls:
                continue
            trip = (_trip_count(ins)
                    if ins.opcode == "while" and not unit_trips else 1)
            for role, callee in calls:
                if callee not in comps:
                    continue
                kk = k * (trip if role in ("body", "condition") else 1)
                if role == "calls":
                    fusion_called.add(callee)
                stack.append((callee, kk))
                seen_depth += 1
                if seen_depth > 200_000:
                    raise RuntimeError("call graph runaway")
    return mult, fusion_called


_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "copy-start", "copy-done", "after-all",
                 "partition-id", "replica-id", "iota"}


@dataclass
class StaticCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes_by_kind: dict = field(default_factory=dict)
    coll_count_by_kind: dict = field(default_factory=dict)
    dot_flops_by_comp: dict = field(default_factory=dict)

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll_bytes_by_kind.values()))


def analyze_hlo(text: str, *, unit_trips: bool = False) -> StaticCost:
    """unit_trips=True pretends every while runs once — matching
    cost_analysis()'s accounting, used to derive the loop-correction ratio."""
    comps = parse_module(text)
    mult, fusion_called = _multipliers(comps, unit_trips=unit_trips)
    out = StaticCost()

    for comp in comps.values():
        k = mult.get(comp.name, 0)
        if k == 0:
            continue
        for ins in comp.instrs:
            # ---- flops: dots anywhere (incl. inside fusions) -------------
            if ins.opcode == "dot":
                res_elems = 1
                for d in _type_dims(ins.type_str):
                    res_elems *= d
                ops = re.findall(r"%([\w.\-]+)", ins.rest.split(")")[0])
                kdim = 1
                m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
                if m and ops:
                    lhs_type = comp.shapes.get(ops[0], "")
                    dims = _type_dims(lhs_type)
                    for ci in m.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            kdim *= dims[int(ci)]
                f = 2.0 * res_elems * kdim
                out.flops += k * f
                out.dot_flops_by_comp[comp.name] = \
                    out.dot_flops_by_comp.get(comp.name, 0.0) + k * f
            # ---- collectives --------------------------------------------
            for ckind in COLLECTIVES:
                if ins.opcode in (ckind, f"{ckind}-start"):
                    res_b = _type_bytes(ins.type_str)
                    opnames = re.findall(r"%([\w.\-]+)",
                                         ins.rest.split("),")[0])
                    op_b = sum(_type_bytes(comp.shapes.get(o, ""))
                               for o in opnames)
                    moved = max(res_b, op_b)
                    if ckind == "all-reduce":
                        moved *= 2
                    out.coll_bytes_by_kind[ckind] = \
                        out.coll_bytes_by_kind.get(ckind, 0) + k * moved
                    out.coll_count_by_kind[ckind] = \
                        out.coll_count_by_kind.get(ckind, 0) + k
                    break
            # ---- hbm traffic at fusion boundaries ------------------------
            if comp.name in fusion_called:
                continue
            if ins.opcode in _SKIP_TRAFFIC or ins.opcode.endswith("-done"):
                continue
            res_b = _type_bytes(ins.type_str)
            opnames = re.findall(r"%([\w.\-]+)", ins.rest)
            op_b = 0
            for o in opnames:
                t = comp.shapes.get(o)
                if t:
                    op_b += _type_bytes(t)
            out.hbm_bytes += k * (res_b + op_b)
    return out
