"""Cell builders: (arch x shape x mesh) -> jittable step fn + abstract args +
shardings.  Used by the dry-run, the roofline harness and the real drivers.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs import SHAPES, Shape, get_config
from repro.models import (abstract_params, cache_struct, decode_step, forward,
                          loss_fn, model_struct)
from repro.models.base import ModelConfig, P, abstract_params as abstract
from repro.optim import AdamWConfig, adamw_init_struct, adamw_update
from repro.sharding import batch_pspec, cache_pspecs, param_pspecs


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, tree)


# ---------------------------------------------------------------------------
# input specs (brief: ShapeDtypeStruct stand-ins for every model input)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.frontend == "audio_stub":
        return {"frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), f32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.frontend == "vision_stub":
        n_txt = S - cfg.n_patches
        return {"tokens": jax.ShapeDtypeStruct((B, n_txt), i32),
                "patches": jax.ShapeDtypeStruct(
                    (B, cfg.n_patches, cfg.frontend_dim), f32),
                "labels": jax.ShapeDtypeStruct((B, n_txt), i32),
                "loss_mask": jax.ShapeDtypeStruct((B, n_txt), f32)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
            "loss_mask": jax.ShapeDtypeStruct((B, S), f32)}


# ---------------------------------------------------------------------------
# cells
# ---------------------------------------------------------------------------

@dataclass
class Cell:
    name: str
    fn: Callable           # jittable
    args: tuple            # abstract (ShapeDtypeStruct) args
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


def _auto_score_shard(cfg: ModelConfig, mesh: Mesh) -> str:
    tp = mesh.shape.get("model", 1)
    return "heads" if cfg.n_heads % tp == 0 else "qseq"


def _auto_kv_shard(cfg: ModelConfig, mesh: Mesh) -> str:
    tp = mesh.shape.get("model", 1)
    if cfg.n_kv_heads % tp == 0:
        return "heads"
    if cfg.hd % tp == 0:
        return "hd"
    return "none"


def _mesh_batch_axes(mesh: Mesh, batch: int) -> tuple:
    from repro.sharding import data_axes
    dax = data_axes(mesh)
    n = 1
    for a in dax:
        n *= mesh.shape[a]
    return tuple(dax) if (dax and batch % n == 0) else ()


def train_cell(arch: str, shape_name: str, mesh: Mesh, *,
               remat: str = "full", fsdp: bool = True,
               rule_overrides: dict | None = None,
               score_shard: str | None = None,
               microbatches: int = 1,
               attn_dtype: str = "bf16",
               attn_impl: str | None = None,
               rwkv_unroll: int = 1,
               rwkv_impl: str = "scan",
               tp_impl: str = "gspmd",
               param_mode: str = "fsdp",
               opt: AdamWConfig = AdamWConfig()) -> Cell:
    """param_mode:
    * "fsdp"  — f32 params FSDP x TP sharded; weights are all-gathered on
      every use (and re-gathered each microbatch under accumulation);
    * "zero1" — bf16 compute params TP-sharded but REPLICATED across data;
      f32 master + moments stay FSDP x TP sharded in the optimizer state.
      Forward/backward do zero weight collectives; one reduce-scatter of the
      accumulated grads + one all-gather of updated bf16 params per step.
    """
    cfg = get_config(arch).replace(remat=remat)
    shape = SHAPES[shape_name]
    cfg = cfg.replace(
        score_shard=score_shard if score_shard is not None
        else _auto_score_shard(cfg, mesh),
        batch_axes=_mesh_batch_axes(mesh, shape.global_batch),
        act_shard="seq", attn_dtype=attn_dtype,
        kv_shard=_auto_kv_shard(cfg, mesh), rwkv_unroll=rwkv_unroll,
        rwkv_impl=rwkv_impl, tp_impl=tp_impl)
    if attn_impl is not None:
        cfg = cfg.replace(attn_impl=attn_impl)
    struct = model_struct(cfg)
    fsdp_spec = param_pspecs(struct, cfg, mesh, fsdp=True,
                             overrides=rule_overrides)
    tp_spec = param_pspecs(struct, cfg, mesh, fsdp=False,
                           overrides=rule_overrides)
    pspec = fsdp_spec if (fsdp and param_mode == "fsdp") else (
        tp_spec if param_mode == "zero1" else
        param_pspecs(struct, cfg, mesh, fsdp=fsdp,
                     overrides=rule_overrides))
    ostruct = adamw_init_struct(struct)
    if param_mode == "zero1":
        opt_spec = {"m": fsdp_spec, "v": fsdp_spec,
                    "master": fsdp_spec, "step": PartitionSpec()}
        ostruct = dict(ostruct, master=jax.tree_util.tree_map(
            lambda p: P(p.shape, p.axes, init=p.init, dtype=p.dtype),
            struct, is_leaf=lambda x: isinstance(x, P)))
    else:
        opt_spec = {"m": pspec, "v": pspec, "step": PartitionSpec()}
    bspec_all = batch_pspec(cfg, mesh, shape.global_batch)
    ins = input_specs(cfg, shape)
    bspec = {k: bspec_all[k] for k in ins}

    def grad_one(params, mb):
        def lossf(p):
            if param_mode == "zero1":       # params already bf16
                return loss_fn(p, cfg, mb)
            return loss_fn(cast_tree(p, jnp.bfloat16), cfg, mb)
        return jax.value_and_grad(lossf, has_aux=True)(params)

    def accumulate(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_one(params, batch)
            return loss, metrics, grads
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((microbatches, -1) + x.shape[1:]), batch)

        def acc_body(carry, mb):
            gsum, lsum = carry
            (loss, _), g = grad_one(params, mb)
            gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
            return (gsum, lsum + loss), ()

        gdt = jnp.bfloat16 if param_mode == "zero1" else jnp.float32
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, gdt), params)
        (grads, loss), _ = jax.lax.scan(
            acc_body, (zeros, jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        loss = loss / microbatches
        return loss, {"ce": loss, "aux": jnp.zeros((), jnp.float32)}, grads

    def step_fsdp(params, opt_state, batch):
        loss, metrics, grads = accumulate(params, batch)
        new_p, new_o, gnorm = adamw_update(params, grads, opt_state, opt)
        return new_p, new_o, dict(metrics, loss=loss, grad_norm=gnorm)

    def step_zero1(params, opt_state, batch):
        loss, metrics, grads = accumulate(params, batch)
        # ONE reduce-scatter: push the (data-replicated) grads into the
        # FSDP layout of the master shards.  Constrain BEFORE the f32 cast:
        # the wire moves bf16 and no full-size f32 grad is ever materialized
        grads = jax.tree_util.tree_map(
            lambda g, sp: jax.lax.with_sharding_constraint(g, sp)
            .astype(jnp.float32),
            grads, fsdp_spec)
        master = opt_state["master"]
        mstate = {"m": opt_state["m"], "v": opt_state["v"],
                  "step": opt_state["step"]}
        new_master, new_mstate, gnorm = adamw_update(master, grads, mstate,
                                                     opt)
        # ONE all-gather: updated bf16 compute params back to TP-only layout
        new_p = jax.tree_util.tree_map(
            lambda w, sp: jax.lax.with_sharding_constraint(
                w.astype(jnp.bfloat16), sp),
            new_master, tp_spec)
        new_o = dict(new_mstate, master=new_master)
        return new_p, new_o, dict(metrics, loss=loss, grad_norm=gnorm)

    if param_mode == "zero1":
        args = (abstract(struct, jnp.bfloat16), abstract(ostruct), ins)
        return Cell(name=f"{arch}:{shape_name}", fn=step_zero1, args=args,
                    in_shardings=(pspec, opt_spec, bspec),
                    out_shardings=(pspec, opt_spec, None),
                    donate_argnums=(0, 1))
    args = (abstract(struct), abstract(ostruct), ins)
    return Cell(
        name=f"{arch}:{shape_name}",
        fn=step_fsdp, args=args,
        in_shardings=(pspec, opt_spec, bspec),
        out_shardings=(pspec, opt_spec, None),
        donate_argnums=(0, 1))


def prefill_cell(arch: str, shape_name: str, mesh: Mesh, *,
                 fsdp: bool = True,
                 rule_overrides: dict | None = None,
                 score_shard: str | None = None,
                 attn_impl: str | None = None,
                 rwkv_unroll: int = 1,
                 rwkv_impl: str = "scan") -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cfg = cfg.replace(
        score_shard=score_shard if score_shard is not None
        else _auto_score_shard(cfg, mesh),
        batch_axes=_mesh_batch_axes(mesh, shape.global_batch),
        act_shard="seq", attn_dtype="bf16",
        kv_shard=_auto_kv_shard(cfg, mesh), rwkv_unroll=rwkv_unroll,
        rwkv_impl=rwkv_impl)
    if attn_impl is not None:
        cfg = cfg.replace(attn_impl=attn_impl)
    struct = model_struct(cfg)
    pspec = param_pspecs(struct, cfg, mesh, fsdp=fsdp,
                         overrides=rule_overrides)
    ins = input_specs(cfg, shape)
    bspec_all = batch_pspec(cfg, mesh, shape.global_batch)
    bspec = {k: bspec_all[k] for k in ins}

    def step(params, batch):
        # encoders have no decode step: their "prefill" is feature extraction
        logits, aux, caches = forward(params, cfg, batch,
                                      return_cache=cfg.is_decoder)
        return logits, caches

    args = (abstract(struct, jnp.bfloat16), ins)
    return Cell(
        name=f"{arch}:{shape_name}",
        fn=step, args=args,
        in_shardings=(pspec, bspec),
        out_shardings=None)


def decode_cell(arch: str, shape_name: str, mesh: Mesh, *,
                fsdp: bool = True,
                rule_overrides: dict | None = None,
                cache_overrides: dict | None = None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B = shape.global_batch
    cfg = cfg.replace(batch_axes=_mesh_batch_axes(mesh, B))
    struct = model_struct(cfg)
    pspec = param_pspecs(struct, cfg, mesh, fsdp=fsdp,
                         overrides=rule_overrides)
    cstruct = cache_struct(cfg, B, shape.seq_len)
    cspec = cache_pspecs(cstruct, cfg, mesh, B, overrides=cache_overrides)
    ins = input_specs(cfg, shape)

    def step(params, caches, tokens, pos):
        return decode_step(params, cfg, caches, tokens, pos)

    args = (abstract(struct, jnp.bfloat16),
            [abstract(cs, jnp.bfloat16) for cs in cstruct],
            ins["tokens"],
            jax.ShapeDtypeStruct((), jnp.int32))
    return Cell(
        name=f"{arch}:{shape_name}",
        fn=step, args=args,
        in_shardings=(pspec, cspec, PartitionSpec(), PartitionSpec()),
        out_shardings=(None, cspec),
        donate_argnums=(1,))


def build_cell(arch: str, shape_name: str, mesh: Mesh, **kw) -> Cell:
    kind = SHAPES[shape_name].kind
    if kind == "train":
        return train_cell(arch, shape_name, mesh, **kw)
    if kind == "prefill":
        return prefill_cell(arch, shape_name, mesh, **kw)
    return decode_cell(arch, shape_name, mesh, **kw)


def lower_cell(cell: Cell, mesh: Mesh):
    """lower() under the mesh; returns the Lowered object."""
    jitted = jax.jit(
        cell.fn,
        in_shardings=jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, PartitionSpec)
            else s, cell.in_shardings,
            is_leaf=lambda x: isinstance(x, PartitionSpec)),
        out_shardings=cell.out_shardings if cell.out_shardings is None else
        jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, PartitionSpec)
            else s, cell.out_shardings,
            is_leaf=lambda x: isinstance(x, PartitionSpec)),
        donate_argnums=cell.donate_argnums)
    try:
        ctx = jax.set_mesh(mesh)      # needed by shard_map's ambient lookup
    except Exception:
        ctx = mesh
    with ctx:
        return jitted.lower(*cell.args)
