import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run (brief deliverable e).

For every (architecture x input shape) run-cell, lower + compile the step
against the production meshes:

* single-pod  (16, 16)      ("data", "model")   — roofline source
* multi-pod   (2, 16, 16)   ("pod", "data", "model") — proves the pod axis

and record memory_analysis() (proves fit), cost_analysis() (FLOPs/bytes) and
the collective schedule (parsed from optimized HLO) into a JSON that
EXPERIMENTS.md SS Dry-run / SS Roofline read.

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod-only | --single-only]
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""


import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             verbose: bool = True, **cell_kw) -> dict:
    import jax
    from repro.configs import SHAPES, cell_status, get_config
    from repro.launch.hlo_analysis import analyze_compiled, model_flops
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell, lower_cell

    ok, why = cell_status(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    # auto-fit: training cells that exceed the HBM budget retry with more
    # gradient-accumulation microbatches (the fit proof the brief requires)
    HBM_BUDGET = 14 * 2 ** 30
    mb = cell_kw.pop("microbatches", 1)
    is_train = SHAPES[shape_name].kind == "train"
    while True:
        kw = dict(cell_kw, microbatches=mb) if is_train else dict(cell_kw)
        cell = build_cell(arch, shape_name, mesh, **kw)
        lowered = lower_cell(cell, mesh)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        temp = compiled.memory_analysis().temp_size_in_bytes
        if not is_train or temp <= HBM_BUDGET or mb >= 16:
            break
        print(f"[dryrun] {arch} x {shape_name}: temp "
              f"{temp/2**30:.1f} GiB > budget, retry microbatches={mb*2}",
              flush=True)
        mb *= 2
        import jax as _jax
        _jax.clear_caches()

    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
    }
    roof = analyze_compiled(compiled)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mf = model_flops(cfg, shape, backward=(shape.kind == "train"))
    n_dev = 1024 if multi_pod else 256
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "microbatches": mb if is_train else None,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_d,
        "roofline": roof.to_dict(),
        "model_flops_total": mf,
        "model_flops_per_dev": mf / n_dev,
        "useful_flop_frac": (mf / n_dev) / max(roof.flops, 1.0),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x "
              f"{'multi' if multi_pod else 'single'}: "
              f"compile {t_compile:.0f}s, "
              f"temp {mem_d['temp_bytes']/2**30:.2f} GiB, "
              f"args {mem_d['argument_bytes']/2**30:.2f} GiB, "
              f"dominant {roof.dominant}, "
              f"terms c/m/x = {roof.compute_s*1e3:.1f}/"
              f"{roof.memory_s*1e3:.1f}/{roof.collective_s*1e3:.1f} ms",
              flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--single-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--remat", default="dots")
    args = ap.parse_args()

    from repro.configs import ARCH_NAMES, SHAPES, cell_status

    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a in ARCH_NAMES for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = []
    if not args.multipod_only:
        meshes.append(False)
    if not args.single_only:
        meshes.append(True)

    import os as _os
    _os.makedirs(_os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if _os.path.exists(args.out):
        results = json.load(open(args.out))

    def key(r):
        return (r["arch"], r["shape"], r["mesh"])

    done = {key(r) for r in results if r.get("status") in ("ok", "skipped")}
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            k = (arch, shape, "multi" if mp else "single")
            if k in done:
                continue
            try:
                kw = {}
                from repro.configs import SHAPES as _S
                if _S[shape].kind == "train":
                    kw["remat"] = args.remat
                rec = run_cell(arch, shape, mp, **kw)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single",
                       "status": "error", "error": f"{type(e).__name__}: {e}"}
                failures += 1
            results = [r for r in results if key(r) != k] + [rec]
            json.dump(results, open(args.out, "w"), indent=1)
            import jax
            jax.clear_caches()
    print(f"[dryrun] wrote {args.out}; {failures} failures")


if __name__ == "__main__":
    main()
