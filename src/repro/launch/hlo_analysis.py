"""Roofline terms from compiled dry-run artifacts (TPU v5e target).

cost_analysis() gives HLO FLOPs and bytes for the per-device SPMD module;
collective bytes are NOT in cost_analysis, so we parse the optimized HLO text
and sum operand/result sizes of every collective op (per the brief).

Hardware constants (v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum per-device collective bytes from optimized HLO text.

    For each collective instruction we take max(result bytes, sum of operand
    bytes) as the data moved; all-reduce counts twice (reduce-scatter +
    all-gather phases of a ring).  HLO shapes post-SPMD are per-device.
    """
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)$", s)
        if not m:
            continue
        body = m.group(1)
        kind = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", body):
                kind = c
                break
        if kind is None:
            continue
        if re.search(rf"\b{kind}-done\(", body):
            continue                       # counted at -start
        shapes = _SHAPE_RE.findall(body)
        if not shapes:
            continue
        # result shape(s) appear before the op name; operands inside parens
        op_pos = body.find(kind)
        result_b = sum(_shape_bytes(d, dims)
                       for d, dims in _SHAPE_RE.findall(body[:op_pos]))
        operand_b = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(body[op_pos:]))
        moved = max(result_b, operand_b)
        if kind == "all-reduce":
            moved *= 2
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0) + moved
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
    return st


@dataclass
class Roofline:
    flops: float               # per-device HLO flops
    hbm_bytes: float           # per-device HLO bytes accessed
    coll_bytes: float          # per-device collective bytes
    collectives: CollectiveStats | None = None

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline lower bound: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "coll_by_kind": dict(self.collectives.bytes_by_kind)
            if self.collectives else {},
        }


def analyze_compiled(compiled, lowered_text: str | None = None) -> Roofline:
    """Trip-count-aware roofline terms (see hlo_static for why
    cost_analysis() alone is insufficient: while bodies count once).

    * flops: static dot accounting with trip counts (validated vs 6ND);
    * hbm:   cost_analysis bytes scaled by the static loop-correction ratio
             (static fusion-boundary traffic with trips / without);
    * collectives: static per-kind bytes with trip counts.
    """
    from .hlo_static import analyze_hlo
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text() if lowered_text is None else lowered_text
    st_trips = analyze_hlo(text)
    st_unit = analyze_hlo(text, unit_trips=True)
    scale = max(1.0, st_trips.hbm_bytes / max(st_unit.hbm_bytes, 1.0))
    cs = CollectiveStats(bytes_by_kind=dict(st_trips.coll_bytes_by_kind),
                         count_by_kind=dict(st_trips.coll_count_by_kind))
    return Roofline(flops=st_trips.flops, hbm_bytes=bytes_acc * scale,
                    coll_bytes=st_trips.coll_bytes, collectives=cs)


def model_flops(cfg, shape, *, backward: bool) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) 'useful' flops for the cell."""
    from repro.models import model_struct, param_count
    from repro.models.base import P
    import jax
    n = 0
    struct = model_struct(cfg)
    leaves = jax.tree_util.tree_flatten_with_path(
        struct, is_leaf=lambda x: isinstance(x, P))[0]
    for path, leaf in leaves:
        size = 1
        for d in leaf.shape:
            size *= d
        keys = "/".join(getattr(k, "key", str(k)) for k in path)
        if cfg.n_experts and ("w_gate" in keys or "w_up" in keys
                              or "w_down" in keys) and "shared" not in keys \
                and "segments" in keys and size >= cfg.n_experts:
            # routed expert weights: only top-k/E of them are active
            size = size * cfg.experts_per_token // cfg.n_experts
        n += size
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6 if backward else 2
    return float(mult) * n * tokens
