"""Serving driver: batched prefill + decode with KV/recurrent caches,
plus the control-flow *simulation service* endpoint.

Greedy-decodes a batch of prompts on a smoke config (CPU) or the production
mesh (TPU).  Prefill is teacher-forced through ``decode_step`` position by
position for windowed/recurrent caches' ring semantics — the compiled decode
step is the same function the decode_32k / long_500k dry-run cells lower.

``serve_simulations`` is the second endpoint: a thin client of
``repro.service.SimulationService`` — the queue-fed, coalescing, sharded
simulation service.  Requests are admitted one by one, coalesced by
execution signature, routed to the vmap-batched JAX ``batch_runner`` when
homogeneous, archived through a (rotating) JSONL sink, and reported with
service metrics (queue depth, latency percentiles, warps/s, batch fill).

``--mode replay`` is the offline half of archival: read a
``RotatingJsonlSink`` archive back (``repro.archive``), re-run every
replayable request, and report the trace-discrepancy aggregate — the
paper's Fig 9 from the durable archive instead of a live run.  With
``--watch`` the replay tails a *growing* archive: new runs appended by a
live service are picked up each poll and folded into a rolling aggregate.

Usage:
  python -m repro.launch.serve --arch rwkv6-3b --batch 4 --prompt-len 16 \\
      --gen-len 32
  python -m repro.launch.serve --mode sim --mechanism hanoi_jax --batch 64
  python -m repro.launch.serve --mode sim --mechanism volta_itps --batch 16 \\
      --workers 4 --max-batch 32 --max-wait-ms 5 --archive-dir sim-archive
  python -m repro.launch.serve --mode sim --mix hanoi_jax,hanoi,simt_stack \\
      --batch 24
  python -m repro.launch.serve --mode sim --sm-warps 8 --sm-policy \\
      greedy_then_oldest --mechanism hanoi --bench RBFS0
  python -m repro.launch.serve --mode sim --batch 16 --record-trace \\
      --archive-dir sim-archive
  python -m repro.launch.serve --mode replay --archive-dir sim-archive \\
      --replay-mechanism turing_oracle
  python -m repro.launch.serve --mode replay --archive-dir sim-archive \\
      --watch --watch-idle-s 30
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import cache_struct, decode_step, init_params, model_struct
from repro.models.base import init_params as init_cache


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 16, gen_len: int = 32, max_len: int = 256,
          seed: int = 0, greedy: bool = True) -> dict:
    cfg = get_config(arch, smoke=smoke)
    assert cfg.is_decoder and cfg.frontend == "token", \
        f"{arch} is not a token decoder"
    params = init_params(model_struct(cfg), jax.random.PRNGKey(seed))
    caches = [init_cache(cs, jax.random.PRNGKey(1))
              for cs in cache_struct(cfg, batch, max_len)]

    rng = np.random.default_rng(seed)
    prompts = rng.integers(2, cfg.vocab_size,
                           size=(batch, prompt_len)).astype(np.int32)

    dec = jax.jit(lambda p, c, t, i: decode_step(p, cfg, c, t, i))
    tokens = jnp.asarray(prompts)
    out_tokens = []
    t0 = time.time()
    logits = None
    for i in range(prompt_len + gen_len - 1):
        if i < prompt_len:
            tok = tokens[:, i:i + 1]
        else:
            tok = out_tokens[-1]
        logits, caches = dec(params, caches, tok,
                             jnp.asarray(i, jnp.int32))
        if i >= prompt_len - 1:
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out_tokens.append(nxt)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    steps = prompt_len + gen_len - 1
    return {"generated": gen, "steps": steps, "wall_s": dt,
            "tokens_per_s": batch * steps / dt}


def serve_simulations(requests, *, mechanism: str = "hanoi_jax",
                      sink=None, max_workers: int | None = None,
                      max_batch: int = 64, max_wait_s: float = 0.005,
                      procs: int = 0, warm_start: str | None = None,
                      service=None) -> dict:
    """Serve a batch of control-flow simulation requests.

    ``requests`` is a sequence of ``repro.engine.SimRequest`` (or Benchmark /
    ndarray program) objects.  Thin client of
    :class:`repro.service.SimulationService`: requests are admitted,
    coalesced by execution signature, and dispatched (natively batched when
    homogeneous); results come back in submission order.  The historical
    signature is preserved — ``sink`` becomes the service archive and
    ``max_workers`` the worker-pool size.  Pass an already-running
    ``service`` to reuse one across calls (its own archive applies;
    combining ``service`` with ``sink`` is rejected rather than silently
    ignoring the sink); otherwise a private service is spun up and drained
    for this batch.

    ``procs > 0`` turns on the process-backed execution tier: N spawned
    shard processes with signature-affine routing (numpy groups chunk
    across shards, escaping the GIL).  ``warm_start`` names a persistent
    compile-cache directory — hot signatures recorded there are re-primed
    before the service admits traffic, so a restarted service serves its
    first hot-path batch with zero re-traces.
    """
    from repro.service import SimulationService

    t0 = time.time()
    if service is not None:
        if sink is not None:
            raise ValueError(
                "pass sink= when serve_simulations creates the service, or "
                "construct the shared service with archive=; a sink given "
                "alongside service= would be silently ignored")
        results = service.run(requests, mechanism=mechanism)
        stats = service.stats()
    else:
        with SimulationService(default_mechanism=mechanism, archive=sink,
                               workers=max_workers or 2,
                               max_batch=max_batch,
                               max_wait_s=max_wait_s,
                               procs=procs, warm_start=warm_start or None
                               ) as svc:
            results = svc.run(requests)
            stats = svc.stats()
    dt = time.time() - t0
    n_ok = sum(1 for r in results if r.ok)
    return {"results": results, "wall_s": dt,
            "warps_per_s": len(results) / max(dt, 1e-9),
            "ok": n_ok, "failed": len(results) - n_ok,
            "mechanism": mechanism, "stats": stats}


def _sim_main(args) -> None:
    from repro.core import MachineConfig
    from repro.core.programs import make_suite
    from repro.engine import RotatingJsonlSink, SimRequest
    from repro.service import SimulationService

    cfg = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
    suite = make_suite(cfg, datasets=1)
    bench = next((b for b in suite if b.name == args.bench), None)
    if bench is None:
        raise SystemExit(f"unknown benchmark {args.bench!r}; available: "
                         + ", ".join(b.name for b in suite))
    archive = (RotatingJsonlSink(args.archive_dir)
               if args.archive_dir else None)
    # --auto-annotate implies strict admission: spin-loop (the repairable
    # hazard) is warn-level, so repair only ever triggers under strict
    verify: "bool | str" = not args.no_verify
    if args.auto_annotate and verify:
        verify = "strict"
    service = SimulationService(
        default_mechanism=args.mechanism, archive=archive,
        workers=args.workers, max_batch=args.max_batch,
        max_wait_s=args.max_wait_ms / 1000.0,
        procs=args.procs, warm_start=args.warm_start or None,
        verify=verify, auto_annotate=args.auto_annotate)
    try:
        with service as svc:
            if args.sm_warps:
                # per-SM mode: one sharded (SM, policy) cell on the pool
                sm = svc.submit_sm(bench, cfg, n_warps=args.sm_warps,
                                   inner=args.mechanism,
                                   policy=args.sm_policy).result()
                print(f"[serve:sim] SM x{sm.n_warps} warps of {args.bench} "
                      f"via {sm.inner} ({sm.policy}): "
                      f"status={sm.status.value} "
                      f"slots={sm.steps} cycles={sm.cycles} ipc={sm.ipc:.2f} "
                      f"util={sm.utilization:.3f}")
                return
            rng = np.random.default_rng(0)
            mix = (args.mix.split(",") if args.mix else [args.mechanism])
            reqs, mechs = [], []
            for i in range(args.batch):
                reqs.append(SimRequest(
                    program=bench.program, cfg=cfg,
                    init_mem=rng.integers(0, 8, size=cfg.mem_size)
                    .astype(np.int32),
                    record_trace=args.record_trace, name=f"req{i}"))
                mechs.append(mix[i % len(mix)])
            t0 = time.time()
            tickets = [svc.submit(r, mechanism=m)
                       for r, m in zip(reqs, mechs)]
            svc.flush()
            results = [t.result() for t in tickets]
            dt = time.time() - t0
            stats = svc.stats()
    finally:
        if archive is not None:     # both branches: drain the writer before
            archive.close()         # exit or queued runs are silently lost
    n_ok = sum(1 for r in results if r.ok)
    mix_label = "+".join(mix)
    print(f"[serve:sim] {args.batch} x {args.bench} via {mix_label}: "
          f"{n_ok} ok / {len(results) - n_ok} failed in {dt:.3f}s "
          f"({len(results) / max(dt, 1e-9):.0f} warps/s)"
          + (f" repaired={stats.repaired}" if stats.repaired else ""))
    print(f"[serve:sim] batches={stats.batches} "
          f"native={stats.native_batches} ({stats.native_warps} warps) "
          f"fill={stats.mean_fill:.1f} "
          f"p50={stats.latency_p50_s * 1e3:.1f}ms "
          f"p99={stats.latency_p99_s * 1e3:.1f}ms "
          + (f"archived={archive.runs_written} runs in "
             f"{len(archive.paths)} file(s)" if archive else ""))
    if stats.procs:
        shard_lbl = " ".join(
            f"s{s.shard}:{s.completed}ok/{s.failed}bad" for s in stats.shards)
        print(f"[serve:sim] procs={stats.procs} [{shard_lbl}] "
              f"cache hits={stats.cache_hits} misses={stats.cache_misses} "
              f"disk={stats.cache_disk_hits} "
              f"warm={stats.warm_loaded}+{stats.warm_retraced}re "
              f"trace={stats.cache_trace_time_s:.2f}s")


def _replay_main(args) -> None:
    from repro.archive import ArchiveReader, Replayer

    if not args.archive_dir:
        raise SystemExit("--mode replay requires --archive-dir")
    reader = ArchiveReader(args.archive_dir, prefix=args.archive_prefix)
    replayer = Replayer(args.replay_mechanism or None)
    t0 = time.time()
    if args.watch:
        # streaming replay: tail the (possibly still-growing) archive,
        # folding each batch of newly appended runs into a rolling
        # aggregate until --limit runs arrive or the archive goes idle
        def progress(report, n_new):
            agg = report.overall()
            rolling = agg.render() if report.rows else "n=0"
            print(f"[serve:replay] +{n_new} run(s) -> "
                  f"{report.replayed} replayed; rolling {rolling}",
                  flush=True)
        report = replayer.watch(
            reader, poll_s=args.watch_poll_ms / 1000.0,
            idle_timeout_s=args.watch_idle_s or None,
            max_runs=args.limit or None, progress=progress)
    else:
        report = replayer.replay(reader, limit=args.limit or None)
    dt = time.time() - t0
    print(report.render())
    print(f"[serve:replay] {report.replayed} run(s) in {dt:.3f}s "
          f"({report.replayed / max(dt, 1e-9):.0f} warps/s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "sim", "replay"], default="lm")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--mechanism", default="hanoi_jax",
                    help="[sim] control-flow mechanism to serve with "
                         "(any registered name, e.g. volta_itps)")
    ap.add_argument("--bench", default="GAUS0",
                    help="[sim] benchmark program to serve")
    ap.add_argument("--sm-warps", type=int, default=0,
                    help="[sim] run N warps per SM through --mechanism "
                         "(0 = single-warp batch mode)")
    ap.add_argument("--sm-policy", default="round_robin",
                    choices=["round_robin", "greedy_then_oldest"],
                    help="[sim] SM warp-scheduler policy for --sm-warps")
    ap.add_argument("--mix", default="",
                    help="[sim] comma-separated mechanisms to round-robin "
                         "requests over (exercises mixed-batch coalescing)")
    ap.add_argument("--procs", type=int, default=0,
                    help="sim mode: size of the process-backed execution "
                         "tier; 0 (default) keeps the in-process thread "
                         "pool, N>0 spawns N shard processes with "
                         "signature-affine routing")
    ap.add_argument("--warm-start", default="",
                    help="sim mode: persistent compile-cache directory; "
                         "hot signatures recorded there are re-primed "
                         "(deserialized or re-traced) before the service "
                         "admits traffic")
    ap.add_argument("--workers", type=int, default=2,
                    help="[sim] service worker threads")
    ap.add_argument("--no-verify", action="store_true",
                    help="[sim] skip static pre-admission analysis "
                         "(repro.analysis); by default error-level "
                         "programs are rejected at admission")
    ap.add_argument("--auto-annotate", action="store_true",
                    help="[sim] repair rejected programs through the "
                         "annotation synthesizer (BSSY/BSYNC/BMOV/YIELD) "
                         "and admit the rewrite instead of rejecting; "
                         "implies strict admission unless --no-verify")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="[sim] coalescer size-flush threshold")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="[sim] coalescer deadline-flush threshold (ms)")
    ap.add_argument("--archive-dir", default="",
                    help="[sim] archive traces to rotating JSONL files in "
                         "this directory; [replay] the archive to replay")
    ap.add_argument("--record-trace", action="store_true",
                    help="[sim] record control-flow traces on served "
                         "requests (required for a replayable/diffable "
                         "archive; off by default to keep serving lean)")
    ap.add_argument("--archive-prefix", default="traces",
                    help="[replay] archive file prefix")
    ap.add_argument("--replay-mechanism", default="",
                    help="[replay] mechanism to replay under (default: "
                         "each run's archived mechanism — the self-replay "
                         "integrity check)")
    ap.add_argument("--limit", type=int, default=0,
                    help="[replay] replay at most N runs (0 = all; with "
                         "--watch, stop after N runs)")
    ap.add_argument("--watch", action="store_true",
                    help="[replay] streaming mode: tail a growing archive "
                         "and replay newly appended runs incrementally "
                         "with a rolling aggregate")
    ap.add_argument("--watch-poll-ms", type=float, default=250.0,
                    help="[replay] --watch poll interval (ms)")
    ap.add_argument("--watch-idle-s", type=float, default=0.0,
                    help="[replay] exit --watch after this long with no "
                         "new runs (0 = watch until --limit/interrupt)")
    args = ap.parse_args()
    if args.mode == "sim":
        _sim_main(args)
        return
    if args.mode == "replay":
        _replay_main(args)
        return
    res = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen_len=args.gen_len)
    print(f"[serve] generated {res['generated'].shape} tokens in "
          f"{res['wall_s']:.2f}s ({res['tokens_per_s']:.1f} tok/s)")
    print(res["generated"][:, :10])


if __name__ == "__main__":
    main()
