"""Serving driver: batched prefill + decode with KV/recurrent caches.

Greedy-decodes a batch of prompts on a smoke config (CPU) or the production
mesh (TPU).  Prefill is teacher-forced through ``decode_step`` position by
position for windowed/recurrent caches' ring semantics — the compiled decode
step is the same function the decode_32k / long_500k dry-run cells lower.

Usage:
  python -m repro.launch.serve --arch rwkv6-3b --batch 4 --prompt-len 16 \\
      --gen-len 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import cache_struct, decode_step, init_params, model_struct
from repro.models.base import init_params as init_cache


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 16, gen_len: int = 32, max_len: int = 256,
          seed: int = 0, greedy: bool = True) -> dict:
    cfg = get_config(arch, smoke=smoke)
    assert cfg.is_decoder and cfg.frontend == "token", \
        f"{arch} is not a token decoder"
    params = init_params(model_struct(cfg), jax.random.PRNGKey(seed))
    caches = [init_cache(cs, jax.random.PRNGKey(1))
              for cs in cache_struct(cfg, batch, max_len)]

    rng = np.random.default_rng(seed)
    prompts = rng.integers(2, cfg.vocab_size,
                           size=(batch, prompt_len)).astype(np.int32)

    dec = jax.jit(lambda p, c, t, i: decode_step(p, cfg, c, t, i))
    tokens = jnp.asarray(prompts)
    out_tokens = []
    t0 = time.time()
    logits = None
    for i in range(prompt_len + gen_len - 1):
        if i < prompt_len:
            tok = tokens[:, i:i + 1]
        else:
            tok = out_tokens[-1]
        logits, caches = dec(params, caches, tok,
                             jnp.asarray(i, jnp.int32))
        if i >= prompt_len - 1:
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out_tokens.append(nxt)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    steps = prompt_len + gen_len - 1
    return {"generated": gen, "steps": steps, "wall_s": dt,
            "tokens_per_s": batch * steps / dt}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()
    res = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen_len=args.gen_len)
    print(f"[serve] generated {res['generated'].shape} tokens in "
          f"{res['wall_s']:.2f}s ({res['tokens_per_s']:.1f} tok/s)")
    print(res["generated"][:, :10])


if __name__ == "__main__":
    main()
