"""Training driver: real steps on whatever devices exist, with the full
production runtime around them — sharded init, deterministic data, periodic
async checkpoints, restart-on-failure resume, straggler monitoring and
optional int8 gradient compression (error feedback).

This is the end-to-end example driver (brief deliverable b): reduced configs
train on CPU; the same code drives the production mesh on real pods.

Usage:
  python -m repro.launch.train --arch llama3.2-1b --smoke --steps 50 \\
      --batch 8 --seq 128 --ckpt-dir /tmp/ck [--resume] [--fail-at-step 30]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.configs import get_config
from repro.data import DataConfig, SyntheticPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, loss_fn, model_struct
from repro.models.base import abstract_params
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.runtime import StragglerMonitor, ef_compress_grads
from repro.sharding import param_pspecs


def build_train_state(cfg, mesh, seed: int = 0):
    struct = model_struct(cfg)
    pspec = param_pspecs(struct, cfg, mesh)
    params = init_params(struct, jax.random.PRNGKey(seed))
    params = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, pspec,
        is_leaf=lambda x: isinstance(x, jax.Array))
    opt_state = adamw_init(params)
    return params, opt_state, pspec


def make_step(cfg, opt_cfg: AdamWConfig, *, total_steps: int,
              compress: bool = False):
    def step(params, opt_state, err_state, batch):
        def lossf(p):
            return loss_fn(p, cfg, batch)
        (loss, metrics), grads = jax.value_and_grad(
            lossf, has_aux=True)(params)
        if compress:
            grads, err_state = ef_compress_grads(grads, err_state)
        lr = cosine_schedule(opt_state["step"], peak_lr=opt_cfg.lr,
                             total=total_steps)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state,
                                                opt_cfg, lr=lr)
        return params, opt_state, err_state, dict(
            metrics, loss=loss, grad_norm=gnorm, lr=lr)
    return jax.jit(step, donate_argnums=(0, 1, 2))


def train(arch: str, *, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 128, ckpt_dir: str | None = None, ckpt_every: int = 20,
          resume: bool = False, fail_at_step: int | None = None,
          compress: bool = False, lr: float = 3e-3, seed: int = 0,
          log_every: int = 10, model_axis: int = 1) -> dict:
    cfg = get_config(arch, smoke=smoke)
    mesh = make_host_mesh(model=model_axis)
    opt_cfg = AdamWConfig(lr=lr)
    params, opt_state, pspec = build_train_state(cfg, mesh, seed)
    err_state = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, jnp.float32), params) if compress else None

    start = 0
    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    if resume and ckpt_dir and (last := latest_step(ckpt_dir)) is not None:
        state = restore_checkpoint(
            ckpt_dir, last, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = last
        print(f"[train] resumed from step {start}", flush=True)

    pipe = SyntheticPipeline(cfg, batch, seq, dc=DataConfig(seed=seed))
    step_fn = make_step(cfg, opt_cfg, total_steps=steps, compress=compress)
    mon = StragglerMonitor()
    losses = []
    with mesh:
        for i in range(start, steps):
            if fail_at_step is not None and i == fail_at_step:
                raise RuntimeError(f"injected failure at step {i}")
            t0 = time.time()
            hb = {k: jnp.asarray(v) for k, v in pipe.get(i).items()}
            params, opt_state, err_state, metrics = step_fn(
                params, opt_state, err_state, hb)
            loss = float(metrics["loss"])
            losses.append(loss)
            mon.record(jax.process_index(), time.time() - t0)
            if mgr and (i + 1) % ckpt_every == 0:
                mgr.save(i + 1, {"params": params, "opt": opt_state})
            if (i + 1) % log_every == 0:
                print(f"[train] step {i+1:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({time.time()-t0:.2f}s)", flush=True)
    if mgr:
        mgr.save(steps, {"params": params, "opt": opt_state})
        mgr.wait()
    return {"losses": losses, "params": params, "opt_state": opt_state,
            "final_loss": losses[-1] if losses else None}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()
    res = train(args.arch, smoke=args.smoke, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, resume=args.resume,
                fail_at_step=args.fail_at_step, compress=args.compress,
                lr=args.lr, model_axis=args.model_axis)
    print(f"[train] done; final loss {res['final_loss']:.4f}")


if __name__ == "__main__":
    main()
