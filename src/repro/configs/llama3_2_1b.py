"""Llama-3.2 1B [hf:meta-llama/Llama-3.2-1B]: small Llama-3, tied embeddings."""
from repro.models.base import GLOBAL, ModelConfig, uniform_plan

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab_size=128256,
    layer_plan=uniform_plan(GLOBAL, 16),
    rope_theta=500_000.0, tie_embeddings=True,
).validate()

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
    vocab_size=96, layer_plan=uniform_plan(GLOBAL, 2),
).validate()
