"""DeepSeekMoE-16B [arXiv:2401.06066]: fine-grained 64 routed experts top-6
plus 2 shared experts; first layer dense (d_ff 10944); expert ff = 1408."""
from repro.models.base import GLOBAL, ModelConfig, uniform_plan

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    layer_plan=uniform_plan(GLOBAL, 28),
    n_experts=64, experts_per_token=6, moe_d_ff=1408,
    n_shared_experts=2, first_dense_layers=1,
).validate()

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=192,
    vocab_size=96, layer_plan=uniform_plan(GLOBAL, 3),
    n_experts=8, experts_per_token=3, moe_d_ff=32, n_shared_experts=2,
    first_dense_layers=1,
).validate()
