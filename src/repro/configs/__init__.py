"""Architecture registry: the 10 assigned configs + reduced smoke variants,
and the per-arch input-shape cell map (which cells run / why skipped)."""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.base import ModelConfig

from . import (deepseek_moe_16b, gemma3_4b, hubert_xlarge, internlm2_20b,
               internvl2_2b, llama3_2_1b, minitron_4b, mixtral_8x7b,
               recurrentgemma_2b, rwkv6_3b)

_MODULES = {
    "hubert-xlarge": hubert_xlarge,
    "gemma3-4b": gemma3_4b,
    "minitron-4b": minitron_4b,
    "internlm2-20b": internlm2_20b,
    "llama3.2-1b": llama3_2_1b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "internvl2-2b": internvl2_2b,
    "mixtral-8x7b": mixtral_8x7b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "rwkv6-3b": rwkv6_3b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = _MODULES[name]
    return mod.SMOKE if smoke else mod.CONFIG


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

# sub-quadratic sequence mixing (long_500k eligibility)
_SUBQUADRATIC = {"gemma3-4b", "recurrentgemma-2b", "mixtral-8x7b", "rwkv6-3b"}


def cell_status(arch: str, shape_name: str) -> tuple[bool, str]:
    """(runs, reason).  All 40 cells get a verdict; skips are documented."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if shape.kind == "decode" and not cfg.is_decoder:
        return False, "encoder-only arch has no decode step"
    if shape_name == "long_500k" and arch not in _SUBQUADRATIC:
        return False, "pure full-attention arch; long_500k needs sub-quadratic"
    return True, "runs"


def run_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_NAMES for s in SHAPES
            if cell_status(a, s)[0]]


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for a in ARCH_NAMES:
        for s in SHAPES:
            ok, why = cell_status(a, s)
            if not ok:
                out.append((a, s, why))
    return out
