"""HuBERT X-Large [arXiv:2106.07447]: 48L encoder-only, same arch as
wav2vec2-XL.  The audio frontend (conv feature encoder) is a STUB: inputs are
precomputed frame embeddings (brief: '[audio] entries specify the transformer
BACKBONE only')."""
from repro.models.base import GLOBAL, ModelConfig, uniform_plan

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504,
    layer_plan=uniform_plan(GLOBAL, 48),
    causal=False,
    frontend="audio_stub", frontend_dim=512,
).validate()

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=64, layer_plan=uniform_plan(GLOBAL, 3), frontend_dim=16,
).validate()
