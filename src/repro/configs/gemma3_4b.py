"""Gemma-3 4B [hf:google/gemma-3-*-pt]: 5 local : 1 global attention pattern,
local window 1024, huge 262k vocabulary, tied embeddings."""
from repro.models.base import GLOBAL, LOCAL, ModelConfig, cycle_plan

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab_size=262144,
    layer_plan=cycle_plan((LOCAL,) * 5 + (GLOBAL,), 34),
    window_size=1024, rope_theta=1_000_000.0, tie_embeddings=True,
).validate()

SMOKE = CONFIG.replace(
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=128, layer_plan=cycle_plan((LOCAL,) * 5 + (GLOBAL,), 7),
    window_size=8,
).validate()
