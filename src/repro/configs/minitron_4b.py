"""Minitron-4B [arXiv:2407.14679]: width/depth-pruned Nemotron-4."""
from repro.models.base import GLOBAL, ModelConfig, uniform_plan

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab_size=256000,
    layer_plan=uniform_plan(GLOBAL, 32),
).validate()

SMOKE = CONFIG.replace(
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=96, layer_plan=uniform_plan(GLOBAL, 2),
).validate()
