"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427]: RG-LRU recurrent blocks
mixed 2:1 with local attention (window 2048), kv=1 MQA."""
from repro.models.base import LOCAL, RECURRENT, ModelConfig, cycle_plan

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256000,
    layer_plan=cycle_plan((RECURRENT, RECURRENT, LOCAL), 26),
    window_size=2048, lru_width=2560, tie_embeddings=True,
).validate()

SMOKE = CONFIG.replace(
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab_size=128, layer_plan=cycle_plan((RECURRENT, RECURRENT, LOCAL), 5),
    window_size=8, lru_width=64,
).validate()
