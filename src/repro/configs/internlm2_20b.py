"""InternLM2-20B [arXiv:2403.17297]: the largest dense cell (GQA kv=8)."""
from repro.models.base import GLOBAL, ModelConfig, uniform_plan

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92544,
    layer_plan=uniform_plan(GLOBAL, 48),
).validate()

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=96, layer_plan=uniform_plan(GLOBAL, 2),
).validate()
