"""Mixtral 8x7B [arXiv:2401.04088]: 8 experts top-2 MoE with sliding-window
attention (window 4096)."""
from repro.models.base import SWA, ModelConfig, uniform_plan

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    layer_plan=uniform_plan(SWA, 32), window_size=4096,
    n_experts=8, experts_per_token=2, moe_d_ff=14336,
).validate()

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=96, layer_plan=uniform_plan(SWA, 2), window_size=8,
    n_experts=4, experts_per_token=2, moe_d_ff=128,
).validate()
