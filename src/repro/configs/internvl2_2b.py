"""InternVL2-2B [arXiv:2404.16821]: InternLM2-1.8B language backbone; the
InternViT vision tower is a STUB (precomputed patch embeddings)."""
from repro.models.base import GLOBAL, ModelConfig, uniform_plan

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92553,
    layer_plan=uniform_plan(GLOBAL, 24),
    frontend="vision_stub", frontend_dim=1024, n_patches=256,
).validate()

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=96, layer_plan=uniform_plan(GLOBAL, 2),
    frontend_dim=16, n_patches=4,
).validate()
