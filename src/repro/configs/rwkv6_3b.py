"""RWKV-6 'Finch' 3B [arXiv:2404.05892]: attention-free; data-dependent decay
time-mix + channel-mix; head dim 64 (40 heads at d=2560)."""
from repro.models.base import RWKV, ModelConfig, uniform_plan

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab_size=65536,
    layer_plan=uniform_plan(RWKV, 32), rwkv_head_dim=64,
).validate()

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=96, layer_plan=uniform_plan(RWKV, 2), rwkv_head_dim=16,
).validate()
