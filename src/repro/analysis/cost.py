"""repro.analysis.cost — static cost model for SASS-lite programs.

Predicts, without executing, roughly how expensive a program will be on
the :mod:`repro.timing` cycle engine: every reachable instruction is
weighted by a loop-trip multiplier (``trip`` per enclosing loop level)
and priced by its :class:`~repro.timing.CycleConfig` latency class
(control / ALU / memory / atomic, with the memory model's expected
latency for sampled models).  On top of the issue estimate the model
reports the structural facts the paper ties to control-flow cost: the
peak reconvergence-stack depth (nested BSSY regions), the sizes of the
divergent regions, and the predicted issue/stall mix.

The model is deliberately coarse — it knows nothing about warp count,
scoreboard hazards, or actual trip counts — but it is *monotone* in the
right things, which is what an optimization pass needs: more divergent
work, deeper nesting, and more long-latency memory traffic all raise the
estimate.  ``tests/test_transform.py`` gates a Spearman rank correlation
between :func:`estimate` and measured ``simulate_cycle`` cycles over the
benchmark suite, so the ordering stays honest as either side evolves.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.isa import ATOMIC_OPS, F_OP, MachineConfig, Op

from .cfg import ProgramCFG

__all__ = ["CostEstimate", "estimate", "rank_correlation"]

_MEM_OPS = frozenset({int(Op.LDG), int(Op.STG)})
_ATOMIC_OPS = frozenset(int(op) for op in ATOMIC_OPS)


def _expected_memory_latency(cycle_cfg) -> float:
    """Expected LDG/STG latency under the config's memory model."""
    model = getattr(cycle_cfg, "memory_model", "fixed")
    if model == "uniform":
        return (cycle_cfg.memory_latency_lo + cycle_cfg.memory_latency_hi) / 2.0
    if model == "bimodal":
        rate = cycle_cfg.memory_hit_rate
        return (rate * cycle_cfg.memory_hit_latency
                + (1.0 - rate) * cycle_cfg.memory_latency)
    return float(cycle_cfg.memory_latency)


@dataclass(frozen=True)
class CostEstimate:
    """Static cost prediction for one program (see module docstring).

    ``issue_cycles`` is the headline number: the latency-weighted,
    trip-weighted sum over reachable instructions.  The ``*_cycles``
    fields partition it by latency class; ``weighted_instructions`` is
    the same sum with every latency set to 1 (a static trace-length
    guess).  ``stack_depth`` / ``region_sizes`` / ``divergent_fraction``
    expose the control-flow-management structure the estimate rests on.
    """

    issue_cycles: float
    weighted_instructions: float
    control_cycles: float
    alu_cycles: float
    memory_cycles: float
    atomic_cycles: float
    stack_depth: int
    region_sizes: tuple[int, ...] = ()
    divergent_fraction: float = 0.0
    spin_loops: int = 0
    trip: int = 8

    @property
    def stall_fraction(self) -> float:
        """Predicted share of cycles spent waiting on memory/atomics."""
        if self.issue_cycles <= 0:
            return 0.0
        return (self.memory_cycles + self.atomic_cycles) / self.issue_cycles

    def render(self) -> str:
        parts = [f"issue={self.issue_cycles:.0f}",
                 f"instrs={self.weighted_instructions:.0f}",
                 f"stack_depth={self.stack_depth}",
                 f"divergent={self.divergent_fraction:.0%}",
                 f"stall={self.stall_fraction:.0%}"]
        if self.spin_loops:
            parts.append(f"spin_loops={self.spin_loops}")
        return " ".join(parts)


def estimate(program, cfg: MachineConfig | None = None, *,
             cycle_cfg=None, trip: int = 8) -> CostEstimate:
    """Statically price ``program`` against ``cycle_cfg`` latencies.

    ``trip`` is the assumed iteration count per loop-nesting level: an
    instruction inside ``k`` nested loops contributes ``trip**k`` times
    its class latency.  Unreachable instructions contribute nothing.
    """
    from repro.timing import CycleConfig  # local: keep import cycle short
    if cfg is None:
        cfg = MachineConfig()
    if cycle_cfg is None:
        cycle_cfg = CycleConfig()
    if trip < 1:
        raise ValueError(f"trip must be >= 1, got {trip}")
    g = program if isinstance(program, ProgramCFG) else ProgramCFG(program)

    mem_lat = _expected_memory_latency(cycle_cfg)
    loop_sets = [loop.nodes for loop in g.loops]
    regions = g.valid_regions

    control = alu = mem = atomic = instrs = 0.0
    divergent_weight = total_weight = 0.0
    for pc in range(g.n):
        if not g.reachable[pc]:
            continue
        weight = float(trip ** sum(1 for nodes in loop_sets if pc in nodes))
        op = g.ops[pc]
        if op in _ATOMIC_OPS:
            atomic += weight * cycle_cfg.atomic_latency
        elif op in _MEM_OPS:
            mem += weight * mem_lat
        elif Op(op) in _CONTROL_OPS:
            control += weight * cycle_cfg.control_latency
        else:
            alu += weight * cycle_cfg.alu_latency
        instrs += weight
        total_weight += weight
        if any(p < pc < t for p, _bx, t in regions):
            divergent_weight += weight

    issue = control + alu + mem + atomic
    spin = sum(1 for loop in g.loops
               if g.loop_has(loop, ATOMIC_OPS) and g.loop_has_exit(loop))
    return CostEstimate(
        issue_cycles=issue,
        weighted_instructions=instrs,
        control_cycles=control,
        alu_cycles=alu,
        memory_cycles=mem,
        atomic_cycles=atomic,
        stack_depth=g.max_region_depth,
        region_sizes=tuple(sorted(t - p - 1 for p, _bx, t in regions)),
        divergent_fraction=(divergent_weight / total_weight
                            if total_weight else 0.0),
        spin_loops=spin,
        trip=trip,
    )


# control-latency ops, mirroring repro.timing's taxonomy without importing
# its private set (the two are cross-checked in tests)
_CONTROL_OPS = frozenset({
    Op.BRA, Op.EXIT, Op.BSSY, Op.BSYNC, Op.BMOV_B2R, Op.BMOV_R2B,
    Op.BREAK, Op.WARPSYNC, Op.YIELD, Op.CALL, Op.RET, Op.NOP,
})


def _ranks(values) -> list[float]:
    """Average ranks (1-based) with ties sharing their mean rank."""
    order = sorted(range(len(values)), key=lambda i: values[i])
    ranks = [0.0] * len(values)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and values[order[j + 1]] == values[order[i]]:
            j += 1
        mean = (i + j) / 2.0 + 1.0
        for k in range(i, j + 1):
            ranks[order[k]] = mean
        i = j + 1
    return ranks


def rank_correlation(xs, ys) -> float:
    """Spearman rank correlation of two equal-length sequences.

    Hand-rolled (Pearson over average ranks) so the gate has no SciPy
    dependency.  Returns 0.0 for degenerate inputs (< 2 points, or a
    constant sequence).
    """
    xs, ys = list(xs), list(ys)
    if len(xs) != len(ys):
        raise ValueError(f"length mismatch: {len(xs)} vs {len(ys)}")
    if len(xs) < 2:
        return 0.0
    rx = np.asarray(_ranks(xs), dtype=np.float64)
    ry = np.asarray(_ranks(ys), dtype=np.float64)
    sx, sy = rx.std(), ry.std()
    if sx == 0.0 or sy == 0.0:
        return 0.0
    return float(((rx - rx.mean()) * (ry - ry.mean())).mean() / (sx * sy))
