"""The static verification passes and their diagnostic catalog.

``analyze_program`` runs every pass over one encoded program — without
executing an instruction — and returns an :class:`AnalysisReport` of
structured :class:`Diagnostic`\\ s, each carrying a severity, a stable
code, the pc, and the disassembled instruction text.

Severity contract (what the platform layers key off):

* ``error`` — the program violates a static contract of the paper's
  control-flow semantics; running it wastes shard fuel on a guaranteed
  malfunction.  `SimulationService` refuses these at admission.
* ``warn`` — legal but hazardous (a YIELD-less spin-loop can hang
  ``simt_stack``; a region nest deeper than the Bx file forces BMOV
  spills).  Reported; runs proceed.
* ``info`` — noteworthy structure (BREAK early reconvergence,
  unannotated divergent branches) that explains mechanism disagreement.

The catalog is documented in docs/analysis.md; codes are stable API.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from functools import lru_cache

import numpy as np

from repro.core.asm import disassemble_line
from repro.core.isa import (ATOMIC_OPS, F_DST, F_IMM, F_OP, F_PRED1, F_PRED2,
                            F_SRC0, MachineConfig, Op)

from .cfg import SINK, ProgramCFG
from .fingerprint import fingerprint

__all__ = ["AnalysisReport", "Diagnostic", "Severity", "StaticAnalysisError",
           "analyze_program", "verify_program"]


class Severity(str, Enum):
    ERROR = "error"
    WARN = "warn"
    INFO = "info"

    def __str__(self) -> str:      # render "error", not "Severity.ERROR"
        return self.value


@dataclass(frozen=True)
class Diagnostic:
    """One finding: ``(severity, stable code, pc, message, disassembly)``."""

    severity: Severity
    code: str
    pc: int
    message: str
    line: str = ""

    def render(self) -> str:
        return (f"pc {self.pc:4d}  [{self.severity}] {self.code}: "
                f"{self.message}\n          {self.line}")


@dataclass(frozen=True)
class AnalysisReport:
    """Everything one analyzer run produced for one program."""

    diagnostics: tuple[Diagnostic, ...] = ()
    fingerprint: tuple[float, ...] = ()
    name: str = ""

    @property
    def errors(self) -> tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics
                     if d.severity is Severity.ERROR)

    @property
    def warnings(self) -> tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics
                     if d.severity is Severity.WARN)

    @property
    def infos(self) -> tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics
                     if d.severity is Severity.INFO)

    @property
    def ok(self) -> bool:
        return not self.errors

    def codes(self) -> tuple[str, ...]:
        return tuple(d.code for d in self.diagnostics)

    def render(self) -> str:
        head = f"analysis{f' of {self.name}' if self.name else ''}: "
        if not self.diagnostics:
            return head + "clean"
        lines = [head + f"{len(self.errors)} error(s), "
                        f"{len(self.warnings)} warning(s), "
                        f"{len(self.infos)} info(s)"]
        lines += [d.render() for d in self.diagnostics]
        return "\n".join(lines)


class StaticAnalysisError(ValueError):
    """Raised (and set on service tickets) for ``error``-level programs."""

    def __init__(self, report: AnalysisReport) -> None:
        self.report = report
        super().__init__(report.render())


def analyze_program(program: np.ndarray, cfg: MachineConfig | None = None,
                    *, name: str = "") -> AnalysisReport:
    """Run every static pass; diagnostics come back sorted by pc then
    severity (errors first at equal pc)."""
    prog = np.ascontiguousarray(np.asarray(program, dtype=np.int32))
    cfg = cfg if cfg is not None else MachineConfig()
    report = _analyze_cached(prog.tobytes(), prog.shape[0],
                             cfg.n_bx, cfg.n_preds, cfg.n_regs)
    if name:
        report = AnalysisReport(report.diagnostics, report.fingerprint, name)
    return report


def verify_program(program: np.ndarray, cfg: MachineConfig | None = None,
                   *, name: str = "", strict: bool = False) -> AnalysisReport:
    """:func:`analyze_program`, raising :class:`StaticAnalysisError` when
    errors (or, with ``strict``, warnings) are present."""
    report = analyze_program(program, cfg, name=name)
    bad = report.errors + (report.warnings if strict else ())
    if bad:
        raise StaticAnalysisError(report)
    return report


@lru_cache(maxsize=4096)
def _analyze_cached(key: bytes, length: int, n_bx: int,
                    n_preds: int, n_regs: int) -> AnalysisReport:
    # the key carries every MachineConfig knob a pass reads (n_bx for
    # stack-depth/bad-bx, n_preds for predicate checks, n_regs for the
    # spill-capacity hint) so reports never go stale across configs
    prog = np.frombuffer(key, dtype=np.int32).reshape(length, -1)
    cfg = MachineConfig(n_bx=n_bx, n_preds=n_preds, n_regs=n_regs)
    return _analyze(prog, cfg)


_SEV_ORDER = {Severity.ERROR: 0, Severity.WARN: 1, Severity.INFO: 2}


def _analyze(prog: np.ndarray, cfg: MachineConfig) -> AnalysisReport:
    g = ProgramCFG(prog, cfg)
    diags: list[Diagnostic] = []

    def emit(severity: Severity, code: str, pc: int, message: str) -> None:
        line = disassemble_line(prog[pc]) if 0 <= pc < g.n else ""
        diags.append(Diagnostic(severity, code, pc, message, line))

    _check_targets(g, emit)
    _check_bx(g, cfg, emit)
    _check_regions(g, emit)
    _check_reconvergence(g, emit)
    _check_warpsync(g, emit)
    _check_reachability(g, emit)
    _check_loops(g, emit)
    _check_stack_depth(g, cfg, emit)

    diags.sort(key=lambda d: (d.pc, _SEV_ORDER[d.severity], d.code))
    return AnalysisReport(tuple(diags), fingerprint(prog, cfg))


# ---------------------------------------------------------------------------
# individual passes
# ---------------------------------------------------------------------------

def _check_targets(g: ProgramCFG, emit) -> None:
    """``bad-target``: a control-transfer immediate outside the program."""
    for pc in g.bad_targets:
        op = Op(g.ops[pc])
        emit(Severity.ERROR, "bad-target", pc,
             f"{op.name} target {g.rows[pc][F_IMM]} is outside the program "
             f"(0..{g.n - 1})")
    # BSSY targets are data, not edges — validate them here
    for pc, _, t in g.regions:
        if not (0 <= t < g.n):
            emit(Severity.ERROR, "bad-target", pc,
                 f"BSSY reconvergence target {t} is outside the program "
                 f"(0..{g.n - 1})")


def _check_bx(g: ProgramCFG, cfg: MachineConfig, emit) -> None:
    """``bad-bx``: a Bx operand beyond the machine's convergence-barrier
    register file."""
    for pc, op in enumerate(g.ops):
        row = g.rows[pc]
        bx = None
        if op in (Op.BSSY, Op.BSYNC, Op.BREAK, Op.BMOV_R2B):
            bx = row[F_DST]
        elif op == Op.BMOV_B2R:
            bx = row[F_SRC0]
        if bx is not None and not (0 <= bx < cfg.n_bx):
            emit(Severity.ERROR, "bad-bx", pc,
                 f"B{bx} out of range for an n_bx={cfg.n_bx} machine")


def _check_regions(g: ProgramCFG, emit) -> None:
    """``bssy-target`` (target isn't this region's BSYNC) and
    ``bx-clobber`` (nested BSSY reuses a live Bx without a BMOV save —
    the Fig 5 spill contract)."""
    for pc, bx, t in g.regions:
        if not (0 <= t < g.n):
            continue                                       # bad-target already
        if g.ops[t] != Op.BSYNC:
            emit(Severity.ERROR, "bssy-target", pc,
                 f"BSSY B{bx} target pc {t} is {Op(g.ops[t]).name}, "
                 f"not BSYNC")
        elif g.rows[t][F_DST] != bx:
            emit(Severity.ERROR, "bssy-target", pc,
                 f"BSSY B{bx} target pc {t} syncs B{g.rows[t][F_DST]}, "
                 f"not B{bx}")
    for outer_pc, bx, outer_t in g.valid_regions:
        for inner_pc, bx2, _ in g.valid_regions:
            if bx2 == bx and outer_pc < inner_pc < outer_t:
                if not g.spills_of(bx, outer_pc, inner_pc):
                    emit(Severity.ERROR, "bx-clobber", inner_pc,
                         f"nested BSSY reuses live B{bx} (held by the "
                         f"region at pc {outer_pc}) with no BMOV "
                         f"spill in between")


def _check_reconvergence(g: ProgramCFG, emit) -> None:
    """Reconvergence verification (paper SS V-B / Fig 5-6).

    For every conditional branch inside a BSSY region, the region's BSYNC
    must be a point all paths from the branch pass through (its IPDom, or
    a straight-line continuation of it — the BMOV-refill preamble).
    A BREAK on the region's Bx makes earlier-than-IPDom reconvergence
    *legal* (Fig 6) and downgrades the finding to ``early-reconvergence``
    info.  Conditional branches under no region get an
    ``unannotated-branch`` info — divergence there reconverges wherever
    the mechanism's fallback picks, which is exactly where mechanisms
    disagree."""
    for pc, op in enumerate(g.ops):
        if op != Op.BRA or not g.reachable[pc]:
            continue
        row = g.rows[pc]
        if row[F_PRED1] == 0 and row[F_PRED2] == 0:
            continue                                       # not divergent
        region = g.innermost_region(pc)
        if region is None:
            emit(Severity.INFO, "unannotated-branch", pc,
                 "conditional branch outside any BSSY region; "
                 "reconvergence point is mechanism-defined")
            continue
        rpc, bx, sync = region
        breaks = g.breaks_on(bx, rpc, sync)
        if g.postdominates(sync, pc):
            ip = g.ipostdom(pc)
            if ip is not None and ip != SINK and ip != sync \
                    and not g.straight_line(ip, sync):
                emit(Severity.WARN, "late-reconvergence", pc,
                     f"region BSYNC at pc {sync} postdominates this "
                     f"branch but its IPDom is pc {ip}; paths "
                     f"re-diverge before syncing")
            continue
        if breaks:
            emit(Severity.INFO, "early-reconvergence", pc,
                 f"BREAK at pc {breaks[0]} releases threads from "
                 f"B{bx} before the BSYNC at pc {sync} "
                 f"(legal earlier-than-IPDom reconvergence)")
        else:
            ip = g.ipostdom(pc)
            where = ("unreachable from it" if ip is None
                     else f"pc {ip}" if ip != SINK else "the exit")
            emit(Severity.ERROR, "reconvergence", pc,
                 f"region BSYNC at pc {sync} does not postdominate this "
                 f"branch (IPDom is {where}) and no BREAK on B{bx} "
                 f"legalizes early reconvergence; threads bypassing "
                 f"the BSYNC strand the ones parked in B{bx}")


def _check_warpsync(g: ProgramCFG, emit) -> None:
    """``warpsync-split``: two static paths from entry lead to *different*
    first WARPSYNC rendezvous — a divergent warp can park complementary
    lane subsets at each, and neither barrier ever fills (the structural
    half of the DEADLOCK class ``volta_itps`` reports)."""
    if g.n == 0:
        return
    first = sorted(g.first_warpsync[0])
    if len(first) > 1:
        pcs = ", ".join(str(p) for p in first)
        emit(Severity.ERROR, "warpsync-split", first[0],
             f"divergent paths rendezvous at different WARPSYNCs "
             f"(pcs {pcs}); lanes parked at one cannot release the other")


def _check_reachability(g: ProgramCFG, emit) -> None:
    """``unreachable`` (warn, one per contiguous range) and
    ``fall-off-end`` (warn: the last instruction can fall off the table,
    which the steppers treat as an implicit EXIT)."""
    pc = 0
    while pc < g.n:
        if g.reachable[pc]:
            pc += 1
            continue
        start = pc
        while pc < g.n and not g.reachable[pc]:
            pc += 1
        span = f"pcs {start}..{pc - 1}" if pc - 1 > start else f"pc {start}"
        emit(Severity.WARN, "unreachable", start,
             f"{span} unreachable from entry ({pc - start} instruction(s))")
    last = g.n - 1
    if last >= 0 and g.reachable[last]:
        row = g.rows[last]
        op = row[F_OP]
        guarded = row[F_PRED1] != 0 or row[F_PRED2] != 0
        terminates = (op in (Op.EXIT, Op.RET) and not guarded) \
            or (op == Op.BRA and not guarded)
        if not terminates:
            emit(Severity.WARN, "fall-off-end", last,
                 "control can run off the end of the program "
                 "(implicit EXIT); terminate explicitly")


def _check_loops(g: ProgramCFG, emit) -> None:
    """``spin-loop`` (warn: atomics but no YIELD — paper Fig 3/7, hangs
    legacy per-warp stacks when the lock holder is in the warp) and
    ``infinite-loop`` (warn: no edge leaves the loop at all)."""
    for loop in g.loops:
        if not g.loop_has_exit(loop):
            emit(Severity.WARN, "infinite-loop", loop.header,
                 f"loop at pc {loop.header} has no exit edge; only "
                 f"fuel exhaustion stops it")
            continue
        has_atomic = g.loop_has(loop, ATOMIC_OPS)
        has_yield = g.loop_has(loop, {int(Op.YIELD)})
        if has_atomic and not has_yield:
            emit(Severity.WARN, "spin-loop", loop.header,
                 f"spin-loop at pc {loop.header} polls an atomic with no "
                 f"YIELD; a serial-execution mechanism (simt_stack, "
                 f"hanoi) cannot switch to the lock holder")


def _check_stack_depth(g: ProgramCFG, cfg: MachineConfig, emit) -> None:
    """``stack-depth``: static BSSY nesting exceeding the Bx file — every
    extra level forces a BMOV spill/fill pair around the inner region
    (paper SS IX-A sizes n_bx=8 to make this rare, not impossible)."""
    depth = g.max_region_depth
    if depth > cfg.n_bx:
        emit(Severity.WARN, "stack-depth", 0,
             f"static divergence-region nesting reaches {depth} but the "
             f"machine has n_bx={cfg.n_bx} barrier registers; "
             f"{depth - cfg.n_bx} level(s) must spill via BMOV "
             f"({cfg.n_regs} general registers available for slots)")
