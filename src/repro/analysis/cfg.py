"""Full-opcode static CFG over encoded SASS-lite programs.

This generalizes :mod:`repro.core.cfg` (which builds just enough graph to
compute IPDoms for conditional branches, via networkx) into the analysis
substrate the static verifier and the CFG fingerprints share:

* every opcode's successor edges — BRA targets, EXIT/RET terminations,
  CALL call+return-continuation edges, RET edges back to every call site's
  continuation, and the predicated fall-through each of those gains when
  guarded (``@P0 EXIT`` falls through for the lanes whose predicate is
  false);
* entry reachability, immediate postdominators for *every* node (pure
  Cooper–Harvey–Kennedy on the reversed graph — no networkx, so a whole
  progen corpus analyzes at >1k programs/s), natural-loop detection with
  nesting depth, BSSY→BSYNC region intervals with their static nesting
  depth, and the "first WARPSYNC rendezvous reachable from here" sets the
  structural-deadlock check consumes.

Everything is computed lazily and cached: the fingerprint path touches only
edges/loops/regions, the verifier additionally forces postdominators.

Out-of-range control-flow targets never crash graph construction — the
edge is redirected to the virtual sink and the pc recorded in
``bad_targets`` for the verifier to report as an ``error``.
"""
from __future__ import annotations

from functools import cached_property

import numpy as np

from repro.core.isa import (ATOMIC_OPS, F_DST, F_IMM, F_OP, F_PRED1, F_PRED2,
                            F_SRC0, MachineConfig, Op)

SINK = -1          # external name for the virtual exit node

__all__ = ["SINK", "Loop", "ProgramCFG"]


class Loop:
    """One natural loop (back edges merged per header)."""

    __slots__ = ("header", "nodes", "back_edges")

    def __init__(self, header: int) -> None:
        self.header = header
        self.nodes: set[int] = {header}
        self.back_edges: list[tuple[int, int]] = []

    def __repr__(self) -> str:
        return f"Loop(header={self.header}, nodes={len(self.nodes)})"


class ProgramCFG:
    """The static control-flow graph of one encoded program.

    Nodes are pcs ``0..L-1`` plus the virtual sink (internally index ``L``;
    the public API renders it as :data:`SINK`).  ``cfg`` supplies machine
    limits (``n_bx`` bounds, warp width) to the passes that need them.
    """

    def __init__(self, program: np.ndarray,
                 cfg: MachineConfig | None = None) -> None:
        prog = np.asarray(program)
        if prog.ndim != 2:
            raise ValueError(f"program must be a 2-D table, got shape "
                             f"{prog.shape}")
        self.program = prog
        self.cfg = cfg if cfg is not None else MachineConfig()
        self.rows: list[list[int]] = prog.tolist()
        self.n = len(self.rows)
        self.sink = self.n
        self.ops = [r[F_OP] for r in self.rows]
        self.bad_targets: list[int] = []
        self.succs: list[list[int]] = self._build_succs()

    # -- construction -------------------------------------------------------

    def _edge_target(self, pc: int, t: int) -> int:
        if 0 <= t < self.n:
            return t
        self.bad_targets.append(pc)
        return self.sink

    def _build_succs(self) -> list[list[int]]:
        n, sink = self.n, self.sink
        # the interprocedural summary: RET returns to every call site's
        # continuation (see repro.core.cfg.build_cfg); with no CALL in the
        # program RET degrades to an exit edge
        returns = [pc + 1 if pc + 1 < n else sink
                   for pc, op in enumerate(self.ops) if op == Op.CALL]
        succs: list[list[int]] = []
        for pc, row in enumerate(self.rows):
            op = row[F_OP]
            predicated = row[F_PRED1] != 0 or row[F_PRED2] != 0
            nxt = pc + 1 if pc + 1 < n else sink
            out: list[int] = []
            if op == Op.BRA:
                out.append(self._edge_target(pc, row[F_IMM]))
                if predicated:
                    out.append(nxt)
            elif op == Op.EXIT:
                out.append(sink)
                if predicated:
                    out.append(nxt)
            elif op == Op.RET:
                out.extend(returns or [sink])
                if predicated:
                    out.append(nxt)
            elif op == Op.CALL:
                out.append(self._edge_target(pc, row[F_IMM]))
                out.append(nxt)          # return continuation / guarded skip
            else:
                out.append(nxt)
            seen: set[int] = set()
            succs.append([s for s in out
                          if not (s in seen or seen.add(s))])
        return succs

    # -- basic graph views --------------------------------------------------

    @cached_property
    def preds(self) -> list[list[int]]:
        preds: list[list[int]] = [[] for _ in range(self.n + 1)]
        for pc, out in enumerate(self.succs):
            for s in out:
                preds[s].append(pc)
        return preds

    @cached_property
    def n_edges(self) -> int:
        return sum(len(out) for out in self.succs)

    @cached_property
    def reachable(self) -> list[bool]:
        """Entry reachability (pc 0), including through CALL edges."""
        seen = [False] * (self.n + 1)
        if self.n == 0:
            return seen
        seen[0] = True
        stack = [0]
        while stack:
            for s in self.succs[stack.pop()]:
                if not seen[s]:
                    seen[s] = True
                    if s != self.sink:
                        stack.append(s)
        return seen

    # -- postdominators (CHK on the reversed graph, rooted at sink) ---------

    @cached_property
    def _ipostdom(self) -> list[int | None]:
        """Immediate postdominator per node (internal sink index space).

        ``None`` for nodes that cannot reach the sink at all (code trapped
        in an exit-free loop) — postdominance is undefined there.
        """
        n, sink = self.n, self.sink
        preds = self.preds
        # postorder DFS over the reversed graph from sink; rev-successors of
        # a node are its forward predecessors
        seen = [False] * (n + 1)
        seen[sink] = True
        order: list[int] = []
        stack: list[tuple[int, "iter"]] = [(sink, iter(preds[sink]))]
        while stack:
            node, it = stack[-1]
            descended = False
            for nb in it:
                if not seen[nb]:
                    seen[nb] = True
                    stack.append((nb, iter(preds[nb])))
                    descended = True
                    break
            if not descended:
                order.append(node)
                stack.pop()
        rpo = order[::-1]                     # sink first
        idx = [0] * (n + 1)
        for i, nd in enumerate(rpo):
            idx[nd] = i
        idom: list[int | None] = [None] * (n + 1)
        idom[sink] = sink

        def intersect(a: int, b: int) -> int:
            while a != b:
                while idx[a] > idx[b]:
                    a = idom[a]               # type: ignore[assignment]
                while idx[b] > idx[a]:
                    b = idom[b]               # type: ignore[assignment]
            return a

        changed = True
        while changed:
            changed = False
            for nd in rpo:
                if nd == sink:
                    continue
                new: int | None = None
                for s in self.succs[nd]:      # rev-preds of nd
                    if idom[s] is not None:
                        new = s if new is None else intersect(new, s)
                if new is not None and idom[nd] != new:
                    idom[nd] = new
                    changed = True
        return idom

    def ipostdom(self, pc: int) -> int | None:
        """The immediate postdominator of ``pc`` (:data:`SINK` for the
        virtual exit; ``None`` when ``pc`` cannot reach an exit)."""
        d = self._ipostdom[pc]
        if d is None:
            return None
        return SINK if d == self.sink else d

    def postdominates(self, t: int, pc: int) -> bool:
        """Whether every path from ``pc`` to an exit passes through ``t``."""
        x: int | None = pc
        for _ in range(self.n + 2):
            if x is None or x == self.sink:
                return False
            x = self._ipostdom[x]
            if x == t:
                return True
        return False

    @cached_property
    def branch_ipdoms(self) -> dict[int, int]:
        """``{branch_pc: ipdom}`` for every reachable BRA — the same map
        :func:`repro.core.cfg.immediate_postdominators` computes, for
        cross-checking the two builders against each other."""
        out: dict[int, int] = {}
        for pc, op in enumerate(self.ops):
            if op == Op.BRA and self.reachable[pc]:
                d = self._ipostdom[pc]
                out[pc] = SINK if d is None or d == self.sink else d
        return out

    def straight_line(self, a: int, t: int) -> bool:
        """Whether ``a`` reaches ``t`` through single-successor nodes only
        (the BMOV-refill preamble between a region's IPDom and its BSYNC)."""
        x = a
        for _ in range(self.n + 1):
            if x == t:
                return True
            if x == self.sink or x < 0 or len(self.succs[x]) != 1:
                return False
            x = self.succs[x][0]
        return False

    # -- loops --------------------------------------------------------------

    @cached_property
    def loops(self) -> list[Loop]:
        """Natural loops of the reachable subgraph, merged per header."""
        n, sink = self.n, self.sink
        if n == 0:
            return []
        color = [0] * (n + 1)                # 0 new / 1 on stack / 2 done
        back: list[tuple[int, int]] = []
        color[0] = 1
        stack: list[tuple[int, "iter"]] = [(0, iter(self.succs[0]))]
        while stack:
            node, it = stack[-1]
            descended = False
            for nb in it:
                if nb == sink:
                    continue
                if color[nb] == 0:
                    color[nb] = 1
                    stack.append((nb, iter(self.succs[nb])))
                    descended = True
                    break
                if color[nb] == 1:
                    back.append((node, nb))
            if not descended:
                color[node] = 2
                stack.pop()
        by_header: dict[int, Loop] = {}
        for u, h in back:
            loop = by_header.setdefault(h, Loop(h))
            loop.back_edges.append((u, h))
            # natural loop body: everything that reaches u without passing h
            work = [u]
            while work:
                x = work.pop()
                if x in loop.nodes:
                    continue
                loop.nodes.add(x)
                work.extend(p for p in self.preds[x]
                            if p != sink and self.reachable[p])
        return [by_header[h] for h in sorted(by_header)]

    @cached_property
    def max_loop_depth(self) -> int:
        loops = self.loops
        depth = 0
        for lp in loops:
            depth = max(depth, sum(1 for other in loops
                                   if lp.header in other.nodes))
        return depth

    def loop_has(self, loop: Loop, ops: "frozenset[int] | set[int]") -> bool:
        return any(self.ops[pc] in ops for pc in loop.nodes)

    def loop_has_exit(self, loop: Loop) -> bool:
        """Whether any node in the loop has an edge leaving it (the sink —
        an EXIT or a fall-off — counts as leaving)."""
        return any(s not in loop.nodes
                   for pc in loop.nodes for s in self.succs[pc])

    # -- BSSY regions -------------------------------------------------------

    @cached_property
    def regions(self) -> list[tuple[int, int, int]]:
        """Every BSSY as ``(bssy_pc, bx, target_pc)`` in program order.
        Targets are raw (possibly out of range) — the verifier validates."""
        return [(pc, self.rows[pc][F_DST], self.rows[pc][F_IMM])
                for pc, op in enumerate(self.ops) if op == Op.BSSY]

    @cached_property
    def valid_regions(self) -> list[tuple[int, int, int]]:
        """Regions whose target really is a BSYNC on the same Bx."""
        return [(p, b, t) for p, b, t in self.regions
                if 0 <= t < self.n and self.ops[t] == Op.BSYNC
                and self.rows[t][F_DST] == b]

    @cached_property
    def max_region_depth(self) -> int:
        """Maximum static BSSY..BSYNC interval nesting — the divergence
        stack depth the Bx file must hold (spills excluded)."""
        depth = 0
        for p, _, t in self.valid_regions:
            d = 1 + sum(1 for p2, _, t2 in self.valid_regions
                        if p2 < p and p < t2)
            depth = max(depth, d)
        return depth

    def innermost_region(self, pc: int) -> tuple[int, int, int] | None:
        """The tightest valid BSSY region strictly containing ``pc``."""
        best: tuple[int, int, int] | None = None
        for p, b, t in self.valid_regions:
            if p < pc < t and (best is None or t - p < best[2] - best[0]):
                best = (p, b, t)
        return best

    # -- WARPSYNC rendezvous ------------------------------------------------

    @cached_property
    def first_warpsync(self) -> list[frozenset[int]]:
        """Per node: the set of WARPSYNC pcs that can be the *first*
        rendezvous a lane starting at that node encounters.

        Lanes that EXIT (or fall off the end) before any WARPSYNC
        contribute nothing — a finished lane counts as arrived at every
        barrier.  ``first_warpsync[0]`` holding two different pcs means a
        divergent warp can park one subset at each: the structural-DEADLOCK
        class ``volta_itps`` reports, detected without executing."""
        n, sink = self.n, self.sink
        fw: list[frozenset[int]] = [frozenset()] * (n + 1)
        changed = True
        while changed:
            changed = False
            for pc in range(n - 1, -1, -1):
                if not self.reachable[pc]:
                    continue
                row = self.rows[pc]
                if self.ops[pc] == Op.WARPSYNC:
                    s = {pc}
                    if row[F_PRED1] != 0 or row[F_PRED2] != 0:
                        nxt = pc + 1 if pc + 1 < n else sink
                        s |= fw[nxt]         # guarded-off lanes skip it
                    new = frozenset(s)
                else:
                    acc: set[int] = set()
                    for s2 in self.succs[pc]:
                        acc |= fw[s2]
                    new = frozenset(acc)
                if new != fw[pc]:
                    fw[pc] = new
                    changed = True
        return fw

    # -- misc counts shared with the fingerprint ----------------------------

    @cached_property
    def op_counts(self) -> dict[int, int]:
        counts: dict[int, int] = {}
        for op in self.ops:
            counts[op] = counts.get(op, 0) + 1
        return counts

    @cached_property
    def block_leaders(self) -> list[int]:
        """Basic-block leader pcs among reachable code."""
        if self.n == 0:
            return []
        leaders = {0}
        for pc, out in enumerate(self.succs):
            if not self.reachable[pc]:
                continue
            multi = len(out) > 1
            for s in out:
                if s != self.sink and (multi or s != pc + 1):
                    leaders.add(s)
        return sorted(pc for pc in leaders if self.reachable[pc])

    @cached_property
    def n_atomics(self) -> int:
        return sum(1 for op in self.ops if op in ATOMIC_OPS)

    def breaks_on(self, bx: int, lo: int, hi: int) -> list[int]:
        """BREAK pcs naming ``bx`` strictly inside ``(lo, hi)``."""
        return [pc for pc in range(lo + 1, hi)
                if self.ops[pc] == Op.BREAK and self.rows[pc][F_DST] == bx]

    def spills_of(self, bx: int, lo: int, hi: int) -> list[int]:
        """BMOV B→R saves of ``bx`` strictly inside ``(lo, hi)``."""
        return [pc for pc in range(lo + 1, hi)
                if self.ops[pc] == Op.BMOV_B2R
                and self.rows[pc][F_SRC0] == bx]
