"""Lint CLI: ``python -m repro.analysis [files.asm ...] [--suite]``.

Assembles each ``.asm`` file (surfacing :class:`repro.core.asm.AsmError`
with its line/column context) and/or walks the built-in benchmark suite,
runs the static verifier, and prints every diagnostic as
``pc NNNN  [severity] code: message`` over the disassembled instruction.

Exit status: 0 clean, 1 when any program has errors (or, with
``--strict``, warnings), 2 when an input fails to assemble.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.core.asm import AsmError, assemble
from repro.core.isa import MachineConfig

from .fingerprint import FEATURES, FP_VERSION, fingerprint
from .passes import analyze_program


def _programs(ns) -> "list[tuple[str, object]]":
    progs: list[tuple[str, object]] = []
    for path in ns.files:
        text = Path(path).read_text()
        try:
            progs.append((path, assemble(text)))
        except AsmError as exc:
            print(f"{path}: assembly failed\n{exc}", file=sys.stderr)
            raise SystemExit(2)
    if ns.suite:
        from repro.core.programs import make_suite
        for bench in make_suite(MachineConfig(n_threads=ns.threads)):
            progs.append((f"suite:{bench.name}", bench.program))
    return progs


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="statically verify SASS-lite programs (no execution)")
    ap.add_argument("files", nargs="*", help=".asm files to lint")
    ap.add_argument("--suite", action="store_true",
                    help="also lint the built-in benchmark suite")
    ap.add_argument("--threads", type=int, default=32,
                    help="warp width for --suite programs (default 32)")
    ap.add_argument("--strict", action="store_true",
                    help="treat warnings as failures")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit one JSON object per program")
    ap.add_argument("--fingerprint", action="store_true",
                    help="also print each program's CFG fingerprint")
    ns = ap.parse_args(argv)
    if not ns.files and not ns.suite:
        ap.error("nothing to lint: pass .asm files and/or --suite")

    progs = _programs(ns)
    failed = False
    for name, prog in progs:
        report = analyze_program(prog, name=name)
        bad = report.errors + (report.warnings if ns.strict else ())
        failed = failed or bool(bad)
        if ns.as_json:
            print(json.dumps({
                "name": name,
                "ok": not bad,
                "diagnostics": [
                    {"severity": str(d.severity), "code": d.code,
                     "pc": d.pc, "message": d.message, "line": d.line}
                    for d in report.diagnostics],
                "fingerprint": {"v": FP_VERSION,
                                "features": dict(zip(FEATURES,
                                                     report.fingerprint))},
            }))
            continue
        print(report.render())
        if ns.fingerprint:
            fp = fingerprint(prog)
            pairs = ", ".join(f"{k}={v:g}" for k, v in zip(FEATURES, fp))
            print(f"  fingerprint v{FP_VERSION}: {pairs}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
