"""Lint CLI: ``python -m repro.analysis [files.asm ...] [--suite]``.

Assembles each ``.asm`` file (surfacing :class:`repro.core.asm.AsmError`
with its line/column context) and/or walks the built-in benchmark suite,
runs the static verifier, and prints every diagnostic as
``pc NNNN  [severity] code: message`` over the disassembled instruction.

``--fix`` runs the annotation synthesizer first (region synthesis, Bx
allocation + BMOV spilling, YIELD insertion) and lints the *rewritten*
program; ``--select``/``--ignore`` narrow the diagnostics that count,
and ``--format=github`` emits GitHub Actions workflow annotations so CI
can gate on a chosen subset.

Exit status: 0 clean, 1 when any program has errors (or, with
``--strict``, warnings), 2 when an input fails to assemble or ``--fix``
cannot rewrite it.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.core.asm import AsmError, assemble
from repro.core.isa import MachineConfig

from .fingerprint import FEATURES, FP_VERSION, fingerprint
from .passes import AnalysisReport, Severity, analyze_program
from .transform import TransformError, synthesize_annotations

_GITHUB_LEVEL = {Severity.ERROR: "error", Severity.WARN: "warning",
                 Severity.INFO: "notice"}


def _programs(ns) -> "list[tuple[str, object]]":
    progs: list[tuple[str, object]] = []
    for path in ns.files:
        text = Path(path).read_text()
        try:
            progs.append((path, assemble(text)))
        except AsmError as exc:
            print(f"{path}: assembly failed\n{exc}", file=sys.stderr)
            raise SystemExit(2)
    if ns.suite:
        from repro.core.programs import make_suite
        for bench in make_suite(MachineConfig(n_threads=ns.threads)):
            progs.append((f"suite:{bench.name}", bench.program))
    return progs


def _code_set(spec: "str | None") -> "frozenset[str] | None":
    if spec is None:
        return None
    codes = frozenset(c.strip() for c in spec.split(",") if c.strip())
    return codes or None


def _filter(report: AnalysisReport, select, ignore) -> AnalysisReport:
    """Narrow a report to the diagnostics the caller cares about."""
    diags = report.diagnostics
    if select is not None:
        diags = tuple(d for d in diags if d.code in select)
    if ignore is not None:
        diags = tuple(d for d in diags if d.code not in ignore)
    if diags is report.diagnostics:
        return report
    return AnalysisReport(diags, report.fingerprint, report.name)


def _github_lines(name: str, report: AnalysisReport) -> "list[str]":
    # GitHub annotation syntax: properties are comma-separated, the
    # message follows '::'.  .asm inputs map pc -> 1-based line; suite
    # programs have no file, so the program name rides in the title.
    is_file = not name.startswith("suite:")
    out = []
    for d in report.diagnostics:
        props = f"file={name}," if is_file else ""
        props += f"line={d.pc + 1},title={d.code}"
        msg = d.message if is_file else f"[{name}] {d.message}"
        out.append(f"::{_GITHUB_LEVEL[d.severity]} {props}::{msg}")
    return out


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="statically verify SASS-lite programs (no execution)")
    ap.add_argument("files", nargs="*", help=".asm files to lint")
    ap.add_argument("--suite", action="store_true",
                    help="also lint the built-in benchmark suite")
    ap.add_argument("--threads", type=int, default=32,
                    help="warp width for --suite programs (default 32)")
    ap.add_argument("--strict", action="store_true",
                    help="treat warnings as failures")
    ap.add_argument("--fix", action="store_true",
                    help="synthesize missing BSSY/BSYNC/BMOV/YIELD "
                         "annotations before linting")
    ap.add_argument("--select", metavar="CODE[,CODE]",
                    help="only count/show these diagnostic codes")
    ap.add_argument("--ignore", metavar="CODE[,CODE]",
                    help="drop these diagnostic codes")
    ap.add_argument("--format", choices=("text", "github"), default="text",
                    help="output style (github = workflow annotations)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit one JSON object per program")
    ap.add_argument("--fingerprint", action="store_true",
                    help="also print each program's CFG fingerprint")
    ns = ap.parse_args(argv)
    if not ns.files and not ns.suite:
        ap.error("nothing to lint: pass .asm files and/or --suite")
    select, ignore = _code_set(ns.select), _code_set(ns.ignore)

    progs = _programs(ns)
    failed = False
    for name, prog in progs:
        if ns.fix:
            try:
                syn = synthesize_annotations(prog, name=name)
            except TransformError as exc:
                print(f"{name}: --fix failed\n{exc}", file=sys.stderr)
                raise SystemExit(2)
            prog = syn.program
            if syn.changed and ns.format == "text" and not ns.as_json:
                print(f"{name}: synthesized {syn.regions} region(s), "
                      f"{syn.spills} spill(s), {syn.yields} yield(s)")
        report = _filter(analyze_program(prog, name=name), select, ignore)
        bad = report.errors + (report.warnings if ns.strict else ())
        failed = failed or bool(bad)
        if ns.as_json:
            print(json.dumps({
                "name": name,
                "ok": not bad,
                "diagnostics": [
                    {"severity": str(d.severity), "code": d.code,
                     "pc": d.pc, "message": d.message, "line": d.line}
                    for d in report.diagnostics],
                "fingerprint": {"v": FP_VERSION,
                                "features": dict(zip(FEATURES,
                                                     report.fingerprint))},
            }))
            continue
        if ns.format == "github":
            for line in _github_lines(name, report):
                print(line)
            continue
        print(report.render())
        if ns.fingerprint:
            fp = fingerprint(prog)
            pairs = ", ".join(f"{k}={v:g}" for k, v in zip(FEATURES, fp))
            print(f"  fingerprint v{FP_VERSION}: {pairs}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
