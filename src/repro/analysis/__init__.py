"""repro.analysis — static control-flow verification and CFG fingerprints.

Analyze encoded SASS-lite programs *without executing them*:

>>> from repro.analysis import analyze_program
>>> report = analyze_program(prog)
>>> report.ok, report.codes()
(True, ())

Layers above consume this three ways: `Simulator.run(..., verify=True)`
and `SimulationService` admission reject ``error``-level programs before
any shard burns fuel; the archive stamps each run's CFG fingerprint into
begin-event meta and the sidecar index; ``python -m repro.archive similar``
ranks archived runs by :func:`fingerprint.distance` without replaying.
``python -m repro.analysis`` is the standalone lint CLI.

The package also *produces* annotations, not just checks them:
:func:`synthesize_annotations` plants BSSY/BSYNC regions, allocates Bx
registers (spilling via BMOV when nesting exceeds the file), and inserts
YIELD into spin-loops; :func:`strip_annotations` is its inverse, and
:func:`estimate` prices a program statically against the
:mod:`repro.timing` latencies.  ``python -m repro.analysis --fix``,
``Simulator.run(..., synthesize=True)`` and ``serve --auto-annotate``
expose the synthesis pipeline through the platform.

See docs/analysis.md for the diagnostic catalog, the synthesis passes,
and the fingerprint format.
"""
from .cfg import SINK, Loop, ProgramCFG
from .cost import CostEstimate, estimate, rank_correlation
from .fingerprint import (FEATURES, FP_VERSION, distance, fingerprint,
                          fingerprint_meta, rank)
from .passes import (AnalysisReport, Diagnostic, Severity,
                     StaticAnalysisError, analyze_program, verify_program)
from .transform import (StripResult, SynthesisResult, TransformError,
                        strip_annotations, synthesize_annotations)

__all__ = [
    "AnalysisReport", "CostEstimate", "Diagnostic", "FEATURES",
    "FP_VERSION", "Loop", "ProgramCFG", "SINK", "Severity",
    "StaticAnalysisError", "StripResult", "SynthesisResult",
    "TransformError", "analyze_program", "distance", "estimate",
    "fingerprint", "fingerprint_meta", "rank", "rank_correlation",
    "strip_annotations", "synthesize_annotations", "verify_program",
]
