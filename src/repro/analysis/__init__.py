"""repro.analysis — static control-flow verification and CFG fingerprints.

Analyze encoded SASS-lite programs *without executing them*:

>>> from repro.analysis import analyze_program
>>> report = analyze_program(prog)
>>> report.ok, report.codes()
(True, ())

Layers above consume this three ways: `Simulator.run(..., verify=True)`
and `SimulationService` admission reject ``error``-level programs before
any shard burns fuel; the archive stamps each run's CFG fingerprint into
begin-event meta and the sidecar index; ``python -m repro.archive similar``
ranks archived runs by :func:`fingerprint.distance` without replaying.
``python -m repro.analysis`` is the standalone lint CLI.

See docs/analysis.md for the diagnostic catalog and fingerprint format.
"""
from .cfg import SINK, Loop, ProgramCFG
from .fingerprint import (FEATURES, FP_VERSION, distance, fingerprint,
                          fingerprint_meta, rank)
from .passes import (AnalysisReport, Diagnostic, Severity,
                     StaticAnalysisError, analyze_program, verify_program)

__all__ = [
    "AnalysisReport", "Diagnostic", "FEATURES", "FP_VERSION", "Loop",
    "ProgramCFG", "SINK", "Severity", "StaticAnalysisError",
    "analyze_program", "distance", "fingerprint", "fingerprint_meta",
    "rank", "verify_program",
]
