"""Per-program CFG fingerprints for control-flow similarity search.

Implements the static side of "A Similarity Measure for GPU Kernel
Subgraph Matching" (arXiv 1707.02423): each program's control-flow graph
is summarized into a fixed-length vector of degree / loop / branch /
region features, and two programs are compared with a Canberra-style
distance over those vectors.  A fingerprint costs microseconds to compute
and ~200 bytes to store, so the archive stamps one into every run's
begin-event meta and sidecar index entry — "find archived runs whose
control flow resembles this pathology" then never replays a trace.

Versioned: bump :data:`FP_VERSION` whenever :data:`FEATURES` changes so
stale archive stamps are recomputed rather than compared across formats.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.core.isa import (F_PRED1, F_PRED2, MEMORY_OPS, MachineConfig,
                            Op)

from .cfg import ProgramCFG

FP_VERSION = 1

#: Feature names, in vector order.  Counts are raw (size-sensitive, per the
#: paper's finding that kernel scale matters) except the ``frac_*`` and
#: ``avg_*`` entries, which are shape-relative.
FEATURES: tuple[str, ...] = (
    "n_instr", "n_edges", "n_blocks", "cyclomatic",
    "n_cond_branch", "n_uncond_branch", "n_back_edges", "n_loops",
    "max_loop_depth", "n_regions", "max_region_depth",
    "n_break", "n_call", "n_ret", "n_warpsync", "n_yield",
    "n_atomic", "n_mem", "n_pred_instr",
    "frac_branch_nodes", "frac_join_nodes", "avg_block_len",
)

__all__ = ["FEATURES", "FP_VERSION", "distance", "fingerprint",
           "fingerprint_meta", "rank"]

_CACHE: "OrderedDict[bytes, tuple[float, ...]]" = OrderedDict()
_CACHE_CAP = 4096


def fingerprint(program: np.ndarray,
                cfg: MachineConfig | None = None) -> tuple[float, ...]:
    """The feature vector of ``program``, aligned with :data:`FEATURES`."""
    prog = np.ascontiguousarray(np.asarray(program, dtype=np.int32))
    key = prog.tobytes()
    hit = _CACHE.get(key)
    if hit is not None:
        _CACHE.move_to_end(key)
        return hit
    fp = _compute(ProgramCFG(prog, cfg))
    _CACHE[key] = fp
    if len(_CACHE) > _CACHE_CAP:
        _CACHE.popitem(last=False)
    return fp


def _compute(g: ProgramCFG) -> tuple[float, ...]:
    n = g.n
    counts = g.op_counts
    n_cond = n_uncond = 0
    for pc, op in enumerate(g.ops):
        if op == Op.BRA:
            row = g.rows[pc]
            if row[F_PRED1] != 0 or row[F_PRED2] != 0:
                n_cond += 1
            else:
                n_uncond += 1
    n_blocks = max(1, len(g.block_leaders))
    n_branch_nodes = sum(1 for out in g.succs if len(out) > 1)
    n_join_nodes = sum(1 for p in g.preds[:n] if len(p) > 1)
    n_reach = sum(g.reachable[:n])
    vals = {
        "n_instr": n,
        "n_edges": g.n_edges,
        "n_blocks": n_blocks,
        # E - N + 2 over the connected reachable component
        "cyclomatic": g.n_edges - (n + 1) + 2,
        "n_cond_branch": n_cond,
        "n_uncond_branch": n_uncond,
        "n_back_edges": sum(len(lp.back_edges) for lp in g.loops),
        "n_loops": len(g.loops),
        "max_loop_depth": g.max_loop_depth,
        "n_regions": len(g.regions),
        "max_region_depth": g.max_region_depth,
        "n_break": counts.get(Op.BREAK, 0),
        "n_call": counts.get(Op.CALL, 0),
        "n_ret": counts.get(Op.RET, 0),
        "n_warpsync": counts.get(Op.WARPSYNC, 0),
        "n_yield": counts.get(Op.YIELD, 0),
        "n_atomic": g.n_atomics,
        "n_mem": sum(1 for op in g.ops if op in MEMORY_OPS),
        "n_pred_instr": sum(1 for r in g.rows
                            if r[F_PRED1] != 0 or r[F_PRED2] != 0),
        "frac_branch_nodes": n_branch_nodes / n if n else 0.0,
        "frac_join_nodes": n_join_nodes / n if n else 0.0,
        "avg_block_len": (n_reach / n_blocks) if n_blocks else 0.0,
    }
    # rounded at the source so a recomputed fingerprint is bit-identical
    # to one round-tripped through a JSON archive stamp — self-matches
    # rank at exactly 0.0 regardless of which side the query came from
    return tuple(round(float(vals[name]), 6) for name in FEATURES)


def fingerprint_meta(program: np.ndarray,
                     cfg: MachineConfig | None = None) -> dict:
    """The JSON-ready form archives stamp: ``{"v": version, "f": [...]}``."""
    return {"v": FP_VERSION,
            "f": [round(x, 6) for x in fingerprint(program, cfg)]}


def distance(a, b) -> float:
    """Canberra-style distance between two fingerprints, in ``[0, 1]``.

    Mean over features of ``|a_i - b_i| / (|a_i| + |b_i|)`` with 0/0
    terms scored 0 — scale-free per feature, and *exactly* 0.0 for a
    self-match (the ``archive similar`` ranking contract).
    """
    a = tuple(a)
    b = tuple(b)
    if len(a) != len(b):
        raise ValueError(f"fingerprint length mismatch: {len(a)} vs {len(b)}")
    if not a:
        return 0.0
    total = 0.0
    for x, y in zip(a, b):
        denom = abs(x) + abs(y)
        if denom:
            total += abs(x - y) / denom
    return total / len(a)


def rank(query, candidates, *, top: int | None = None):
    """Rank ``candidates`` — an iterable of ``(key, fingerprint)`` — by
    ascending :func:`distance` to ``query``.  Returns ``(key, dist)``
    pairs; ties break on key for determinism."""
    scored = sorted(((distance(query, fp), key) for key, fp in candidates
                     if fp is not None))
    out = [(key, d) for d, key in scored]
    return out[:top] if top is not None else out
