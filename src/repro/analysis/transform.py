"""Annotation-synthesis compiler passes (paper SS V, Fig 5-7).

The verifier (:mod:`repro.analysis.passes`) polices the control-flow
management contract the vendor compiler emits; this module *produces* it.
Given an unannotated (or stripped) program, :func:`synthesize_annotations`
plants the same annotations ``repro.core.structured`` lowers from its AST:

* **Region synthesis** — for every divergent conditional branch, a
  ``BSSY Bk, <sync>`` ahead of the branch (hoisted out of any loop the
  branch re-executes in) and a ``BSYNC Bk`` at the branch's immediate
  postdominator.
* **Bx allocation** — an interval-based allocator over the nesting forest:
  region at nesting level *d* gets ``pool[d % len(pool)]`` where ``pool``
  excludes Bx registers pinned by retained (pre-existing) regions.  When a
  subtree nests deeper than the pool, the outer region spills its Bx
  through ``BMOV R{n_regs-1-d}, Bk`` / ``BMOV Bk, R{n_regs-1-d}`` — the
  exact contract the ``bx-clobber`` pass polices.
* **YIELD insertion** — a ``YIELD`` at the header of every atomic-polling
  loop the ``spin-loop`` warning flags, restoring forward progress for
  serial-execution mechanisms (paper Fig 3/7).

:func:`strip_annotations` is the inverse: it removes every annotation the
synthesizer can faithfully reconstruct, so ``strip -> synthesize``
round-trips the suite and the progen corpus (bit-exactly wherever the
original followed the structured-compiler idiom).  Regions that carry
semantics the synthesizer must not guess at — BREAK loops (early
reconvergence), regions whose BSYNC sits *later* than the branch IPDom to
cover real work (the spinlock critical section), predicated annotations —
are retained, recursively: a region is only strippable if everything
nested inside it is.

Programs containing CALL/RET are never edited: ``MOV Rd, <label>`` stages
return addresses as plain immediates (see ``programs.CALLS``), so any
insertion or removal would silently shift them.  Such edits are refused
with a diagnostic instead of mis-annotating.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.asm import EditInstr, ProgramEditor
from repro.core.isa import (ATOMIC_OPS, F_DST, F_IMM, F_OP, F_PRED1,
                            F_PRED2, Instr, MachineConfig, Op)

from .cfg import SINK, ProgramCFG
from .passes import AnalysisReport, analyze_program

__all__ = ["ANNOTATION_OPS", "Refusal", "StripResult", "SynthesisResult",
           "TransformError", "strip_annotations", "synthesize_annotations"]

#: The ops the transform layer owns: pure control-flow management with no
#: architectural effect on registers or memory (BMOV writes a register, but
#: only as a spill slot the allocator reserves from the top of the file).
ANNOTATION_OPS = frozenset({int(Op.BSSY), int(Op.BSYNC), int(Op.BMOV_B2R),
                            int(Op.BMOV_R2B), int(Op.YIELD)})


class TransformError(ValueError):
    """A rewrite could not be completed safely.

    Raised when the synthesizer has no free Bx register, no free spill
    register, or — the backstop — when the rewritten program fails
    re-analysis.  ``refusals`` carries the per-site diagnostics; ``report``
    the post-rewrite analysis when one was produced.
    """

    def __init__(self, message: str,
                 refusals: "tuple[Refusal, ...]" = (),
                 report: "AnalysisReport | None" = None) -> None:
        self.refusals = refusals
        self.report = report
        detail = "; ".join(r.message for r in refusals)
        super().__init__(message + (f" [{detail}]" if detail else ""))


@dataclass(frozen=True)
class Refusal:
    """One site the synthesizer declined to annotate, and why."""

    pc: int
    code: str        # stable: ipdom-sink / warpsync-join / call-ret / ...
    message: str


@dataclass(frozen=True)
class StripResult:
    """Output of :func:`strip_annotations`."""

    program: np.ndarray
    removed: tuple[int, ...]                    # input pcs removed
    kept_regions: tuple[tuple[int, int, int], ...]   # retained (p, bx, t)
    pc_map: tuple[tuple[int, int], ...]         # (input pc, output pc)

    @property
    def changed(self) -> bool:
        return bool(self.removed)


@dataclass(frozen=True)
class SynthesisResult:
    """Output of :func:`synthesize_annotations`."""

    program: np.ndarray
    regions: int                                # BSSY/BSYNC pairs inserted
    spills: int                                 # BMOV pairs inserted
    yields: int                                 # YIELDs inserted
    skipped: tuple[Refusal, ...]                # benign: nothing to place
    refused: tuple[Refusal, ...]                # unsafe: declined to place
    report: AnalysisReport                      # post-synthesis analysis
    pc_map: tuple[tuple[int, int], ...]         # (input pc, output pc)

    @property
    def changed(self) -> bool:
        return bool(self.regions or self.spills or self.yields)


# ---------------------------------------------------------------------------
# strip
# ---------------------------------------------------------------------------

def _has_call(g: ProgramCFG) -> bool:
    return any(op in (Op.CALL, Op.RET) for op in g.ops)


def _region_spills(g: ProgramCFG, p: int, bx: int, t: int) -> list[int]:
    """Both halves of a region's spill pair (B2R saves + R2B refills)."""
    out = g.spills_of(bx, p, t)
    out += [pc for pc in range(p + 1, t)
            if g.ops[pc] == Op.BMOV_R2B and g.rows[pc][F_DST] == bx]
    return out


def _branch_canonical(g: ProgramCFG, pc: int,
                      region: tuple[int, int, int]) -> bool:
    """Whether the region syncs ``pc`` exactly at its IPDom (modulo the
    refill preamble) — i.e. carries no information synthesis can't rebuild."""
    p, bx, t = region
    ip = g.ipostdom(pc)
    if ip is None or ip == SINK:
        return False
    ip = _sink_through_exit_bra(g, pc, ip)
    if ip == t:
        return True
    if not p < ip < t:
        return False
    # Everything between the IPDom and the BSYNC must be this region's own
    # refill; any real instruction there (e.g. a critical section guarded
    # by the late BSYNC, as in SPINLOCK) is semantics we must not drop.
    return all(g.ops[x] == Op.BMOV_R2B and g.rows[x][F_DST] == bx
               for x in range(ip, t))


def _strippable_regions(g: ProgramCFG) -> list[tuple[int, int, int]]:
    regions = g.valid_regions
    overlapped: set[tuple[int, int, int]] = set()
    for a in regions:
        for b in regions:
            if a is not b and a[0] < b[0] <= a[2] < b[2]:
                overlapped.add(a)
                overlapped.add(b)

    def nested_in(outer: tuple[int, int, int]) -> list[tuple[int, int, int]]:
        return [r for r in regions if r is not outer
                and outer[0] <= r[0] and r[2] <= outer[2]]

    def branches_of(r: tuple[int, int, int]) -> list[int]:
        p, _, t = r
        return [b2 for b2, op in enumerate(g.ops)
                if op == Op.BRA and p < b2 < t and g.reachable[b2]
                and (g.rows[b2][F_PRED1] or g.rows[b2][F_PRED2])
                and g.innermost_region(b2) == r]

    def joins_at_warpsync(r: tuple[int, int, int]) -> bool:
        # if stripping this region's closers would leave the join sitting
        # on a WARPSYNC, synthesis would (correctly) defer to the explicit
        # rendezvous and never re-create the region — keep it instead
        x = r[2]
        while x < g.n and g.ops[x] in (Op.BSYNC, Op.BMOV_R2B):
            x += 1
        return x < g.n and g.ops[x] == Op.WARPSYNC

    ok: dict[tuple[int, int, int], bool] = {}
    # innermost-first so the recursive condition is a plain lookup
    for r in sorted(regions, key=lambda r: r[2] - r[0]):
        p, bx, t = r
        strippable = (
            r not in overlapped
            and t < g.n - 1                       # never expose a fall-off
            and not g.breaks_on(bx, p, t)         # BREAK: early reconvergence
            and g.rows[p][F_PRED1] == 0 and g.rows[p][F_PRED2] == 0
            and g.rows[t][F_PRED1] == 0 and g.rows[t][F_PRED2] == 0
            and all(g.ops[x] not in (Op.CALL, Op.RET) for x in range(p + 1, t))
            and not joins_at_warpsync(r)
            and all(_branch_canonical(g, b2, r) for b2 in branches_of(r))
            and all(ok[r2] for r2 in nested_in(r)))
        ok[r] = strippable

    # Fixpoint: a strippable region sitting inside a RETAINED one can only
    # be removed if synthesis would re-plan it.  A retained If region whose
    # BSYNC postdominates the inner branch "covers" it — stripping the
    # inner region would silently coarsen reconvergence to the outer sync
    # (the base-progen else-arm shape).  A retained BREAK region does NOT
    # cover its interior (the break path bypasses its BSYNC), so regions
    # inside it re-plan fine and stay strippable.  Retention cascades:
    # anything wrapping a newly retained region is retained too.
    keep = {r for r in regions if ok.get(r, False)}
    changed = True
    while changed:
        changed = False
        for r in sorted(keep, key=lambda r: r[2] - r[0]):
            ancestors = [a for a in regions if a not in keep
                         and a[0] <= r[0] and r[2] <= a[2] and a != r]
            if not ancestors:
                continue
            a = min(ancestors, key=lambda a: a[2] - a[0])   # nearest retained
            for b2 in branches_of(r):
                ip = g.ipostdom(b2)
                if ip is None or ip == SINK:
                    continue
                ip = _sink_through_exit_bra(g, b2, ip)
                replanned = (not g.postdominates(a[2], b2)
                             and a[0] < ip < a[2])
                if not replanned:
                    keep.discard(r)
                    changed = True
                    break
        for r in sorted(keep, key=lambda r: r[2] - r[0]):
            if any(r2 not in keep for r2 in nested_in(r)):
                keep.discard(r)
                changed = True
    return [r for r in regions if r in keep]


def _spin_headers(g: ProgramCFG) -> list[int]:
    """Headers of loops the ``spin-loop`` pass would flag were their YIELD
    removed (atomics + an exit edge), in pc order."""
    return sorted(lp.header for lp in g.loops
                  if g.loop_has_exit(lp) and g.loop_has(lp, ATOMIC_OPS))


def strip_annotations(program: np.ndarray,
                      cfg: MachineConfig | None = None) -> StripResult:
    """Remove every annotation :func:`synthesize_annotations` can rebuild.

    Strippable regions (see module docstring) lose their BSSY, BSYNC and
    spill pairs; a YIELD sitting at the header of an atomic-polling loop is
    removed too.  Everything else — BREAK regions and anything nested
    around them, late-sync regions, predicated annotations, whole CALL/RET
    programs — survives untouched and is reported in ``kept_regions``.
    """
    prog = np.ascontiguousarray(np.asarray(program, dtype=np.int32))
    g = ProgramCFG(prog, cfg)
    identity = tuple((pc, pc) for pc in range(g.n))
    if _has_call(g):
        return StripResult(prog, (), tuple(g.valid_regions), identity)

    strippable = _strippable_regions(g)
    doomed: set[int] = set()
    for p, bx, t in strippable:
        doomed.update((p, t))
        doomed.update(_region_spills(g, p, bx, t))
    for header in _spin_headers(g):
        if g.ops[header] == Op.YIELD:
            doomed.add(header)

    if not doomed:
        return StripResult(prog, (), tuple(g.valid_regions), identity)

    editor = ProgramEditor(prog)
    nodes0 = list(editor.nodes)
    for pc in sorted(doomed):
        editor.remove(nodes0[pc])
    out = editor.encode()
    positions = editor.positions()
    pc_map = tuple((pc, positions[node]) for pc, node in enumerate(nodes0)
                   if node in positions)
    kept = tuple(r for r in g.valid_regions if r not in set(strippable))
    return StripResult(out, tuple(sorted(doomed)), kept, pc_map)


# ---------------------------------------------------------------------------
# synthesize
# ---------------------------------------------------------------------------

@dataclass
class _Plan:
    """One region to materialize, in *input* coordinates."""

    branch: int                     # the divergent BRA
    anchor: int                     # where BSSY goes (== branch or hoisted)
    t: int                          # IPDom: where BSYNC goes
    bx: int = -1
    spill_reg: int = -1             # <0: no spill
    bssy: EditInstr = field(default=None, repr=False)    # type: ignore
    bsync: EditInstr = field(default=None, repr=False)   # type: ignore
    spill: EditInstr = field(default=None, repr=False)   # type: ignore
    refill: EditInstr = field(default=None, repr=False)  # type: ignore

    @property
    def interval(self) -> tuple[int, int]:
        return (self.anchor, self.t)


def _needs_region(g: ProgramCFG, pc: int) -> bool:
    """Whether a divergent branch lacks reconvergence coverage.

    Uncovered means: no region contains it, or the innermost region's
    BSYNC does not postdominate it *and* its IPDom falls strictly inside
    that region (a fixable inner join — e.g. an If inside a retained BREAK
    loop).  Branches whose IPDom escapes the enclosing region (the BREAK
    loop's own exit test) are that region's business, not ours.
    """
    region = g.innermost_region(pc)
    if region is None:
        return True
    p, _, t = region
    if g.postdominates(t, pc):
        return False
    ip = g.ipostdom(pc)
    return ip is not None and ip != SINK and p < ip < t


def _anchor(g: ProgramCFG, pc: int, t: int) -> int:
    """BSSY placement for the branch at ``pc`` reconverging at ``t``.

    A BSSY inside a loop re-executes and re-arms Bk every iteration, so a
    branch whose reconvergence point lies outside a containing loop hoists
    its BSSY to that loop's header (the structured-compiler While shape).
    Otherwise the BSSY lands just above the branch's guard ISETP when the
    branch consumes one directly (the If shape), else above the branch.
    """
    hoists = [lp for lp in g.loops if pc in lp.nodes and t not in lp.nodes]
    if hoists:
        best = max(hoists, key=lambda lp: (len(lp.nodes), -lp.header))
        return best.header
    row = g.rows[pc]
    prev = pc - 1
    if prev >= 0 and g.ops[prev] == Op.ISETP:
        prow = g.rows[prev]
        guards = {abs(p) - 1 for p in (row[F_PRED1], row[F_PRED2]) if p}
        if prow[F_PRED1] == 0 and prow[F_PRED2] == 0 \
                and prow[F_DST] in guards:
            return prev
    return pc


def _sink_through_exit_bra(g: ProgramCFG, pc: int, t: int) -> int:
    """Sink a BSYNC site through the branch's own fall-through exit jump.

    A While lowers to ``@P BRA body / BRA rest`` — every path from the cond
    branch funnels through the unconditional ``BRA rest`` at ``pc+1``, so
    the IPDom lands ON that jump.  The reconvergence point the compiler
    means is the jump's (forward) destination; syncing there keeps the
    BSYNC out of the loop body and matches the structured-compiler layout.
    """
    while (t == pc + 1 and 0 <= t < g.n and g.ops[t] == Op.BRA
           and g.rows[t][F_PRED1] == 0 and g.rows[t][F_PRED2] == 0
           and g.rows[t][F_IMM] > t):
        pc, t = t, g.rows[t][F_IMM]
    return t


def _contains(a: tuple[int, int], b: tuple[int, int]) -> bool:
    """Interval ``a`` strictly wraps interval ``b`` (shared endpoints nest
    outermost-first, matching how shared-join regions stack their BSYNCs)."""
    if a == b:
        return False
    return a[0] <= b[0] and b[1] <= a[1]


def _allocate(plans: list[_Plan], retained: list[tuple[int, int, int]],
              g: ProgramCFG, mach: MachineConfig) -> int:
    """Assign ``bx`` / ``spill_reg`` to every plan; returns spill count.

    Mirrors ``repro.core.structured._Ctx`` exactly: retained BREAK regions
    pin their (top-of-file) dedicated Bx, the regular pool is ``[0, n_bx -
    n_breaks)``, nesting level *d* (counting both planned and retained
    enclosing regions) maps to ``pool[d % P]``, and a spill pair is added
    when the subtree below reaches ``P`` levels deeper.  Retained non-BREAK
    regions keep their Bx in the pool — at matching depth parity the
    original already carried the spill the contract requires, and if a
    hand-written input didn't, re-analysis flags the clobber and synthesis
    refuses rather than emitting it.
    """
    break_regions = [r for r in retained if g.breaks_on(r[1], r[0], r[2])]
    pool = list(range(mach.n_bx - len(break_regions)))
    if plans and not pool:
        raise TransformError(
            f"no free Bx registers: the {mach.n_bx}-entry file is entirely "
            f"pinned by {len(break_regions)} BREAK region(s)")

    intervals: list[tuple[int, int]] = (
        [p.interval for p in plans] + [(r[0], r[2]) for r in retained])

    def level(iv: tuple[int, int]) -> int:
        return sum(1 for other in intervals if _contains(other, iv))

    spills = 0
    for plan in plans:
        d = level(plan.interval)
        plan.bx = pool[d % len(pool)]
        inner = [level(iv) for iv in intervals
                 if _contains(plan.interval, iv)]
        deepest = max(inner, default=d)
        if deepest - d >= len(pool):
            plan.spill_reg = mach.n_regs - 1 - d
            if plan.spill_reg < 0:
                raise TransformError(
                    f"branch at pc {plan.branch}: nesting level {d} "
                    f"exhausts the register file (n_regs={mach.n_regs}); "
                    f"no spill register left")
            spills += 1
    return spills


def _row(op: Op, **kw: int) -> list[int]:
    return list(Instr(op, **kw))


def synthesize_annotations(program: np.ndarray,
                           cfg: MachineConfig | None = None, *,
                           name: str = "",
                           strict: bool = False) -> SynthesisResult:
    """Plant BSSY/BSYNC regions, Bx spills and spin-loop YIELDs.

    Safe sites are rewritten; sites with nothing to anchor to are recorded
    in ``skipped`` (IPDom is the virtual exit, or reconvergence is already
    a WARPSYNC rendezvous); sites the pass must not touch are recorded in
    ``refused`` (CALL/RET programs, irreducible shapes).  With ``strict``
    any refusal raises :class:`TransformError`.  The rewritten program is
    always re-analyzed; synthesis introducing *errors* raises regardless.
    """
    prog = np.ascontiguousarray(np.asarray(program, dtype=np.int32))
    mach = cfg if cfg is not None else MachineConfig()
    g = ProgramCFG(prog, mach)
    skipped: list[Refusal] = []
    refused: list[Refusal] = []
    has_call = _has_call(g)

    plans: list[_Plan] = []
    for pc, op in enumerate(g.ops):
        if op != Op.BRA or not g.reachable[pc]:
            continue
        row = g.rows[pc]
        if row[F_PRED1] == 0 and row[F_PRED2] == 0:
            continue                                   # not divergent
        if not _needs_region(g, pc):
            continue
        t = g.ipostdom(pc)
        if t is None:
            refused.append(Refusal(
                pc, "no-postdominator",
                f"branch at pc {pc} has no postdominator (cannot reach an "
                f"exit); no reconvergence point exists"))
            continue
        if t == SINK:
            skipped.append(Refusal(
                pc, "ipdom-sink",
                f"branch at pc {pc} reconverges only at the virtual exit; "
                f"no BSYNC site exists (paths EXIT or fall off separately)"))
            continue
        t = _sink_through_exit_bra(g, pc, t)
        if g.ops[t] == Op.WARPSYNC:
            skipped.append(Refusal(
                pc, "warpsync-join",
                f"branch at pc {pc} reconverges at the WARPSYNC rendezvous "
                f"at pc {t}; the explicit barrier already manages it"))
            continue
        anchor = _anchor(g, pc, t)
        if has_call:
            refused.append(Refusal(
                pc, "call-ret",
                f"branch at pc {pc}: program contains CALL/RET and stages "
                f"return addresses as MOV immediates; a region spanning "
                f"pcs {anchor}..{t} would shift them — refusing to "
                f"annotate rather than mis-annotate"))
            continue
        if not anchor <= pc < t:
            refused.append(Refusal(
                pc, "unstructured",
                f"branch at pc {pc}: anchor pc {anchor} / IPDom pc {t} do "
                f"not bracket the branch; shape is not reducible to a "
                f"BSSY..BSYNC interval"))
            continue
        plans.append(_Plan(branch=pc, anchor=anchor, t=t))

    yield_headers = [h for h in _spin_headers(g)
                     if not any(g.ops[pc2] == Op.YIELD
                                for pc2 in next(lp.nodes for lp in g.loops
                                                if lp.header == h))]
    if has_call and yield_headers:
        for h in yield_headers:
            refused.append(Refusal(
                h, "call-ret",
                f"spin-loop at pc {h}: inserting YIELD would shift the "
                f"MOV-staged return addresses of this CALL/RET program"))
        yield_headers = []

    if strict and refused:
        raise TransformError(
            f"{len(refused)} site(s) refused", tuple(refused))

    if not plans and not yield_headers:
        report = analyze_program(prog, mach, name=name)
        return SynthesisResult(prog, 0, 0, 0, tuple(skipped), tuple(refused),
                               report, tuple((pc, pc) for pc in range(g.n)))

    n_spills = _allocate(plans, g.valid_regions, g, mach)

    editor = ProgramEditor(prog)
    nodes0 = list(editor.nodes)
    for plan in plans:
        plan.bsync = EditInstr(_row(Op.BSYNC, dst=plan.bx))
        plan.bssy = EditInstr(_row(Op.BSSY, dst=plan.bx), target=plan.bsync)
        if plan.spill_reg >= 0:
            plan.spill = EditInstr(
                _row(Op.BMOV_B2R, dst=plan.spill_reg, src0=plan.bx))
            plan.refill = EditInstr(
                _row(Op.BMOV_R2B, dst=plan.bx, src0=plan.spill_reg))

    def jump_refs(node: EditInstr) -> list[EditInstr]:
        # only control transfers follow a retarget; a BSSY referencing the
        # node names its own BSYNC and must never be captured
        return [r for r in editor.refs_to(node) if r.fields[F_OP] == Op.BRA]

    # Closes run before opens: when one region's BSYNC site coincides with
    # the next region's BSSY anchor (a While followed directly by an If),
    # the close must end up ABOVE the open at that shared boundary node.

    # Close phase: innermost-first at shared joins so BSYNCs stack
    # inner-above-outer.  Jumps to the join from *inside* the region (the
    # If's BRA over the then-arm) funnel through the refill/BSYNC.
    for plan in sorted(plans, key=lambda p: (p.t, -p.anchor, -p.branch)):
        at = nodes0[plan.t]
        a_pos = editor.index(nodes0[plan.anchor])
        t_pos = editor.index(at)
        first = plan.refill if plan.refill is not None else plan.bsync
        for r in jump_refs(at):
            if a_pos <= editor.index(r) < t_pos:
                r.target = first
        if plan.refill is not None:
            editor.insert_before(at, plan.refill)
        editor.insert_before(at, plan.bsync)

    # Open phase: outermost-first at equal anchors.  Jumps into the anchor
    # from *outside* the region (loop back-edges, then-labels of a
    # preceding If) land on the new BSSY; jumps from inside stay put.
    for plan in sorted(plans, key=lambda p: (p.anchor, -p.t, p.branch)):
        at = nodes0[plan.anchor]
        a_pos, t_pos = editor.index(at), editor.index(nodes0[plan.t])
        outside = [r for r in jump_refs(at)
                   if not a_pos <= editor.index(r) < t_pos]
        editor.insert_before(at, plan.bssy, capture=outside)
        if plan.spill is not None:
            editor.insert_before(at, plan.spill)

    # Phase C: spin-loop YIELDs at loop headers; every jump to the header
    # (back-edges included) must re-execute the YIELD each iteration.
    for header in yield_headers:
        at = nodes0[header]
        editor.insert_before(at, EditInstr(_row(Op.YIELD)),
                             capture=jump_refs(at))

    out = editor.encode()
    positions = editor.positions()
    pc_map = tuple((pc, positions[node]) for pc, node in enumerate(nodes0))
    report = analyze_program(out, mach, name=name)
    if report.errors:
        raise TransformError(
            f"synthesis produced {len(report.errors)} analysis error(s): "
            + ", ".join(d.code for d in report.errors),
            tuple(refused), report)
    return SynthesisResult(out, len(plans), n_spills, len(yield_headers),
                           tuple(skipped), tuple(refused), report, pc_map)
