"""RG-LRU linear-recurrence kernel (Pallas TPU).

h_t = a_t * h_{t-1} + b_t over [B, S, W].  Tiling: the W (channel) axis is
split into lane-aligned tiles, the S axis into VMEM-sized chunks walked
sequentially (innermost grid axis) with the carry h kept in VMEM scratch —
the HBM traffic is exactly one read of (a, b) and one write of h, which is
the memory-bound roofline for this op.  Within a chunk the recurrence is a
short unrolled chain of VPU fmas.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BS = 256     # time-steps per chunk
DEFAULT_BW = 512     # channels per tile


def _rglru_kernel(a_ref, b_ref, h_ref, carry_ref, *, bs: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    h = carry_ref[...]                       # [bw]
    a = a_ref[0]                             # [bs, bw]
    b = b_ref[0]

    def step(t, carry):
        h, out = carry
        h = a[t] * h + b[t]
        out = out.at[t].set(h)
        return h, out

    h, out = jax.lax.fori_loop(0, bs, step,
                               (h, jnp.zeros_like(a)))
    h_ref[0] = out
    carry_ref[...] = h


def rglru_scan_pallas(a, b, *, bs: int = DEFAULT_BS, bw: int = DEFAULT_BW,
                      interpret: bool = False):
    """a, b: [B, S, W] float32 -> h [B, S, W] float32."""
    B, S, W = a.shape
    bs = min(bs, S)
    bw = min(bw, W)
    assert S % bs == 0 and W % bw == 0, (S, W, bs, bw)
    kernel = functools.partial(_rglru_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=(B, W // bw, S // bs),          # S innermost: carry in scratch
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, bs, bw), lambda bi, wi, ti: (bi, ti, wi)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda bi, wi, ti: (bi, ti, wi)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, b)
