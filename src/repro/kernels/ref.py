"""Pure-jnp oracles for every Pallas kernel (the per-kernel ref.py the brief
requires).  Tests sweep shapes/dtypes and assert_allclose kernels vs these."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  kv_len: int | None = None):
    """q: [B, Sq, H, hd]; k, v: [B, Sk, K, hd] (GQA: H % K == 0).

    window <= 0 means unlimited; kv_len masks trailing kv padding.
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, kf) * (hd ** -0.5)
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qi >= kj
    if window and window > 0:
        mask &= qi - kj < window
    if kv_len is not None:
        mask &= kj < kv_len
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def rglru_scan_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t, h_0 = b_0.  a, b: [B, S, W] float32."""
    def bin_op(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(bin_op, (a, b), axis=1)
    return h


def rwkv6_scan_ref(r, k, v, w, u):
    """RWKV-6 wkv recurrence.

    r,k,v,w: [B, S, H, hd] float32; u: [H, hd].
    out_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (out [B,S,H,hd], s_last [B,H,hd,hd]).
    """
    B, S, H, hd = r.shape
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp
        at = kt[..., :, None] * vt[..., None, :]
        out_t = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * at)
        s = wt[..., :, None] * s + at
        return s, out_t

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s_last, out = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(out, 0, 1), s_last
