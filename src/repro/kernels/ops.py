"""JIT-ready wrappers around the Pallas kernels.

Handle layout (BSHD <-> BHSD), GQA expansion, block padding and the
interpret-mode fallback (this container is CPU-only: TPU is the TARGET,
``interpret=True`` executes the kernel body for validation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import rglru_scan as _rg
from . import rwkv6_scan as _rw


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, mult: int, axis: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = _fa.DEFAULT_BQ, bk: int = _fa.DEFAULT_BK,
                    interpret: bool | None = None):
    """q: [B, S, H, hd]; k, v: [B, S, K, hd] (GQA).  Returns [B, S, H, hd]."""
    if interpret is None:
        interpret = _interpret_default()
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    # layout: BSHD -> BHSD; expand GQA kv heads
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.repeat(jnp.moveaxis(k, 1, 2), G, axis=1)
    vt = jnp.repeat(jnp.moveaxis(v, 1, 2), G, axis=1)
    bq = min(bq, max(8, Sq))
    bk = min(bk, max(8, Sk))
    qt = _pad_to(qt, bq, 2)
    kt = _pad_to(kt, bk, 2)
    vt = _pad_to(vt, bk, 2)
    out = _fa.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                   kv_len=Sk, bq=bq, bk=bk,
                                   interpret=interpret)
    return jnp.moveaxis(out[:, :, :Sq], 2, 1)


@functools.partial(jax.jit, static_argnames=("bs", "bw", "interpret"))
def rglru_scan(a, b, *, bs: int = _rg.DEFAULT_BS, bw: int = _rg.DEFAULT_BW,
               interpret: bool | None = None):
    """a, b: [B, S, W] f32 recurrence coefficients -> h [B, S, W] f32."""
    if interpret is None:
        interpret = _interpret_default()
    B, S, W = a.shape
    bs = min(bs, S)
    bw = min(bw, W)
    ap = _pad_to(_pad_to(a, bs, 1), bw, 2)
    bp = _pad_to(_pad_to(b, bs, 1), bw, 2)
    h = _rg.rglru_scan_pallas(ap, bp, bs=bs, bw=bw, interpret=interpret)
    return h[:, :S, :W]


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def rwkv6_scan(r, k, v, w, u, *, bs: int = _rw.DEFAULT_BS,
               interpret: bool | None = None):
    """r,k,v,w: [B, S, H, hd] f32; u: [H, hd].  Returns (out, s_last) with
    out [B, S, H, hd], s_last [B, H, hd, hd]."""
    if interpret is None:
        interpret = _interpret_default()
    B, S, H, hd = r.shape
    bs = min(bs, S)
    rt, kt, vt, wt = (jnp.moveaxis(_pad_to(t, bs, 1), 1, 2)
                      for t in (r, k, v, w))
    # padded tail: w=1, k=0 keeps the state unchanged
    if S % bs:
        pad = (-S) % bs
        wt = wt.at[:, :, S:, :].set(1.0)
        kt = kt.at[:, :, S:, :].set(0.0)
    out, s_last = _rw.rwkv6_scan_pallas(rt, kt, vt, wt, u, bs=bs,
                                        interpret=interpret)
    return jnp.moveaxis(out, 2, 1)[:, :S], s_last
