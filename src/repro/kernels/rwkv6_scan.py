"""RWKV-6 (Finch) wkv recurrence kernel (Pallas TPU).

Per (batch, head): out_t = r_t . (S + diag(u) k_t v_t^T);
                   S    <- diag(w_t) S + k_t v_t^T          (S: [hd, hd] f32)

Tiling: grid (B, H, S-chunks) with the time axis innermost and the [hd, hd]
state held in VMEM scratch across chunks.  Each chunk streams (r, k, v, w)
tiles of [bs, hd] through VMEM; the inner chain is bs rank-1 updates — VPU
work with an arithmetic intensity of O(hd) flops/byte, comfortably above the
memory roofline for hd = 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BS = 128


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sf_ref,
                 state_ref, *, bs: int, nt: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0]                                     # [hd]
    r, k, v, w = r_ref[0, 0], k_ref[0, 0], v_ref[0, 0], w_ref[0, 0]   # [bs, hd]

    def step(t, carry):
        s, out = carry                               # s: [hd, hd]
        at = k[t][:, None] * v[t][None, :]           # rank-1 update
        out = out.at[t].set((r[t][:, None] * (s + u[:, None] * at)).sum(0))
        s = w[t][:, None] * s + at
        return s, out

    s, out = jax.lax.fori_loop(0, bs, step,
                               (state_ref[...], jnp.zeros_like(r)))
    o_ref[0, 0] = out
    state_ref[...] = s

    @pl.when(it == nt - 1)
    def _emit_state():
        sf_ref[0, 0] = s


def rwkv6_scan_pallas(r, k, v, w, u, *, bs: int = DEFAULT_BS,
                      interpret: bool = False):
    """r,k,v,w: [B, H, S, hd] f32; u: [H, hd] f32.

    Returns (out [B, H, S, hd], s_last [B, H, hd, hd])."""
    B, H, S, hd = r.shape
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    nt = S // bs
    kernel = functools.partial(_rwkv_kernel, bs=bs, nt=nt)
    spec = pl.BlockSpec((1, 1, bs, hd), lambda b, h, t: (b, h, t, 0))
    out, s_last = pl.pallas_call(
        kernel,
        grid=(B, H, nt),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hd), lambda b, h, t: (h, 0))],
        out_specs=[spec,
                   pl.BlockSpec((1, 1, hd, hd), lambda b, h, t: (b, h, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, H, S, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out, s_last
