# Pallas TPU kernels for the perf-critical compute layers, each with a
# pure-jnp oracle in ref.py and a jit'd wrapper in ops.py:
#   flash_attention — divergence-aware tile-masked attention (Hanoi tiles)
#   rglru_scan      — RG-LRU linear recurrence (RecurrentGemma)
#   rwkv6_scan      — RWKV-6 wkv recurrence (Finch)
from . import ops, ref
from .flash_attention import tile_stats

__all__ = ["ops", "ref", "tile_stats"]
