"""Divergence-aware flash attention (Pallas TPU).

This is the Hanoi insight at MXU-tile granularity (DESIGN.md SS2b).  The
(q-block, kv-block) grid is an *active-mask* grid; each tile is classified at
schedule time exactly like Hanoi classifies thread subsets:

* EMPTY   — no (q, k) pair in the tile is live (outside the causal frontier
            or past the sliding window): the path is never scheduled; the
            tile's FLOPs are skipped entirely via ``pl.when`` (its WS-stack
            entry is never pushed);
* PARTIAL — the tile straddles the mask frontier: executed under a lane mask
            (predicated execution);
* FULL    — every pair is live: the reconverged fast path, no mask applied.

One kernel serves full/causal attention, sliding windows (Mixtral), local
windows (gemma3/recurrentgemma local layers) and right-padded KV tails.

VMEM tiling: q tile (bq, hd), k/v tiles (bk, hd), f32 accumulators
(bq, hd) + (bq,) m/l in scratch; the kv-block grid axis is innermost so the
scratch carries the online-softmax state across kv tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _tile_class(qs, ks, bq, bk, *, causal: bool, window: int, kv_len: int):
    """Classify tile [qs:qs+bq) x [ks:ks+bk).  Returns (empty, full) preds.

    All inputs are traced scalars or python ints; pure arithmetic."""
    q_min, q_max = qs, qs + bq - 1
    k_min, k_max = ks, ks + bk - 1
    empty = jnp.asarray(False)
    full = jnp.asarray(True)
    if causal:
        empty |= k_min > q_max                     # entirely in the future
        full &= k_max <= q_min                     # all pairs past-or-diag
    if window > 0:
        empty |= k_max < q_min - window + 1        # entirely older than window
        full &= k_min >= q_max - window + 1        # all pairs inside window
    # kv padding tail
    empty |= k_min >= kv_len
    full &= k_max < kv_len
    return empty, full


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 bq: int, bk: int, causal: bool, window: int, kv_len: int,
                 nk: int, sm_scale: float):
    ik = pl.program_id(3)
    iq = pl.program_id(2)
    qs = iq * bq
    ks = ik * bk

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    empty, full = _tile_class(qs, ks, bq, bk, causal=causal, window=window,
                              kv_len=kv_len)

    @pl.when(~empty)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= sm_scale

        # PARTIAL tiles apply the lane mask; FULL tiles take the fast path.
        qi = qs + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = ks + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        live = jnp.ones((bq, bk), bool)
        if causal:
            live &= qi >= kj
        if window > 0:
            live &= qi - kj < window
        live &= kj < kv_len
        s = jnp.where(full | live, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         kv_len: int | None = None, bq: int = DEFAULT_BQ,
                         bk: int = DEFAULT_BK, interpret: bool = False):
    """q: [B, H, Sq, hd]; k, v: [B, K, Sk, hd] (already GQA-expanded or K==H).

    Sq/Sk are padded to block multiples by the caller (ops.py)."""
    B, H, Sq, hd = q.shape
    K, Sk = k.shape[1], k.shape[2]
    assert H == K, "ops.py expands GQA before calling the kernel"
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    kv_len = Sk if kv_len is None else kv_len

    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, causal=causal, window=int(window),
        kv_len=int(kv_len), nk=nk, sm_scale=hd ** -0.5)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # m
            pltpu.VMEM((bq,), jnp.float32),      # l
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)


def tile_stats(Sq: int, Sk: int, *, causal: bool, window: int,
               kv_len: int | None = None, bq: int = DEFAULT_BQ,
               bk: int = DEFAULT_BK) -> dict:
    """Schedule-time tile census — the 'SIMD utilization' of the mask grid.

    Used by benchmarks to report how much work the EMPTY-tile skipping saves
    (the Hanoi path-never-scheduled analogue)."""
    kv_len = Sk if kv_len is None else kv_len
    nq, nk = -(-Sq // bq), -(-Sk // bk)
    empty = full = partial = 0
    for i in range(nq):
        for j in range(nk):
            e, f = _tile_class(i * bq, j * bk, bq, bk, causal=causal,
                               window=window, kv_len=kv_len)
            if bool(e):
                empty += 1
            elif bool(f):
                full += 1
            else:
                partial += 1
    total = nq * nk
    return {"total": total, "empty": empty, "full": full, "partial": partial,
            "flops_kept_frac": (full + partial) / total,
            "mask_overhead_frac": partial / max(1, full + partial)}
