from .adamw import AdamWConfig, adamw_init, adamw_init_struct, adamw_update
from .schedule import cosine_schedule

__all__ = ["AdamWConfig", "adamw_init", "adamw_init_struct", "adamw_update",
           "cosine_schedule"]
