"""AdamW in pure JAX with fully-sharded state.

Optimizer moments mirror the parameter pytree, so the same PartitionSpec
trees shard them (first/second moments live wherever the weights live — the
ZeRO-3 layout when the FSDP axis is active)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.base import P, abstract_params


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    return {
        "m": jax.tree_util.tree_map(jnp.zeros_like, params),
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_init_struct(struct, dtype=jnp.float32):
    """Structure tree of the optimizer state (for specs / dry-run)."""
    return {
        "m": jax.tree_util.tree_map(
            lambda p: P(p.shape, p.axes, init="zeros", dtype=p.dtype),
            struct, is_leaf=lambda x: isinstance(x, P)),
        "v": jax.tree_util.tree_map(
            lambda p: P(p.shape, p.axes, init="zeros", dtype=p.dtype),
            struct, is_leaf=lambda x: isinstance(x, P)),
        "step": P((), (), init="zeros", dtype="int32"),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr: jax.Array | float | None = None):
    """Returns (new_params, new_state, grad_norm)."""
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        pf = p.astype(jnp.float32)
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        upd = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        pf = pf - lr * (upd + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m.astype(p.dtype), v.astype(p.dtype)

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
