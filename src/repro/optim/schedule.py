"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int = 200,
                    total: int = 10_000, floor_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(1.0, step / max(1, warmup))
    t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, peak_lr * cos)
