"""Reading rotated JSONL trace archives back into structured runs.

The write path (:class:`repro.engine.sinks.RotatingJsonlSink`, fed through
:func:`repro.engine.sinks.run_meta`) appends whole runs — ``begin`` /
``issue``* / ``end`` event lines — to ``{directory}/{prefix}-NNNNN.jsonl``
files, rotating by size.  :class:`ArchiveReader` is the read half: it walks
the rotated files in order and reassembles every run into an
:class:`ArchivedRun` — the ``(pc, mask)`` control-flow trace, the begin-event
meta (JSON lists normalized back to tuples), and the end-event summary.

Degradation is *reported, never raised*: a archive whose writer crashed or
degraded mid-stream (truncated tail line, file ending inside a run, orphan
events from pre-fix writers) yields every intact run and accounts for the
rest in :class:`ReadReport` — ``reader.report`` after an iteration.  A
fleet-scale replay job must not die on the one shard whose node was lost.

Runs archived through :func:`~repro.engine.sinks.run_meta` carry a
``replay`` payload in their begin event; :func:`request_from_meta` decodes
it back into a :class:`~repro.engine.types.SimRequest` so the run can be
re-executed (see :mod:`repro.archive.replay`).  Runs archived with
hand-built meta (e.g. per-warp SM-cell archives) read back fine but are not
replayable — ``ArchivedRun.replayable`` distinguishes the two.
"""
from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass
from typing import Any, Iterator, Mapping

import numpy as np

from repro.core.isa import MachineConfig
from repro.engine.types import SimRequest

__all__ = ["ArchivedRun", "ArchiveReader", "ReadReport", "parse_run",
           "request_from_meta"]


def _tuplize(value: Any) -> Any:
    """JSON round-trip normalization: lists back to tuples, recursively."""
    if isinstance(value, list):
        return tuple(_tuplize(v) for v in value)
    if isinstance(value, dict):
        return {k: _tuplize(v) for k, v in value.items()}
    return value


def request_from_meta(meta: Mapping[str, Any]) -> SimRequest | None:
    """Decode a begin-event meta's ``replay`` payload into a SimRequest.

    Returns ``None`` when the run is not replayable — no payload (hand-built
    meta, e.g. SM-cell warp archives), a payload this reader cannot decode,
    or a payload whose writer had to drop request-meta entries
    (``meta_dropped``): replaying without those mechanism options could
    silently execute differently from the archived run, so such runs are
    counted as unreplayable rather than diffed unfaithfully.  Unknown
    ``cfg`` fields from a newer writer are ignored.
    """
    payload = meta.get("replay")
    if not isinstance(payload, Mapping):
        return None
    if payload.get("meta_dropped"):
        return None

    def arr(x: Any) -> Any:
        return None if x is None else np.asarray(x, dtype=np.int32)

    try:
        cfg = MachineConfig(**{k: int(v) for k, v in payload["cfg"].items()
                               if k in MachineConfig._fields})
        req_meta = payload.get("meta") or {}
        return SimRequest(
            program=np.asarray(payload["program"], dtype=np.int32),
            cfg=cfg,
            init_regs=arr(payload.get("init_regs")),
            init_mem=arr(payload.get("init_mem")),
            lane_ids=arr(payload.get("lane_ids")),
            active0=(None if payload.get("active0") is None
                     else int(payload["active0"])),
            fuel=(None if payload.get("fuel") is None
                  else int(payload["fuel"])),
            record_trace=bool(payload.get("record_trace", True)),
            majority_first=bool(payload.get("majority_first", True)),
            bsync_skip_pcs=tuple(int(p) for p in
                                 (payload.get("bsync_skip_pcs") or ())),
            name=str(payload.get("name") or ""),
            meta={str(k): _tuplize(v) for k, v in req_meta.items()})
    except (KeyError, TypeError, ValueError):
        return None


@dataclass(frozen=True)
class ArchivedRun:
    """One reassembled ``begin`` → ``issue``* → ``end`` run.

    ``meta`` is the begin-event payload (minus the ``event`` tag) with JSON
    lists normalized back to tuples; the remaining fields mirror the end
    event.  ``path``/``line`` locate the begin event for diagnostics.
    """

    meta: Mapping[str, Any]
    trace: tuple[tuple[int, int], ...]
    mechanism: str
    status: str
    steps: int
    fuel_left: int
    finished: int
    utilization: float
    error: str | None
    path: str
    line: int

    @property
    def program(self) -> str:
        return str(self.meta.get("program") or "")

    @property
    def replayable(self) -> bool:
        return isinstance(self.meta.get("replay"), Mapping)

    @property
    def traced(self) -> bool:
        """Whether the archived run recorded its control-flow trace (an
        untraced run replays to an equally empty trace — nothing to diff)."""
        payload = self.meta.get("replay")
        if isinstance(payload, Mapping):
            return bool(payload.get("record_trace", True))
        return bool(self.trace) or self.steps == 0

    def request(self) -> SimRequest | None:
        """The re-runnable request, or ``None`` if not replayable."""
        return request_from_meta(self.meta)

    @property
    def sm_cell(self) -> int | None:
        """The (SM, policy) cell this warp belonged to, if any (stamped by
        :func:`repro.engine.sinks.sm_run_meta` on archived SM-cell warps)."""
        cell = self.meta.get("sm_cell")
        return None if cell is None else int(cell)


def parse_run(lines: "list[str] | tuple[str, ...]", *, path: str = "",
              begin_line: int = 0) -> ArchivedRun:
    """Reassemble one contiguous, well-formed ``begin``/``issue``*/``end``
    event-line sequence into an :class:`ArchivedRun`.

    This is the random-access counterpart of :meth:`ArchiveReader.__iter__`
    — :meth:`ArchiveReader.get` reads exactly one indexed run's bytes and
    decodes them here.  Unlike iteration, damage is *raised* (ValueError):
    a malformed indexed span means the sidecar index is stale, and the
    caller should rebuild it rather than silently skip.
    """
    events = []
    for line in lines:
        line = line.strip()
        if line:
            events.append(json.loads(line))
    if (not events or events[0].get("event") != "begin"
            or events[-1].get("event") != "end"):
        raise ValueError("not a whole begin..end run")
    meta_ev = dict(events[0])
    meta_ev.pop("event", None)
    trace = []
    for ev in events[1:-1]:
        if ev.get("event") != "issue":
            raise ValueError(f"unexpected {ev.get('event')!r} event "
                             f"inside a run")
        trace.append((int(ev["pc"]), int(ev["mask"])))
    end = events[-1]
    return ArchivedRun(
        meta=_tuplize(meta_ev), trace=tuple(trace),
        mechanism=str(end.get("mechanism") or ""),
        status=str(end.get("status") or ""),
        steps=int(end.get("steps") or 0),
        fuel_left=int(end.get("fuel_left", -1)),
        finished=int(end.get("finished") or 0),
        utilization=float(end.get("utilization") or 0.0),
        error=end.get("error"),
        path=path, line=begin_line)


@dataclass
class ReadReport:
    """Accounting for one archive iteration (``ArchiveReader.report``).

    ``clean`` archives have every counter at zero: nothing truncated,
    interrupted, orphaned, or corrupt.  A crashed writer leaves exactly a
    ``truncated_tail`` (the partial final line / unfinished final run of
    the last file); anything else indicates a damaged or pre-fix archive.

    ``complete`` records whether the iteration that produced this report
    *walked the whole archive*: a partial walk (``runs(limit=N)``, or any
    caller that breaks out of iteration early) leaves the unscanned tail
    unvalidated, so its counters — and ``clean`` — speak only for the
    prefix that was read.  Integrity gates must require ``complete``
    (``python -m repro.archive --expect-zero`` refuses a ``--limit`` walk).
    """

    files: tuple[str, ...] = ()
    runs: int = 0                    # intact runs yielded
    events: int = 0                  # well-formed event lines seen
    truncated_tail: str | None = None   # last file ends mid-line / mid-run
    truncated_runs: int = 0          # runs lost to the truncated tail
    interrupted_runs: int = 0        # begin without end, *not* at the tail
    orphan_events: int = 0           # issue/end outside a run
    corrupt_lines: int = 0           # undecodable lines not at the tail
    complete: bool = False           # the walk reached the archive's end

    @property
    def clean(self) -> bool:
        return (self.truncated_tail is None and self.truncated_runs == 0
                and self.interrupted_runs == 0 and self.orphan_events == 0
                and self.corrupt_lines == 0)


class ArchiveReader:
    """Iterates whole runs across the rotated files of one archive.

    >>> reader = ArchiveReader("sim-archive")
    >>> runs = reader.runs()
    >>> reader.report.clean, reader.report.runs
    (True, 128)

    Iteration is streaming (one file's lines in memory at a time) and
    re-entrant: each ``__iter__`` resets ``report`` and re-walks the
    directory, so a reader can watch a live, still-growing archive.
    """

    def __init__(self, directory: str, *, prefix: str = "traces") -> None:
        if not os.path.isdir(directory):
            raise FileNotFoundError(f"archive directory {directory!r} "
                                    f"does not exist")
        self.directory = directory
        self.prefix = prefix
        self.report = ReadReport(files=tuple(self.paths()))
        self._index = None          # cached sidecar index (see get())

    def paths(self) -> list[str]:
        """The archive's files, ordered by rotation index."""
        pat = re.compile(rf"^{re.escape(self.prefix)}-(\d+)\.jsonl$")
        found = []
        for fn in os.listdir(self.directory):
            m = pat.match(fn)
            if m:
                found.append((int(m.group(1)),
                              os.path.join(self.directory, fn)))
        return [p for _, p in sorted(found)]

    def runs(self, limit: int | None = None) -> list[ArchivedRun]:
        """The archive's runs, in order (at most ``limit`` of them).

        A limited walk stops mid-iteration, so the resulting ``report``
        has ``complete == False``: the unscanned tail was never validated
        and the damage counters speak only for the prefix read.
        """
        out = []
        for run in self:
            out.append(run)
            if limit is not None and len(out) >= limit:
                break
        return out

    def get(self, run_id: str) -> ArchivedRun:
        """Fetch one run by id through the sidecar index — O(1), no scan.

        The index (``{prefix}.index.jsonl``, see :mod:`repro.archive.index`)
        is loaded on first use and automatically rebuilt when its
        fingerprint no longer matches the on-disk files (new runs appended,
        archive compacted).  Raises ``KeyError`` for an unknown id.
        """
        from .index import ArchiveIndex       # local: index imports reader
        idx = self._index
        if idx is None or not idx.fresh():
            idx = ArchiveIndex.ensure(self.directory, prefix=self.prefix)
            self._index = idx
        entry = idx.lookup(run_id)
        path = os.path.join(self.directory, entry.file)
        with open(path, "rb") as fh:
            fh.seek(entry.offset)
            data = fh.read(entry.length)
        try:
            return parse_run(data.decode("utf-8").splitlines(), path=path,
                             begin_line=entry.line)
        except (ValueError, KeyError, TypeError) as exc:
            # the fingerprint matched but the span no longer parses: the
            # file was mutated in place (same size).  Distinct from an
            # unknown id — surface it as corruption, not a lookup miss
            raise ValueError(
                f"indexed span for {run_id!r} at {entry.file}:"
                f"{entry.offset} no longer parses ({exc}); the archive "
                f"was modified in place — rebuild the index") from exc

    def __iter__(self) -> Iterator[ArchivedRun]:
        paths = self.paths()
        report = ReadReport(files=tuple(paths))
        self.report = report
        for fi, path in enumerate(paths):
            last_file = fi == len(paths) - 1
            with open(path, encoding="utf-8") as fh:
                raw = fh.read()
            # a well-formed file ends with a newline; a missing one means
            # the writer (or its node) died mid-line
            complete_tail = raw == "" or raw.endswith("\n")
            lines = raw.split("\n")
            if lines and lines[-1] == "":
                lines.pop()
            meta: Mapping[str, Any] | None = None
            trace: list[tuple[int, int]] = []
            begin_line = 0
            for li, line in enumerate(lines, start=1):
                at_tail = last_file and li == len(lines)
                try:
                    if at_tail and not complete_tail:
                        raise ValueError("partial tail line")
                    ev = json.loads(line)
                    kind = ev.get("event")
                    if kind == "begin":
                        if meta is not None:
                            report.interrupted_runs += 1
                        ev.pop("event", None)
                        meta = _tuplize(ev)
                        trace = []
                        begin_line = li
                        report.events += 1
                        continue
                    if kind == "issue":
                        report.events += 1
                        if meta is None:
                            report.orphan_events += 1
                            continue
                        trace.append((int(ev["pc"]), int(ev["mask"])))
                        continue
                    if kind == "end":
                        report.events += 1
                        if meta is None:
                            report.orphan_events += 1
                            continue
                        run = ArchivedRun(
                            meta=meta, trace=tuple(trace),
                            mechanism=str(ev.get("mechanism") or ""),
                            status=str(ev.get("status") or ""),
                            steps=int(ev.get("steps") or 0),
                            fuel_left=int(ev.get("fuel_left", -1)),
                            finished=int(ev.get("finished") or 0),
                            utilization=float(ev.get("utilization") or 0.0),
                            error=ev.get("error"),
                            path=path, line=begin_line)
                        meta = None
                        trace = []
                        report.runs += 1
                        yield run
                        continue
                    raise ValueError(f"unknown event kind {kind!r}")
                except (ValueError, KeyError, TypeError):
                    # undecodable or semantically broken line.  Only a
                    # *partial* tail line fingerprints a crashed writer;
                    # a newline-terminated line that fails to parse is
                    # data corruption wherever it sits
                    if at_tail and not complete_tail:
                        report.truncated_tail = path
                        if meta is not None:
                            report.truncated_runs += 1
                            meta = None
                    else:
                        report.corrupt_lines += 1
                        if meta is not None:   # the run it belonged to is gone
                            report.interrupted_runs += 1
                            meta = None
            if meta is not None:               # file ended inside a run
                if last_file:
                    report.truncated_tail = report.truncated_tail or path
                    report.truncated_runs += 1
                else:
                    report.interrupted_runs += 1
        # only a walk that reaches this point validated the whole archive;
        # a consumer that breaks early (runs(limit=N)) leaves it False
        report.complete = True
