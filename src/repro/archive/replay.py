"""Replaying archived runs and diffing them — the paper's Fig 9 offline.

The live evaluation (`Simulator.compare`) runs two mechanisms side by side
and reports the normalized Levenshtein discrepancy between their
control-flow traces.  The :class:`Replayer` produces the *same numbers from
the durable archive*: each archived run's request is reconstructed
(:func:`~repro.archive.reader.request_from_meta`), re-executed under a
registered mechanism, and the replayed trace is diffed against the archived
one with the archived trace in the hardware-reference role — so

* ``Replayer()`` (no override) is the **integrity check**: every mechanism
  is deterministic, so self-replay must be bit-equal (0.0 discrepancy);
* ``Replayer("some_mechanism")`` is **Fig 9 at archive scale**: diff a fleet
  of archived reference traces against any mechanism without re-running the
  reference — e.g. archive ``turing_oracle`` (the hardware proxy) once,
  then replay under ``hanoi`` to reproduce the paper's headline metric.

Replay executes through :meth:`repro.engine.Simulator.run_batch` (grouped
per mechanism, so signature-homogeneous JAX groups hit the native vmap
``batch_runner``) or, when a running
:class:`~repro.service.SimulationService` is supplied, through its queue —
the fleet path.  The Levenshtein itself is the bit-parallel Myers
implementation in :mod:`repro.core.trace`, which is what makes
million-warp archives tractable.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Mapping

import numpy as np

# the one nearest-rank percentile the service latency stats also use
from repro.core.trace import levenshtein, nearest_rank, trace_tokens
from repro.engine.registry import get_mechanism
from repro.engine.simulator import Simulator

from .reader import ArchivedRun, ArchiveReader, ReadReport
from .tail import ArchiveTailer

__all__ = ["Aggregate", "Replayer", "ReplayReport", "ReplayRow",
           "TimingRederivation", "nearest_rank"]


@dataclass(frozen=True)
class TimingRederivation:
    """One archived SM cell's IPC, re-derived offline from its warp traces.

    ``result`` is the re-run of the cycle engine over the archived traces
    and replay-payload programs under the archived ``sm_policy``;
    ``archived`` is the ``sm_timing`` summary stamped at execution time
    (``None`` for pre-timing archives).  When the same timing config is
    used, ``matches_archive`` cross-checks the stamp bit-for-bit — the
    archive-integrity analogue of the replay discrepancy being 0.0.
    """

    cell: int
    policy: str
    n_warps: int
    result: Any                       # extended TimingResult
    archived: "Mapping[str, Any] | None" = None

    @property
    def ipc(self) -> float:
        return self.result.ipc

    @property
    def matches_archive(self) -> bool:
        if self.archived is None:
            return False
        return (int(self.archived.get("cycles", -1)) == self.result.cycles
                and int(self.archived.get("thread_instructions", -1))
                == self.result.thread_instructions)


@dataclass(frozen=True)
class ReplayRow:
    """One archived run diffed against its replay."""

    index: int                   # ordinal of the run in the archive
    program: str
    archived_mechanism: str
    replay_mechanism: str
    edit_distance: int
    discrepancy: float           # edit_distance / len(archived trace)
    archived_trace_len: int
    replayed_trace_len: int
    archived_status: str
    replayed_status: str
    # SM-cell coordinates (sm_run_meta archives); None for single-warp runs
    sm_cell: int | None = None
    sm_warp: int | None = None
    sm_policy: str | None = None

    @property
    def discrepancy_pct(self) -> float:
        return 100.0 * self.discrepancy

    @property
    def pair(self) -> str:
        """Breakdown key: replayed mechanism vs the archived reference."""
        return f"{self.replay_mechanism} vs {self.archived_mechanism}"

    @property
    def cell_key(self) -> str | None:
        """Breakdown key grouping this warp back into its SM cell."""
        if self.sm_cell is None:
            return None
        return f"cell{self.sm_cell} ({self.sm_policy or '?'})"


@dataclass(frozen=True)
class Aggregate:
    """Count / mean / nearest-rank percentiles over one slice of rows."""

    count: int
    mean: float
    p50: float
    p90: float
    p99: float
    max: float

    @classmethod
    def of(cls, values: Iterable[float]) -> "Aggregate":
        vals = sorted(float(v) for v in values)
        if not vals:
            nan = float("nan")
            return cls(0, nan, nan, nan, nan, nan)
        return cls(len(vals), float(np.mean(vals)),
                   nearest_rank(vals, 0.50), nearest_rank(vals, 0.90),
                   nearest_rank(vals, 0.99), vals[-1])

    def render(self) -> str:
        return (f"n={self.count} mean={100 * self.mean:.2f}% "
                f"p50={100 * self.p50:.2f}% p90={100 * self.p90:.2f}% "
                f"p99={100 * self.p99:.2f}% max={100 * self.max:.2f}%")


@dataclass(frozen=True)
class ReplayReport:
    """Fleet-scale discrepancy report over one archive replay.

    ``rows`` hold every replayed run in archive order; the aggregates are
    the paper's Fig 9 summary statistics over whatever slice you ask for.
    ``skipped_unreplayable`` counts runs with no (or undecodable) replay
    payload — e.g. per-warp SM-cell archives; ``skipped_untraced`` counts
    runs archived with ``record_trace=False`` (their replay would diff one
    empty trace against another); ``skipped_unknown_mechanism`` counts
    runs whose archived mechanism is not registered in this process (a
    plugin archive replayed without the plugin — the rest of the fleet
    still replays).  ``read`` is the reader's accounting for the iteration
    that produced the rows (``None`` when the replayer was handed pre-read
    runs instead of an archive).
    """

    rows: tuple[ReplayRow, ...]
    skipped_unreplayable: int
    skipped_untraced: int
    skipped_unknown_mechanism: int = 0
    read: ReadReport | None = None

    @property
    def replayed(self) -> int:
        return len(self.rows)

    def overall(self) -> Aggregate:
        return Aggregate.of(r.discrepancy for r in self.rows)

    def mean_discrepancy(self) -> float:
        return self.overall().mean

    def _slices(self, key) -> dict[str, Aggregate]:
        groups: dict[str, list[float]] = {}
        for r in self.rows:
            groups.setdefault(key(r), []).append(r.discrepancy)
        return {k: Aggregate.of(v) for k, v in sorted(groups.items())}

    def by_mechanism(self) -> dict[str, Aggregate]:
        """Per (replay vs archived) mechanism pair."""
        return self._slices(lambda r: r.pair)

    def by_program(self) -> dict[str, Aggregate]:
        return self._slices(lambda r: r.program or "<anonymous>")

    def _sm_rows(self) -> list[ReplayRow]:
        return [r for r in self.rows if r.sm_cell is not None]

    def by_sm_cell(self) -> dict[str, Aggregate]:
        """Archived SM-cell warps grouped back into their cells (empty for
        archives with no SM-cell runs)."""
        groups: dict[str, list[float]] = {}
        for r in self._sm_rows():
            groups.setdefault(r.cell_key, []).append(r.discrepancy)
        return {k: Aggregate.of(v) for k, v in sorted(groups.items())}

    def by_sm_policy(self) -> dict[str, Aggregate]:
        """Per SM warp-scheduler policy, over the SM-cell warps only."""
        groups: dict[str, list[float]] = {}
        for r in self._sm_rows():
            groups.setdefault(r.sm_policy or "?", []).append(r.discrepancy)
        return {k: Aggregate.of(v) for k, v in sorted(groups.items())}

    def render(self) -> str:
        """Human-readable report (the CLI surface prints exactly this)."""
        out = []
        if self.read is not None:
            rd = self.read
            health = ("clean" if rd.clean else
                      f"truncated_tail={bool(rd.truncated_tail)} "
                      f"truncated={rd.truncated_runs} "
                      f"interrupted={rd.interrupted_runs} "
                      f"orphans={rd.orphan_events} "
                      f"corrupt={rd.corrupt_lines}")
            if not rd.complete:
                health += ", partial walk"
            out.append(f"[archive] {len(rd.files)} file(s), {rd.runs} "
                       f"run(s) read ({health})")
        skips = (f"skipped: {self.skipped_unreplayable} unreplayable, "
                 f"{self.skipped_untraced} untraced")
        if self.skipped_unknown_mechanism:
            skips += (f", {self.skipped_unknown_mechanism} "
                      f"unknown-mechanism")
        out.append(f"[replay] {self.replayed} run(s) replayed ({skips})")
        if self.rows:
            out.append(f"[replay] overall: {self.overall().render()}")
            by_pair = self.by_mechanism()
            if by_pair:
                out.append("[replay] by mechanism pair:")
                width = max(len(k) for k in by_pair)
                for k, agg in by_pair.items():
                    out.append(f"    {k:<{width}}  {agg.render()}")
            by_prog = self.by_program()
            if len(by_prog) > 1:
                out.append("[replay] by program:")
                width = max(len(k) for k in by_prog)
                for k, agg in by_prog.items():
                    out.append(f"    {k:<{width}}  {agg.render()}")
            by_cell = self.by_sm_cell()
            if by_cell:
                out.append("[replay] by SM cell:")
                width = max(len(k) for k in by_cell)
                for k, agg in by_cell.items():
                    out.append(f"    {k:<{width}}  {agg.render()}")
                by_pol = self.by_sm_policy()
                if by_pol:
                    out.append("[replay] by SM policy:")
                    width = max(len(k) for k in by_pol)
                    for k, agg in by_pol.items():
                        out.append(f"    {k:<{width}}  {agg.render()}")
        return "\n".join(out)


class Replayer:
    """Re-executes archived runs and diffs replayed vs archived traces.

    Parameters
    ----------
    mechanism:
        ``None`` replays each run under its *archived* mechanism (the
        self-replay integrity check — deterministic mechanisms must come
        back bit-equal).  A registry name replays every run under that
        mechanism instead: the offline Fig 9, with the archive as the
        reference side of the diff.
    simulator:
        The :class:`~repro.engine.Simulator` used for batch replay
        (a default one is built when omitted).  Replay requests are grouped
        per mechanism, so homogeneous JAX groups take the native vmap path.
    service:
        A *running* :class:`~repro.service.SimulationService` to replay
        through instead of the simulator — the queue-fed fleet path.
    """

    def __init__(self, mechanism: str | None = None, *,
                 simulator: Simulator | None = None,
                 service: Any = None) -> None:
        self._override = (get_mechanism(mechanism).name
                          if mechanism else None)
        self._sim = simulator or Simulator()
        self._service = service

    def replay(self, source: "str | ArchiveReader | Iterable[ArchivedRun]",
               *, limit: int | None = None) -> ReplayReport:
        """Replay ``source`` (a directory, reader, or pre-read runs)."""
        reader: ArchiveReader | None = None
        if isinstance(source, str):
            reader = ArchiveReader(source)
        elif isinstance(source, ArchiveReader):
            reader = source
        runs = (reader.runs(limit) if reader is not None
                else list(source)[:limit] if limit is not None
                else list(source))

        skipped_unreplayable = skipped_untraced = skipped_unknown = 0
        by_mech: dict[str, list[tuple[int, ArchivedRun, Any]]] = {}
        for idx, run in enumerate(runs):
            req = run.request()
            if req is None:
                skipped_unreplayable += 1
                continue
            if not run.traced:
                skipped_untraced += 1
                continue
            # the begin meta records what the run was *served* under; the
            # end event's mechanism is whatever the runner returned (a
            # delegating plugin reports its inner engine there)
            mech = self._override or \
                str(run.meta.get("mechanism") or "") or run.mechanism
            try:
                mech = get_mechanism(mech).name
            except KeyError:
                # a plugin archive replayed in a process without the
                # plugin: skip this run, keep the fleet going
                skipped_unknown += 1
                continue
            by_mech.setdefault(mech, []).append((idx, run, req))

        rows: list[ReplayRow] = []
        for mech, items in by_mech.items():
            reqs = [req for _, _, req in items]
            if self._service is not None:
                tickets = [self._service.submit(r, mechanism=mech)
                           for r in reqs]
                self._service.flush()
                results = [t.result() for t in tickets]
            else:
                results = self._sim.run_batch(reqs, mechanism=mech)
            for (idx, run, req), res in zip(items, results):
                archived = trace_tokens(list(run.trace))
                replayed = trace_tokens(list(res.trace))
                dist = int(levenshtein(replayed, archived))
                sm_warp = run.meta.get("sm_warp")
                rows.append(ReplayRow(
                    index=idx, program=run.program or req.name,
                    archived_mechanism=run.mechanism,
                    replay_mechanism=mech,
                    edit_distance=dist,
                    discrepancy=dist / max(1, len(archived)),
                    archived_trace_len=len(archived),
                    replayed_trace_len=len(replayed),
                    archived_status=run.status,
                    replayed_status=res.status.value,
                    sm_cell=run.sm_cell,
                    sm_warp=None if sm_warp is None else int(sm_warp),
                    sm_policy=(None if run.sm_cell is None
                               else str(run.meta.get("sm_policy") or ""))))
        rows.sort(key=lambda r: r.index)
        return ReplayReport(rows=tuple(rows),
                            skipped_unreplayable=skipped_unreplayable,
                            skipped_untraced=skipped_untraced,
                            skipped_unknown_mechanism=skipped_unknown,
                            read=reader.report if reader is not None
                            else None)

    def rederive_timing(self, source:
                        "str | ArchiveReader | Iterable[ArchivedRun]", *,
                        timing_cfg: Any = None,
                        limit: int | None = None
                        ) -> list[TimingRederivation]:
        """Re-derive cycle-level SM timing from the archive, offline.

        Archived SM-cell warps (stamped by
        :func:`repro.engine.sinks.sm_run_meta`) carry everything the cycle
        engine needs: per-warp traces, replay-payload programs, and the
        cell's issue policy.  This regroups each cell's warps and re-runs
        :func:`repro.engine.mechanisms.sm.interleave_cycle` over them —
        IPC and the full stall taxonomy without re-executing any warp.

        ``timing_cfg`` (a :class:`~repro.core.timing.TimingConfig` or
        :class:`~repro.timing.CycleConfig`) defaults to the live path's
        default, in which case each rederivation's ``matches_archive``
        cross-checks the ``sm_timing`` stamp written at execution time.
        Passing a different config is the offline what-if: re-price an
        archived fleet under new latency assumptions.  Cells with
        unreplayable warps are skipped.
        """
        from repro.core.timing import TimingConfig
        from repro.engine.mechanisms.sm import interleave_cycle
        if isinstance(source, str):
            source = ArchiveReader(source)
        runs = (source.runs(limit) if isinstance(source, ArchiveReader)
                else list(source)[:limit] if limit is not None
                else list(source))
        cells: dict[int, list[ArchivedRun]] = {}
        for run in runs:
            if run.sm_cell is not None:
                cells.setdefault(run.sm_cell, []).append(run)
        cfg = timing_cfg if timing_cfg is not None else TimingConfig()
        out: list[TimingRederivation] = []
        for cell, warps in sorted(cells.items()):
            warps.sort(key=lambda r: int(r.meta.get("sm_warp", 0)))
            traces, programs = [], []
            for r in warps:
                req = r.request()
                if req is None:
                    break
                traces.append(list(r.trace))
                programs.append(req.program)
            else:
                policy = str(warps[0].meta.get("sm_policy")
                             or "greedy_then_oldest")
                sched = interleave_cycle(traces, programs, policy, cfg)
                archived = warps[0].meta.get("sm_timing")
                out.append(TimingRederivation(
                    cell=cell, policy=policy, n_warps=len(warps),
                    result=sched.to_timing_result(),
                    archived=(dict(archived)
                              if isinstance(archived, Mapping) else None)))
        return out

    def watch(self, source: "str | ArchiveReader", *,
              poll_s: float = 0.25,
              idle_timeout_s: float | None = None,
              max_runs: int | None = None,
              progress: "Callable[[ReplayReport, int], None] | None" = None,
              ) -> ReplayReport:
        """Tail a growing archive, replaying runs as they are appended.

        Polls ``source`` every ``poll_s`` seconds through an incremental
        :class:`~repro.archive.tail.ArchiveTailer` — per-file byte offsets
        carried between polls, so a tick costs only the newly appended
        bytes (an unchanged archive is not even re-opened; a full re-walk
        happens only when a file shrinks/disappears or the rotation order
        changes).  Replays only the runs not yet seen, and calls
        ``progress(report, n_new)`` with the *rolling cumulative*
        :class:`ReplayReport` after each batch of new runs — the live
        Fig 9 aggregate of everything replayed so far.

        Returns the final report when ``max_runs`` archived runs have been
        processed (replayed or skipped), or when no new runs have appeared
        for ``idle_timeout_s`` seconds.  With neither bound the watch runs
        until interrupted.  Truncated-tail debris at the end of the live
        file is tolerated per poll exactly as in a one-shot read — a run
        the writer has not finished flushing is simply not yielded yet.
        """
        if isinstance(source, ArchiveReader):
            tailer = ArchiveTailer(source.directory, prefix=source.prefix)
        else:
            tailer = ArchiveTailer(source)
        rows: list[ReplayRow] = []
        skipped = {"unreplayable": 0, "untraced": 0, "unknown": 0}
        seen = 0
        last_new = time.monotonic()

        def rolling() -> ReplayReport:
            return ReplayReport(
                rows=tuple(rows),
                skipped_unreplayable=skipped["unreplayable"],
                skipped_untraced=skipped["untraced"],
                skipped_unknown_mechanism=skipped["unknown"],
                read=tailer.report)

        while True:
            new = tailer.poll()
            if max_runs is not None:
                new = new[:max(0, max_runs - seen)]
            if new:
                part = self.replay(new)
                rows.extend(dataclasses.replace(r, index=r.index + seen)
                            for r in part.rows)
                skipped["unreplayable"] += part.skipped_unreplayable
                skipped["untraced"] += part.skipped_untraced
                skipped["unknown"] += part.skipped_unknown_mechanism
                seen += len(new)
                last_new = time.monotonic()
                if progress is not None:
                    progress(rolling(), len(new))
            if max_runs is not None and seen >= max_runs:
                break
            if (idle_timeout_s is not None
                    and time.monotonic() - last_new >= idle_timeout_s):
                break
            time.sleep(poll_s)
        return rolling()
