"""CLI: replay, index, compact, and query RotatingJsonlSink archives.

Usage::

    python -m repro.archive DIR                       # self-replay integrity
    python -m repro.archive DIR --mechanism hanoi     # offline Fig 9 vs DIR
    python -m repro.archive DIR --expect-zero         # CI gate: bit-equal

    python -m repro.archive index DIR                 # (re)build the sidecar
    python -m repro.archive get DIR run-000042        # O(1) indexed lookup
    python -m repro.archive get DIR run-000042 --json # full run as JSON
    python -m repro.archive compact DIR               # drop debris, reindex
    python -m repro.archive similar DIR --to run-000042    # CF neighbors
    python -m repro.archive similar DIR --to prog.asm --top 5

``--expect-zero`` exits non-zero unless at least one run replayed and every
replayed run came back with exactly 0.0 discrepancy — the self-replay
integrity gate CI runs against a freshly written archive.  It refuses to
gate a *partial* walk (``--limit``): an unscanned tail could hide
truncation or corruption the walked prefix never sees.
"""
from __future__ import annotations

import argparse
import json
import sys

from .index import ArchiveIndex, compact
from .reader import ArchiveReader
from .replay import Replayer

_SUBCOMMANDS = ("index", "compact", "get", "similar")


def _main_replay(argv: "list[str]") -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.archive",
        description="Replay a rotated JSONL trace archive and report "
                    "control-flow discrepancy (the paper's Fig 9, offline). "
                    "Subcommands: index DIR / get DIR RUN_ID / compact DIR.")
    ap.add_argument("directory", help="archive directory "
                                      "(RotatingJsonlSink output)")
    ap.add_argument("--prefix", default="traces",
                    help="archive file prefix (default: traces)")
    ap.add_argument("--mechanism", default="",
                    help="replay mechanism override (default: replay each "
                         "run under its archived mechanism)")
    ap.add_argument("--limit", type=int, default=0,
                    help="replay at most N runs (0 = all; a limited walk "
                         "cannot be gated with --expect-zero)")
    ap.add_argument("--expect-zero", action="store_true",
                    help="exit 1 unless >=1 run replayed, every run has "
                         "exactly 0.0 discrepancy, and the whole archive "
                         "was walked (self-replay gate)")
    ap.add_argument("--rederive-timing", action="store_true",
                    help="also re-derive cycle-level IPC + stall breakdown "
                         "for archived SM cells from their traces and "
                         "cross-check the stamped sm_timing meta")
    args = ap.parse_args(argv)

    reader = ArchiveReader(args.directory, prefix=args.prefix)
    replayer = Replayer(args.mechanism or None)
    report = replayer.replay(reader, limit=args.limit or None)
    print(report.render())

    if args.rederive_timing:
        cells = replayer.rederive_timing(reader, limit=args.limit or None)
        if not cells:
            print("[timing] no SM cells in archive")
        for td in cells:
            t = td.result
            stamp = ("stamp=match" if td.matches_archive else
                     "stamp=MISMATCH" if td.archived is not None else
                     "stamp=absent")
            print(f"[timing] cell{td.cell} ({td.policy}, "
                  f"{td.n_warps} warps): ipc={t.ipc:.3f} "
                  f"cycles={t.cycles} stalls(i/s/m)="
                  f"{t.issue_stall_cycles}/{t.scoreboard_stall_cycles}/"
                  f"{t.memory_stall_cycles} {stamp}")

    if args.expect_zero:
        if report.read is not None and not report.read.complete:
            print("[archive] expect-zero FAILED: partial walk (--limit) "
                  "left the archive tail unvalidated; drop --limit to "
                  "gate integrity", file=sys.stderr)
            return 1
        bad = [r for r in report.rows if r.discrepancy != 0.0]
        if not report.rows:
            print("[archive] expect-zero FAILED: no runs replayed",
                  file=sys.stderr)
            return 1
        if bad:
            worst = max(bad, key=lambda r: r.discrepancy)
            print(f"[archive] expect-zero FAILED: {len(bad)} run(s) with "
                  f"non-zero discrepancy (worst: {worst.program} "
                  f"{worst.discrepancy_pct:.2f}%)", file=sys.stderr)
            return 1
    return 0


def _main_index(argv: "list[str]") -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.archive index",
        description="(Re)build the sidecar index: one scan writes "
                    "{prefix}.index.jsonl mapping run id -> byte span "
                    "for O(1) `get` lookups.")
    ap.add_argument("directory")
    ap.add_argument("--prefix", default="traces")
    args = ap.parse_args(argv)
    idx = ArchiveIndex.build(args.directory, args.prefix)
    print(f"[index] {len(idx)} run(s) across {len(idx.files)} file(s) "
          f"-> {idx.path}")
    if idx.entries:
        print(f"[index] ids {idx.entries[0].run_id} .. "
              f"{idx.entries[-1].run_id}")
    return 0


def _main_get(argv: "list[str]") -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.archive get",
        description="Fetch one archived run by id through the sidecar "
                    "index (built/rebuilt on demand) — no archive scan.")
    ap.add_argument("directory")
    ap.add_argument("run_id", help="e.g. run-000042 (see `index`)")
    ap.add_argument("--prefix", default="traces")
    ap.add_argument("--json", action="store_true",
                    help="print the full run (meta + trace + end fields) "
                         "as one JSON object")
    args = ap.parse_args(argv)
    reader = ArchiveReader(args.directory, prefix=args.prefix)
    try:
        run = reader.get(args.run_id)
    except (KeyError, ValueError) as exc:        # unknown id / stale span
        print(f"[get] {exc.args[0]}", file=sys.stderr)
        return 1
    if args.json:
        def listify(v):
            if isinstance(v, tuple):
                return [listify(x) for x in v]
            if isinstance(v, dict):
                return {k: listify(x) for k, x in v.items()}
            return v
        print(json.dumps({
            "id": args.run_id, "file": run.path, "line": run.line,
            "meta": listify(dict(run.meta)),
            "trace": [[pc, mask] for pc, mask in run.trace],
            "mechanism": run.mechanism, "status": run.status,
            "steps": run.steps, "fuel_left": run.fuel_left,
            "finished": run.finished, "utilization": run.utilization,
            "error": run.error}))
    else:
        cell = "" if run.sm_cell is None else (
            f" sm_cell={run.sm_cell} sm_warp={run.meta.get('sm_warp')} "
            f"sm_policy={run.meta.get('sm_policy')}")
        print(f"[get] {args.run_id}: program={run.program or '<anonymous>'} "
              f"mechanism={run.meta.get('mechanism') or run.mechanism} "
              f"status={run.status} steps={run.steps} "
              f"trace={len(run.trace)} slot(s) "
              f"replayable={run.replayable}{cell}")
    return 0


def _main_compact(argv: "list[str]") -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.archive compact",
        description="Rewrite rotated files dropping corrupt/interrupted "
                    "debris (intact runs are preserved byte-for-byte) and "
                    "rebuild the sidecar index.  Only compact an archive "
                    "with no live writer.")
    ap.add_argument("directory")
    ap.add_argument("--prefix", default="traces")
    args = ap.parse_args(argv)
    report = compact(args.directory, args.prefix)
    print(f"[compact] {report.render()}")
    return 0


def _main_similar(argv: "list[str]") -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.archive similar",
        description="Rank archived runs by static control-flow similarity "
                    "to a query — a run id or a .asm file — using the CFG "
                    "fingerprints in the sidecar index (built/rebuilt on "
                    "demand).  Nothing is replayed and no archive file is "
                    "opened: the ranking reads the sidecar alone.")
    ap.add_argument("directory")
    ap.add_argument("--to", required=True, metavar="RUN_ID|FILE.asm",
                    help="query: an indexed run id (e.g. run-000042) or a "
                         "path to a SASS-lite .asm file")
    ap.add_argument("--top", type=int, default=10,
                    help="show the N nearest runs (default 10; 0 = all)")
    ap.add_argument("--prefix", default="traces")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the ranking as one JSON object")
    args = ap.parse_args(argv)

    idx = ArchiveIndex.ensure(args.directory, args.prefix)
    if args.to.endswith(".asm"):
        from repro.analysis import fingerprint
        from repro.core.asm import AsmError, assemble
        try:
            query_fp = fingerprint(assemble(open(args.to).read()))
        except OSError as exc:
            print(f"[similar] cannot read {args.to}: {exc}", file=sys.stderr)
            return 1
        except AsmError as exc:
            print(f"[similar] {args.to}: assembly failed\n{exc}",
                  file=sys.stderr)
            return 1
    else:
        try:
            entry = idx.lookup(args.to)
        except KeyError as exc:
            print(f"[similar] {exc.args[0]}", file=sys.stderr)
            return 1
        if entry.fp is None:
            print(f"[similar] {args.to} has no fingerprint (undecodable "
                  f"begin meta); re-archive or query by .asm file",
                  file=sys.stderr)
            return 1
        query_fp = entry.fp

    ranked = idx.rank_similar(query_fp, top=args.top or None)
    if args.as_json:
        print(json.dumps({"query": args.to,
                          "ranked": [{"id": rid, "distance": round(d, 6)}
                                     for rid, d in ranked]}))
        return 0
    if not ranked:
        print("[similar] no fingerprinted runs in the index")
        return 0
    print(f"[similar] {len(idx)} indexed run(s); "
          f"{len(ranked)} nearest to {args.to}:")
    by_id = {e.run_id: e for e in idx.entries}
    for rank_i, (rid, d) in enumerate(ranked, start=1):
        e = by_id[rid]
        print(f"  {rank_i:3d}. {rid}  d={d:.4f}  "
              f"program={e.program or '<anonymous>'} "
              f"mechanism={e.mechanism} status={e.status}")
    return 0


def main(argv: "list[str] | None" = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] in _SUBCOMMANDS:
        return {"index": _main_index, "get": _main_get,
                "compact": _main_compact,
                "similar": _main_similar}[argv[0]](argv[1:])
    return _main_replay(argv)


if __name__ == "__main__":
    raise SystemExit(main())
