"""CLI: replay a RotatingJsonlSink archive and report Fig 9 discrepancy.

Usage::

    python -m repro.archive DIR                       # self-replay integrity
    python -m repro.archive DIR --mechanism hanoi     # offline Fig 9 vs DIR
    python -m repro.archive DIR --expect-zero         # CI gate: bit-equal

``--expect-zero`` exits non-zero unless at least one run replayed and every
replayed run came back with exactly 0.0 discrepancy — the self-replay
integrity gate CI runs against a freshly written archive.
"""
from __future__ import annotations

import argparse
import sys

from .reader import ArchiveReader
from .replay import Replayer


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.archive",
        description="Replay a rotated JSONL trace archive and report "
                    "control-flow discrepancy (the paper's Fig 9, offline).")
    ap.add_argument("directory", help="archive directory "
                                      "(RotatingJsonlSink output)")
    ap.add_argument("--prefix", default="traces",
                    help="archive file prefix (default: traces)")
    ap.add_argument("--mechanism", default="",
                    help="replay mechanism override (default: replay each "
                         "run under its archived mechanism)")
    ap.add_argument("--limit", type=int, default=0,
                    help="replay at most N runs (0 = all)")
    ap.add_argument("--expect-zero", action="store_true",
                    help="exit 1 unless >=1 run replayed and every run has "
                         "exactly 0.0 discrepancy (self-replay gate)")
    args = ap.parse_args(argv)

    reader = ArchiveReader(args.directory, prefix=args.prefix)
    replayer = Replayer(args.mechanism or None)
    report = replayer.replay(reader, limit=args.limit or None)
    print(report.render())

    if args.expect_zero:
        bad = [r for r in report.rows if r.discrepancy != 0.0]
        if not report.rows:
            print("[archive] expect-zero FAILED: no runs replayed",
                  file=sys.stderr)
            return 1
        if bad:
            worst = max(bad, key=lambda r: r.discrepancy)
            print(f"[archive] expect-zero FAILED: {len(bad)} run(s) with "
                  f"non-zero discrepancy (worst: {worst.program} "
                  f"{worst.discrepancy_pct:.2f}%)", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
