"""Incremental byte-offset tailing of a live, rotating JSONL archive.

:meth:`repro.archive.Replayer.watch` used to re-walk the *whole* archive
directory on every poll — O(archive) work per tick, unbounded as the fleet
appends.  :class:`ArchiveTailer` keeps a per-file byte offset (advanced
only through the last complete line) and per-file partial-run buffers, so
a poll costs exactly the newly appended bytes: an unchanged file is
``stat``-ed and skipped without even being opened, and a poll over an
unchanged archive reads zero bytes (:class:`TailStats` proves it — a
regression test pins this).

Why per-file buffers are safe: the write path
(:class:`repro.engine.sinks.RotatingJsonlSink`) rotates only at run
boundaries — runs never span files — so a ``begin`` whose ``end`` has not
arrived yet always completes in the *same* file, and a file that is no
longer the newest can be finalized (its dangling tail force-parsed, its
unfinished run counted as interrupted) without ever touching it again.

The tailer re-walks from scratch only on the events that invalidate
offsets: a tracked file shrank, disappeared, or the rotation order
changed under us (compaction).  Already-emitted runs are not re-emitted
across a rescan.

Damage accounting matches :class:`~repro.archive.reader.ArchiveReader`
semantics, with one tailing-specific refinement: an unterminated tail
line (or unfinished tail run) of the *newest* file is not damage — it is
a run the writer has not finished flushing, and it stays buffered until
the next poll.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Mapping

from .reader import ArchivedRun, ReadReport, _tuplize

__all__ = ["ArchiveTailer", "TailStats"]


@dataclass
class TailStats:
    """I/O accounting across polls (the no-re-read regression surface)."""

    polls: int = 0
    files_opened: int = 0        # open() calls — unchanged files do none
    bytes_read: int = 0          # appended bytes consumed (plus partial-
    runs: int = 0                # tail re-reads, which are O(one line))
    full_rescans: int = 0


@dataclass
class _FileState:
    offset: int = 0              # bytes consumed through last complete line
    line_no: int = 0             # 1-based line counter at ``offset``
    meta: "Mapping[str, Any] | None" = None    # open run's begin meta
    trace: list = field(default_factory=list)
    begin_line: int = 0
    finalized: bool = False      # rotated-away file, fully drained


class ArchiveTailer:
    """Stateful incremental reader over one rotating archive directory.

    ``poll()`` returns the runs appended since the previous poll (in
    archive order).  ``report`` is a :class:`ReadReport`-shaped snapshot of
    everything consumed so far, suitable for the rolling watch display.
    """

    def __init__(self, directory: str, *, prefix: str = "traces") -> None:
        if not os.path.isdir(directory):
            raise FileNotFoundError(f"archive directory {directory!r} "
                                    f"does not exist")
        self.directory = directory
        self.prefix = prefix
        self.stats = TailStats()
        self._files: dict[str, _FileState] = {}
        self._order: list[str] = []
        self._emitted = 0
        self._events = 0
        self._interrupted = 0
        self._orphans = 0
        self._corrupt = 0

    # -- directory listing --------------------------------------------------

    def _paths(self) -> list[str]:
        import re
        pat = re.compile(rf"^{re.escape(self.prefix)}-(\d+)\.jsonl$")
        found = []
        for fn in os.listdir(self.directory):
            m = pat.match(fn)
            if m:
                found.append((int(m.group(1)),
                              os.path.join(self.directory, fn)))
        return [p for _, p in sorted(found)]

    # -- event machine (one file's stream) ----------------------------------

    def _feed_line(self, st: _FileState, line: str,
                   path: str) -> "ArchivedRun | None":
        st.line_no += 1
        self_events_before = self._events
        try:
            ev = json.loads(line)
            kind = ev.get("event")
            if kind == "begin":
                if st.meta is not None:
                    self._interrupted += 1
                ev.pop("event", None)
                st.meta = _tuplize(ev)
                st.trace = []
                st.begin_line = st.line_no
                self._events += 1
                return None
            if kind == "issue":
                self._events += 1
                if st.meta is None:
                    self._orphans += 1
                    return None
                st.trace.append((int(ev["pc"]), int(ev["mask"])))
                return None
            if kind == "end":
                self._events += 1
                if st.meta is None:
                    self._orphans += 1
                    return None
                run = ArchivedRun(
                    meta=st.meta, trace=tuple(st.trace),
                    mechanism=str(ev.get("mechanism") or ""),
                    status=str(ev.get("status") or ""),
                    steps=int(ev.get("steps") or 0),
                    fuel_left=int(ev.get("fuel_left", -1)),
                    finished=int(ev.get("finished") or 0),
                    utilization=float(ev.get("utilization") or 0.0),
                    error=ev.get("error"),
                    path=path, line=st.begin_line)
                st.meta = None
                st.trace = []
                return run
            raise ValueError(f"unknown event kind {kind!r}")
        except (ValueError, KeyError, TypeError):
            self._events = self_events_before
            self._corrupt += 1
            if st.meta is not None:      # the run it belonged to is gone
                self._interrupted += 1
                st.meta = None
            return None

    # -- polling ------------------------------------------------------------

    def _needs_rescan(self, paths: list[str]) -> bool:
        if self._order and paths[:len(self._order)] != self._order:
            return True                  # rotation order changed / removal
        for path, st in self._files.items():
            try:
                if os.stat(path).st_size < st.offset:
                    return True          # file shrank (compaction/rewrite)
            except OSError:
                return True              # file disappeared
        return False

    def _drain_file(self, path: str, st: _FileState,
                    is_last: bool) -> list[ArchivedRun]:
        """Consume bytes appended to ``path`` past ``st.offset``."""
        size = os.stat(path).st_size
        out: list[ArchivedRun] = []
        if size > st.offset:
            with open(path, "rb") as fh:
                fh.seek(st.offset)
                chunk = fh.read(size - st.offset)
            self.stats.files_opened += 1
            self.stats.bytes_read += len(chunk)
            cut = chunk.rfind(b"\n") + 1        # consume whole lines only
            consumed, leftover = chunk[:cut], chunk[cut:]
            if not is_last and leftover:
                # the writer rotated away: this dangling final line will
                # never get its newline — finalize it (the reader yields
                # such a line when it parses; see test_index_scan_*)
                consumed, leftover = chunk, b""
            for line in consumed.decode("utf-8").split("\n"):
                if not line:
                    continue
                run = self._feed_line(st, line, path)
                if run is not None:
                    out.append(run)
            st.offset += len(consumed)
        if not is_last and not st.finalized and st.offset >= size:
            # fully drained a rotated-away file: a still-open run in it
            # will never end — account it as interrupted, then stop
            # tracking content (the offset check above still guards it)
            if st.meta is not None:
                self._interrupted += 1
                st.meta = None
            st.finalized = True
        return out

    def poll(self) -> list[ArchivedRun]:
        """Runs appended since the last poll, in archive order."""
        self.stats.polls += 1
        paths = self._paths()
        if self._needs_rescan(paths):
            return self._rescan(paths)
        out: list[ArchivedRun] = []
        for path in paths:
            st = self._files.get(path)
            if st is None:
                st = self._files[path] = _FileState()
            if st.finalized:
                continue
            out.extend(self._drain_file(path, st, is_last=path == paths[-1]))
        self._order = paths
        self._emitted += len(out)
        self.stats.runs += len(out)
        return out

    def _rescan(self, paths: list[str]) -> list[ArchivedRun]:
        """Full re-walk after compaction/rewrite; already-emitted runs (by
        archive position) are not re-emitted."""
        self.stats.full_rescans += 1
        already = self._emitted
        self._files = {}
        self._order = []
        self._events = self._interrupted = self._orphans = self._corrupt = 0
        runs: list[ArchivedRun] = []
        for path in paths:
            st = self._files[path] = _FileState()
            runs.extend(self._drain_file(path, st, is_last=path == paths[-1]))
        self._order = paths
        new = runs[already:]
        self._emitted = len(runs[:already]) + len(new)
        self.stats.runs += len(new)
        return new

    # -- reporting ----------------------------------------------------------

    @property
    def pending(self) -> bool:
        """Whether any file holds a buffered, not-yet-complete run (or an
        unterminated tail line the writer has not finished flushing)."""
        for path, st in self._files.items():
            if st.meta is not None:
                return True
            try:
                if not st.finalized and os.stat(path).st_size > st.offset:
                    return True
            except OSError:
                return True
        return False

    @property
    def report(self) -> ReadReport:
        """Snapshot of everything consumed so far, reader-shaped.

        ``complete`` is True when the tailer has drained every known file
        through its current end with no run left buffered — the watch
        analogue of "the walk reached the archive's end".
        """
        return ReadReport(
            files=tuple(self._order), runs=self.stats.runs,
            events=self._events, truncated_tail=None, truncated_runs=0,
            interrupted_runs=self._interrupted,
            orphan_events=self._orphans, corrupt_lines=self._corrupt,
            complete=not self.pending)
