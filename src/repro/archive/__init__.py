"""repro.archive — offline reading and replay of durable trace archives.

The simulation service writes every completed warp to rotated JSONL files
through :class:`~repro.engine.sinks.RotatingJsonlSink`; this package is the
matching read path, closing the write-path/read-path asymmetry:

* :class:`ArchiveReader` — iterates whole runs across the rotated
  ``{prefix}-NNNNN.jsonl`` files, reassembling ``begin``/``issue``/``end``
  events into ``(pc, mask)`` traces plus request meta, tolerating (and
  accounting for, via :class:`ReadReport`) a truncated tail from a crashed
  or degraded writer;
* :class:`Replayer` — reconstructs each run's
  :class:`~repro.engine.types.SimRequest`, re-executes it under any
  registered mechanism (batched through ``Simulator.run_batch`` or a
  running ``SimulationService``), and emits a :class:`ReplayReport` of
  per-run Levenshtein discrepancies with aggregate / per-mechanism /
  per-program breakdowns — the paper's Fig 9 at archive scale.

Quick start::

    from repro.archive import ArchiveReader, Replayer

    report = Replayer().replay("sim-archive")        # self-replay: 0.0
    assert report.mean_discrepancy() == 0.0

    fig9 = Replayer("hanoi").replay("oracle-archive")  # offline Fig 9
    print(fig9.render())

CLI: ``python -m repro.archive DIR [--mechanism NAME] [--expect-zero]`` or
``python -m repro.launch.serve --mode replay --archive-dir DIR``.
"""
from .reader import ArchivedRun, ArchiveReader, ReadReport, request_from_meta
from .replay import (Aggregate, Replayer, ReplayReport, ReplayRow,
                     nearest_rank)

__all__ = [
    "Aggregate", "ArchiveReader", "ArchivedRun", "ReadReport", "Replayer",
    "ReplayReport", "ReplayRow", "nearest_rank", "request_from_meta",
]
