"""repro.archive — offline reading, replay, and indexing of trace archives.

The simulation service writes every completed warp to rotated JSONL files
through :class:`~repro.engine.sinks.RotatingJsonlSink`; this package is the
matching read path, closing the write-path/read-path asymmetry:

* :class:`ArchiveReader` — iterates whole runs across the rotated
  ``{prefix}-NNNNN.jsonl`` files, reassembling ``begin``/``issue``/``end``
  events into ``(pc, mask)`` traces plus request meta, tolerating (and
  accounting for, via :class:`ReadReport`) a truncated tail from a crashed
  or degraded writer; :meth:`ArchiveReader.get` fetches one run by id in
  O(1) through the sidecar index;
* :class:`ArchiveIndex` / :func:`compact` (:mod:`repro.archive.index`) —
  the sidecar ``{prefix}.index.jsonl`` mapping run id → byte span
  (rebuilt automatically on fingerprint mismatch) and the compaction pass
  that rewrites rotated files dropping corrupt/interrupted debris while
  preserving intact runs byte-for-byte; each entry also carries the run's
  static CFG fingerprint (:mod:`repro.analysis.fingerprint`), so
  :meth:`ArchiveIndex.rank_similar` — CLI ``python -m repro.archive
  similar DIR --to <run_id|file.asm>`` — ranks archived runs by
  control-flow similarity from the sidecar alone, replaying nothing;
* :class:`Replayer` — reconstructs each run's
  :class:`~repro.engine.types.SimRequest`, re-executes it under any
  registered mechanism (batched through ``Simulator.run_batch`` or a
  running ``SimulationService``), and emits a :class:`ReplayReport` of
  per-run Levenshtein discrepancies with aggregate / per-mechanism /
  per-program / per-SM-cell / per-policy breakdowns — the paper's Fig 9
  at archive scale.  :meth:`Replayer.watch` tails a still-growing archive
  and replays new runs incrementally with a rolling aggregate.

SM-cell warps archived through the service (or ``Simulator.run_sm`` with a
sink) carry the full replay payload plus their cell coordinates
(``sm_cell``/``sm_warp``/``sm_warps``/``sm_policy``) — they replay exactly
like single-warp runs and group back into cells in the report.

Quick start::

    from repro.archive import ArchiveReader, Replayer

    report = Replayer().replay("sim-archive")        # self-replay: 0.0
    assert report.mean_discrepancy() == 0.0

    fig9 = Replayer("hanoi").replay("oracle-archive")  # offline Fig 9
    print(fig9.render())

    run = ArchiveReader("sim-archive").get("run-000042")  # O(1), indexed

CLI: ``python -m repro.archive DIR [--mechanism NAME] [--expect-zero]``,
``python -m repro.archive index|get|compact|similar DIR ...``, or
``python -m repro.launch.serve --mode replay --archive-dir DIR [--watch]``.
"""
from .index import ArchiveIndex, CompactReport, IndexEntry, compact
from .reader import (ArchivedRun, ArchiveReader, ReadReport, parse_run,
                     request_from_meta)
from .replay import (Aggregate, Replayer, ReplayReport, ReplayRow,
                     TimingRederivation, nearest_rank)
from .tail import ArchiveTailer, TailStats

__all__ = [
    "Aggregate", "ArchiveIndex", "ArchiveReader", "ArchiveTailer",
    "ArchivedRun", "CompactReport", "IndexEntry", "ReadReport", "Replayer",
    "ReplayReport", "ReplayRow", "TailStats", "TimingRederivation",
    "compact", "nearest_rank", "parse_run", "request_from_meta",
]
