"""Sidecar index + compaction for rotated JSONL trace archives.

A multi-GB archive answers "give me run ``run-000123``" only by scanning
every rotated file from the start — O(archive) per lookup.  The sidecar
index is the O(1) path: one scan of the archive writes
``{directory}/{prefix}.index.jsonl`` mapping every intact run's id to its
exact byte span, and :meth:`repro.archive.ArchiveReader.get` then seeks
straight to the run (read ``length`` bytes at ``offset``, decode with
:func:`~repro.archive.reader.parse_run`) without touching the rest of the
archive.

Run ids are ordinal in archive order (``run-000000``, ``run-000001``, ...):
deterministic for a given archive content, so tooling can address runs
without a discovery step.  They are *archive coordinates* — rewriting the
archive (compaction) renumbers them, and the index is rebuilt alongside.

Sidecar format (JSONL): a header line

    {"kind": "repro-archive-index", "version": 2, "prefix": ...,
     "files": [[name, bytes], ...], "runs": N}

followed by one entry line per run (``id``, ``file``, ``offset``,
``length``, ``line``, ``mechanism``, ``program``, ``status``, ``fp`` —
the run's static CFG fingerprint, see
:mod:`repro.analysis.fingerprint`; it is what ``python -m repro.archive
similar`` ranks on without opening the archive files at all).  The
``files`` fingerprint — (name, size) of every rotated file at build time —
is how staleness is detected: a grown, rotated, or compacted archive no
longer matches, and :meth:`ArchiveIndex.ensure` (and ``ArchiveReader.get``)
transparently rebuild.  The sidecar is written atomically (tmp +
``os.replace``) so a concurrent reader never sees a torn index.

:func:`compact` is the repair pass: it rewrites each rotated file keeping
only the byte spans of intact runs — corrupt lines, interrupted runs, and
a crashed writer's truncated tail are dropped — and preserves those spans
*verbatim* (replay fidelity is bit-exact: the surviving runs' lines are
untouched).  Files left empty are removed; the index is rebuilt.  Compact
only a quiescent archive: a live writer appending mid-compaction would
race the rewrite.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Any, Mapping

from .reader import ArchiveReader

__all__ = ["ArchiveIndex", "CompactReport", "IndexEntry", "compact",
           "index_path", "scan_archive"]

INDEX_KIND = "repro-archive-index"
# v2 added the per-run "fp" CFG fingerprint; older sidecars load as None
# and ensure() transparently rebuilds them with fingerprints filled in.
INDEX_VERSION = 2


def index_path(directory: str, prefix: str = "traces") -> str:
    """The sidecar's path: ``{directory}/{prefix}.index.jsonl``."""
    return os.path.join(directory, f"{prefix}.index.jsonl")


@dataclass(frozen=True)
class IndexEntry:
    """One intact run's coordinates + identification."""

    run_id: str
    file: str           # basename of the rotated file holding the run
    offset: int         # byte offset of the begin line within that file
    length: int         # bytes from begin through the end line (inclusive)
    line: int           # 1-based line number of the begin line
    mechanism: str      # begin-meta mechanism (what the run was served as)
    program: str
    status: str
    fp: tuple[float, ...] | None = None   # CFG fingerprint (None: unknown)

    def to_json(self) -> dict[str, Any]:
        out = {"id": self.run_id, "file": self.file, "offset": self.offset,
               "length": self.length, "line": self.line,
               "mechanism": self.mechanism, "program": self.program,
               "status": self.status}
        if self.fp is not None:
            out["fp"] = [round(float(x), 6) for x in self.fp]
        return out

    @classmethod
    def from_json(cls, obj: Mapping[str, Any]) -> "IndexEntry":
        fp = obj.get("fp")
        return cls(run_id=str(obj["id"]), file=str(obj["file"]),
                   offset=int(obj["offset"]), length=int(obj["length"]),
                   line=int(obj["line"]),
                   mechanism=str(obj.get("mechanism") or ""),
                   program=str(obj.get("program") or ""),
                   status=str(obj.get("status") or ""),
                   fp=None if fp is None else tuple(float(x) for x in fp))


def _begin_fp(ev: Mapping[str, Any]) -> tuple[float, ...] | None:
    """The run's CFG fingerprint from its begin event, best effort.

    Prefers the stamped ``cfg_fp`` (current :data:`~repro.analysis.
    fingerprint.FP_VERSION` only — a stamp from an older format is
    recomputed, never compared across versions); falls back to computing
    from the archived ``replay.program`` for pre-fingerprint archives.
    Never raises: a malformed stamp must not void an otherwise-intact run.
    """
    from repro.analysis.fingerprint import FP_VERSION, fingerprint
    try:
        stamp = ev.get("cfg_fp")
        if isinstance(stamp, Mapping) and stamp.get("v") == FP_VERSION:
            return tuple(float(x) for x in stamp["f"])
    except (KeyError, TypeError, ValueError):
        pass
    try:
        program = (ev.get("replay") or {}).get("program")
        if program:
            import numpy as np
            return fingerprint(np.asarray(program, dtype=np.int32))
    except Exception:
        pass
    return None


def scan_archive(directory: str, prefix: str = "traces",
                 ) -> tuple[list[tuple[str, int]], list[IndexEntry]]:
    """One pass over the rotated files: byte-accurate run coordinates.

    Returns ``(files, entries)`` — ``files`` is the fingerprint
    (``(basename, size_bytes)`` per rotated file, in rotation order) and
    ``entries`` the intact runs with ordinal ids.  Intactness matches
    :class:`~repro.archive.reader.ArchiveReader` exactly: a run survives
    only if its begin line, every issue line, and its end line all decode
    and nothing interleaves — corrupt lines, a begin over an unfinished
    run, and a partial tail line all void the run in progress, just as the
    reader drops it.
    """
    files: list[tuple[str, int]] = []
    entries: list[IndexEntry] = []
    ordinal = 0
    paths = ArchiveReader(directory, prefix=prefix).paths()
    for fi, path in enumerate(paths):
        last_file = fi == len(paths) - 1
        name = os.path.basename(path)
        files.append((name, os.path.getsize(path)))
        with open(path, "rb") as fh:
            offset = 0
            lineno = 0
            # (begin offset, begin lineno, mechanism, program, fp) of the
            # run in progress, or None outside a run
            cur: tuple[int, int, str, str,
                       tuple[float, ...] | None] | None = None
            for raw in fh:
                lineno += 1
                start = offset
                offset += len(raw)
                try:
                    # a missing trailing newline fingerprints a crashed
                    # writer only in the LAST file (the reader's rule: a
                    # complete-parse final line elsewhere is a normal event)
                    if last_file and not raw.endswith(b"\n"):
                        raise ValueError("partial tail line")
                    ev = json.loads(raw.decode("utf-8"))
                    kind = ev.get("event")
                    if kind == "begin":
                        cur = (start, lineno,
                               str(ev.get("mechanism") or ""),
                               str(ev.get("program") or ""),
                               _begin_fp(ev))
                        continue
                    if kind == "issue":
                        # same field validation the reader applies: an
                        # issue line whose pc/mask are missing or non-int
                        # is corruption and voids the run in progress
                        int(ev["pc"]), int(ev["mask"])
                        continue
                    if kind == "end":
                        # mirror the reader's end-event casts exactly
                        int(ev.get("steps") or 0)
                        int(ev.get("fuel_left", -1))
                        int(ev.get("finished") or 0)
                        float(ev.get("utilization") or 0.0)
                        if cur is not None:
                            entries.append(IndexEntry(
                                run_id=f"run-{ordinal:06d}", file=name,
                                offset=cur[0], length=offset - cur[0],
                                line=cur[1],
                                mechanism=cur[2] or str(ev.get("mechanism")
                                                        or ""),
                                program=cur[3],
                                status=str(ev.get("status") or ""),
                                fp=cur[4]))
                            ordinal += 1
                        cur = None
                        continue
                    raise ValueError(f"unknown event kind {kind!r}")
                except (ValueError, KeyError, TypeError,
                        UnicodeDecodeError):
                    cur = None                  # corruption voids the run
    return files, entries


@dataclass(frozen=True)
class ArchiveIndex:
    """A loaded (or freshly built) sidecar index for one archive."""

    directory: str
    prefix: str
    files: tuple[tuple[str, int], ...]      # fingerprint at build time
    entries: tuple[IndexEntry, ...]

    @property
    def path(self) -> str:
        return index_path(self.directory, self.prefix)

    def __len__(self) -> int:
        return len(self.entries)

    def run_ids(self) -> list[str]:
        return [e.run_id for e in self.entries]

    def lookup(self, run_id: str) -> IndexEntry:
        """The entry for ``run_id``; raises KeyError with the id range."""
        entry = self._by_id().get(run_id)
        if entry is None:
            span = (f"{self.entries[0].run_id} .. {self.entries[-1].run_id}"
                    if self.entries else "<empty archive>")
            raise KeyError(f"unknown run id {run_id!r}; indexed: {span}")
        return entry

    def _by_id(self) -> dict[str, IndexEntry]:
        cache = self.__dict__.get("_by_id_cache")
        if cache is None:
            cache = {e.run_id: e for e in self.entries}
            self.__dict__["_by_id_cache"] = cache
        return cache

    def rank_similar(self, query_fp, *, top: int | None = None,
                     ) -> list[tuple[str, float]]:
        """Archived runs ranked by ascending control-flow distance to
        ``query_fp`` (see :func:`repro.analysis.fingerprint.distance`) —
        ``(run_id, distance)`` pairs, computed from the sidecar alone (no
        archive file is opened, nothing is replayed).  Entries without a
        fingerprint (undecodable pre-fingerprint begin meta) are skipped.
        A query taken from an indexed run ranks that run first at exactly
        0.0."""
        from repro.analysis.fingerprint import rank
        return rank(query_fp, ((e.run_id, e.fp) for e in self.entries),
                    top=top)

    def fresh(self) -> bool:
        """Whether the fingerprint still matches the on-disk files."""
        try:
            current = [(os.path.basename(p), os.path.getsize(p))
                       for p in ArchiveReader(self.directory,
                                              prefix=self.prefix).paths()]
        except FileNotFoundError:
            return False
        return tuple(current) == self.files

    # -- build / load / ensure ----------------------------------------------

    @classmethod
    def build(cls, directory: str, prefix: str = "traces") -> "ArchiveIndex":
        """Scan the archive and (atomically) write the sidecar."""
        files, entries = scan_archive(directory, prefix)
        idx = cls(directory=directory, prefix=prefix, files=tuple(files),
                  entries=tuple(entries))
        header = {"kind": INDEX_KIND, "version": INDEX_VERSION,
                  "prefix": prefix, "files": [list(f) for f in files],
                  "runs": len(entries)}
        tmp = idx.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as fh:
            fh.write(json.dumps(header, separators=(",", ":")) + "\n")
            for e in entries:
                fh.write(json.dumps(e.to_json(), separators=(",", ":"))
                         + "\n")
        os.replace(tmp, idx.path)      # atomic: no torn sidecar
        return idx

    @classmethod
    def load(cls, directory: str,
             prefix: str = "traces") -> "ArchiveIndex | None":
        """The sidecar as written, or ``None`` if missing/undecodable
        (an undecodable sidecar is treated like a missing one — rebuilt,
        never fatal)."""
        path = index_path(directory, prefix)
        try:
            with open(path, encoding="utf-8") as fh:
                lines = fh.read().splitlines()
            header = json.loads(lines[0])
            if (header.get("kind") != INDEX_KIND
                    or header.get("version") != INDEX_VERSION):
                return None
            entries = tuple(IndexEntry.from_json(json.loads(ln))
                            for ln in lines[1:] if ln)
            files = tuple((str(n), int(b)) for n, b in header["files"])
        except (OSError, ValueError, KeyError, TypeError, IndexError):
            return None
        return cls(directory=directory, prefix=prefix, files=files,
                   entries=entries)

    @classmethod
    def ensure(cls, directory: str,
               prefix: str = "traces") -> "ArchiveIndex":
        """Load the sidecar, rebuilding when missing or stale
        (fingerprint mismatch: the archive grew, rotated, or compacted)."""
        idx = cls.load(directory, prefix)
        if idx is None or not idx.fresh():
            idx = cls.build(directory, prefix)
        return idx


@dataclass(frozen=True)
class CompactReport:
    """Accounting for one :func:`compact` pass."""

    runs_kept: int
    bytes_before: int
    bytes_after: int
    files_rewritten: tuple[str, ...]
    files_removed: tuple[str, ...]          # rewritten down to zero runs

    @property
    def bytes_dropped(self) -> int:
        return self.bytes_before - self.bytes_after

    def render(self) -> str:
        return (f"kept {self.runs_kept} run(s); dropped "
                f"{self.bytes_dropped} byte(s) of debris "
                f"({len(self.files_rewritten)} file(s) rewritten, "
                f"{len(self.files_removed)} removed)")


def compact(directory: str, prefix: str = "traces", *,
            reindex: bool = True) -> CompactReport:
    """Rewrite rotated files keeping only intact runs, byte-for-byte.

    Corrupt lines, interrupted runs, orphan events, and a crashed writer's
    truncated tail are dropped; every surviving run's lines are copied
    *verbatim* (same bytes → bit-identical replay).  Already-clean files
    are left untouched; files with no surviving runs are removed (rotation
    numbering may gain gaps — the reader orders by index, not contiguity).
    Rebuilds the sidecar index afterwards unless ``reindex=False``.

    Only compact a quiescent archive: a live writer appending to the last
    file would race the rewrite.
    """
    files, entries = scan_archive(directory, prefix)
    by_file: dict[str, list[IndexEntry]] = {}
    for e in entries:
        by_file.setdefault(e.file, []).append(e)
    bytes_before = sum(size for _, size in files)
    bytes_after = 0
    rewritten: list[str] = []
    removed: list[str] = []
    for name, size in files:
        keep = by_file.get(name, [])
        kept_bytes = sum(e.length for e in keep)
        path = os.path.join(directory, name)
        if kept_bytes == size:                  # nothing to drop
            bytes_after += size
            continue
        if not keep:
            os.remove(path)
            removed.append(name)
            continue
        with open(path, "rb") as fh:
            data = fh.read()
        tmp = path + ".tmp"
        with open(tmp, "wb") as fh:
            for e in keep:
                fh.write(data[e.offset:e.offset + e.length])
        os.replace(tmp, path)
        rewritten.append(name)
        bytes_after += kept_bytes
    if reindex:
        ArchiveIndex.build(directory, prefix)
    return CompactReport(runs_kept=len(entries), bytes_before=bytes_before,
                         bytes_after=bytes_after,
                         files_rewritten=tuple(rewritten),
                         files_removed=tuple(removed))
