"""repro.engine — the unified control-flow simulation API.

This package is the **canonical entry point** for running SASS-lite warps
under any control-flow-management mechanism.  It replaces the four ad-hoc
engine entry points (``interp.run_hanoi``, ``interp.run_simt_stack``,
``dualpath.run_dual_path`` and the JAX ``hanoi`` module) with one façade,
one request/result schema, and one trace format.

Quick start
-----------
::

    from repro.core.programs import make_suite
    from repro.engine import MachineConfig, Simulator

    cfg = MachineConfig(n_threads=32, mem_size=256, max_steps=60_000)
    sim = Simulator("hanoi")

    # one warp, one mechanism
    res = sim.run(make_suite(cfg)[0], cfg)
    print(res.status, res.utilization, len(res.trace))

    # the paper's Fig 9/10 evaluation in one call
    report = sim.compare(["hanoi", "turing_oracle"], make_suite(cfg), cfg)
    print(report.mean_discrepancy("hanoi", "turing_oracle"))

    # batched execution: one vmap over warps+programs on the JAX engine
    results = sim.run_batch(make_suite(cfg), cfg, mechanism="hanoi_jax")

Layout
------
* :mod:`repro.engine.types`     — frozen :class:`SimRequest` /
  :class:`SimResult` with the normalized :class:`SimStatus`
  (``OK`` / ``OUT_OF_FUEL`` / ``DEADLOCK`` / ``ERROR``);
* :mod:`repro.engine.registry`  — the :class:`Mechanism` registry and the
  :func:`register_mechanism` decorator for third-party mechanisms;
* :mod:`repro.engine.adapters`  — the five built-ins: ``simt_stack``,
  ``hanoi``, ``turing_oracle``, ``dualpath``, ``hanoi_jax``;
* :mod:`repro.engine.mechanisms` — plugin mechanisms beyond the adapter
  family: ``volta_itps`` (per-thread-PC independent thread scheduling) and
  ``sm_interleave`` (per-SM multi-warp time-multiplexing);
* :mod:`repro.engine.sinks`     — pluggable :class:`TraceSink` consumers
  (:class:`MemorySink`, :class:`JsonlSink`, :class:`RingBufferSink`, the
  rotating archival :class:`RotatingJsonlSink`); :func:`run_meta` stamps
  begin events with a ``replay`` payload, making archives replayable
  offline by :mod:`repro.archive` (read + Fig 9 diffing at archive scale);
* :mod:`repro.engine.simulator` — the :class:`Simulator` façade with
  ``run`` / ``run_batch`` / ``run_sm`` / ``compare``; batch dispatch is
  shared with :mod:`repro.service` (the queue-fed simulation service —
  admission coalescing, native-batch routing, sharded SM cells, service
  metrics).

Adding a mechanism
------------------
::

    from repro.engine import SimRequest, SimResult, register_mechanism

    @register_mechanism("darm", description="divergence-melding prototype")
    def run_darm(req: SimRequest) -> SimResult:
        ...

New plugins must pass the differential conformance suite
(``tests/test_conformance.py``): final architectural state must agree with
``simt_stack`` on every program where both report ``SimStatus.OK``.
Candidate future mechanisms (see ROADMAP): DARM-style branch melding and
decoupled control flow.
"""
from repro.core.isa import MachineConfig

from .registry import (Mechanism, available_mechanisms, get_mechanism,
                       iter_mechanisms, register_mechanism,
                       unregister_mechanism)
from .sinks import (JsonlSink, MemorySink, RingBufferSink, RotatingJsonlSink,
                    TraceSink, feed_result, replay_payload, run_meta,
                    sm_run_meta, timing_meta)
from .types import (SimRequest, SimResult, SimStatus, SmResult,
                    classify_status, worst_status)
from .simulator import (CompareReport, CompareRow, Simulator, as_request)
from .compile_cache import (CompileCache, WarmReport, compile_cache_stats,
                            install_compile_cache, installed_cache,
                            uninstall_compile_cache)
from . import adapters as _adapters            # registers the built-ins
from . import mechanisms as _mechanisms        # registers the plugins

__all__ = [
    "CompareReport", "CompareRow", "CompileCache", "JsonlSink",
    "MachineConfig", "Mechanism",
    "MemorySink", "RingBufferSink", "RotatingJsonlSink", "SimRequest",
    "SimResult", "SimStatus", "SmResult", "Simulator", "TraceSink",
    "WarmReport",
    "as_request", "available_mechanisms", "classify_status",
    "compile_cache_stats", "feed_result",
    "get_mechanism", "install_compile_cache", "installed_cache",
    "iter_mechanisms", "register_mechanism",
    "replay_payload", "run_meta", "sm_run_meta", "timing_meta",
    "uninstall_compile_cache", "unregister_mechanism",
    "worst_status",
]
