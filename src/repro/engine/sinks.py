"""Pluggable trace sinks: one consumer protocol for every engine's trace.

Historically each engine had its own trace format (python list of pairs in
the numpy interpreters, ring arrays in the JAX state, int64 token vectors
for Levenshtein).  A :class:`TraceSink` receives the *normalized* stream —
``begin(meta)`` once, ``emit(pc, mask)`` per issued scheduler slot, and
``end(result)`` with the finished :class:`~repro.engine.types.SimResult` —
regardless of which mechanism produced it.

Built-ins:

* :class:`MemorySink`     — accumulates complete runs in memory (the default
  for tests and notebooks);
* :class:`JsonlSink`      — streams one JSON object per event to a file, the
  archival format for offline diffing at service scale;
* :class:`RingBufferSink` — keeps only the last ``capacity`` slots, the
  flight-recorder mode for long-running / high-traffic simulation where full
  traces would be unbounded.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Any, IO, Mapping

from .types import SimResult


class TraceSink:
    """Base class; all hooks are optional no-ops."""

    def begin(self, meta: Mapping[str, Any]) -> None:
        pass

    def emit(self, pc: int, mask: int) -> None:
        pass

    def end(self, result: SimResult) -> None:
        pass

    def close(self) -> None:
        pass

    def __enter__(self) -> "TraceSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class MemorySink(TraceSink):
    """Collects ``(meta, trace, result)`` triples for every run."""

    def __init__(self) -> None:
        self.runs: list[dict[str, Any]] = []
        self._cur: dict[str, Any] | None = None

    def begin(self, meta: Mapping[str, Any]) -> None:
        self._cur = {"meta": dict(meta), "trace": [], "result": None}

    def emit(self, pc: int, mask: int) -> None:
        if self._cur is not None:
            self._cur["trace"].append((pc, mask))

    def end(self, result: SimResult) -> None:
        if self._cur is not None:
            self._cur["result"] = result
            self.runs.append(self._cur)
            self._cur = None

    @property
    def traces(self) -> list[list[tuple[int, int]]]:
        return [r["trace"] for r in self.runs]


class JsonlSink(TraceSink):
    """Streams events as JSON lines to ``path`` (or an open file object)."""

    def __init__(self, path_or_file: "str | IO[str]") -> None:
        if isinstance(path_or_file, str):
            self._fh: IO[str] = open(path_or_file, "w", encoding="utf-8")
            self._owns = True
        else:
            self._fh = path_or_file
            self._owns = False
        self.events_written = 0

    def _write(self, obj: Mapping[str, Any]) -> None:
        self._fh.write(json.dumps(obj, separators=(",", ":")) + "\n")
        self.events_written += 1

    def begin(self, meta: Mapping[str, Any]) -> None:
        self._write({"event": "begin", **dict(meta)})

    def emit(self, pc: int, mask: int) -> None:
        self._write({"event": "issue", "pc": int(pc), "mask": int(mask)})

    def end(self, result: SimResult) -> None:
        self._write({"event": "end", "mechanism": result.mechanism,
                     "status": result.status.value, "steps": result.steps,
                     "fuel_left": result.fuel_left,
                     "finished": int(result.finished),
                     "utilization": result.utilization,
                     "error": result.error})
        self._fh.flush()

    def close(self) -> None:
        if self._owns and not self._fh.closed:
            self._fh.close()


class RingBufferSink(TraceSink):
    """Flight recorder: keeps the last ``capacity`` issued slots only."""

    def __init__(self, capacity: int = 1024) -> None:
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self.buffer: deque[tuple[int, int]] = deque(maxlen=capacity)
        self.total_emitted = 0
        self.last_result: SimResult | None = None

    def emit(self, pc: int, mask: int) -> None:
        self.buffer.append((pc, mask))
        self.total_emitted += 1

    def end(self, result: SimResult) -> None:
        self.last_result = result

    def snapshot(self) -> list[tuple[int, int]]:
        return list(self.buffer)
