"""Pluggable trace sinks: one consumer protocol for every engine's trace.

Historically each engine had its own trace format (python list of pairs in
the numpy interpreters, ring arrays in the JAX state, int64 token vectors
for Levenshtein).  A :class:`TraceSink` receives the *normalized* stream —
``begin(meta)`` once, ``emit(pc, mask)`` per issued scheduler slot, and
``end(result)`` with the finished :class:`~repro.engine.types.SimResult` —
regardless of which mechanism produced it.

Built-ins:

* :class:`MemorySink`     — accumulates complete runs in memory (the default
  for tests and notebooks);
* :class:`JsonlSink`      — streams one JSON object per event to a file, the
  archival format for offline diffing at service scale;
* :class:`RingBufferSink` — keeps only the last ``capacity`` slots, the
  flight-recorder mode for long-running / high-traffic simulation where full
  traces would be unbounded;
* :class:`RotatingJsonlSink` — the durable service archive: buffered,
  written by a background thread, rotated across ``prefix-NNNNN.jsonl``
  files by size, and safe for concurrent producers (whole runs are
  enqueued atomically, so events from different workers never interleave).

:func:`feed_result` replays a finished :class:`SimResult` into any sink as
the normalized ``begin``/``emit``/``end`` stream — the one feeding path the
Simulator façade and the simulation service both use.
"""
from __future__ import annotations

import json
import os
import queue
import threading
from collections import deque
from typing import Any, IO, Mapping

from .types import SimResult


class TraceSink:
    """Base class; all hooks are optional no-ops."""

    def begin(self, meta: Mapping[str, Any]) -> None:
        pass

    def emit(self, pc: int, mask: int) -> None:
        pass

    def end(self, result: SimResult) -> None:
        pass

    def close(self) -> None:
        pass

    def __enter__(self) -> "TraceSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class MemorySink(TraceSink):
    """Collects ``(meta, trace, result)`` triples for every run."""

    def __init__(self) -> None:
        self.runs: list[dict[str, Any]] = []
        self._cur: dict[str, Any] | None = None

    def begin(self, meta: Mapping[str, Any]) -> None:
        self._cur = {"meta": dict(meta), "trace": [], "result": None}

    def emit(self, pc: int, mask: int) -> None:
        if self._cur is not None:
            self._cur["trace"].append((pc, mask))

    def end(self, result: SimResult) -> None:
        if self._cur is not None:
            self._cur["result"] = result
            self.runs.append(self._cur)
            self._cur = None

    @property
    def traces(self) -> list[list[tuple[int, int]]]:
        return [r["trace"] for r in self.runs]


# One encoder per archival event shape, shared by JsonlSink and
# RotatingJsonlSink so the two writers can never fork the format the
# offline diffing tools read.

def begin_event(meta: Mapping[str, Any]) -> dict[str, Any]:
    return {"event": "begin", **dict(meta)}


def issue_event(pc: int, mask: int) -> dict[str, Any]:
    return {"event": "issue", "pc": int(pc), "mask": int(mask)}


def end_event(result: SimResult) -> dict[str, Any]:
    return {"event": "end", "mechanism": result.mechanism,
            "status": result.status.value, "steps": result.steps,
            "fuel_left": result.fuel_left,
            "finished": int(result.finished),
            "utilization": result.utilization,
            "error": result.error}


class JsonlSink(TraceSink):
    """Streams events as JSON lines to ``path`` (or an open file object)."""

    def __init__(self, path_or_file: "str | IO[str]") -> None:
        if isinstance(path_or_file, str):
            self._fh: IO[str] = open(path_or_file, "w", encoding="utf-8")
            self._owns = True
        else:
            self._fh = path_or_file
            self._owns = False
        self.events_written = 0

    def _write(self, obj: Mapping[str, Any]) -> None:
        self._fh.write(json.dumps(obj, separators=(",", ":")) + "\n")
        self.events_written += 1

    def begin(self, meta: Mapping[str, Any]) -> None:
        self._write(begin_event(meta))

    def emit(self, pc: int, mask: int) -> None:
        self._write(issue_event(pc, mask))

    def end(self, result: SimResult) -> None:
        self._write(end_event(result))
        self._fh.flush()

    def close(self) -> None:
        if self._owns and not self._fh.closed:
            self._fh.close()


def feed_result(sink: "TraceSink | None", result: SimResult,
                meta: Mapping[str, Any]) -> None:
    """Replay one finished result into ``sink`` as the normalized stream."""
    if sink is None:
        return
    sink.begin(meta)
    for pc, mask in result.trace:
        sink.emit(pc, mask)
    sink.end(result)


class RotatingJsonlSink(TraceSink):
    """Durable archival writer: buffered, background-flushed, size-rotated.

    Events for the current run are buffered in memory (per producer thread)
    and enqueued as one atomic chunk at ``end()``; a single writer thread
    drains the queue, appending to ``{directory}/{prefix}-NNNNN.jsonl`` and
    starting a new file once the current one would exceed ``max_bytes``
    (a single run larger than ``max_bytes`` still lands in one file — runs
    are never split across rotations).

    Because the unit of writing is a whole run, multiple service workers
    can drive one sink through the ordinary ``begin``/``emit``/``end``
    protocol without interleaving each other's events.  ``flush()`` blocks
    until every enqueued run is on disk; ``close()`` flushes and joins the
    writer.

    IO failures (disk full, directory removed) never wedge producers: the
    writer records the first exception in ``write_error``, then keeps
    draining and *dropping* chunks (counted in ``runs_dropped``) so
    ``end()``/``flush()`` stay non-blocking.  Callers that need durability
    guarantees check ``write_error`` after ``flush()``.
    """

    def __init__(self, directory: str, *, prefix: str = "traces",
                 max_bytes: int = 8 << 20, queue_size: int = 1024) -> None:
        if max_bytes <= 0:
            raise ValueError("max_bytes must be positive")
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.prefix = prefix
        self.max_bytes = int(max_bytes)
        self.paths: list[str] = []
        self.runs_written = 0
        self.runs_dropped = 0                 # chunks dropped after an error
        self.bytes_written = 0
        self.write_error: Exception | None = None   # first writer failure
        self._local = threading.local()
        self._q: "queue.Queue[str | None]" = queue.Queue(maxsize=queue_size)
        self._fh: IO[str] | None = None
        self._cur_bytes = 0
        self._closed = False
        self._writer = threading.Thread(target=self._drain, daemon=True,
                                        name="rotating-jsonl-writer")
        self._writer.start()

    # -- producer side (per-thread run buffers) -----------------------------

    def _lines(self) -> list[str]:
        lines = getattr(self._local, "lines", None)
        if lines is None:
            lines = self._local.lines = []
        return lines

    def _append(self, obj: Mapping[str, Any]) -> None:
        if self._closed:
            raise RuntimeError("RotatingJsonlSink is closed")
        self._lines().append(json.dumps(obj, separators=(",", ":")) + "\n")

    def begin(self, meta: Mapping[str, Any]) -> None:
        self._lines().clear()
        self._append(begin_event(meta))

    def emit(self, pc: int, mask: int) -> None:
        self._append(issue_event(pc, mask))

    def end(self, result: SimResult) -> None:
        self._append(end_event(result))
        lines = self._lines()
        self._q.put("".join(lines))
        lines.clear()

    # -- writer thread ------------------------------------------------------

    def _rotate(self) -> None:
        if self._fh is not None:
            self._fh.close()
        path = os.path.join(self.directory,
                            f"{self.prefix}-{len(self.paths):05d}.jsonl")
        self._fh = open(path, "w", encoding="utf-8")
        self._cur_bytes = 0
        self.paths.append(path)

    def _drain(self) -> None:
        while True:
            chunk = self._q.get()
            try:
                if chunk is None:
                    break
                if self.write_error is not None:
                    self.runs_dropped += 1       # degraded: ack + drop
                    continue
                if (self._fh is None
                        or (self._cur_bytes > 0
                            and self._cur_bytes + len(chunk)
                            > self.max_bytes)):
                    self._rotate()
                self._fh.write(chunk)
                self._fh.flush()
                self._cur_bytes += len(chunk)
                self.bytes_written += len(chunk)
                self.runs_written += 1
            except Exception as exc:             # disk full, dir deleted, ...
                # the writer must keep draining and acking chunks: dying
                # here would wedge flush() in _q.join() and, once the queue
                # fills, block every producer inside end()
                self.write_error = exc
                self.runs_dropped += 1
            finally:
                self._q.task_done()
        try:
            if self._fh is not None:
                self._fh.close()
        except Exception as exc:
            self.write_error = self.write_error or exc
        self._fh = None

    # -- control ------------------------------------------------------------

    def flush(self) -> None:
        """Block until every enqueued run has been written to disk."""
        self._q.join()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._q.put(None)
        self._writer.join(timeout=30)


class RingBufferSink(TraceSink):
    """Flight recorder: keeps the last ``capacity`` issued slots only."""

    def __init__(self, capacity: int = 1024) -> None:
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self.buffer: deque[tuple[int, int]] = deque(maxlen=capacity)
        self.total_emitted = 0
        self.last_result: SimResult | None = None

    def emit(self, pc: int, mask: int) -> None:
        self.buffer.append((pc, mask))
        self.total_emitted += 1

    def end(self, result: SimResult) -> None:
        self.last_result = result

    def snapshot(self) -> list[tuple[int, int]]:
        return list(self.buffer)
