"""Pluggable trace sinks: one consumer protocol for every engine's trace.

Historically each engine had its own trace format (python list of pairs in
the numpy interpreters, ring arrays in the JAX state, int64 token vectors
for Levenshtein).  A :class:`TraceSink` receives the *normalized* stream —
``begin(meta)`` once, ``emit(pc, mask)`` per issued scheduler slot, and
``end(result)`` with the finished :class:`~repro.engine.types.SimResult` —
regardless of which mechanism produced it.

Built-ins:

* :class:`MemorySink`     — accumulates complete runs in memory (the default
  for tests and notebooks);
* :class:`JsonlSink`      — streams one JSON object per event to a file, the
  archival format for offline diffing at service scale;
* :class:`RingBufferSink` — keeps only the last ``capacity`` slots, the
  flight-recorder mode for long-running / high-traffic simulation where full
  traces would be unbounded;
* :class:`RotatingJsonlSink` — the durable service archive: buffered,
  written by a background thread, rotated across ``prefix-NNNNN.jsonl``
  files by size, and safe for concurrent producers (whole runs are
  enqueued atomically, so events from different workers never interleave).

:func:`feed_result` replays a finished :class:`SimResult` into any sink as
the normalized ``begin``/``emit``/``end`` stream — the one feeding path the
Simulator façade and the simulation service both use.
"""
from __future__ import annotations

import codecs
import itertools
import json
import os
import queue
import threading
from collections import deque
from typing import Any, IO, Mapping

import numpy as np

from .types import SimRequest, SimResult


class TraceSink:
    """Base class; all hooks are optional no-ops."""

    def begin(self, meta: Mapping[str, Any]) -> None:
        pass

    def emit(self, pc: int, mask: int) -> None:
        pass

    def end(self, result: SimResult) -> None:
        pass

    def close(self) -> None:
        pass

    def __enter__(self) -> "TraceSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class MemorySink(TraceSink):
    """Collects ``(meta, trace, result)`` triples for every run."""

    def __init__(self) -> None:
        self.runs: list[dict[str, Any]] = []
        self._cur: dict[str, Any] | None = None

    def begin(self, meta: Mapping[str, Any]) -> None:
        self._cur = {"meta": dict(meta), "trace": [], "result": None}

    def emit(self, pc: int, mask: int) -> None:
        if self._cur is not None:
            self._cur["trace"].append((pc, mask))

    def end(self, result: SimResult) -> None:
        if self._cur is not None:
            self._cur["result"] = result
            self.runs.append(self._cur)
            self._cur = None

    @property
    def traces(self) -> list[list[tuple[int, int]]]:
        return [r["trace"] for r in self.runs]


# One encoder per archival event shape, shared by JsonlSink and
# RotatingJsonlSink so the two writers can never fork the format the
# offline diffing tools read.

def begin_event(meta: Mapping[str, Any]) -> dict[str, Any]:
    return {"event": "begin", **dict(meta)}


def issue_event(pc: int, mask: int) -> dict[str, Any]:
    return {"event": "issue", "pc": int(pc), "mask": int(mask)}


def end_event(result: SimResult) -> dict[str, Any]:
    return {"event": "end", "mechanism": result.mechanism,
            "status": result.status.value, "steps": result.steps,
            "fuel_left": result.fuel_left,
            "finished": int(result.finished),
            "utilization": result.utilization,
            "error": result.error}


def _sanitize(value: Any) -> Any:
    """Best-effort coercion to JSON-able types; raises TypeError otherwise."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, np.bool_):
        return bool(value)
    if isinstance(value, np.integer):
        return int(value)
    if isinstance(value, np.floating):
        return float(value)
    if isinstance(value, np.ndarray):
        return value.tolist()
    if isinstance(value, (list, tuple)):
        return [_sanitize(v) for v in value]
    if isinstance(value, Mapping):
        return {str(k): _sanitize(v) for k, v in value.items()}
    raise TypeError(f"not JSON-serializable: {type(value).__name__}")


def replay_payload(req: SimRequest) -> dict[str, Any]:
    """JSON-able encoding of everything needed to re-run ``req``.

    This is the write half of the archive round trip:
    ``repro.archive.ArchiveReader`` decodes it back into a
    :class:`~repro.engine.types.SimRequest` (``request_from_meta``) so
    archived runs can be replayed offline under any registered mechanism.
    Request ``meta`` entries that cannot be serialized are dropped and
    listed under ``meta_dropped`` rather than failing the write path.
    """
    def arr(x: Any) -> Any:
        return None if x is None else np.asarray(x).tolist()

    meta: dict[str, Any] = {}
    dropped: list[str] = []
    for k, v in req.meta.items():
        try:
            meta[str(k)] = _sanitize(v)
        except TypeError:
            dropped.append(str(k))
    payload: dict[str, Any] = {
        "program": np.asarray(req.program).tolist(),
        "cfg": dict(req.cfg._asdict()),
        "init_regs": arr(req.init_regs),
        "init_mem": arr(req.init_mem),
        "lane_ids": arr(req.lane_ids),
        "active0": None if req.active0 is None else int(req.active0),
        "fuel": None if req.fuel is None else int(req.fuel),
        "record_trace": bool(req.record_trace),
        "majority_first": bool(req.majority_first),
        "bsync_skip_pcs": [int(p) for p in req.bsync_skip_pcs],
        "name": req.name,
        "meta": meta,
    }
    if dropped:
        payload["meta_dropped"] = sorted(dropped)
    return payload


def run_meta(mechanism: str, req: SimRequest) -> dict[str, Any]:
    """The canonical begin-event meta for one request.

    Human-readable identification (mechanism, program name, shape), the
    program's static CFG fingerprint (``cfg_fp`` — what ``python -m
    repro.archive similar`` ranks on without replaying; see
    :mod:`repro.analysis.fingerprint`), plus the ``replay`` payload that
    makes the archive round-trippable — the one meta builder the Simulator
    façade and the simulation service share.
    """
    from repro.analysis.fingerprint import fingerprint_meta   # lazy; cached
    return {"mechanism": mechanism, "program": req.name,
            "n_threads": req.resolved_cfg().n_threads,
            "program_len": int(np.asarray(req.program).shape[0]),
            "cfg_fp": fingerprint_meta(req.program, req.resolved_cfg()),
            "replay": replay_payload(req)}


# Per-process SM-cell ids: every archived warp of one run_sm/submit_sm cell
# carries the same ``sm_cell`` so offline tooling can group the warps back
# into the cell they executed in.  itertools.count().__next__ is atomic
# under the GIL, so concurrent service workers never share an id.
_sm_cell_ids = itertools.count()


def next_sm_cell_id() -> int:
    """A process-unique id for one (SM, policy) cell's archived warps."""
    return next(_sm_cell_ids)


def timing_meta(sched: Any) -> dict[str, Any]:
    """JSON-able cycle/stall summary of a timed schedule.

    Accepts anything with the cycle-engine accounting fields (``SmResult``,
    ``CycleResult``, extended ``TimingResult``).  Archived alongside the
    replay payload so offline tooling can read the stall taxonomy and
    re-derive IPC (= ``thread_instructions / cycles``) without re-running
    the timing model — and cross-check it against a re-run when it does
    (:meth:`repro.archive.Replayer.rederive_timing`).
    """
    return {"cycles": int(sched.cycles),
            "thread_instructions": int(sched.thread_instructions),
            "busy_cycles": int(getattr(sched, "busy_cycles", 0)),
            "issue_stall_cycles": int(getattr(sched, "issue_stall_cycles", 0)),
            "scoreboard_stall_cycles":
                int(getattr(sched, "scoreboard_stall_cycles", 0)),
            "memory_stall_cycles":
                int(getattr(sched, "memory_stall_cycles", 0))}


def sm_run_meta(inner: str, req: SimRequest, *, warp: int, n_warps: int,
                policy: str, cell: int,
                timing: "Mapping[str, Any] | None" = None) -> dict[str, Any]:
    """The canonical begin-event meta for one warp of an SM cell.

    The SM variant of :func:`run_meta`: the same replayable payload (the
    warp re-runs standalone under ``inner`` — warps are architecturally
    independent, so a standalone replay is bit-equal to its in-cell
    execution) plus the cell coordinates — ``sm_warp`` (index within the
    cell), ``sm_warps`` (cell width), ``sm_policy`` (issue scheduler) and
    ``sm_cell`` (grouping id) — so :class:`repro.archive.Replayer` can
    reassemble per-cell and per-policy discrepancy breakdowns.  ``timing``
    (usually :func:`timing_meta` of the cell's schedule) lands under
    ``sm_timing`` so archives carry the cycle/stall breakdown.
    """
    meta = run_meta(inner, req)
    meta.update({"sm_warp": int(warp), "sm_warps": int(n_warps),
                 "sm_policy": str(policy), "sm_cell": int(cell)})
    if timing is not None:
        meta["sm_timing"] = dict(timing)
    return meta


class JsonlSink(TraceSink):
    """Streams events as JSON lines to ``path`` (or an open file object)."""

    def __init__(self, path_or_file: "str | IO[str]") -> None:
        if isinstance(path_or_file, str):
            self._fh: IO[str] = open(path_or_file, "w", encoding="utf-8")
            self._owns = True
        else:
            self._fh = path_or_file
            self._owns = False
        # native UTF-8 (not \uXXXX escapes) — but only when the handle can
        # take it: a caller-supplied file opened with a legacy encoding
        # would raise UnicodeEncodeError mid-stream, so fall back to
        # ASCII-escaped output there
        enc = getattr(self._fh, "encoding", None)
        self._ensure_ascii = (enc is not None
                              and codecs.lookup(enc).name != "utf-8")
        self.events_written = 0

    def _write(self, obj: Mapping[str, Any]) -> None:
        self._fh.write(json.dumps(obj, separators=(",", ":"),
                                  ensure_ascii=self._ensure_ascii) + "\n")
        self.events_written += 1

    def begin(self, meta: Mapping[str, Any]) -> None:
        self._write(begin_event(meta))

    def emit(self, pc: int, mask: int) -> None:
        self._write(issue_event(pc, mask))

    def end(self, result: SimResult) -> None:
        self._write(end_event(result))
        self._fh.flush()

    def close(self) -> None:
        if self._owns and not self._fh.closed:
            self._fh.close()


def feed_result(sink: "TraceSink | None", result: SimResult,
                meta: Mapping[str, Any]) -> None:
    """Replay one finished result into ``sink`` as the normalized stream."""
    if sink is None:
        return
    sink.begin(meta)
    for pc, mask in result.trace:
        sink.emit(pc, mask)
    sink.end(result)


class RotatingJsonlSink(TraceSink):
    """Durable archival writer: buffered, background-flushed, size-rotated.

    Events for the current run are buffered in memory (per producer thread)
    and enqueued as one atomic chunk at ``end()``; a single writer thread
    drains the queue, appending to ``{directory}/{prefix}-NNNNN.jsonl`` and
    starting a new file once the current one would exceed ``max_bytes``
    (a single run larger than ``max_bytes`` still lands in one file — runs
    are never split across rotations).

    Because the unit of writing is a whole run, multiple service workers
    can drive one sink through the ordinary ``begin``/``emit``/``end``
    protocol without interleaving each other's events.  ``flush()`` blocks
    until every enqueued run is on disk; ``close()`` flushes and joins the
    writer.

    IO failures (disk full, directory removed) never wedge producers: the
    writer records the first exception in ``write_error``, then keeps
    draining and *dropping* chunks (counted in ``runs_dropped``) so
    ``end()``/``flush()`` stay non-blocking.  Callers that need durability
    guarantees check ``write_error`` after ``flush()``.

    Protocol violations degrade the same way — counted, never enqueued:
    an ``end()`` with no matching ``begin()`` on that thread is dropped
    (``runs_malformed``; the chunk would be unreadable by
    ``repro.archive.ArchiveReader``), an ``emit()`` outside a run is
    dropped (``events_orphaned``), and a ``begin()`` over a stale buffer
    left by a producer that errored between ``begin`` and ``end`` discards
    the unfinished run (``runs_stale``) before starting the new one.

    ``max_bytes`` and ``bytes_written`` are measured in *encoded UTF-8
    bytes* (what actually lands on disk), not characters — non-ASCII
    request meta rotates at the same on-disk size as ASCII.
    """

    def __init__(self, directory: str, *, prefix: str = "traces",
                 max_bytes: int = 8 << 20, queue_size: int = 1024) -> None:
        if max_bytes <= 0:
            raise ValueError("max_bytes must be positive")
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.prefix = prefix
        self.max_bytes = int(max_bytes)
        self.paths: list[str] = []
        self.runs_written = 0
        self.runs_dropped = 0                 # chunks dropped after an error
        self.runs_malformed = 0               # end() with no matching begin()
        self.runs_stale = 0                   # begin() over an unfinished run
        self.events_orphaned = 0              # emit() outside begin()..end()
        self.bytes_written = 0                # encoded UTF-8 bytes on disk
        self.write_error: Exception | None = None   # first writer failure
        # protocol-violation counters are bumped from producer threads;
        # a bare += is a non-atomic read-modify-write and loses counts
        self._counter_lock = threading.Lock()
        self._local = threading.local()
        self._q: "queue.Queue[str | None]" = queue.Queue(maxsize=queue_size)
        self._fh: IO[str] | None = None
        self._cur_bytes = 0
        self._closed = False
        self._writer = threading.Thread(target=self._drain, daemon=True,
                                        name="rotating-jsonl-writer")
        self._writer.start()

    # -- producer side (per-thread run buffers) -----------------------------

    def _lines(self) -> list[str]:
        lines = getattr(self._local, "lines", None)
        if lines is None:
            lines = self._local.lines = []
        return lines

    def _append(self, obj: Mapping[str, Any]) -> None:
        if self._closed:
            raise RuntimeError("RotatingJsonlSink is closed")
        self._lines().append(json.dumps(obj, separators=(",", ":"),
                                        ensure_ascii=False) + "\n")

    def _active(self) -> bool:
        return getattr(self._local, "active", False)

    def begin(self, meta: Mapping[str, Any]) -> None:
        if self._active():
            with self._counter_lock:     # producer died between begin/end
                self.runs_stale += 1
        self._lines().clear()
        self._local.active = False
        self._append(begin_event(meta))
        self._local.active = True

    def emit(self, pc: int, mask: int) -> None:
        if not self._active():
            with self._counter_lock:
                self.events_orphaned += 1
            return
        self._append(issue_event(pc, mask))

    def end(self, result: SimResult) -> None:
        if not self._active():
            # no matching begin(): enqueuing would archive an unreadable
            # chunk — drop it and count instead
            with self._counter_lock:
                self.runs_malformed += 1
            self._lines().clear()
            return
        self._append(end_event(result))
        self._local.active = False
        lines = self._lines()
        self._q.put("".join(lines))
        lines.clear()

    # -- writer thread ------------------------------------------------------

    def _rotate(self) -> None:
        if self._fh is not None:
            self._fh.close()
        path = os.path.join(self.directory,
                            f"{self.prefix}-{len(self.paths):05d}.jsonl")
        self._fh = open(path, "w", encoding="utf-8")
        self._cur_bytes = 0
        self.paths.append(path)

    def _drain(self) -> None:
        while True:
            chunk = self._q.get()
            try:
                if chunk is None:
                    break
                if self.write_error is not None:
                    self.runs_dropped += 1       # degraded: ack + drop
                    continue
                # measure what hits the disk: encoded bytes, not characters
                # (len(chunk) undercounts non-ASCII meta and would let
                # files overshoot max_bytes)
                nbytes = len(chunk.encode("utf-8"))
                if (self._fh is None
                        or (self._cur_bytes > 0
                            and self._cur_bytes + nbytes
                            > self.max_bytes)):
                    self._rotate()
                self._fh.write(chunk)
                self._fh.flush()
                self._cur_bytes += nbytes
                self.bytes_written += nbytes
                self.runs_written += 1
            except Exception as exc:             # disk full, dir deleted, ...
                # the writer must keep draining and acking chunks: dying
                # here would wedge flush() in _q.join() and, once the queue
                # fills, block every producer inside end()
                self.write_error = exc
                self.runs_dropped += 1
            finally:
                self._q.task_done()
        try:
            if self._fh is not None:
                self._fh.close()
        except Exception as exc:
            self.write_error = self.write_error or exc
        self._fh = None

    # -- control ------------------------------------------------------------

    def flush(self) -> None:
        """Block until every enqueued run has been written to disk."""
        self._q.join()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._q.put(None)
        self._writer.join(timeout=30)


class RingBufferSink(TraceSink):
    """Flight recorder: keeps the last ``capacity`` issued slots only."""

    def __init__(self, capacity: int = 1024) -> None:
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self.buffer: deque[tuple[int, int]] = deque(maxlen=capacity)
        self.total_emitted = 0
        self.last_result: SimResult | None = None

    def emit(self, pc: int, mask: int) -> None:
        self.buffer.append((pc, mask))
        self.total_emitted += 1

    def end(self, result: SimResult) -> None:
        self.last_result = result

    def snapshot(self) -> list[tuple[int, int]]:
        return list(self.buffer)
