"""Persistent compilation cache + startup warming for the jax batch path.

A long-lived service amortizes XLA trace+compile latency across requests —
until the process restarts and every hot (cfg, pad-class, batch-class)
signature pays it again, right on the latency-critical warm-up path.  This
module makes that state durable:

* **Signature manifest** — every fresh compile writes one small JSON file
  under ``{dir}/sigs/`` recording the (mechanism, cfg, majority_first,
  pad-class, batch-class) key and its observed compile time.  The manifest
  is the durable record of *what was hot*; replaying it re-traces each
  signature before a restarted worker admits traffic.
* **Serialized AOT executables** — where the installed jaxlib supports
  ``jax.experimental.serialize_executable``, the compiled executable itself
  is pickled under ``{dir}/execs/``, so warming (and cold misses at serve
  time) deserialize instead of re-tracing at all.

Both layers are written atomically (tmp file + ``os.replace``) with one
file per entry, so N shard processes can share one cache directory without
coordination: concurrent stores of the same signature are idempotent
last-writer-wins of identical content.

:class:`~repro.service.core.SimulationService` wires this up via its
``warm_start=`` argument; shards warm only the slice of the manifest whose
:func:`affinity_token` hashes to them, mirroring the service's
signature-affine routing so each process re-traces exactly the signatures
it will serve.

The module imports no jax at top level — installing a cache keeps
numpy-only deployments jax-free.
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import tempfile
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Iterable

from repro.core.isa import MachineConfig

__all__ = [
    "affinity_token", "shard_of_token", "CompileCache", "WarmReport",
    "install_compile_cache", "installed_cache", "uninstall_compile_cache",
    "compile_cache_stats",
]


# ---------------------------------------------------------------------------
# affinity hashing — shared by service routing and warm-start sharding
# ---------------------------------------------------------------------------

def _canon_cfg(cfg: MachineConfig) -> str:
    return json.dumps(cfg._asdict(), sort_keys=True, separators=(",", ":"))


def affinity_token(mechanism: str, cfg: MachineConfig,
                   majority_first: bool, pad_len: int) -> str:
    """The stable routing token of one compiled-state locality class.

    Everything that shares a token shares jit/executable cache state
    (mechanism + canonical cfg + scheduling flavor + padding class), so the
    service routes it to one shard and warm-start replays it there.  The
    token is plain text — hash it with :func:`shard_of_token`, never with
    the builtin ``hash`` (randomized per process, useless across a pool).
    """
    return (f"{mechanism}|{_canon_cfg(cfg)}|mf{int(bool(majority_first))}"
            f"|pad{int(pad_len)}")


def shard_of_token(token: str, n_shards: int) -> int:
    """Deterministic shard assignment of a token: crc32 mod ``n_shards``."""
    if n_shards <= 1:
        return 0
    return zlib.crc32(token.encode("utf-8")) % n_shards


# ---------------------------------------------------------------------------
# serialization support probe
# ---------------------------------------------------------------------------

_SERIALIZE_SUPPORT: bool | None = None


def supports_serialization() -> bool:
    """Whether this jaxlib can serialize/deserialize AOT executables."""
    global _SERIALIZE_SUPPORT
    if _SERIALIZE_SUPPORT is None:
        try:
            from jax.experimental import serialize_executable  # noqa: F401
            _SERIALIZE_SUPPORT = (
                hasattr(serialize_executable, "serialize")
                and hasattr(serialize_executable, "deserialize_and_load"))
        except Exception:
            _SERIALIZE_SUPPORT = False
    return _SERIALIZE_SUPPORT


# ---------------------------------------------------------------------------
# cache entries
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CacheEntry:
    """One manifest record: a hot compile-shape signature."""

    mechanism: str
    cfg: dict[str, Any]
    majority_first: bool
    batch: int
    pad_len: int
    token: str
    compile_time_s: float = 0.0

    def machine_config(self) -> MachineConfig:
        known = {k: v for k, v in self.cfg.items()
                 if k in MachineConfig._fields}
        return MachineConfig(**known)


@dataclass
class WarmReport:
    """Outcome of replaying the manifest slice assigned to one shard."""

    shard: int = 0
    n_shards: int = 1
    signatures: int = 0     # manifest entries assigned to this shard
    loaded: int = 0         # satisfied by a deserialized AOT executable
    retraced: int = 0       # had to trace+compile from scratch
    errors: int = 0
    wall_s: float = 0.0

    def as_dict(self) -> dict[str, Any]:
        return dict(self.__dict__)

    @staticmethod
    def from_dict(d: dict[str, Any]) -> "WarmReport":
        r = WarmReport()
        for k, v in d.items():
            if hasattr(r, k):
                setattr(r, k, v)
        return r


def _atomic_write(path: str, data: bytes) -> None:
    d = os.path.dirname(path)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp-")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


@dataclass
class CompileCache:
    """One on-disk cache directory: ``sigs/*.json`` + ``execs/*.jaxexec``."""

    directory: str
    stats: dict[str, Any] = field(default_factory=lambda: {
        "stored": 0, "disk_hits": 0, "disk_misses": 0,
        "serialize_failures": 0, "load_errors": 0, "load_time_s": 0.0})

    def __post_init__(self) -> None:
        self.directory = os.path.abspath(self.directory)
        self._lock = threading.Lock()
        os.makedirs(self._sig_dir, exist_ok=True)
        os.makedirs(self._exec_dir, exist_ok=True)

    @property
    def _sig_dir(self) -> str:
        return os.path.join(self.directory, "sigs")

    @property
    def _exec_dir(self) -> str:
        return os.path.join(self.directory, "execs")

    # -- keying ----------------------------------------------------------

    @staticmethod
    def _digest(token: str, batch: int) -> str:
        return hashlib.sha1(f"{token}|b{int(batch)}"
                            .encode("utf-8")).hexdigest()[:20]

    def _paths(self, mechanism: str, cfg: MachineConfig,
               majority_first: bool, batch: int, pad_len: int
               ) -> tuple[str, str, str]:
        token = affinity_token(mechanism, cfg, majority_first, pad_len)
        digest = self._digest(token, batch)
        return (token,
                os.path.join(self._sig_dir, f"{digest}.json"),
                os.path.join(self._exec_dir, f"{digest}.jaxexec"))

    # -- store / load ----------------------------------------------------

    def store_executable(self, mechanism: str, cfg: MachineConfig,
                         majority_first: bool, batch: int, pad_len: int,
                         compiled: Any, compile_time_s: float | None = None
                         ) -> bool:
        """Record a fresh compile: always the manifest entry, plus the
        serialized executable when jaxlib supports it.  Returns whether the
        executable payload was persisted."""
        token, sig_path, exec_path = self._paths(
            mechanism, cfg, majority_first, batch, pad_len)
        entry = {"mechanism": mechanism, "cfg": cfg._asdict(),
                 "majority_first": bool(majority_first), "batch": int(batch),
                 "pad_len": int(pad_len), "token": token,
                 "compile_time_s": float(compile_time_s or 0.0)}
        _atomic_write(sig_path,
                      json.dumps(entry, sort_keys=True).encode("utf-8"))
        wrote_exec = False
        if supports_serialization():
            try:
                from jax.experimental import serialize_executable as se
                payload, in_tree, out_tree = se.serialize(compiled)
                _atomic_write(exec_path,
                              pickle.dumps((payload, in_tree, out_tree)))
                wrote_exec = True
            except Exception:
                with self._lock:
                    self.stats["serialize_failures"] += 1
        with self._lock:
            self.stats["stored"] += 1
        return wrote_exec

    def has(self, mechanism: str, cfg: MachineConfig, majority_first: bool,
            batch: int, pad_len: int) -> bool:
        """Whether the manifest already records this signature."""
        _, sig_path, _ = self._paths(mechanism, cfg, majority_first,
                                     batch, pad_len)
        return os.path.exists(sig_path)

    def load_executable(self, mechanism: str, cfg: MachineConfig,
                        majority_first: bool, batch: int, pad_len: int
                        ) -> Any | None:
        """A deserialized AOT executable for the signature, or ``None``."""
        if not supports_serialization():
            return None
        _, _, exec_path = self._paths(mechanism, cfg, majority_first,
                                      batch, pad_len)
        if not os.path.exists(exec_path):
            with self._lock:
                self.stats["disk_misses"] += 1
            return None
        t0 = time.perf_counter()
        try:
            with open(exec_path, "rb") as f:
                payload, in_tree, out_tree = pickle.load(f)
            from jax.experimental import serialize_executable as se
            compiled = se.deserialize_and_load(payload, in_tree, out_tree)
        except Exception:
            with self._lock:
                self.stats["load_errors"] += 1
            return None
        with self._lock:
            self.stats["disk_hits"] += 1
            self.stats["load_time_s"] += time.perf_counter() - t0
        return compiled

    # -- manifest --------------------------------------------------------

    def entries(self) -> list[CacheEntry]:
        """All manifest entries, sorted by token then batch (stable warm
        order).  Corrupt files are skipped, not fatal."""
        out: list[CacheEntry] = []
        try:
            names = sorted(os.listdir(self._sig_dir))
        except OSError:
            return out
        for name in names:
            if not name.endswith(".json"):
                continue
            try:
                with open(os.path.join(self._sig_dir, name),
                          encoding="utf-8") as f:
                    d = json.load(f)
                out.append(CacheEntry(
                    mechanism=str(d["mechanism"]), cfg=dict(d["cfg"]),
                    majority_first=bool(d["majority_first"]),
                    batch=int(d["batch"]), pad_len=int(d["pad_len"]),
                    token=str(d["token"]),
                    compile_time_s=float(d.get("compile_time_s", 0.0))))
            except (OSError, ValueError, KeyError, TypeError):
                continue
        out.sort(key=lambda e: (e.token, e.batch))
        return out

    # -- warming ---------------------------------------------------------

    def warm(self, *, shard: int = 0, n_shards: int = 1,
             mechanisms: Iterable[str] = ("hanoi_jax",)) -> WarmReport:
        """Replay this shard's manifest slice through the adapter compile
        path, so every hot signature is compiled (deserialized where the
        executable payload survives, re-traced otherwise) *before* the
        caller admits traffic."""
        from .adapters import _compiled_batch_exec, batch_cache_stats

        wanted = set(mechanisms)
        report = WarmReport(shard=int(shard), n_shards=int(n_shards))
        t0 = time.perf_counter()
        for entry in self.entries():
            if entry.mechanism not in wanted:
                continue
            if shard_of_token(entry.token, n_shards) != shard:
                continue
            report.signatures += 1
            before = batch_cache_stats()
            try:
                _compiled_batch_exec(entry.machine_config(),
                                     entry.majority_first, entry.batch,
                                     entry.pad_len)
            except Exception:
                report.errors += 1
                continue
            after = batch_cache_stats()
            if after["misses"] > before["misses"]:
                report.retraced += 1
            elif after["disk_hits"] > before["disk_hits"]:
                report.loaded += 1
            # a plain in-memory hit (duplicate manifest slice) counts as
            # neither — the signature was already warm
        report.wall_s = time.perf_counter() - t0
        return report

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            snap = dict(self.stats)
        snap["manifest_entries"] = len(self.entries())
        snap["supports_serialization"] = supports_serialization()
        return snap


# ---------------------------------------------------------------------------
# process-global installation (consulted by adapters._compiled_batch_exec)
# ---------------------------------------------------------------------------

_INSTALLED: CompileCache | None = None


def install_compile_cache(directory: str) -> CompileCache:
    """Install (or re-point) the process-global persistent cache."""
    global _INSTALLED
    _INSTALLED = CompileCache(directory)
    return _INSTALLED


def installed_cache() -> CompileCache | None:
    return _INSTALLED


def uninstall_compile_cache() -> None:
    global _INSTALLED
    _INSTALLED = None


def compile_cache_stats() -> dict[str, Any]:
    """One merged snapshot: in-memory batch-cache counters plus (when a
    persistent cache is installed) its disk-layer counters."""
    from .adapters import batch_cache_stats
    snap: dict[str, Any] = dict(batch_cache_stats())
    cache = installed_cache()
    if cache is not None:
        snap["disk"] = cache.snapshot()
    return snap
