"""Mechanism registry: named, swappable control-flow-management models.

A *mechanism* is anything that can execute a SASS-lite warp and return a
normalized :class:`~repro.engine.types.SimResult` — the paper's comparable
family (pre-Volta SIMT-Stack, Hanoi, the Turing runtime heuristic), the
Dual-Path comparison point, and the vectorized JAX engine are all registered
here.  Third-party mechanisms (e.g. a DARM-style divergence-melding variant)
plug in with the decorator::

    from repro.engine import SimRequest, SimResult, register_mechanism

    @register_mechanism("darm", backend="numpy",
                        description="branch-melding prototype")
    def run_darm(req: SimRequest) -> SimResult:
        ...

and immediately work with :class:`~repro.engine.simulator.Simulator`,
``run_batch`` and ``compare`` — no other plumbing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterator, Sequence

from .types import SimRequest, SimResult

Runner = Callable[[SimRequest], SimResult]
BatchRunner = Callable[[Sequence[SimRequest]], "list[SimResult]"]


@dataclass(frozen=True)
class Mechanism:
    """A registered control-flow-management model.

    ``runner`` executes one request; ``batch_runner`` (optional) executes a
    *homogeneous* batch natively (the JAX engine vmaps over warps and over
    padded programs).  Without one, the Simulator runs requests
    sequentially (or through an opt-in thread pool — see ``Simulator``'s
    ``max_workers``).
    """

    name: str
    runner: Runner
    backend: str = "numpy"                 # "numpy" | "jax"
    description: str = ""
    batch_runner: BatchRunner | None = None
    uses_skip_pcs: bool = False            # consumes SimRequest.bsync_skip_pcs
    tags: tuple[str, ...] = ()

    def __call__(self, req: SimRequest) -> SimResult:
        return self.runner(req)


_REGISTRY: dict[str, Mechanism] = {}


def register_mechanism(name: str, *, backend: str = "numpy",
                       description: str = "",
                       batch_runner: BatchRunner | None = None,
                       uses_skip_pcs: bool = False,
                       tags: Sequence[str] = (),
                       overwrite: bool = False) -> Callable[[Runner], Runner]:
    """Decorator registering ``fn(SimRequest) -> SimResult`` under ``name``."""
    def deco(fn: Runner) -> Runner:
        if name in _REGISTRY and not overwrite:
            raise ValueError(f"mechanism {name!r} already registered "
                             f"(pass overwrite=True to replace)")
        _REGISTRY[name] = Mechanism(
            name=name, runner=fn, backend=backend, description=description,
            batch_runner=batch_runner, uses_skip_pcs=uses_skip_pcs,
            tags=tuple(tags))
        return fn
    return deco


def unregister_mechanism(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_mechanism(name: str) -> Mechanism:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY)) or "<none>"
        raise KeyError(f"unknown mechanism {name!r}; registered: {known}") \
            from None


def available_mechanisms() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def iter_mechanisms() -> Iterator[Mechanism]:
    for name in available_mechanisms():
        yield _REGISTRY[name]
