"""Built-in mechanism adapters: existing engines -> normalized SimResult.

Five mechanisms ship with the engine (the paper's comparable family plus
the Dual-Path comparison point and the TPU-vectorized engine):

==============  =======  ====================================================
name            backend  model
==============  =======  ====================================================
simt_stack      numpy    pre-Volta SIMT-Stack, IPDom reconvergence (SS II)
hanoi           numpy    the paper's Hanoi mechanism (SS VII)
turing_oracle   numpy    Hanoi + the runtime skip heuristic (SS IX); consumes
                         ``SimRequest.bsync_skip_pcs``
dualpath        numpy    Dual-Path execution model (Rhu & Erez, HPCA'13)
hanoi_jax       jax      Hanoi as a JIT/vmap JAX state machine with the
                         native batched runner.  Drop-in for ``hanoi``:
                         it *ignores* ``bsync_skip_pcs`` (use the low-level
                         ``repro.core.hanoi.run_hanoi_jax`` for oracle-mode
                         JAX runs)
==============  =======  ====================================================

Each adapter funnels through :func:`~repro.engine.types.classify_status`, so
``SimResult.status`` means the same thing no matter which engine produced it.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Sequence

import numpy as np

from repro.core.interp import RunResult, run_hanoi, run_simt_stack, \
    simd_utilization
from repro.core.dualpath import run_dual_path

from .registry import register_mechanism
from .types import SimRequest, SimResult, classify_status

__all__ = ["PAD_QUANTUM", "padded_len", "result_from_runresult",
           "batch_cache_stats", "reset_batch_caches",
           "set_batch_cache_capacity"]


def result_from_runresult(mechanism: str, r: RunResult, req: SimRequest,
                          wall_time_s: float = 0.0) -> SimResult:
    """Map a legacy numpy ``RunResult`` onto the normalized schema."""
    cfg = req.resolved_cfg()
    trace = tuple(r.trace)
    return SimResult(
        mechanism=mechanism,
        status=classify_status(finished=r.finished, full_mask=cfg.full_mask,
                               fuel_left=r.fuel_left, error=r.error),
        regs=np.asarray(r.regs), preds=np.asarray(r.preds),
        mem=np.asarray(r.mem), finished=int(r.finished), steps=int(r.steps),
        fuel_left=int(r.fuel_left), trace=trace,
        utilization=simd_utilization(r.trace, cfg.n_threads),
        error=r.error, wall_time_s=wall_time_s)


# ---------------------------------------------------------------------------
# numpy mechanisms
# ---------------------------------------------------------------------------

@register_mechanism(
    "hanoi", backend="numpy", tags=("paper", "reference"),
    description="Hanoi WS/REC-stack mechanism (paper SS VII), numpy "
                "reference interpreter")
def _run_hanoi(req: SimRequest) -> SimResult:
    cfg = req.resolved_cfg()
    t0 = time.perf_counter()
    r = run_hanoi(req.program, cfg, init_regs=req.init_regs,
                  init_mem=req.init_mem, lane_ids=req.lane_ids,
                  active0=req.active0, majority_first=req.majority_first,
                  record_trace=req.record_trace)
    return result_from_runresult("hanoi", r, req, time.perf_counter() - t0)


@register_mechanism(
    "turing_oracle", backend="numpy", uses_skip_pcs=True, tags=("paper",),
    description="Hanoi plus the Turing runtime skip heuristic (paper SS IX);"
                " skips reconvergence at SimRequest.bsync_skip_pcs")
def _run_turing_oracle(req: SimRequest) -> SimResult:
    cfg = req.resolved_cfg()
    t0 = time.perf_counter()
    r = run_hanoi(req.program, cfg, init_regs=req.init_regs,
                  init_mem=req.init_mem, lane_ids=req.lane_ids,
                  active0=req.active0, majority_first=req.majority_first,
                  bsync_skip_pcs=frozenset(req.bsync_skip_pcs),
                  record_trace=req.record_trace)
    return result_from_runresult("turing_oracle", r, req,
                                 time.perf_counter() - t0)


@register_mechanism(
    "simt_stack", backend="numpy", tags=("paper", "baseline"),
    description="pre-Volta SIMT-Stack with compile-time IPDom reconvergence "
                "(paper SS II)")
def _run_simt_stack(req: SimRequest) -> SimResult:
    cfg = req.resolved_cfg()
    t0 = time.perf_counter()
    r = run_simt_stack(req.program, cfg, init_regs=req.init_regs,
                       init_mem=req.init_mem, lane_ids=req.lane_ids,
                       record_trace=req.record_trace)
    return result_from_runresult("simt_stack", r, req,
                                 time.perf_counter() - t0)


@register_mechanism(
    "dualpath", backend="numpy", tags=("related-work",),
    description="Dual-Path execution model (Rhu & Erez, HPCA'13), the "
                "paper's SS X comparison point")
def _run_dualpath(req: SimRequest) -> SimResult:
    cfg = req.resolved_cfg()
    t0 = time.perf_counter()
    r = run_dual_path(req.program, cfg, init_regs=req.init_regs,
                      init_mem=req.init_mem, lane_ids=req.lane_ids,
                      record_trace=req.record_trace)
    return result_from_runresult("dualpath", r, req,
                                 time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# vectorized JAX mechanism (lazy import: keep numpy-only paths jax-free)
# ---------------------------------------------------------------------------

PAD_QUANTUM = 32       # pad program length up to a multiple -> fewer recompiles


def padded_len(n: int) -> int:
    """The padding class of an ``n``-instruction program: its length rounded
    up to the next :data:`PAD_QUANTUM` multiple.  Programs in the same class
    compile to (and batch into) the same XLA executable; the service planner
    uses it as part of the execution signature."""
    return -(-n // PAD_QUANTUM) * PAD_QUANTUM


def _jax_result(req: SimRequest, state, wall_time_s: float,
                mechanism: str = "hanoi_jax",
                meta: "dict | None" = None) -> SimResult:
    from repro.core.hanoi import ERR_NO_FREE_BX, state_trace
    cfg = req.resolved_cfg()
    err_flags = int(state.error)
    error = ("WARPSYNC: no free Bx register"
             if err_flags & ERR_NO_FREE_BX else None)
    trace = tuple(state_trace(state)) if req.record_trace else ()
    fuel_left = int(state.fuel)
    return SimResult(
        mechanism=mechanism,
        status=classify_status(finished=int(state.finished),
                               full_mask=cfg.full_mask,
                               fuel_left=fuel_left, error=error),
        regs=np.asarray(state.regs), preds=np.asarray(state.preds),
        mem=np.asarray(state.mem), finished=int(state.finished),
        steps=int(state.steps), fuel_left=fuel_left, trace=trace,
        utilization=simd_utilization(list(trace), cfg.n_threads),
        error=error, wall_time_s=wall_time_s, meta=meta or {})


class _LruDict(OrderedDict):
    """A bounded mapping with LRU eviction and an eviction counter.

    The old ``functools.lru_cache(maxsize=None)`` / bare-dict pair grew
    without bound in a long-lived service process — one entry per distinct
    (cfg, majority_first, batch, pad-class) shape a tenant ever submitted.
    ``__setitem__`` evicts the least-recently-used entry past ``maxsize``;
    ``get`` refreshes recency.  Callers serialize access through
    ``_BATCH_CACHE_LOCK`` — the class itself is not thread-safe.
    """

    def __init__(self, maxsize: int):
        super().__init__()
        self.maxsize = int(maxsize)
        self.evictions = 0

    def get(self, key, default=None):
        try:
            self.move_to_end(key)
        except KeyError:
            return default
        return super().__getitem__(key)

    def __setitem__(self, key, value):
        super().__setitem__(key, value)
        self.move_to_end(key)
        while len(self) > self.maxsize:
            self.popitem(last=False)
            self.evictions += 1


#: Default capacities: executables dominate host memory, jit wrappers are
#: cheap but each fronts its own XLA trace cache, so both are bounded.
_EXEC_CACHE_CAPACITY = 256
_JIT_CACHE_CAPACITY = 64

_BATCH_CACHE_LOCK = threading.Lock()
_JITTED_RUNNERS = _LruDict(_JIT_CACHE_CAPACITY)

#: hits / misses are *executable*-cache counters: a miss means a fresh XLA
#: trace+compile happened in this process (the "re-trace" the warm-start
#: gate asserts to zero); a disk_hit means the persistent compile cache
#: supplied the executable without tracing.
_BATCH_STATS = {"hits": 0, "misses": 0, "disk_hits": 0, "trace_time_s": 0.0}


def _jitted_batch_runner(cfg, majority_first: bool):
    """One jitted vmap-over-(warps, programs) callable per (cfg,
    majority_first).  The jit boundary is essential for service throughput:
    a bare ``jax.vmap(one)`` re-traces the whole state machine on *every*
    batch call (slower than the per-request path, whose inner ``_run`` jit
    caches), whereas this callable re-traces only per new (batch size,
    padded length) shape and then replays the cached executable."""
    key = (cfg, bool(majority_first))
    with _BATCH_CACHE_LOCK:
        fn = _JITTED_RUNNERS.get(key)
    if fn is not None:
        return fn
    import jax
    from repro.core.hanoi import _run, init_state

    def one(prog, skip, reg, mem, lane):
        st = init_state(prog.shape[0], cfg, init_regs=reg, init_mem=mem,
                        lane_ids=lane)
        return _run(prog, st, skip, cfg, majority_first)

    fn = jax.jit(jax.vmap(one))
    with _BATCH_CACHE_LOCK:
        _JITTED_RUNNERS[key] = fn
    return fn


def _batch_arrays(reqs: Sequence[SimRequest], cfg, pad_len: int
                  ) -> tuple[np.ndarray, ...]:
    """``(progs, skips, regs, mems, lanes)`` operand arrays for one
    signature-homogeneous batch, programs padded with unreachable EXITs to
    ``pad_len``.  Shared by the hanoi_jax batch runner and the sm_jax
    per-warp phase."""
    from repro.core.isa import Op

    W = cfg.n_threads
    progs = np.zeros((len(reqs), pad_len, 8), np.int32)
    progs[:, :, 0] = int(Op.EXIT)                      # unreachable pad
    skips = np.zeros((len(reqs), pad_len), bool)       # hanoi: no oracle skips
    regs = np.zeros((len(reqs), W, cfg.n_regs), np.int32)
    mems = np.zeros((len(reqs), cfg.mem_size), np.int32)
    lanes = np.broadcast_to(np.arange(W, dtype=np.int32),
                            (len(reqs), W)).copy()
    for i, r in enumerate(reqs):
        p = np.asarray(r.program, np.int32)
        progs[i, :p.shape[0]] = p
        if r.init_regs is not None:
            regs[i] = np.asarray(r.init_regs, np.int32).reshape(W, cfg.n_regs)
        if r.init_mem is not None:
            mems[i] = np.asarray(r.init_mem, np.int32).reshape(cfg.mem_size)
        if r.lane_ids is not None:
            lanes[i] = np.asarray(r.lane_ids, np.int32).reshape(W)
    return progs, skips, regs, mems, lanes


# AOT-compiled executables keyed by (cfg, majority_first, batch, pad_len).
# Compilation happens exactly once per key, *outside* any request's timed
# window — first-call compile latency used to be amortized into the batch's
# per-request wall times, poisoning ServiceStats p50/p99 and bench numbers.
_COMPILED_BATCH = _LruDict(_EXEC_CACHE_CAPACITY)


def batch_cache_stats() -> dict:
    """Snapshot of the hanoi_jax batch-compilation caches.

    ``misses`` counts fresh XLA trace+compiles in this process (the
    "re-trace" events the warm-start gate asserts to zero); ``disk_hits``
    counts executables supplied by an installed persistent
    :mod:`~repro.engine.compile_cache` without tracing; ``trace_time_s``
    is the cumulative wall time spent tracing+compiling.
    """
    with _BATCH_CACHE_LOCK:
        return {**_BATCH_STATS,
                "entries": len(_COMPILED_BATCH),
                "capacity": _COMPILED_BATCH.maxsize,
                "evictions": (_COMPILED_BATCH.evictions
                              + _JITTED_RUNNERS.evictions)}


def reset_batch_caches() -> None:
    """Drop every in-memory compiled executable / jit wrapper and zero the
    counters — simulates a process restart for warm-start tests without
    actually respawning the interpreter."""
    with _BATCH_CACHE_LOCK:
        _COMPILED_BATCH.clear()
        _COMPILED_BATCH.evictions = 0
        _JITTED_RUNNERS.clear()
        _JITTED_RUNNERS.evictions = 0
        for k in _BATCH_STATS:
            _BATCH_STATS[k] = 0.0 if k == "trace_time_s" else 0


def set_batch_cache_capacity(executables: int | None = None,
                             runners: int | None = None) -> None:
    """Re-bound the in-memory caches (existing overflow evicts eagerly)."""
    with _BATCH_CACHE_LOCK:
        if executables is not None:
            _COMPILED_BATCH.maxsize = int(executables)
            while len(_COMPILED_BATCH) > _COMPILED_BATCH.maxsize:
                _COMPILED_BATCH.popitem(last=False)
                _COMPILED_BATCH.evictions += 1
        if runners is not None:
            _JITTED_RUNNERS.maxsize = int(runners)
            while len(_JITTED_RUNNERS) > _JITTED_RUNNERS.maxsize:
                _JITTED_RUNNERS.popitem(last=False)
                _JITTED_RUNNERS.evictions += 1


def _compiled_batch_exec(cfg, majority_first: bool, batch: int, pad_len: int):
    """``(compiled executable, fresh compile seconds | None)`` for one
    (cfg, majority_first, batch-size, padding-class) shape signature.

    Lookup order: in-memory LRU -> installed persistent compile cache
    (deserialized AOT executable, no trace) -> fresh AOT trace+compile
    (``jit(...).lower(...).compile()``), which is then offered back to the
    persistent cache.  An in-memory hit whose signature is missing from
    the installed cache's manifest is *adopted* (stored on the spot):
    executables compiled before the cache was installed are still hot
    traffic, and a warm start must replay them too.  Only the
    fresh-compile path returns a non-``None`` compile time —
    trace/compile latency is measured separately from execution so it
    never inflates request wall times.
    """
    from .compile_cache import installed_cache

    key = (cfg, bool(majority_first), int(batch), int(pad_len))
    with _BATCH_CACHE_LOCK:
        hit = _COMPILED_BATCH.get(key)
        if hit is not None:
            _BATCH_STATS["hits"] += 1
    if hit is not None:
        cache = installed_cache()
        if cache is not None and not cache.has(
                "hanoi_jax", cfg, majority_first, batch, pad_len):
            # compiled before the cache was installed: adopt it, so the
            # signature is hot in the manifest and warm starts replay it
            cache.store_executable("hanoi_jax", cfg, majority_first,
                                   batch, pad_len, hit)
        return hit, None

    cache = installed_cache()
    if cache is not None:
        compiled = cache.load_executable("hanoi_jax", cfg, majority_first,
                                         batch, pad_len)
        if compiled is not None:
            with _BATCH_CACHE_LOCK:
                _BATCH_STATS["disk_hits"] += 1
                _COMPILED_BATCH[key] = compiled
            return compiled, None

    import jax
    import jax.numpy as jnp

    W = cfg.n_threads
    sds = jax.ShapeDtypeStruct
    t0 = time.perf_counter()
    compiled = _jitted_batch_runner(cfg, majority_first).lower(
        sds((batch, pad_len, 8), jnp.int32),
        sds((batch, pad_len), jnp.bool_),
        sds((batch, W, cfg.n_regs), jnp.int32),
        sds((batch, cfg.mem_size), jnp.int32),
        sds((batch, W), jnp.int32)).compile()
    compile_s = time.perf_counter() - t0
    with _BATCH_CACHE_LOCK:
        _BATCH_STATS["misses"] += 1
        _BATCH_STATS["trace_time_s"] += compile_s
        _COMPILED_BATCH[key] = compiled
    if cache is not None:
        cache.store_executable("hanoi_jax", cfg, majority_first, batch,
                               pad_len, compiled, compile_s)
    return compiled, compile_s


def _run_hanoi_jax_batch(reqs: Sequence[SimRequest]) -> list[SimResult]:
    """Native batched execution: vmap over warps AND over (padded) programs.

    All requests must share cfg / majority_first / active0=None (the
    planner's execution signature guarantees it before dispatching here).
    Programs of different lengths are padded with unreachable EXITs to one
    shape so a single compiled executable serves the whole batch.

    Wall-time accounting: ``wall_time_s`` is execution-only, amortized per
    request.  A fresh XLA compile (first batch per shape signature) is
    measured separately and stamped as ``meta["compile_time_s"]`` on that
    batch's results — it never inflates latency percentiles.
    """
    import jax
    import jax.numpy as jnp

    cfg = reqs[0].resolved_cfg()
    majority_first = reqs[0].majority_first
    L = padded_len(max(int(np.asarray(r.program).shape[0]) for r in reqs))
    progs, skips, regs, mems, lanes = _batch_arrays(reqs, cfg, L)

    compiled, compile_s = _compiled_batch_exec(cfg, majority_first,
                                               len(reqs), L)
    t0 = time.perf_counter()
    states = compiled(jnp.asarray(progs), jnp.asarray(skips),
                      jnp.asarray(regs), jnp.asarray(mems),
                      jnp.asarray(lanes))
    jax.block_until_ready(states.regs)
    wall = (time.perf_counter() - t0) / max(1, len(reqs))
    meta = {"compile_time_s": compile_s} if compile_s is not None else None
    per_warp = [jax.tree_util.tree_map(lambda x, i=i: x[i], states)
                for i in range(len(reqs))]
    return [_jax_result(r, st, wall, meta=meta)
            for r, st in zip(reqs, per_warp)]


@register_mechanism(
    "hanoi_jax", backend="jax",
    batch_runner=_run_hanoi_jax_batch, tags=("paper", "vectorized"),
    description="Hanoi as a JIT-compiled, vmap-batched JAX state machine "
                "(TPU-native); bit-identical to the numpy reference. "
                "Ignores bsync_skip_pcs — drop-in for 'hanoi'; use the "
                "low-level run_hanoi_jax for oracle-mode batches")
def _run_hanoi_jax(req: SimRequest) -> SimResult:
    from repro.core.hanoi import run_hanoi_jax
    cfg = req.resolved_cfg()
    t0 = time.perf_counter()
    state = run_hanoi_jax(
        req.program, cfg, init_regs=req.init_regs, init_mem=req.init_mem,
        lane_ids=req.lane_ids, active0=req.active0,
        majority_first=req.majority_first,
        pad_to=padded_len(int(np.asarray(req.program).shape[0])))
    import jax
    jax.block_until_ready(state.regs)
    return _jax_result(req, state, time.perf_counter() - t0)
