"""The Simulator façade: one entry point over every registered mechanism.

``Simulator.run`` executes one request, ``run_batch`` many (vmap-batched on
the JAX engine; sequential — or opt-in thread-pooled — on the numpy
engines), and ``compare`` runs
the same programs under several mechanisms and reports per-pair trace
discrepancy and IPC deltas — the paper's Fig 9 / Fig 10 evaluation as a
one-call API.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Iterable, Sequence

import numpy as np

from repro.core.isa import MachineConfig
from repro.core.timing import TimingConfig, ipc_delta, simulate
from repro.core.trace import discrepancy

from .registry import Mechanism, get_mechanism
from .sinks import (TraceSink, feed_result, next_sm_cell_id, run_meta,
                    sm_run_meta, timing_meta)
from .types import SimRequest, SimResult, SmResult

ProgramLike = Any    # np.ndarray | Benchmark | SimRequest


def as_request(program: ProgramLike, cfg: MachineConfig | None = None,
               **kw) -> SimRequest:
    """Coerce an ndarray / Benchmark / SimRequest into a SimRequest.

    A SimRequest passes through untouched unless ``cfg`` or request kwargs
    are given, in which case they override the corresponding fields (so
    ``run(req, fuel=3)`` re-budgets an existing request instead of silently
    ignoring the override).
    """
    if isinstance(program, SimRequest):
        if cfg is None and not kw:
            return program
        if cfg is not None:
            kw.setdefault("cfg", cfg)
        return dataclasses.replace(program, **kw)
    if hasattr(program, "program"):          # programs.Benchmark duck-type
        b = program
        fields = dict(program=np.asarray(b.program),
                      cfg=cfg or MachineConfig(),
                      init_mem=getattr(b, "init_mem", None),
                      bsync_skip_pcs=tuple(getattr(b, "skip_bsync_pcs", ())),
                      name=getattr(b, "name", ""))
        fields.update(kw)                    # overrides win, never collide
        return SimRequest(**fields)
    return SimRequest(program=np.asarray(program),
                      cfg=cfg or MachineConfig(), **kw)


@dataclass(frozen=True)
class CompareRow:
    """One (program, mechanism pair) comparison cell."""

    program: str
    mech_a: str
    mech_b: str
    discrepancy: float           # Levenshtein(trace_a, trace_b)/len(trace_b)
    ipc_a: float
    ipc_b: float
    ipc_delta: float             # (ipc_a - ipc_b) / ipc_b
    util_a: float
    util_b: float
    status_a: str
    status_b: str
    trace_len_a: int
    trace_len_b: int

    @property
    def discrepancy_pct(self) -> float:
        return 100.0 * self.discrepancy

    @property
    def ipc_delta_pct(self) -> float:
        return 100.0 * self.ipc_delta


@dataclass(frozen=True)
class CompareReport:
    """All pairwise rows plus the per-mechanism raw results.

    ``timing_results`` maps ``(program, mechanism)`` to the
    :class:`~repro.core.timing.TimingResult` behind the row's IPC numbers
    (empty when ``timing=False``) — under ``timing="cycle"`` that is where
    the per-schedule stall breakdown lives.
    """

    mechanisms: tuple[str, ...]
    rows: tuple[CompareRow, ...]
    results: dict = field(default_factory=dict)   # (program, mech) -> SimResult
    timing_results: dict = field(default_factory=dict)

    def pair(self, mech_a: str, mech_b: str) -> list[CompareRow]:
        """Rows for the ordered pair; raises KeyError for a pair that was
        never computed (a typo or swapped order would otherwise read as a
        perfect 0.0-discrepancy match)."""
        rows = [r for r in self.rows
                if r.mech_a == mech_a and r.mech_b == mech_b]
        if not rows:
            known = sorted({(r.mech_a, r.mech_b) for r in self.rows})
            raise KeyError(f"no comparison rows for pair ({mech_a!r}, "
                           f"{mech_b!r}); computed pairs: {known}")
        return rows

    def mean_discrepancy(self, mech_a: str, mech_b: str) -> float:
        return float(np.mean([r.discrepancy
                              for r in self.pair(mech_a, mech_b)]))

    def mean_abs_ipc_delta(self, mech_a: str, mech_b: str) -> float:
        return float(np.mean([abs(r.ipc_delta)
                              for r in self.pair(mech_a, mech_b)]))


class Simulator:
    """Façade over the mechanism registry.

    >>> sim = Simulator("hanoi")
    >>> res = sim.run(program, cfg=MachineConfig(n_threads=8))
    >>> res.status
    <SimStatus.OK: 'ok'>

    A default mechanism is chosen at construction; ``run``/``run_batch``
    accept ``mechanism=`` overrides, and ``compare`` takes an explicit list.
    A :class:`~repro.engine.sinks.TraceSink` attached at construction (or
    per call) receives every normalized trace.

    ``max_workers`` opts numpy-mechanism batches into a thread pool.  The
    default (None) runs them sequentially: the reference interpreters are
    per-slot Python loops over tiny arrays, so they hold the GIL and a pool
    only adds contention — measured slower than sequential on the paper
    suite.  The knob exists for mechanisms that genuinely release the GIL.
    """

    def __init__(self, mechanism: str = "hanoi", *,
                 sink: TraceSink | None = None,
                 max_workers: int | None = None,
                 verify: "bool | str" = False) -> None:
        self._default = get_mechanism(mechanism).name   # validate eagerly
        self._sink = sink
        self._max_workers = max_workers
        self._verify = verify

    @property
    def mechanism(self) -> str:
        return self._default

    def _check(self, reqs: "Iterable[SimRequest]",
               verify: "bool | str | None") -> None:
        """Static pre-admission verification (:mod:`repro.analysis`).

        ``verify=True`` raises
        :class:`~repro.analysis.StaticAnalysisError` for programs with
        ``error``-level diagnostics before any engine runs; ``"strict"``
        also fails on warnings.  Default off: the façade is also the tool
        used to *study* broken programs (the volta_itps structural-deadlock
        experiments run them on purpose) — the service flips the default.
        """
        verify = self._verify if verify is None else verify
        if not verify:
            return
        from repro.analysis import verify_program   # lazy: keep import light
        for req in reqs:
            verify_program(req.program, req.resolved_cfg(), name=req.name,
                           strict=(verify == "strict"))

    @staticmethod
    def _synthesize(reqs: "list[SimRequest]") -> "list[SimRequest]":
        """Rewrite each request's program through the annotation
        synthesizer (:func:`repro.analysis.synthesize_annotations`):
        BSSY/BSYNC regions for unannotated divergent branches, BMOV
        spills past the Bx file, YIELD in spin-loops.

        Raises :class:`repro.analysis.TransformError` when a program
        cannot be safely rewritten (CALL/RET-crossing regions,
        unstructured joins).  Note ``bsync_skip_pcs`` is *not* remapped —
        a request combining ``synthesize=True`` with oracle skip-pcs
        would point at stale pcs, so pick one or the other.
        """
        from repro.analysis import synthesize_annotations  # lazy: light path
        out = []
        for req in reqs:
            syn = synthesize_annotations(req.program, req.resolved_cfg(),
                                         name=req.name)
            out.append(dataclasses.replace(req, program=syn.program)
                       if syn.changed else req)
        return out

    # -- single run ---------------------------------------------------------

    def run(self, program: ProgramLike, cfg: MachineConfig | None = None, *,
            mechanism: str | None = None, sink: TraceSink | None = None,
            verify: "bool | str | None" = None, synthesize: bool = False,
            **request_kw) -> SimResult:
        mech = get_mechanism(mechanism or self._default)
        req = as_request(program, cfg, **request_kw)
        if synthesize:
            [req] = self._synthesize([req])
        self._check([req], verify)
        result = mech(req)
        self._feed_sink(sink or self._sink, mech, req, result)
        return result

    # -- batched run --------------------------------------------------------

    def run_batch(self, programs: Sequence[ProgramLike],
                  cfg: MachineConfig | None = None, *,
                  mechanism: str | None = None, sink: TraceSink | None = None,
                  verify: "bool | str | None" = None,
                  synthesize: bool = False,
                  **request_kw) -> list[SimResult]:
        """Run many requests under one mechanism, preserving order.

        Grouping and routing are delegated to the service planner
        (:mod:`repro.service.planner`) — the same dispatch path the
        queue-fed :class:`~repro.service.SimulationService` uses: requests
        are grouped by execution signature, every signature-homogeneous
        group with a native ``batch_runner`` executes as one vmap batch
        (a *mixed* batch no longer forfeits native execution for its
        homogeneous sub-groups), and the per-request remainder runs
        sequentially unless the Simulator was built with ``max_workers``
        (see class docstring).
        """
        mech = get_mechanism(mechanism or self._default)
        reqs = [as_request(p, cfg, **request_kw) for p in programs]
        if not reqs:
            return []
        if synthesize:
            reqs = self._synthesize(reqs)
        self._check(reqs, verify)
        from repro.service.planner import execute_plan   # lazy: no cycle at
        results = execute_plan(mech, reqs,               # package import time
                               max_workers=self._max_workers)
        for req, res in zip(reqs, results):
            self._feed_sink(sink or self._sink, mech, req, res)
        return results

    # -- per-SM multi-warp execution ----------------------------------------

    def run_sm(self, programs: "ProgramLike | Sequence[ProgramLike]",
               cfg: MachineConfig | None = None, *,
               n_warps: int | None = None,
               inner: str | None = None,
               policy: str = "round_robin",
               timing_cfg: "TimingConfig | object" = TimingConfig(),
               sm_mechanism: str = "sm_interleave",
               sink: TraceSink | None = None,
               **request_kw) -> SmResult:
        """Run N warps on one SM through a single-warp mechanism.

        ``programs`` is either one program (replicated across ``n_warps``
        identical warps, default 4) or a sequence with one entry per warp
        (heterogeneous SMs — different programs and/or memory images; any
        sized sequence works, including a 3-D ndarray of stacked programs).
        Each warp executes under ``inner`` (default: this Simulator's
        mechanism, or ``hanoi`` if that is a composite SM mechanism), then
        the per-warp traces are time-multiplexed through the SM issue
        scheduler under ``policy`` (``round_robin`` /
        ``greedy_then_oldest`` / ``oldest_first``).  The returned
        :class:`~repro.engine.types.SmResult` carries the per-warp
        ``SimResult``s (and their ``SimRequest``s) plus the interleaved
        ``(warp, pc, mask)`` SM trace and its latency-aware cycle count.

        ``sm_mechanism`` selects the SM engine: ``"sm_interleave"``
        (default — Python scheduler, any single-warp ``inner``) or
        ``"sm_jax"`` (the whole cell as one ``jit(vmap)`` lane-parallel
        program, bit-identical traces, ``inner`` limited to the hanoi
        engines).

        A sink receives each warp as one normalized run whose begin event
        is the SM variant of the replay meta
        (:func:`~repro.engine.sinks.sm_run_meta`: warp index, cell width,
        policy, cell id, full replay payload) — SM-cell archives replay
        offline exactly like single-warp ones.
        """
        from .mechanisms.sm import build_sm_result, per_warp_programs
        if sm_mechanism not in ("sm_interleave", "sm_jax"):
            raise ValueError(f"sm_mechanism must be 'sm_interleave' or "
                             f"'sm_jax', got {sm_mechanism!r}")
        if inner is None:
            inner_name = self._default
            if "composite" in get_mechanism(inner_name).tags:
                inner_name = "hanoi"     # default fallback only:
        else:                            # nesting is an error below
            inner_mech = get_mechanism(inner)
            inner_name = inner_mech.name
            if "composite" in inner_mech.tags:
                raise ValueError("inner must be a single-warp mechanism, "
                                 f"not the composite {inner_name!r}")
        per_warp = per_warp_programs(programs, n_warps)
        if not per_warp:
            raise ValueError("run_sm needs at least one warp")
        reqs = [as_request(p, cfg, **request_kw) for p in per_warp]
        if sm_mechanism == "sm_jax":
            from .mechanisms.sm_jax import run_cells
            sm = run_cells([reqs], policy=policy, timing_cfg=timing_cfg,
                           inner_label=inner_name)[0]
            results: Sequence[SimResult] = sm.warps
        else:
            # dispatch through the shared planner (the run_batch path) but
            # feed the sink ourselves: warps of an SM cell archive under
            # sm_run_meta, not the single-warp run_meta run_batch stamps
            from repro.service.planner import execute_plan  # lazy: no cycle
            mech = get_mechanism(inner_name)
            t0 = time.perf_counter()
            results = execute_plan(mech, reqs,
                                   max_workers=self._max_workers)
            wall = time.perf_counter() - t0
            sm = build_sm_result(reqs, results, inner=inner_name,
                                 policy=policy, timing_cfg=timing_cfg,
                                 wall_time_s=wall)
        out_sink = sink or self._sink
        if out_sink is not None:
            cell = next_sm_cell_id()
            tmeta = timing_meta(sm)
            for w, (req, res) in enumerate(zip(reqs, results)):
                feed_result(out_sink, res,
                            sm_run_meta(inner_name, req, warp=w,
                                        n_warps=len(reqs), policy=sm.policy,
                                        cell=cell, timing=tmeta))
        return sm

    # -- mechanism comparison (the paper's evaluation as an API) ------------

    def compare(self, mechanisms: "str | Sequence[str]",
                programs: Iterable[ProgramLike] | None = None,
                cfg: MachineConfig | None = None, *,
                baseline: str | None = None,
                pairs: Sequence[tuple[str, str]] | None = None,
                timing: "bool | str" = True,
                timing_warps: int = 4,
                timing_cfg: "TimingConfig | object" = TimingConfig(),
                **request_kw) -> CompareReport:
        """Run ``programs`` under each mechanism; diff every pair.

        For each program and ordered pair ``(a, b)`` the report carries the
        paper's two metrics: control-flow trace discrepancy (normalized
        Levenshtein, ``b`` as the reference — Fig 9) and the relative IPC
        delta from the GTO timing model (Fig 10, with ``timing_warps``
        identical warps per scheduler).  ``pairs`` defaults to all ordered
        pairs of ``mechanisms``.

        ``timing`` selects the IPC model:

        * ``True`` / ``"trace"`` — the legacy trace-conservative uniform
          model (every instruction depends on its predecessor);
        * ``"cycle"`` — the event-driven cycle engine (:mod:`repro.timing`)
          with per-warp register scoreboards, the Fig 10 configuration the
          paper's 0.19%-IPC claim is judged under; per-schedule stall
          breakdowns land in ``report.timing_results``.  ``timing_cfg`` may
          be a :class:`~repro.timing.CycleConfig` to also pick memory
          distributions / dual issue (a plain :class:`TimingConfig` is
          lifted onto the scoreboard model);
        * ``False`` — skip the (pure-Python, per-trace-slot) timing model
          for callers that only consume discrepancy/utilization: IPC fields
          come back NaN and utilization is taken directly from the traces
          (the same value the timing model would report).

        Conveniences: ``mechanisms`` may be a single name, ``baseline``
        appends a reference mechanism and restricts ``pairs`` to
        ``(mech, baseline)``, and ``programs=None`` defaults to the paper's
        benchmark suite — so ``compare("volta_itps",
        baseline="turing_oracle")`` is a complete evaluation call.
        """
        if isinstance(timing, str) and timing not in ("trace", "cycle"):
            raise ValueError(f"timing must be True/False/'trace'/'cycle', "
                             f"got {timing!r}")
        if isinstance(mechanisms, str):
            mechanisms = [mechanisms]
        names = [get_mechanism(m).name for m in mechanisms]
        if baseline is not None:
            base = get_mechanism(baseline).name
            if pairs is None:
                pairs = [(m, base) for m in names if m != base]
            if base not in names:
                names.append(base)
        if programs is None:
            from repro.core.programs import make_suite
            if cfg is None:     # the paper's evaluation config, not the
                cfg = MachineConfig(n_threads=32, mem_size=256,
                                    max_steps=60_000)   # 4096-fuel default
            programs = make_suite(cfg)
        reqs = [as_request(p, cfg, **request_kw) for p in programs]
        # unique program ids (anonymous ndarrays would otherwise collide)
        pids: list[str] = []
        for i, req in enumerate(reqs):
            pid = req.name or f"prog{i}"
            if pid in pids:
                pid = f"{pid}#{i}"
            pids.append(pid)
        results: dict[tuple[str, str], SimResult] = {}
        for mech_name in names:
            for pid, res in zip(pids,
                                self.run_batch(reqs, mechanism=mech_name)):
                results[(pid, mech_name)] = res

        if pairs is None:
            pairs = [(a, b) for a, b in itertools.permutations(names, 2)]
        rows = []
        timing_cache: dict[tuple[str, str], Any] = {}
        if timing == "cycle":
            from repro.timing import CycleConfig
            run_cfg: Any = CycleConfig.from_timing(timing_cfg,
                                                   scoreboard=True)
        else:
            run_cfg = timing_cfg

        def timed(pid: str, req: SimRequest, mech_name: str):
            key = (pid, mech_name)
            if key not in timing_cache:
                res = results[key]
                timing_cache[key] = simulate(
                    [list(res.trace)] * timing_warps, req.program,
                    req.resolved_cfg().n_threads, run_cfg)
            return timing_cache[key]

        nan = float("nan")
        for pid, req in zip(pids, reqs):
            for a, b in pairs:
                ra, rb = results[(pid, a)], results[(pid, b)]
                if timing:
                    ta, tb = timed(pid, req, a), timed(pid, req, b)
                    ipc_a, ipc_b = ta.ipc, tb.ipc
                    delta = ipc_delta(ta, tb)
                    util_a, util_b = ta.simd_utilization, tb.simd_utilization
                else:
                    ipc_a = ipc_b = delta = nan
                    util_a, util_b = ra.utilization, rb.utilization
                rows.append(CompareRow(
                    program=pid, mech_a=a, mech_b=b,
                    discrepancy=discrepancy(list(ra.trace), list(rb.trace)),
                    ipc_a=ipc_a, ipc_b=ipc_b,
                    ipc_delta=delta,
                    util_a=util_a, util_b=util_b,
                    status_a=ra.status.value, status_b=rb.status.value,
                    trace_len_a=len(ra.trace), trace_len_b=len(rb.trace)))
        return CompareReport(mechanisms=tuple(names), rows=tuple(rows),
                             results=results, timing_results=timing_cache)

    # -- internals ----------------------------------------------------------

    @staticmethod
    def _feed_sink(sink: TraceSink | None, mech: Mechanism,
                   req: SimRequest, result: SimResult) -> None:
        if sink is None:       # don't build the replay payload just to
            return             # throw it away — run/run_batch hot path
        feed_result(sink, result, run_meta(mech.name, req))
