"""``sm_interleave`` — a per-SM model: N warps through one issue scheduler.

A streaming multiprocessor runs many warps; its scheduler picks one ready
warp per slot.  Warps are architecturally independent in this simulator
(each request carries its own register file and memory image), so the SM
model composes exactly: every warp executes to completion under any
registered *single-warp* mechanism, and the SM scheduler time-multiplexes
their control-flow traces into one latency-aware issue schedule — the same
trace-driven approach as :mod:`repro.core.timing`, generalized to
per-warp programs, pluggable policies, and a full SM-level trace.

Policies:

* ``round_robin``        — rotate over ready warps every slot (fair,
  latency-hiding, worst locality);
* ``greedy_then_oldest`` — GTO (the paper's Table III scheduler): stay on
  the current warp while it is ready, else switch to the oldest ready warp.

Request options (``SimRequest.meta``) for the registered mechanism, which
replicates one request across identical warps:

* ``sm_warps``  (int, default 4)            — warps per SM;
* ``sm_inner``  (str, default ``"hanoi"``)  — single-warp mechanism name;
* ``sm_policy`` (str, default ``"round_robin"``).

Heterogeneous warps (different programs / memory images per warp) go
through :meth:`repro.engine.Simulator.run_sm`, which returns the full
:class:`~repro.engine.types.SmResult`; the registered mechanism exposes the
same model through the universal ``SimResult`` schema (warp-0 architectural
state, SM-level trace, ``meta["sm"]`` holding the aggregate) so
``run_batch`` / ``compare`` work unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core.timing import TimingConfig
from repro.timing import CycleConfig, CycleResult, schedule_cycle
from repro.timing.policies import POLICY_NAMES, resolve_policy_name

from ..registry import get_mechanism, register_mechanism
from ..types import SimRequest, SimResult, SmResult, worst_status

# the SM scheduler arbitrates through the shared repro.timing policy layer,
# so its policy names are exactly the registered issue policies
SM_POLICIES = POLICY_NAMES

DEFAULT_WARPS = 4
DEFAULT_INNER = "hanoi"
DEFAULT_POLICY = "round_robin"


def interleave_cycle(traces: Sequence[Sequence[tuple[int, int]]],
                     programs: Sequence[np.ndarray],
                     policy: str = DEFAULT_POLICY,
                     tcfg: "TimingConfig | CycleConfig" = TimingConfig(),
                     ) -> CycleResult:
    """Schedule per-warp traces through one SM issue port, cycle-level.

    Thin façade over :func:`repro.timing.schedule_cycle` — the one issue
    engine the Fig 10 IPC model also uses — passing full program rows so a
    scoreboard :class:`~repro.timing.CycleConfig` gets real register
    dependences.  A legacy :class:`TimingConfig` runs the exact-compat
    trace-conservative mode.
    """
    policy = resolve_policy_name(policy)
    return schedule_cycle([list(t) for t in traces],
                          [np.asarray(p) for p in programs],
                          policy, CycleConfig.from_timing(tcfg))


def interleave_traces(traces: Sequence[Sequence[tuple[int, int]]],
                      programs: Sequence[np.ndarray],
                      policy: str = DEFAULT_POLICY,
                      tcfg: "TimingConfig | CycleConfig" = TimingConfig(),
                      ) -> tuple[list[tuple[int, int, int]], int, int]:
    """Legacy-shaped façade over :func:`interleave_cycle`.

    Returns ``(sm_trace, cycles, thread_instructions)`` where ``sm_trace``
    is the issue order as ``(warp, pc, mask)``; callers that want the stall
    breakdown use :func:`interleave_cycle` directly.
    """
    res = interleave_cycle(traces, programs, policy, tcfg)
    return res.order, res.cycles, res.thread_instructions


def build_sm_result(reqs: Sequence[SimRequest],
                    results: Sequence[SimResult],
                    *,
                    inner: str,
                    policy: str = DEFAULT_POLICY,
                    timing_cfg: "TimingConfig | CycleConfig" = TimingConfig(),
                    wall_time_s: float = 0.0) -> SmResult:
    """Assemble the SM aggregate from per-warp requests and results."""
    sched = interleave_cycle(
        [list(r.trace) for r in results],
        [np.asarray(q.program) for q in reqs], policy, timing_cfg)
    width = max(q.resolved_cfg().n_threads for q in reqs)
    steps = len(sched.order)
    return SmResult(
        mechanism="sm_interleave", inner=inner,
        policy=resolve_policy_name(policy),
        warps=tuple(results), sm_trace=tuple(sched.order),
        status=worst_status([r.status for r in results]),
        steps=steps, cycles=sched.cycles,
        thread_instructions=sched.thread_instructions,
        utilization=sched.thread_instructions / max(1, steps * width),
        requests=tuple(reqs),
        wall_time_s=wall_time_s,
        busy_cycles=sched.busy_cycles,
        issue_stall_cycles=sched.issue_stall_cycles,
        scoreboard_stall_cycles=sched.scoreboard_stall_cycles,
        memory_stall_cycles=sched.memory_stall_cycles)


def _sequence_len(programs) -> "int | None":
    """``len()`` of a *sequence of programs*, or ``None`` for one program.

    A single program is a 2-D instruction-row table (any ndarray of
    ``ndim != 3``), a ``Benchmark`` duck-type, or a ``SimRequest``; a
    sequence is a list/tuple, a 3-D ndarray of stacked row tables, or any
    other sized container.  Unsized iterables (generators) raise instead of
    silently desynchronizing the façade's cell width from the service's
    per-warp stats accounting.
    """
    if isinstance(programs, (list, tuple)):
        return len(programs)
    if isinstance(programs, np.ndarray):
        return int(programs.shape[0]) if programs.ndim == 3 else None
    if hasattr(programs, "program"):     # SimRequest / Benchmark duck-type
        return None
    if isinstance(programs, (str, bytes)):
        raise TypeError("programs must be a program or a sequence of "
                        f"programs, not {type(programs).__name__}")
    if hasattr(programs, "__len__"):
        return len(programs)
    if hasattr(programs, "__iter__"):
        raise TypeError(
            "programs must be a single program or a *sized* sequence of "
            "programs; got an unsized iterable — materialize it as a list")
    return None


def warp_count(programs, n_warps: "int | None") -> int:
    """Cell width for ``run_sm``/``submit_sm`` arguments — the ONE
    derivation both the façade and the service's warp-level stats use:
    one warp per entry of a program sequence (any sized sequence, including
    a 3-D ndarray of stacked programs), else ``n_warps``
    (default :data:`DEFAULT_WARPS`)."""
    n = _sequence_len(programs)
    if n is not None:
        return n
    return DEFAULT_WARPS if n_warps is None else int(n_warps)


def per_warp_programs(programs, n_warps: "int | None") -> list:
    """Normalize ``run_sm``/``submit_sm`` ``programs`` into one entry per
    warp, consistently with :func:`warp_count` (a conflict between an
    explicit ``n_warps`` and a sequence's own length is an error)."""
    n = _sequence_len(programs)
    if n is None:
        return [programs] * warp_count(programs, n_warps)
    if n_warps is not None and int(n_warps) != n:
        raise ValueError(f"n_warps={n_warps} conflicts with {n} "
                         f"per-warp programs")
    if isinstance(programs, np.ndarray):
        return [programs[i] for i in range(n)]
    return list(programs)


def _sm_options(req: SimRequest) -> tuple[int, str, str]:
    n_warps = int(req.meta.get("sm_warps", DEFAULT_WARPS))
    if n_warps < 1:
        raise ValueError(f"sm_warps must be >= 1, got {n_warps}")
    inner = str(req.meta.get("sm_inner", DEFAULT_INNER))
    policy = str(req.meta.get("sm_policy", DEFAULT_POLICY))
    return n_warps, inner, policy


@register_mechanism(
    "sm_interleave", backend="numpy", tags=("sm", "multi-warp", "composite"),
    description="per-SM model: time-multiplexes N identical warps through "
                "any registered single-warp mechanism (meta: sm_warps, "
                "sm_inner, sm_policy); SimResult carries warp-0 state, the "
                "interleaved SM trace, and meta['sm'] = SmResult")
def _run_sm_interleave(req: SimRequest) -> SimResult:
    n_warps, inner_name, policy = _sm_options(req)
    inner = get_mechanism(inner_name)
    if "composite" in inner.tags or inner.name == "sm_interleave":
        raise ValueError("sm_inner must be a single-warp mechanism, "
                         f"not the composite {inner.name!r}")
    stripped = {k: v for k, v in req.meta.items()
                if not k.startswith("sm_")}
    t0 = time.perf_counter()
    reqs = [dataclasses.replace(req, meta=stripped,
                                name=f"{req.name or 'warp'}/w{w}")
            for w in range(n_warps)]
    # dispatch the warps through the shared planner, not a serial Python
    # loop: an inner mechanism with a native batch_runner (sm_inner=
    # "hanoi_jax") executes the whole homogeneous cell as ONE cached
    # jit(vmap) batch call
    from repro.service.planner import execute_plan   # lazy: no import cycle
    results = execute_plan(inner, reqs)
    sm = build_sm_result(reqs, results, inner=inner.name, policy=policy,
                         wall_time_s=time.perf_counter() - t0)
    w0 = results[0]
    return SimResult(
        mechanism="sm_interleave", status=sm.status,
        regs=w0.regs, preds=w0.preds, mem=w0.mem, finished=w0.finished,
        steps=sm.steps, fuel_left=min(r.fuel_left for r in results),
        trace=tuple((pc, mask) for _, pc, mask in sm.sm_trace),
        utilization=sm.utilization,
        error=next((r.error for r in results if r.error), None),
        wall_time_s=sm.wall_time_s, meta={"sm": sm})


__all__ = ["SM_POLICIES", "DEFAULT_WARPS", "DEFAULT_INNER", "DEFAULT_POLICY",
           "interleave_cycle", "interleave_traces", "build_sm_result",
           "warp_count", "per_warp_programs"]
