"""Registered mechanism plugins beyond the built-in adapter family.

Each submodule registers one mechanism with the
:mod:`repro.engine.registry` at import time:

* :mod:`.volta`  — ``volta_itps``: Volta-style independent thread
  scheduling (per-thread PCs, no reconvergence stack, greedy convergence
  optimizer with a forward-progress guarantee);
* :mod:`.sm`     — ``sm_interleave``: a per-SM model that time-multiplexes
  N warps through any registered single-warp mechanism under a pluggable
  warp-scheduler policy;
* :mod:`.sm_jax` — ``sm_jax``: the same SM model as one ``jit(vmap)``
  lane-parallel program (warps on the cached hanoi batch executable, the
  issue policy as an argmin over a priority vector), SM traces
  bit-identical to ``sm_interleave``.

Importing this package (done by ``repro.engine``) registers all of them.
"""
from . import volta, sm, sm_jax  # noqa: F401  (import side effect:
#                                  registration)

from .sm import (SM_POLICIES, build_sm_result, interleave_cycle,  # noqa: F401
                 interleave_traces)
from .sm_jax import run_cells  # noqa: F401
from .volta import run_volta_itps  # noqa: F401

__all__ = ["SM_POLICIES", "build_sm_result", "interleave_cycle",
           "interleave_traces", "run_cells",
           "run_volta_itps"]
