"""Registered mechanism plugins beyond the built-in adapter family.

Each submodule registers one mechanism with the
:mod:`repro.engine.registry` at import time:

* :mod:`.volta`  — ``volta_itps``: Volta-style independent thread
  scheduling (per-thread PCs, no reconvergence stack, greedy convergence
  optimizer with a forward-progress guarantee);
* :mod:`.sm`     — ``sm_interleave``: a per-SM model that time-multiplexes
  N warps through any registered single-warp mechanism under a pluggable
  warp-scheduler policy.

Importing this package (done by ``repro.engine``) registers both.
"""
from . import volta, sm  # noqa: F401  (import side effect: registration)

from .sm import (SM_POLICIES, build_sm_result, interleave_cycle,  # noqa: F401
                 interleave_traces)
from .volta import run_volta_itps  # noqa: F401

__all__ = ["SM_POLICIES", "build_sm_result", "interleave_cycle",
           "interleave_traces",
           "run_volta_itps"]
