"""``sm_jax`` — the whole SM as one ``jit(vmap)`` lane-parallel program.

``sm_interleave`` time-multiplexes warps in Python, one issue slot per
iteration of :func:`repro.timing.schedule_cycle`.  This module reformulates
the same SM model as a lane-parallel state machine so it runs in array
land end to end, in two fused device programs:

1. **warp phase** — every warp of every cell executes the paper's Hanoi
   mechanism through the *same* cached ``jit(vmap)`` batch executable the
   ``hanoi_jax`` service path uses (:func:`repro.engine.adapters.
   _compiled_batch_exec`, one row per warp, programs padded to their
   :func:`~repro.engine.adapters.padded_len` class);
2. **scheduler phase** — one ``lax.while_loop`` steps an entire N-warp SM:
   per-warp trace cursors, completion times and memory-blocked flags are
   vectors, warp readiness is a boolean vector, and the issue policy is an
   ``argmin`` over the :func:`repro.timing.policies.priority_keys` vector
   (``greedy_then_oldest`` / ``round_robin`` / ``oldest_first`` — the same
   formulation the Python policy classes expose, pinned by a drift test).
   ``jax.vmap`` lifts the cell scheduler over a whole *grid* of SM cells,
   so a batch of cells is one compiled call.

The schedule reproduces :func:`repro.timing.schedule_cycle`'s
trace-conservative single-issue fixed-latency mode **bit-for-bit**: the
``(warp, pc, mask)`` SM trace, cycle count, and the busy/issue/scoreboard/
memory stall taxonomy all match ``sm_interleave`` exactly (the conformance
suite and ``bench_sm.py --smoke`` gate this).  Scoreboard mode, dual issue
and stochastic memory models remain ``sm_interleave``'s domain — requests
asking for them are rejected with a pointer, never silently approximated.

Request options mirror ``sm_interleave`` (``sm_warps`` / ``sm_policy``);
``sm_inner`` must name a Hanoi engine (``hanoi`` or ``hanoi_jax`` — the
warp phase *is* the jitted Hanoi lane step, bit-identical to both).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core.isa import ATOMIC_OPS, F_OP, MEMORY_OPS, Op
from repro.core.timing import TimingConfig
from repro.timing import CycleConfig
from repro.timing.policies import POLICY_NAMES, resolve_policy_name
from repro.timing.sm_model import _CONTROL_LAT_OPS

from ..adapters import _batch_arrays, _compiled_batch_exec, _jax_result, \
    padded_len
from ..registry import get_mechanism, register_mechanism
from ..types import SimRequest, SimResult, SmResult, worst_status
from .sm import DEFAULT_POLICY, _sm_options

__all__ = ["run_cells"]

# hanoi engines the warp phase is bit-identical to (it *is* the jitted
# hanoi lane step); anything else must go through sm_interleave
_SUPPORTED_INNER = ("hanoi", "hanoi_jax")

# static policy ids for the compiled scheduler (one executable per policy)
_POLICY_IDS = {name: i for i, name in enumerate(POLICY_NAMES)}
_GTO = _POLICY_IDS["greedy_then_oldest"]
_RR = _POLICY_IDS["round_robin"]

_N_OPS = max(int(op) for op in Op) + 1


def _supported_cycle_cfg(tcfg) -> CycleConfig:
    """Validate that the cycle model requested is the one sm_jax compiles."""
    ccfg = CycleConfig.from_timing(tcfg)     # default lift: trace-conservative
    if ccfg.scoreboard or ccfg.issue_width != 1 \
            or ccfg.memory_model != "fixed":
        raise ValueError(
            "sm_jax schedules in the trace-conservative, single-issue, "
            "fixed-latency mode (the sm_interleave default); use "
            "sm_interleave for scoreboard / dual-issue / stochastic-memory "
            "cycle models")
    if min(ccfg.alu_latency, ccfg.control_latency,
           ccfg.memory_latency, ccfg.atomic_latency) < 1:
        raise ValueError("sm_jax requires all class latencies >= 1")
    return ccfg


def _latency_tables(ccfg: CycleConfig) -> tuple[np.ndarray, np.ndarray]:
    """Per-opcode ``(issue latency, blocks-on-memory?)`` lookup tables —
    the array form of ``schedule_cycle``'s latency classification."""
    lat = np.full(_N_OPS, ccfg.alu_latency, np.int32)
    for op in _CONTROL_LAT_OPS:
        lat[int(op)] = ccfg.control_latency
    for op in MEMORY_OPS:                    # includes atomics; atomics
        lat[int(op)] = ccfg.memory_latency   # override below
    for op in ATOMIC_OPS:
        lat[int(op)] = ccfg.atomic_latency
    is_mem = np.zeros(_N_OPS, bool)
    for op in MEMORY_OPS:
        is_mem[int(op)] = True
    return lat, is_mem


def _out_capacity(n: int) -> int:
    """Issue-slot capacity class: power of two with a floor, so the
    scheduler recompiles per coarse trace-volume class, not per cell."""
    return max(256, 1 << max(0, int(n) - 1).bit_length())


def _batch_class(n: int) -> int:
    """Batch-size padding class (power of two, floor 8) for the unique-row
    warp phase — bounds recompiles the same way ``padded_len`` does for
    program length."""
    return max(8, 1 << max(0, int(n) - 1).bit_length())


def _dedupe_rows(progs: np.ndarray, skips: np.ndarray, regs: np.ndarray,
                 mems: np.ndarray, lanes: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Hash-cons warp rows: ``(first, inv)`` with ``first`` the indices of
    the unique rows (in first-seen order) and ``inv[i]`` the unique slot of
    row ``i``.  Execution is a pure function of the row operands (the
    resolved config and ``majority_first`` are grid-wide), so identical
    rows — N replicated warps of a cell, repeated cells of a grid — run
    the lane program once and share one result."""
    uniq: dict[bytes, int] = {}
    first: list[int] = []
    inv = np.empty(progs.shape[0], np.int64)
    for i in range(progs.shape[0]):
        key = (progs[i].tobytes() + skips[i].tobytes() + regs[i].tobytes()
               + mems[i].tobytes() + lanes[i].tobytes())
        u = uniq.get(key)
        if u is None:
            u = len(first)
            uniq[key] = u
            first.append(i)
        inv[i] = u
    return np.asarray(first, np.int64), inv


def _cell_scheduler(n_warps: int, out_cap: int, policy_id: int,
                    lat_tab: np.ndarray, mem_tab: np.ndarray):
    """One-cell scheduler: a single ``lax.while_loop`` over issue slots.

    State is entirely vectors over the cell's warps; each iteration issues
    exactly one instruction (after an optional event hop over an idle gap),
    mirroring ``schedule_cycle``'s trace-conservative single-issue loop.
    """
    import jax.numpy as jnp
    from jax import lax

    I32 = jnp.int32
    BIG = jnp.int32(np.iinfo(np.int32).max)
    LAT = jnp.asarray(lat_tab)
    ISMEM = jnp.asarray(mem_tab)
    w_ids = jnp.arange(n_warps, dtype=jnp.int32)
    NOP = jnp.int32(int(Op.NOP))

    def priority(last, cursor):
        # the priority_keys() vector formulation, in jnp (drift-tested
        # against repro.timing.policies on the numpy side)
        if policy_id == _GTO:
            return jnp.where(w_ids == last, I32(0), w_ids + 1)
        if policy_id == _RR:
            return (w_ids - cursor) % n_warps
        return w_ids                                   # oldest_first

    def schedule(warp_map, trace_n, ops, trace_pc_u, trace_mask_u):
        # warp_map[w] -> row in the hash-consed trace buffers (shared,
        # un-vmapped operands): replicated warps read one trace copy.
        # A fixed-length scan over issue slots (not a while_loop with
        # output rings): scan's stacked ys are dense per-slot stores,
        # which XLA lowers far better than per-iteration batched
        # dynamic-update scatters.  Slots past ``total`` are masked
        # no-ops (``out_cap`` is the grid's padded slot budget).
        total = jnp.sum(trace_n)
        L = ops.shape[1]

        def step(st, _):
            (idx, t_ready, t_mem, in_order, cycle, issued, last, cursor,
             busy, istall, sstall, mstall, tinstr) = st
            active = issued < total
            pending = idx < trace_n
            earliest = jnp.where(pending,
                                 jnp.maximum(in_order, t_ready), BIG)
            next_t = jnp.min(earliest)
            stalled = active & (next_t > cycle)
            # idle gap: hop to the earliest completion that readies a warp,
            # classified memory/scoreboard by the warps waking at it
            blocked_mem = t_mem & (t_ready >= in_order)
            gap_mem = jnp.any(pending & (earliest <= next_t) & blocked_mem)
            gap = jnp.where(stalled, next_t - cycle, I32(0))
            mstall = mstall + jnp.where(gap_mem, gap, I32(0))
            sstall = sstall + jnp.where(gap_mem, I32(0), gap)
            cycle = jnp.where(active, jnp.maximum(cycle, next_t), cycle)
            last = jnp.where(stalled, I32(-1), last)   # pol.stalled()
            ready = pending & (earliest <= cycle)
            sel = jnp.argmin(jnp.where(ready, priority(last, cursor),
                                       BIG)).astype(jnp.int32)
            n_ready = jnp.sum(ready).astype(jnp.int32)
            pc = trace_pc_u[warp_map[sel], idx[sel]]
            mask = trace_mask_u[warp_map[sel], idx[sel]]
            op = jnp.where((pc >= 0) & (pc < L),
                           ops[sel, jnp.clip(pc, 0, L - 1)], NOP)
            op = jnp.clip(op, 0, _N_OPS - 1)
            t_ready = jnp.where(active, t_ready.at[sel].set(cycle + LAT[op]),
                                t_ready)
            t_mem = jnp.where(active, t_mem.at[sel].set(ISMEM[op]), t_mem)
            in_order = jnp.where(active, in_order.at[sel].set(cycle + 1),
                                 in_order)
            idx = jnp.where(active, idx.at[sel].add(1), idx)
            act32 = active.astype(jnp.int32)
            tinstr = tinstr + act32 * lax.population_count(mask).astype(
                jnp.int32)
            busy = busy + act32
            # port contention: a warp left ready in the issued cycle
            istall = istall + act32 * (n_ready > 1).astype(jnp.int32)
            if policy_id == _GTO:
                last = jnp.where(active, sel, last)
            if policy_id == _RR:
                cursor = jnp.where(active, (sel + 1) % n_warps, cursor)
            out = (jnp.where(active, sel, I32(-1)),
                   jnp.where(active, pc, I32(-1)),
                   jnp.where(active, mask, jnp.uint32(0)))
            return (idx, t_ready, t_mem, in_order, cycle + act32,
                    issued + act32, last, cursor, busy, istall, sstall,
                    mstall, tinstr), out

        init = (jnp.zeros(n_warps, jnp.int32),          # idx
                jnp.zeros(n_warps, jnp.int32),          # t_ready
                jnp.zeros(n_warps, jnp.bool_),          # t_mem
                jnp.zeros(n_warps, jnp.int32),          # in_order
                I32(0), I32(0),                         # cycle, issued
                I32(0), I32(0),                         # last (GTO init 0),
                                                        # cursor
                I32(0), I32(0), I32(0), I32(0), I32(0))  # busy + stalls +
                                                         # tinstr
        st, (ow, opc, om) = lax.scan(step, init, None, length=out_cap)
        (idx, t_ready, t_mem, in_order, cycle, issued, last, cursor,
         busy, istall, sstall, mstall, tinstr) = st
        return ow, opc, om, issued, cycle, busy, istall, sstall, mstall, \
            tinstr

    return schedule


# AOT-compiled grid schedulers, keyed by every static the kernel closes
# over; compile time is measured at build, never inside a timed window
_SCHED_CACHE: dict = {}


def _compiled_grid_scheduler(n_cells: int, n_warps: int, n_uniq: int,
                             trace_cap: int, prog_len: int, out_cap: int,
                             policy_id: int,
                             lat_key: tuple[int, int, int, int]):
    key = (n_cells, n_warps, n_uniq, trace_cap, prog_len, out_cap,
           policy_id, lat_key)
    hit = _SCHED_CACHE.get(key)
    if hit is not None:
        return hit, None
    import jax
    import jax.numpy as jnp

    alu, ctrl, mem, atom = lat_key
    lat_tab, mem_tab = _latency_tables(CycleConfig(
        alu_latency=alu, control_latency=ctrl, memory_latency=mem,
        atomic_latency=atom, scoreboard=False))
    fn = jax.jit(jax.vmap(_cell_scheduler(n_warps, out_cap, policy_id,
                                          lat_tab, mem_tab),
                          in_axes=(0, 0, 0, None, None)))
    sds = jax.ShapeDtypeStruct
    t0 = time.perf_counter()
    compiled = fn.lower(
        sds((n_cells, n_warps), jnp.int32),           # warp_map
        sds((n_cells, n_warps), jnp.int32),           # trace_n
        sds((n_cells, n_warps, prog_len), jnp.int32),  # opcode columns
        sds((n_uniq, trace_cap), jnp.int32),          # hash-consed traces
        sds((n_uniq, trace_cap), jnp.uint32)).compile()
    compile_s = time.perf_counter() - t0
    _SCHED_CACHE[key] = compiled
    return compiled, compile_s


def run_cells(cells: Sequence[Sequence[SimRequest]], *,
              policy: str = DEFAULT_POLICY,
              timing_cfg: "TimingConfig | CycleConfig" = TimingConfig(),
              inner_label: str = "hanoi_jax") -> list[SmResult]:
    """Run a grid of SM cells — ``cells[c][w]`` is cell *c*'s warp *w* —
    through the two fused device programs; returns one
    :class:`~repro.engine.types.SmResult` per cell.

    Every warp request across the grid must share its resolved config,
    ``majority_first``, ``record_trace`` and a full entry mask; warps may
    differ in program, memory image, registers and lane ids (heterogeneous
    cells).  All cells must have the same warp count (one compiled
    scheduler steps the whole grid).
    """
    policy_name = resolve_policy_name(policy)
    ccfg = _supported_cycle_cfg(timing_cfg)
    if inner_label not in _SUPPORTED_INNER:
        raise ValueError(
            f"sm_jax executes warps on the jitted hanoi lane step; inner "
            f"must be one of {_SUPPORTED_INNER}, got {inner_label!r} — use "
            f"sm_interleave for other inner mechanisms")
    if not cells or any(not cell for cell in cells):
        raise ValueError("run_cells needs at least one warp per cell")
    n_warps = len(cells[0])
    if any(len(cell) != n_warps for cell in cells):
        raise ValueError("all cells in one sm_jax grid must share a warp "
                         "count")
    flat = [q for cell in cells for q in cell]
    cfg = flat[0].resolved_cfg()
    mf, record = flat[0].majority_first, flat[0].record_trace
    for q in flat:
        if q.resolved_cfg() != cfg or q.majority_first != mf \
                or q.record_trace != record:
            raise ValueError("sm_jax warps must share cfg, majority_first "
                             "and record_trace across the grid")
        if q.active0 is not None:
            raise ValueError("sm_jax assumes a full entry mask "
                             "(active0=None)")

    import jax
    import jax.numpy as jnp

    # phase 1: hash-cons the warp rows — identical (program, skips, regs,
    # mem, lanes) rows execute ONCE through the shared hanoi batch
    # executable (same compile cache as the hanoi_jax service path).  The
    # replicated-warp path collapses N identical warps per cell to one
    # row, so a whole grid costs #unique-programs lane executions.
    L = padded_len(max(int(np.asarray(q.program).shape[0]) for q in flat))
    progs, skips, regs, mems, lanes = _batch_arrays(flat, cfg, L)
    first, inv = _dedupe_rows(progs, skips, regs, mems, lanes)
    n_uniq = _batch_class(len(first))                 # batch-size class
    sel = np.concatenate([first, np.full(n_uniq - len(first), first[0],
                                         dtype=np.int64)])
    compiled, compile_s = _compiled_batch_exec(cfg, mf, n_uniq, L)
    t0 = time.perf_counter()
    states = compiled(jnp.asarray(progs[sel]), jnp.asarray(skips[sel]),
                      jnp.asarray(regs[sel]), jnp.asarray(mems[sel]),
                      jnp.asarray(lanes[sel]))
    jax.block_until_ready(states.regs)
    exec_s = time.perf_counter() - t0
    dev_pc, dev_mask = states.trace_pc, states.trace_mask  # stay on device
    states = jax.tree_util.tree_map(np.asarray, states)

    C, N, T = len(cells), n_warps, cfg.max_steps
    warp_map = inv.reshape(C, N).astype(np.int32)
    trace_n = states.trace_n[inv].reshape(C, N).astype(np.int32)
    total_compile = compile_s or 0.0
    scheduled = bool(record) and int(trace_n.max(initial=0)) > 0
    if scheduled:
        # phase 2: the whole grid through one compiled vmapped scheduler;
        # the hash-consed trace buffers are passed un-vmapped, so warps
        # gather their (pc, mask) stream from one device-resident copy
        ops = progs[:, :, F_OP].reshape(C, N, L)
        out_cap = _out_capacity(int(trace_n.sum(axis=1).max()))
        lat_key = (ccfg.alu_latency, ccfg.control_latency,
                   ccfg.memory_latency, ccfg.atomic_latency)
        sched, sched_compile_s = _compiled_grid_scheduler(
            C, N, n_uniq, T, L, out_cap, _POLICY_IDS[policy_name], lat_key)
        total_compile += sched_compile_s or 0.0
        t0 = time.perf_counter()
        out = sched(jnp.asarray(warp_map), jnp.asarray(trace_n),
                    jnp.asarray(ops), dev_pc, dev_mask)
        out = [np.asarray(x) for x in jax.block_until_ready(out)]
        exec_s += time.perf_counter() - t0
        ow, opc, om, out_n, cycles, busy, istall, sstall, mstall, tinstr = out

    warp_wall = exec_s / max(1, len(flat))
    cell_wall = exec_s / max(1, C)
    sm_meta = {"compile_time_s": total_compile} if total_compile else {}
    width = cfg.n_threads
    # one SimResult per unique row, shared by every warp that hash-consed
    # onto it (SimResult is frozen; SmResult.requests keeps per-warp names)
    uniq_results = [
        _jax_result(flat[int(first[u])],
                    jax.tree_util.tree_map(lambda x, u=u: x[u], states),
                    warp_wall)
        for u in range(len(first))]
    sms: list[SmResult] = []
    for c, cell in enumerate(cells):
        warps = tuple(uniq_results[inv[i]]
                      for i in range(c * N, (c + 1) * N))
        if scheduled:
            n_c = int(out_n[c])
            sm_trace = tuple(zip(ow[c, :n_c].tolist(),
                                 opc[c, :n_c].tolist(),
                                 om[c, :n_c].tolist()))
            kw = dict(steps=n_c, cycles=int(cycles[c]),
                      thread_instructions=int(tinstr[c]),
                      utilization=int(tinstr[c]) / max(1, n_c * width),
                      busy_cycles=int(busy[c]),
                      issue_stall_cycles=int(istall[c]),
                      scoreboard_stall_cycles=int(sstall[c]),
                      memory_stall_cycles=int(mstall[c]))
        else:
            sm_trace = ()
            kw = dict(steps=0, cycles=0, thread_instructions=0,
                      utilization=0.0, busy_cycles=0, issue_stall_cycles=0,
                      scoreboard_stall_cycles=0, memory_stall_cycles=0)
        sms.append(SmResult(
            mechanism="sm_jax", inner=inner_label, policy=policy_name,
            warps=warps, sm_trace=sm_trace,
            status=worst_status([r.status for r in warps]),
            requests=tuple(cell), wall_time_s=cell_wall, meta=sm_meta,
            **kw))
    return sms


def _sm_jax_options(req: SimRequest) -> tuple[int, str, str]:
    n_warps, inner_name, policy = _sm_options(req)
    inner = get_mechanism(inner_name)
    if "composite" in inner.tags:
        raise ValueError("sm_inner must be a single-warp mechanism, not "
                         f"the composite {inner.name!r}")
    if inner.name not in _SUPPORTED_INNER:
        raise ValueError(
            f"sm_jax executes warps on the jitted hanoi lane step; "
            f"sm_inner must be one of {_SUPPORTED_INNER} (got "
            f"{inner.name!r}) — use sm_interleave for other inner "
            f"mechanisms")
    return n_warps, inner.name, policy


def _run_sm_jax_batch(reqs: Sequence[SimRequest]) -> list[SimResult]:
    """Native batch runner: a whole grid of signature-homogeneous SM cells
    as one warp-phase call plus one scheduler call."""
    n_warps, inner_name, policy = _sm_jax_options(reqs[0])
    cells = []
    for req in reqs:
        stripped = {k: v for k, v in req.meta.items()
                    if not k.startswith("sm_")}
        cells.append([dataclasses.replace(req, meta=stripped,
                                          name=f"{req.name or 'warp'}/w{w}")
                      for w in range(n_warps)])
    sms = run_cells(cells, policy=policy, inner_label=inner_name)
    out = []
    for sm in sms:
        w0 = sm.warps[0]
        out.append(SimResult(
            mechanism="sm_jax", status=sm.status,
            regs=w0.regs, preds=w0.preds, mem=w0.mem, finished=w0.finished,
            steps=sm.steps, fuel_left=min(r.fuel_left for r in sm.warps),
            trace=tuple((pc, mask) for _, pc, mask in sm.sm_trace),
            utilization=sm.utilization,
            error=next((r.error for r in sm.warps if r.error), None),
            wall_time_s=sm.wall_time_s, meta={"sm": sm}))
    return out


@register_mechanism(
    "sm_jax", backend="jax", batch_runner=_run_sm_jax_batch,
    tags=("sm", "multi-warp", "composite", "vectorized"),
    description="per-SM model as one jit(vmap) lane-parallel program: "
                "warps run on the cached hanoi_jax batch executable, the "
                "SM scheduler is a lax.while_loop with the issue policy "
                "as an argmin over a priority vector (meta: sm_warps, "
                "sm_inner in {hanoi, hanoi_jax}, sm_policy); SM traces "
                "bit-identical to sm_interleave")
def _run_sm_jax(req: SimRequest) -> SimResult:
    return _run_sm_jax_batch([req])[0]
