"""``volta_itps`` — Volta-style independent thread scheduling (ITS).

Post-Volta NVIDIA GPUs abandoned the single-PC-per-warp model: every lane
carries its own PC (plus call stack), and a *convergence optimizer* in the
scheduler opportunistically regroups lanes that sit at the same PC so SIMD
lanes are still shared ("Analyzing Modern NVIDIA GPU cores", arXiv
2503.20481, SS II-B; CUDA's independent-thread-scheduling contract).  The
two properties this mechanism reproduces:

* **no reconvergence stack** — BSSY/BSYNC bracketing, Bx registers, BREAK
  mask edits and YIELD are no-ops (:data:`~repro.core.stepper.STACKLESS_NOPS`);
  reconvergence happens exactly when diverged lanes happen to reach a
  common PC and the optimizer merges them into one issue group;
* **a forward-progress guarantee** — the scheduler may favor wide groups,
  but every runnable lane is issued within a bounded number of slots
  (``itps_patience``).  This is what makes the paper's Fig 3 spinlock — and
  its YIELD-less SS V-G ablation, which deadlocks both the pre-Volta
  SIMT-Stack and Hanoi — terminate here: the lock holder's singleton group
  is eventually scheduled no matter how wide the spinning group is.

Scheduling policy ("greedy convergence optimizer with aging"): each slot,
group runnable lanes by PC and issue the widest group (ties: lowest PC —
lagging lanes catch up toward reconvergence points); but if some runnable
lane has been starved for ``itps_patience`` slots, its group is issued
instead.  WARPSYNC is the one instruction with real synchronization
semantics on this machine: executing lanes park at the sync PC until every
unfinished lane named in the mask has arrived (finished lanes count as
arrived), and a rendezvous that can never assemble is reported as a
*structural* ``DEADLOCK`` (fuel to spare), not fuel exhaustion.

Request options (``SimRequest.meta``):

* ``itps_patience`` (int, default 8) — the starvation bound, in slots.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.interp import RunResult, simd_utilization
from repro.core.isa import MachineConfig
from repro.core.stepper import ArchState, lanes, popcount, step_group

from ..adapters import result_from_runresult
from ..registry import register_mechanism
from ..types import SimRequest, SimResult

DEFAULT_PATIENCE = 8


def run_volta_itps(program: np.ndarray,
                   cfg: MachineConfig = MachineConfig(),
                   *,
                   init_regs=None, init_mem=None, lane_ids=None,
                   active0: int | None = None,
                   patience: int = DEFAULT_PATIENCE,
                   record_trace: bool = True) -> RunResult:
    """Run one warp under independent thread scheduling; see module doc."""
    prog = np.asarray(program, dtype=np.int64)
    L = prog.shape[0]
    W, FULL = cfg.n_threads, cfg.full_mask
    st = ArchState(cfg, init_regs, init_mem, lane_ids)
    patience = max(1, int(patience))

    active = FULL if active0 is None else (active0 & FULL)
    pcs = [0] * W
    finished = 0
    blocked = 0                      # lanes parked at a WARPSYNC rendezvous
    syncs: dict[int, int] = {}       # sync pc -> required mask
    resume: dict[int, int] = {}      # parked lane -> pc to resume at
    last_issue = [0] * W
    trace: list[tuple[int, int]] = []

    def retire(mask: int) -> None:
        nonlocal finished
        finished |= mask

    def release_ready_syncs() -> None:
        """Unpark every rendezvous whose mask has fully arrived (finished
        lanes count as arrived — they can never get there)."""
        nonlocal blocked
        for spc in list(syncs):
            need = syncs[spc] & active & ~finished
            parked_here = sum(1 << t for t in lanes(blocked)
                              if pcs[t] == spc)
            if need & ~parked_here:
                continue             # someone named in the mask is still out
            for t in lanes(parked_here):
                pcs[t] = resume.pop(t, spc + 1)
            blocked &= ~parked_here
            del syncs[spc]

    fuel = cfg.max_steps
    steps = 0
    while fuel > 0:
        # retire lanes that fell off the program (implicit EXIT, no slot)
        off = sum(1 << t for t in lanes(active & ~finished & ~blocked)
                  if not 0 <= pcs[t] < L)
        if off:
            retire(off)
            release_ready_syncs()
        runnable = active & ~finished & ~blocked
        if not runnable:
            break                    # all done, or a structural deadlock

        # --- convergence optimizer: group runnable lanes by PC -------------
        groups: dict[int, int] = {}
        for t in lanes(runnable):
            groups[pcs[t]] = groups.get(pcs[t], 0) | (1 << t)

        # --- pick a group: greedy-widest with a progress guarantee ---------
        starved = min(lanes(runnable), key=lambda t: last_issue[t])
        if steps - last_issue[starved] >= patience:
            pc = pcs[starved]
        else:
            pc = max(groups, key=lambda p: (popcount(groups[p]), -p))
        gmask = groups[pc]

        fuel -= 1
        steps += 1
        if record_trace:
            trace.append((pc, gmask))
        for t in lanes(gmask):
            last_issue[t] = steps

        out = step_group(prog, st, pc, gmask, full_mask=FULL)
        if out.exited:
            retire(out.exited)
        for t, npc in out.next_pcs.items():
            pcs[t] = npc
        if out.sync_mask is not None and out.sync_lanes:
            # park the executing lanes AT the sync pc; their post-release
            # pcs were reported by the stepper.  Divergent register-operand
            # masks at one pc (UB on real hardware) UNION rather than
            # overwrite: conservative — a rendezvous can only get harder to
            # assemble, never spuriously release earlier arrivals
            syncs[pc] = syncs.get(pc, 0) | out.sync_mask
            for t in lanes(out.sync_lanes):
                resume[t] = out.next_pcs.get(t, pc + 1)
                pcs[t] = pc
            blocked |= out.sync_lanes
        release_ready_syncs()

    deadlocked = (finished & FULL) != FULL or fuel <= 0
    return RunResult(st.regs, st.preds, st.mem, finished, steps, deadlocked,
                     None, trace, fuel_left=max(0, fuel))


@register_mechanism(
    "volta_itps", backend="numpy", tags=("post-volta", "per-thread-pc"),
    description="Volta-style independent thread scheduling: per-lane PCs, "
                "no reconvergence stack, greedy convergence optimizer with "
                "a forward-progress guarantee (spinlocks terminate without "
                "YIELD)")
def _run_volta_itps(req: SimRequest) -> SimResult:
    cfg = req.resolved_cfg()
    t0 = time.perf_counter()
    r = run_volta_itps(
        req.program, cfg, init_regs=req.init_regs, init_mem=req.init_mem,
        lane_ids=req.lane_ids, active0=req.active0,
        patience=int(req.meta.get("itps_patience", DEFAULT_PATIENCE)),
        record_trace=req.record_trace)
    return result_from_runresult("volta_itps", r, req,
                                 time.perf_counter() - t0)


# re-exported for callers that want the raw engine (tests, benchmarks)
__all__ = ["run_volta_itps", "DEFAULT_PATIENCE"]
