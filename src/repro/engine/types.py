"""Normalized request/result schema shared by every control-flow mechanism.

Before this module existed each engine had its own calling convention and
result shape: ``interp.run_hanoi`` / ``run_simt_stack`` returned a mutable
``RunResult`` with a python-list trace, ``dualpath.run_dual_path`` the same
but with different keyword knobs, and the vectorized JAX engine returned a
raw :class:`~repro.core.hanoi.HanoiState` pytree with a ring-buffer trace.
``SimRequest``/``SimResult`` are the one schema all of them now map onto.

Out-of-fuel normalization
-------------------------
All engines burn one unit of fuel per scheduler slot and stop issuing the
moment fuel reaches zero, so their traces are *truncated* identically — the
property suite asserts the numpy and JAX engines agree step-for-step even
when fuel dies mid-split.  What used to differ is the *flagging*: the numpy
engines folded fuel exhaustion into a generic ``deadlocked`` bool while the
JAX engine required inspecting ``state.fuel``.  ``SimResult.status`` makes
the distinction explicit and uniform:

* ``OK``           — every thread retired through EXIT with fuel to spare;
* ``OUT_OF_FUEL``  — the scheduler-slot budget expired first (the trace is
  truncated at the last fueled slot, never silently dropped);
* ``DEADLOCK``     — no runnable path remained while threads were still
  unfinished (fuel was left over — a *structural* hang, e.g. a BSYNC whose
  mask can never assemble);
* ``ERROR``        — a structural resource error (Bx exhaustion on
  WARPSYNC).
"""
from __future__ import annotations

import enum
import types as _pytypes
from dataclasses import dataclass, field
from typing import Any, Mapping

import numpy as np

from repro.core.isa import MachineConfig
from repro.core.trace import trace_tokens as _trace_tokens


def _freeze_meta(obj: Any, value: Mapping[str, Any]) -> None:
    """Normalize a ``meta`` mapping on a frozen dataclass to an immutable
    view.  ``field(default_factory=dict)`` alone still hands every caller a
    mutable dict (and ``meta=SHARED_DICT`` a *shared* mutable one) — copying
    into a ``MappingProxyType`` closes both holes."""
    object.__setattr__(obj, "meta",
                       _pytypes.MappingProxyType(dict(value)))


class _PicklableMeta:
    """Pickle support for the frozen request/result dataclasses.

    The ``meta`` field is normalized to a ``MappingProxyType``, which
    pickle refuses — a problem for the multi-process service tier, whose
    job/result envelopes carry these objects across process boundaries.
    ``__getstate__`` downgrades the proxy to a plain dict;
    ``__setstate__`` restores the fields and re-freezes ``meta``, so the
    immutability contract survives the round trip.
    """

    def __getstate__(self) -> dict[str, Any]:
        state = dict(self.__dict__)
        state["meta"] = dict(state["meta"])
        return state

    def __setstate__(self, state: dict[str, Any]) -> None:
        for k, v in state.items():
            object.__setattr__(self, k, v)
        _freeze_meta(self, state["meta"])


class SimStatus(enum.Enum):
    """Normalized termination status (see module docstring)."""

    OK = "ok"
    OUT_OF_FUEL = "out_of_fuel"
    DEADLOCK = "deadlock"
    ERROR = "error"


@dataclass(frozen=True, eq=False)
class SimRequest(_PicklableMeta):
    """One warp execution: program + machine + initial state + run options.

    ``fuel`` overrides ``cfg.max_steps`` when given (so a shared config can
    be re-budgeted per request).  ``bsync_skip_pcs`` is consumed only by
    the ``turing_oracle`` mechanism; the others ignore it.

    ``meta`` carries mechanism-specific options that are not part of the
    universal schema — e.g. ``itps_patience`` for ``volta_itps`` or
    ``sm_warps`` / ``sm_inner`` / ``sm_policy`` for ``sm_interleave``.
    Mechanisms ignore keys they do not know.  It is normalized to an
    immutable mapping in ``__post_init__``.

    ``eq=False``: ndarray fields make generated ``__eq__``/``__hash__``
    raise, so requests/results compare and hash by identity — usable as
    set members and dict keys.
    """

    program: np.ndarray
    cfg: MachineConfig = MachineConfig()
    init_regs: Any = None
    init_mem: Any = None
    lane_ids: Any = None
    active0: int | None = None
    fuel: int | None = None
    record_trace: bool = True
    majority_first: bool = True
    bsync_skip_pcs: tuple[int, ...] = ()
    name: str = ""
    meta: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        _freeze_meta(self, self.meta)

    def resolved_cfg(self) -> MachineConfig:
        if self.fuel is None:
            return self.cfg
        return self.cfg._replace(max_steps=int(self.fuel))


@dataclass(frozen=True, eq=False)
class SimResult(_PicklableMeta):
    """Normalized outcome of running one warp under one mechanism.

    ``eq=False`` for the same reason as :class:`SimRequest`: identity
    comparison/hashing instead of crashing on the ndarray fields.
    """

    mechanism: str
    status: SimStatus
    regs: np.ndarray
    preds: np.ndarray
    mem: np.ndarray
    finished: int
    steps: int
    fuel_left: int
    trace: tuple[tuple[int, int], ...]
    utilization: float
    error: str | None = None
    wall_time_s: float = 0.0
    meta: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        _freeze_meta(self, self.meta)

    @property
    def ok(self) -> bool:
        return self.status is SimStatus.OK

    @property
    def deadlocked(self) -> bool:
        """Legacy predicate: matches ``RunResult.deadlocked`` (fuel
        exhaustion and structural deadlock were historically one flag)."""
        return self.status is not SimStatus.OK

    def trace_tokens(self) -> np.ndarray:
        return _trace_tokens(list(self.trace))


#: Severity order used when aggregating warp statuses into one SM status.
_STATUS_SEVERITY = {SimStatus.OK: 0, SimStatus.OUT_OF_FUEL: 1,
                    SimStatus.DEADLOCK: 2, SimStatus.ERROR: 3}


def worst_status(statuses) -> SimStatus:
    """The most severe status in ``statuses`` (OK < OUT_OF_FUEL < DEADLOCK
    < ERROR); OK for an empty sequence."""
    return max(statuses, key=_STATUS_SEVERITY.__getitem__,
               default=SimStatus.OK)


@dataclass(frozen=True, eq=False)
class SmResult(_PicklableMeta):
    """Outcome of running N warps on one SM through a single-warp mechanism.

    The SM model time-multiplexes the warps' control-flow traces through one
    issue scheduler (``policy``: ``round_robin`` or ``greedy_then_oldest``),
    so the per-warp architectural results come straight from the inner
    mechanism while the SM-level schedule — ``sm_trace`` of
    ``(warp, pc, mask)`` slots and the latency-aware ``cycles`` — reflects
    the interleaving.  ``requests`` keeps the per-warp
    :class:`SimRequest`s the cell executed (``requests[w]`` produced
    ``warps[w]``) so SM cells archive replayably: the service and the
    façade stamp each warp's begin event with the full replay payload via
    :func:`repro.engine.sinks.sm_run_meta`.  ``eq=False`` for the same
    identity-comparison reason as :class:`SimResult`.
    """

    mechanism: str
    inner: str
    policy: str
    warps: tuple[SimResult, ...]
    sm_trace: tuple[tuple[int, int, int], ...]
    status: SimStatus                 # worst across warps
    steps: int                        # total SM issue slots
    cycles: int                       # latency-aware schedule length
    thread_instructions: int          # sum of active-mask popcounts
    utilization: float                # SIMD utilization over the SM trace
    requests: tuple[SimRequest, ...] = ()   # per-warp requests (replay)
    wall_time_s: float = 0.0
    # stall taxonomy of the cycle-level schedule (repro.timing): busy +
    # scoreboard-stall + memory-stall partition ``cycles``; issue-stall
    # counts port-contention cycles and overlaps busy ones
    busy_cycles: int = 0
    issue_stall_cycles: int = 0
    scoreboard_stall_cycles: int = 0
    memory_stall_cycles: int = 0
    meta: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        _freeze_meta(self, self.meta)

    @property
    def n_warps(self) -> int:
        return len(self.warps)

    @property
    def ok(self) -> bool:
        return self.status is SimStatus.OK

    @property
    def ipc(self) -> float:
        """Thread-level IPC of the interleaved SM schedule (0.0 for an
        empty schedule)."""
        if self.cycles <= 0:
            return 0.0
        return self.thread_instructions / self.cycles

    @property
    def warp_ipc(self) -> float:
        if self.cycles <= 0:
            return 0.0
        return self.steps / self.cycles

    @property
    def stall_breakdown(self) -> dict[str, int]:
        return {"issue": self.issue_stall_cycles,
                "scoreboard": self.scoreboard_stall_cycles,
                "memory": self.memory_stall_cycles}


def classify_status(*, finished: int, full_mask: int, fuel_left: int,
                    error: str | None) -> SimStatus:
    """The one status derivation every adapter funnels through.

    ``fuel_left < 0`` means "unknown" (the legacy ``RunResult`` default for
    engines that predate fuel accounting): such runs classify on the
    finished mask alone and are never flagged OUT_OF_FUEL.
    """
    if error:
        return SimStatus.ERROR
    if fuel_left == 0:
        # budget expired — even a fully-finished run keeps the legacy
        # "deadlocked" view (fuel exhaustion has always been flagged)
        return SimStatus.OUT_OF_FUEL
    if (finished & full_mask) == full_mask:
        return SimStatus.OK
    return SimStatus.DEADLOCK
