"""Model assembly: layer plans -> param structure, forward, loss, prefill and
decode, for every assigned architecture family.

Layers are STACKED per plan segment and iterated with ``lax.scan`` so the
lowered HLO stays compact (one body per distinct layer pattern) — essential
for compiling 30+ dry-run cells against 512-device meshes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .base import (GLOBAL, LOCAL, RECURRENT, RWKV, SWA, ModelConfig, P,
                   abstract_params, init_params, partition_specs)
from .layers import (attention, attention_cache_struct, attention_struct,
                     cross_entropy, embed_struct, head_struct, lm_logits, mlp,
                     mlp_struct, rmsnorm, rmsnorm_struct, shard_act)
from .moe import moe, moe_struct
from .recurrent import (rglru, rglru_state_struct, rglru_struct,
                        rwkv6_channel_mix, rwkv6_state_struct,
                        rwkv6_struct, rwkv6_time_mix)

# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------


def _stack(struct, r: int):
    """Add a leading stacked-layers axis to every P leaf."""
    return jax.tree_util.tree_map(
        lambda p: P((r,) + p.shape, ("layers",) + p.axes, init=p.init,
                    scale=p.scale, dtype=p.dtype),
        struct, is_leaf=lambda x: isinstance(x, P))


def _segments(cfg: ModelConfig) -> list[dict]:
    """Expand the layer plan into segments with per-position layer kinds and
    moe-ness.  first_dense_layers (DeepSeek) forces dense FFN at the start."""
    segs = []
    layer_idx = 0
    for pattern, repeat in cfg.layer_plan:
        if (cfg.family == "moe" and cfg.first_dense_layers > layer_idx
                and repeat > 1):
            # split off the dense prefix as its own segment(s)
            n_dense = min(repeat, -(-(cfg.first_dense_layers - layer_idx)
                                    // len(pattern)))
            segs.append({"pattern": pattern, "repeat": n_dense,
                         "moe": False})
            layer_idx += n_dense * len(pattern)
            if repeat - n_dense:
                segs.append({"pattern": pattern, "repeat": repeat - n_dense,
                             "moe": True})
                layer_idx += (repeat - n_dense) * len(pattern)
        else:
            is_moe = cfg.family == "moe" and layer_idx >= cfg.first_dense_layers
            segs.append({"pattern": pattern, "repeat": repeat, "moe": is_moe})
            layer_idx += repeat * len(pattern)
    return segs


def _layer_struct(cfg: ModelConfig, kind: str, is_moe: bool):
    d = cfg.d_model
    if kind == RWKV:
        s = rwkv6_struct(cfg)
        return {"ln1": rmsnorm_struct(d), "tm": s["tm"],
                "ln2": rmsnorm_struct(d), "cm": s["cm"]}
    if kind == RECURRENT:
        core: dict[str, Any] = {"rglru": rglru_struct(cfg)}
    else:
        core = {"attn": attention_struct(cfg)}
    ffn = moe_struct(cfg) if is_moe else mlp_struct(d, cfg.d_ff)
    return {"ln1": rmsnorm_struct(d), **core,
            "ln2": rmsnorm_struct(d), "ffn": ffn}


def model_struct(cfg: ModelConfig):
    segs = _segments(cfg)
    seg_structs = []
    for seg in segs:
        per_pos = {str(j): _layer_struct(cfg, kind, seg["moe"])
                   for j, kind in enumerate(seg["pattern"])}
        seg_structs.append(_stack(per_pos, seg["repeat"]))
    return {
        "embed": embed_struct(cfg),
        "segments": seg_structs,
        "final_norm": rmsnorm_struct(cfg.d_model),
        "head": head_struct(cfg),
    }


def cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    """Decode-state structure mirroring the segment layout."""
    segs = _segments(cfg)
    out = []
    for seg in segs:
        per_pos = {}
        for j, kind in enumerate(seg["pattern"]):
            if kind == RWKV:
                per_pos[str(j)] = rwkv6_state_struct(cfg, batch)
            elif kind == RECURRENT:
                per_pos[str(j)] = rglru_state_struct(cfg, batch)
            else:
                # local/swa layers only need a window-sized cache
                n = max_len if kind == GLOBAL else min(
                    max_len, max(cfg.window_size, 1))
                per_pos[str(j)] = attention_cache_struct(cfg, batch, n)
        out.append(_stack(per_pos, seg["repeat"]))
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    e = params["embed"]
    if cfg.frontend == "audio_stub":
        # precomputed frame embeddings (the modality frontend is a stub)
        x = batch["frames"] @ e["frontend_proj"].astype(batch["frames"].dtype)
    elif cfg.frontend == "vision_stub":
        tok = e["tok"][batch["tokens"]]
        patch = batch["patches"] @ e["frontend_proj"].astype(
            batch["patches"].dtype)
        x = jnp.concatenate([patch.astype(tok.dtype), tok], axis=1)
    else:
        x = e["tok"][batch["tokens"]]
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)


def _apply_layer(lp, x, *, cfg: ModelConfig, kind: str, is_moe: bool,
                 positions, cache=None, cache_pos=None):
    """One residual block.  Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if kind == RWKV:
        out, tm_state = rwkv6_time_mix(
            lp["tm"], h, cfg=cfg,
            state=None if cache is None else {"shift": cache["tm_shift"],
                                              "wkv": cache["wkv"]})
        x = x + out
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        out2, cm_state = rwkv6_channel_mix(
            lp["cm"], h2,
            state=None if cache is None else {"shift": cache["cm_shift"]})
        x = x + out2
        new_cache = {"tm_shift": tm_state["shift"], "wkv": tm_state["wkv"],
                     "cm_shift": cm_state["shift"]}
        return x, new_cache, aux

    if kind == RECURRENT:
        out, new_cache = rglru(lp["rglru"], h, cfg=cfg, state=cache)
    else:
        out, new_cache = attention(lp["attn"], h, cfg=cfg, kind=kind,
                                   positions=positions, kv_cache=cache,
                                   cache_pos=cache_pos)
    # constrain the SUBLAYER OUTPUT (a TP partial-sum) to the seq-sharded
    # layout before the residual add: GSPMD then lowers the combine as a
    # reduce-scatter instead of all-reduce + slice (2x collective bytes)
    x = x + shard_act(out, cfg)
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if is_moe:
        out2, aux = moe(lp["ffn"], h2, cfg)
    elif cfg.tp_impl == "shard_map" and cfg.batch_axes and cache is None:
        from .shardmap_tp import mlp_tp
        return x + mlp_tp(lp["ffn"], h2, cfg), new_cache, aux
    else:
        out2 = mlp(lp["ffn"], h2)
    return shard_act(x + shard_act(out2, cfg), cfg), new_cache, aux


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def forward(params, cfg: ModelConfig, batch: dict, *,
            return_cache: bool = False):
    """Full-sequence forward (training / prefill).

    Returns (logits, aux_loss, caches) — caches is None unless requested.
    """
    x = shard_act(_embed(params, cfg, batch), cfg)
    B, S, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)   # 1-D: batch-independent
    segs = _segments(cfg)
    caches = [] if return_cache else None
    aux_total = jnp.zeros((), jnp.float32)

    for seg, seg_params in zip(segs, params["segments"]):
        pattern, is_moe = seg["pattern"], seg["moe"]

        def body(x, lp, pattern=pattern, is_moe=is_moe):
            new_caches = {}
            aux_sum = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(pattern):
                x, c, aux = _apply_layer(lp[str(j)], x, cfg=cfg, kind=kind,
                                         is_moe=is_moe, positions=positions)
                new_caches[str(j)] = c
                aux_sum = aux_sum + aux
            return x, (new_caches, aux_sum)

        body = _remat_wrap(body, cfg)

        if cfg.scan_layers:
            def scan_body(carry, lp):
                x, auxc = carry
                x, (cs, aux) = body(x, lp)
                return (x, auxc + aux), (cs if return_cache else 0)
            (x, aux_total), ys = jax.lax.scan(scan_body, (x, aux_total),
                                              seg_params)
            if return_cache:
                caches.append(ys)
        else:
            for i in range(seg["repeat"]):
                lp = jax.tree_util.tree_map(lambda a: a[i], seg_params)
                x, (cs, aux) = body(x, lp)
                aux_total = aux_total + aux
                if return_cache:
                    caches.append(cs)     # unstacked; tests only

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params.get("head", {}), params["embed"], x, cfg)
    return logits, aux_total, caches


def loss_fn(params, cfg: ModelConfig, batch: dict):
    """Scalar loss for one batch; labels/masks per family."""
    logits, aux, _ = forward(params, cfg, batch)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.frontend == "vision_stub":
        # logits cover [patches; tokens] — score text positions only
        n_txt = labels.shape[1]
        logits = logits[:, -n_txt:]
    if cfg.is_decoder and cfg.frontend == "token":
        logits = logits[:, :-1]
        labels = labels[:, 1:]
        mask = None if mask is None else mask[:, 1:]
    ce = cross_entropy(logits, labels, mask)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, caches, tokens: jax.Array,
                cache_pos: jax.Array):
    """One token step.  tokens: [B, 1] int32; caches as from cache_struct
    (stacked per segment); cache_pos: scalar int32 position.

    Returns (logits [B, 1, V], new_caches).
    """
    e = params["embed"]
    x = e["tok"][tokens] * jnp.asarray(cfg.d_model ** 0.5,
                                       e["tok"].dtype)
    B = x.shape[0]
    positions = jnp.full((1,), cache_pos, jnp.int32)   # 1-D, batch-free
    segs = _segments(cfg)
    new_caches = []

    for seg, seg_params, seg_cache in zip(segs, params["segments"], caches):
        pattern, is_moe = seg["pattern"], seg["moe"]

        def body(x, lp_cache, pattern=pattern, is_moe=is_moe):
            lp, cache = lp_cache
            ncs = {}
            for j, kind in enumerate(pattern):
                x, nc, _ = _apply_layer(
                    lp[str(j)], x, cfg=cfg, kind=kind, is_moe=is_moe,
                    positions=positions, cache=cache[str(j)],
                    cache_pos=cache_pos)
                ncs[str(j)] = nc
            return x, ncs

        def scan_body(x, lp_cache):
            x, ncs = body(x, lp_cache)
            return x, ncs

        x, ncs = jax.lax.scan(scan_body, x, (seg_params, seg_cache))
        new_caches.append(ncs)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params.get("head", {}), params["embed"], x, cfg)
    return logits, new_caches
