"""Model substrate: configs and structure-trees.

Every parameter is declared once as a :class:`P` leaf carrying its shape,
LOGICAL axis names and initializer.  From the same declaration we derive:

* materialized random params (smoke tests, examples, real training),
* ``jax.ShapeDtypeStruct`` stand-ins (the multi-pod dry-run never allocates),
* ``PartitionSpec`` trees via logical-axis -> mesh-axis rules (the MaxText
  idiom), which is what the SS Perf loop iterates on.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

# ---------------------------------------------------------------------------
# parameter structure leaves
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class P:
    """A parameter declaration: shape + logical axes + init."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float | None = None    # std override for normal
    dtype: str | None = None      # override (default: model param dtype)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaves(struct) -> list[tuple[tuple, P]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(
        struct, is_leaf=lambda x: isinstance(x, P))
    return flat


def init_params(struct, key: jax.Array, dtype=jnp.float32):
    """Materialize a random param tree from a structure tree."""
    flat = _leaves(struct)
    keys = jax.random.split(key, len(flat))

    def make(leaf: P, k):
        dt = jnp.dtype(leaf.dtype) if leaf.dtype else dtype
        if leaf.init == "zeros":
            return jnp.zeros(leaf.shape, dt)
        if leaf.init == "ones":
            return jnp.ones(leaf.shape, dt)
        std = leaf.scale
        if std is None:
            fan_in = leaf.shape[0] if leaf.shape else 1
            std = 0.02 if len(leaf.shape) < 2 else min(0.02, fan_in ** -0.5)
        return (jax.random.normal(k, leaf.shape) * std).astype(dt)

    made = {path: make(leaf, k) for (path, leaf), k in zip(flat, keys)}
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: made[path], struct,
        is_leaf=lambda x: isinstance(x, P))


def abstract_params(struct, dtype=jnp.float32):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    def mk(leaf: P):
        dt = jnp.dtype(leaf.dtype) if leaf.dtype else dtype
        return jax.ShapeDtypeStruct(leaf.shape, dt)
    return jax.tree_util.tree_map(mk, struct,
                                  is_leaf=lambda x: isinstance(x, P))


def partition_specs(struct, rules: dict[str, Any]):
    """Logical-axis -> mesh-axis mapping, e.g. {"mlp": "model",
    "embed": "data", "vocab": "model"}.  Unknown axes are replicated.
    A mesh axis may appear at most once per spec; later repeats replicate."""
    def mk(leaf: P):
        used: set = set()
        spec = []
        for ax in leaf.axes:
            m = rules.get(ax)
            flat = tuple(m) if isinstance(m, (tuple, list)) else (m,)
            if m is None or any(f in used for f in flat if f):
                spec.append(None)
            else:
                used.update(f for f in flat if f)
                spec.append(m if not isinstance(m, list) else tuple(m))
        return PartitionSpec(*spec)
    return jax.tree_util.tree_map(mk, struct,
                                  is_leaf=lambda x: isinstance(x, P))


def sharded_zeros_like_specs(struct, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda leaf: jnp.zeros(leaf.shape,
                               jnp.dtype(leaf.dtype) if leaf.dtype else dtype),
        struct, is_leaf=lambda x: isinstance(x, P))


def param_count(struct) -> int:
    return sum(int(np.prod(leaf.shape)) for _, leaf in _leaves(struct))


# ---------------------------------------------------------------------------
# model configuration
# ---------------------------------------------------------------------------

# layer kinds used in layer plans
GLOBAL, LOCAL, SWA, RECURRENT, RWKV = "global", "local", "swa", "recurrent", "rwkv"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | rwkv | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    # layer plan: list of (pattern, repeats); sum(len(p)*r) == n_layers
    layer_plan: tuple[tuple[tuple[str, ...], int], ...] = (((GLOBAL,), 0),)
    window_size: int = 0          # for local/swa layers
    causal: bool = True
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    # modality frontend stub
    frontend: str = "token"       # token | audio_stub | vision_stub
    frontend_dim: int = 0
    n_patches: int = 0
    # recurrent widths
    lru_width: int = 0
    conv_width: int = 4
    rwkv_head_dim: int = 64
    # misc
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # runtime knobs (overridable per cell by the perf loop)
    attn_impl: str = "reference"  # reference | flash
    score_shard: str = "none"     # none | heads | qseq (context-parallel)
    act_shard: str = "dp"         # dp (batch only) | seq (Megatron-SP:
                                  # residual stream sequence-sharded on model)
    attn_dtype: str = "f32"       # f32 | bf16 score/prob materialization
    kv_shard: str = "none"        # none | heads | hd (KV cache TP axis)
    rwkv_unroll: int = 1          # tokens per scan body (state HBM
                                  # round-trips / unroll; Pallas kernel
                                  # equivalent on the dry-run path)
    tp_impl: str = "gspmd"        # gspmd | shard_map (explicit AG/RS TP
                                  # combines; requires zero1 TP params)
    rwkv_impl: str = "scan"       # scan | chunked (per-chunk matmul wkv:
                                  # state HBM traffic / chunk, MXU-friendly)
    rwkv_chunk: int = 64
    batch_axes: tuple = ()        # mesh axes for the batch dim ("" = no
                                  # activation constraints; set by builders)
    remat: str = "none"           # none | full | dots
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a TP-shardable multiple (MaxText-style padding;
        the config keeps the paper-exact vocab_size, logits are sliced)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def layers_in_plan(self) -> int:
        return sum(len(p) * r for p, r in self.layer_plan)

    @property
    def is_decoder(self) -> bool:
        return self.family != "encoder"

    @property
    def kinds(self) -> tuple[str, ...]:
        out = []
        for pattern, r in self.layer_plan:
            out.extend(list(pattern) * r)
        return tuple(out)

    def validate(self) -> "ModelConfig":
        assert self.layers_in_plan == self.n_layers, (
            f"{self.name}: plan covers {self.layers_in_plan} layers, "
            f"config says {self.n_layers}")
        assert self.n_heads % self.n_kv_heads == 0
        return self

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def uniform_plan(kind: str, n_layers: int):
    return (((kind,), n_layers),)


def cycle_plan(pattern: tuple[str, ...], n_layers: int):
    """Repeat ``pattern`` to cover n_layers, with a trailing remainder."""
    p = len(pattern)
    full, rem = divmod(n_layers, p)
    plan = []
    if full:
        plan.append((tuple(pattern), full))
    if rem:
        plan.append((tuple(pattern[:rem]), 1))
    return tuple(plan)
