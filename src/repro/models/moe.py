"""Mixture-of-Experts with grouped sort-based dispatch.

The Hanoi mapping (DESIGN.md SS2b): tokens *diverge* into expert paths and
*reconverge* at the combine.  The dispatch below is the WS-stack discipline
at tile granularity:

* each expert's [capacity, d] buffer is a *path* executed as one dense block
  (paths serialized per shard rather than finely interleaved — the paper's
  cost argument for coarse path scheduling);
* the scatter indices are the *reconvergence mask*: they record which tokens
  rejoin where;
* capacity-dropped tokens are BREAK: removed from the reconvergence mask,
  they rejoin the residual stream only (never waited on).

Dispatch is GROUPED (GShard-style, group = sequence): routing, sort, scatter
and combine are all local to a group, so under SPMD no dispatch step needs a
global collective — a global argsort would force XLA to all-gather the whole
token stream inside every layer.  Supports Mixtral-style top-k over E experts
and DeepSeek-style shared + fine-grained routed experts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ModelConfig, P
from .layers import mlp, mlp_struct


def moe_struct(cfg: ModelConfig):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    s = {
        "router": P((d, E), ("embed", "experts"), scale=0.02),
        "w_gate": P((E, d, ff), ("experts", "embed", "mlp")),
        "w_up": P((E, d, ff), ("experts", "embed", "mlp")),
        "w_down": P((E, ff, d), ("experts", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        s["shared"] = mlp_struct(d, (cfg.moe_d_ff or cfg.d_ff)
                                 * cfg.n_shared_experts)
    return s


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    E, k = cfg.n_experts, cfg.experts_per_token
    cap = int(tokens_per_group * k / E * cfg.capacity_factor)
    return max(4, -(-cap // 4) * 4)


def _dispatch_group(xt, gates, eidx, C: int, E: int, k: int):
    """One group: xt [T, d]; gates/eidx [T, k].  All ops group-local."""
    T, d = xt.shape
    flat_e = eidx.reshape(-1)                            # [T*k]
    order = jnp.argsort(flat_e, stable=True)             # local sort
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * k) - offsets[sorted_e]
    kept = rank < C                                      # BREAK: drop overflow
    dest = jnp.where(kept, sorted_e * C + rank, E * C)   # OOB -> scatter-drop
    src_token = order // k
    buf = jnp.zeros((E * C, d), xt.dtype)
    buf = buf.at[dest].set(xt[src_token], mode="drop")
    slot_gate = (gates.reshape(-1)[order] * kept).astype(xt.dtype)
    return buf, dest, src_token, slot_gate


def _combine_group(ex_out, dest, src_token, slot_gate, T: int):
    # dropped slots have gate 0: the clipped OOB gather contributes nothing
    contrib = ex_out.at[dest].get(mode="clip") * slot_gate[:, None]
    return jnp.zeros((T, ex_out.shape[-1]), ex_out.dtype) \
        .at[src_token].add(contrib)


def moe(params, x, cfg: ModelConfig):
    """x: [B, S, d] -> ([B, S, d], aux).  Groups = sequences (S > 1) or the
    whole batch as one group (decode)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    grouped = S > 1
    xg = x if grouped else x.reshape(1, B, d)            # [G, T, d]
    G, T = xg.shape[0], xg.shape[1]
    C = _capacity(T, cfg)

    logits = (xg @ params["router"].astype(xg.dtype)).astype(jnp.float32)
    gates_all = jax.nn.softmax(logits, axis=-1)          # [G, T, E]
    gates, eidx = jax.lax.top_k(gates_all, k)            # [G, T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    def shard_g(t, *extra):
        """Pin the group dim to the data axes — the vmapped scatters defeat
        SPMD propagation and would replicate every expert-path buffer."""
        if not cfg.batch_axes:
            return t
        from jax.sharding import PartitionSpec as PS
        spec = [tuple(cfg.batch_axes)] + list(extra)
        spec += [None] * (t.ndim - len(spec))
        return jax.lax.with_sharding_constraint(t, PS(*spec))

    buf, dest, src, sgate = jax.vmap(
        lambda xt, g, e: _dispatch_group(xt, g, e, C, E, k))(xg, gates, eidx)
    ex_in = shard_g(buf).reshape(G, E, C, d)             # [G, E, C, d]

    w_gate = params["w_gate"].astype(xg.dtype)
    w_up = params["w_up"].astype(xg.dtype)
    w_down = params["w_down"].astype(xg.dtype)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ex_in, w_gate)) \
        * jnp.einsum("gecd,edf->gecf", ex_in, w_up)
    h = shard_g(h, None, None, "model")                  # ff TP-sharded
    ex_out = jnp.einsum("gecf,efd->gecd", h, w_down)
    ex_out = shard_g(ex_out).reshape(G, E * C, d)

    out = jax.vmap(lambda eo, de, sr, sg:
                   _combine_group(eo, de, sr, sg, T))(ex_out, dest, src, sgate)
    out = shard_g(out)
    out = out if grouped else out.reshape(B, S, d)
    out = out.reshape(B, S, d)

    if cfg.n_shared_experts:
        out = out + mlp(params["shared"], x.reshape(B * S, d)).reshape(
            B, S, d)

    aux = load_balance_loss(gates_all.reshape(-1, E), eidx.reshape(-1, k), E)
    return out, aux


def load_balance_loss(gates_all: jax.Array, eidx: jax.Array, E: int):
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    onehot = jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32)
    f = onehot.mean(0)
    p = gates_all.mean(0)
    return E * jnp.sum(f * p)
