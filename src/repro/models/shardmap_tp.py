"""Explicit-collective TP blocks via shard_map.

GSPMD on this XLA version lowers the row-parallel TP combine as
``all-reduce + dynamic-slice`` (2x wire bytes) instead of a reduce-scatter
(1x) — the SS Perf negative result.  These blocks bypass the partitioner for
the two hot combines (MLP down-projection and attention out-projection):

    all_gather(x, seq axis) -> local matmuls -> psum_scatter(out, seq axis)

which is Megatron sequence-parallelism with the reduce-scatter guaranteed.
Requires TP-resident weights (ZeRO-1 param mode: weights sharded on 'model'
only), and a mesh with ('data'[, 'pod'], 'model') axes in scope.

Autodiff: jax.shard_map is differentiable; psum_scatter transposes to
all_gather and vice versa, so the backward pass gets the mirrored schedule
for free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


def _shmap(body, mesh, in_specs, out_specs):
    # mesh=None: bind to the ambient mesh context at trace time (works under
    # jit with in_shardings meshes; a concrete mesh object would also do)
    try:
        return jax.shard_map(body, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except TypeError:  # older API spellings
        from jax.experimental.shard_map import shard_map
        return shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def mlp_tp(params, x, cfg):
    """Gated-SiLU MLP with explicit AG/RS.  x: [B, S, d] seq-sharded on
    'model', batch on cfg.batch_axes; weights TP-sharded on 'model'."""
    mesh = jax.sharding.get_abstract_mesh()
    b = tuple(cfg.batch_axes)

    def body(x_l, wg, wu, wd):
        # x_l: [B/dp, S/tp, d]; w*: [d, ff/tp] / [ff/tp, d]
        xg = jax.lax.all_gather(x_l, "model", axis=1, tiled=True)
        h = jax.nn.silu(xg @ wg.astype(xg.dtype)) * (xg @ wu.astype(xg.dtype))
        out = h @ wd.astype(xg.dtype)            # partial sums over ff
        return jax.lax.psum_scatter(out, "model", scatter_dimension=1,
                                    tiled=True)

    return _shmap(
        body, mesh,
        in_specs=(PS(b, "model", None), PS(None, "model"),
                  PS(None, "model"), PS("model", None)),
        out_specs=PS(b, "model", None),
    )(x, params["w_gate"], params["w_up"], params["w_down"])


def o_proj_tp(out_heads, wo, cfg):
    """Attention out-projection with explicit RS.  out_heads: [B, S, H, hd]
    heads-sharded on 'model' with FULL sequence (post-attention); wo:
    [H, hd, d] heads-sharded.  Returns [B, S, d] seq-sharded on 'model'."""
    mesh = jax.sharding.get_abstract_mesh()
    b = tuple(cfg.batch_axes)

    def body(oh, wo_l):
        # oh: [B/dp, S, H/tp, hd]; wo_l: [H/tp, hd, d]
        out = jnp.einsum("bshk,hkd->bsd", oh, wo_l.astype(oh.dtype))
        return jax.lax.psum_scatter(out, "model", scatter_dimension=1,
                                    tiled=True)

    return _shmap(
        body, mesh,
        in_specs=(PS(b, None, "model", None), PS("model", None, None)),
        out_specs=PS(b, "model", None),
    )(out_heads, wo)
