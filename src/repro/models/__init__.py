from .base import (GLOBAL, LOCAL, RECURRENT, RWKV, SWA, ModelConfig, P,
                   abstract_params, cycle_plan, init_params, param_count,
                   partition_specs, uniform_plan)
from .transformer import (cache_struct, decode_step, forward, loss_fn,
                          model_struct)

__all__ = [
    "GLOBAL", "LOCAL", "RECURRENT", "RWKV", "SWA", "ModelConfig", "P",
    "abstract_params", "cache_struct", "cycle_plan", "decode_step", "forward",
    "init_params", "loss_fn", "model_struct", "param_count",
    "partition_specs", "uniform_plan",
]
