"""Core neural layers: RMSNorm, RoPE, GQA attention (full / local / SWA,
causal or bidirectional, train and decode paths), gated MLP, embeddings.

Attention masking is expressed through the divergence-mask vocabulary of the
paper's adaptation (see repro.core.divergence): the (q, k) index grid is an
*active mask*; windowed/causal patterns make whole tiles EMPTY (never
scheduled — the Pallas kernel skips them), PARTIAL (predicated) or FULL
(reconverged fast path).  The reference implementation here materializes the
same mask densely so the kernel has an oracle to match bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .base import GLOBAL, LOCAL, RECURRENT, RWKV, SWA, ModelConfig, P


def rmsnorm_struct(d: int):
    return {"scale": P((d,), ("embed",), init="ones")}


def rmsnorm(params, x, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [S] (shared across batch) or [B, S].

    Batch-independent positions stay 1-D so the cos/sin tables broadcast —
    a [B, ...] iota-derived table is replicated by SPMD and can force XLA to
    replicate the (much larger) activation operand instead of sharding it."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq     # [.., S, half]
    if positions.ndim == 1:
        cos = jnp.cos(ang)[None, :, None, :]
        sin = jnp.sin(ang)[None, :, None, :]
    else:
        cos = jnp.cos(ang)[:, :, None, :]
        sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_struct(cfg: ModelConfig):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": P((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((H, hd, d), ("heads", "head_dim", "embed")),
    }


def attn_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
              window: jax.Array | int) -> jax.Array:
    """The active-mask grid: [.., Sq, Sk] bool.  window<=0 means unlimited."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    m = jnp.ones(diff.shape, bool)
    if causal:
        m &= diff >= 0
    m &= jnp.where(window > 0, diff < window, True)
    return m


def shard_act(x, cfg):
    """Pin the residual stream's layout.  SPMD propagation alone flip-flops
    between batch-sharded and TP-sharded layouts inside scanned layers,
    replicating O(activation) buffers; explicit constraints fix the 2-D
    layout.  act_shard='seq' additionally shards the sequence dim on 'model'
    (Megatron sequence parallelism): the per-layer saved carries under remat
    shrink by the TP factor, and XLA inserts the all-gather before qkv /
    reduce-scatter after the out-projection automatically.
    No-op when cfg.batch_axes is empty (single-device tests)."""
    if not cfg.batch_axes:
        return x
    from jax.sharding import PartitionSpec as PS
    seq = "model" if (cfg.act_shard == "seq" and x.shape[1] > 1) else None
    spec = [tuple(cfg.batch_axes), seq] + [None] * (x.ndim - 2)
    return jax.lax.with_sharding_constraint(x, PS(*spec))


def _score_constraint(s, cfg):
    """Pin the O(S^2) score tensor: 'heads' = TP over the head axis;
    'qseq' = context-parallel query axis (archs whose head count does not
    divide the TP axis); 'none' = leave propagation alone."""
    if not cfg.batch_axes or cfg.score_shard == "none":
        return s
    from jax.sharding import PartitionSpec as PS
    b = tuple(cfg.batch_axes)
    if cfg.score_shard == "heads":
        return jax.lax.with_sharding_constraint(s, PS(b, "model", None, None))
    if cfg.score_shard == "qseq":
        return jax.lax.with_sharding_constraint(s, PS(b, None, "model", None))
    return s


def _sdpa(q, k, v, mask, *, scale, cfg):
    """q:[B,Sq,H,hd] k,v:[B,Sk,K,hd] mask:[Sq,Sk] (batch-free) -> out.

    GQA is computed by expanding kv heads to H so the score tensor keeps the
    TP-sharded head axis [B, H, Sq, Sk] (a 5-D (K, G) split defeats SPMD
    propagation when K < mesh model size and replicates O(S^2) bytes)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    # bf16 scores halve the only O(S^2) buffers; exp/max run elementwise so
    # XLA fuses the precision-sensitive pieces either way.  f32 is the
    # default for numerics tests; production cells choose bf16.
    acc = jnp.float32 if cfg.attn_dtype == "f32" else jnp.bfloat16
    logits = jnp.einsum("bqhe,bshe->bhqs", q.astype(acc),
                        k.astype(acc)) * jnp.asarray(scale, acc)
    logits = _score_constraint(logits, cfg)
    neg = jnp.asarray(-3e38 if acc == jnp.float32 else -3e4, acc)
    logits = jnp.where(mask[None, None, :, :], logits, neg)
    if acc == jnp.float32:
        probs = jax.nn.softmax(logits, axis=-1)
        probs = _score_constraint(probs, cfg)
        out = jnp.einsum("bhqs,bshe->bqhe", probs, v.astype(acc))
        return out.astype(q.dtype)
    # bf16 path: the only materialized O(S^2) tensors are bf16.  The stable
    # exp runs in f32 inside the fused elementwise loop; the row-sum
    # accumulates in f32; normalization multiplies by a precomputed f32
    # reciprocal of the [.., Sq, 1] sums (a full-width f32 divide would be
    # materialized by XLA before the convert).
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp((logits - m).astype(jnp.float32)).astype(acc)
    rsum = (1.0 / jnp.maximum(
        jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True), 1e-30))
    probs = (e * rsum.astype(acc))
    probs = _score_constraint(probs, cfg)
    out = jnp.einsum("bhqs,bshe->bqhe", probs, v.astype(acc))
    return out.astype(q.dtype)


def _chunked_sdpa(q, k, v, *, cfg: ModelConfig, window: int, causal: bool,
                  chunk: int = 512):
    """Divergence-aware chunked attention in pure XLA (the Pallas kernel's
    schedule, expressible on the dry-run path): q is processed in chunks;
    for windowed layers each chunk attends only to its [start-window+1,
    start+chunk) KV band — EMPTY tiles are never *computed* (the Hanoi
    path-never-scheduled saving becomes real FLOPs/bytes here, not just
    masking), and no O(S^2) tensor is ever materialized."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # fallback: single chunk
    nq = S // chunk
    if cfg.batch_axes:
        # reshard ONCE per layer to the heads-TP layout: the chunk loop then
        # slices locally (a seq-sharded k/v would be re-gathered every chunk)
        from jax.sharding import PartitionSpec as PS
        h_ax = "model" if cfg.score_shard == "heads" else None
        spec = PS(tuple(cfg.batch_axes), None, h_ax, None)
        q = jax.lax.with_sharding_constraint(q, spec)
        k = jax.lax.with_sharding_constraint(k, spec)
        v = jax.lax.with_sharding_constraint(v, spec)
    acc = jnp.float32 if cfg.attn_dtype == "f32" else jnp.bfloat16
    scale = jnp.asarray(hd ** -0.5, acc)
    band = None
    if window > 0:
        band = min(S, -(-(window + chunk - 1) // chunk) * chunk)

    def one(i):
        qs = i * chunk
        qc = jax.lax.dynamic_slice_in_dim(q, qs, chunk, 1).astype(acc)
        if band is not None:
            ks0 = jnp.clip(qs + chunk - band, 0, S - band)
            kc = jax.lax.dynamic_slice_in_dim(k, ks0, band, 1).astype(acc)
            vc = jax.lax.dynamic_slice_in_dim(v, ks0, band, 1).astype(acc)
            kpos = ks0 + jnp.arange(band)
        else:
            kc, vc = k.astype(acc), v.astype(acc)
            kpos = jnp.arange(S)
        qpos = qs + jnp.arange(chunk)
        live = jnp.ones((chunk, kpos.shape[0]), bool)
        diff = qpos[:, None] - kpos[None, :]
        if causal:
            live &= diff >= 0
        if window > 0:
            live &= diff < window
        s = jnp.einsum("bqhe,bshe->bhqs", qc, kc) * scale
        neg = jnp.asarray(-3e38 if acc == jnp.float32 else -3e4, acc)
        s = jnp.where(live[None, None], s, neg)
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp((s - m).astype(jnp.float32)).astype(acc)
        rs = 1.0 / jnp.maximum(
            jnp.sum(e.astype(jnp.float32), -1, keepdims=True), 1e-30)
        p = e * rs.astype(acc)
        return jnp.einsum("bhqs,bshe->bqhe", p, vc).astype(q.dtype)

    # remat per chunk: the backward pass re-computes each chunk's scores
    # instead of stacking O(S^2) saves across the map
    outs = jax.lax.map(jax.checkpoint(one), jnp.arange(nq))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


def attention(params, x, *, cfg: ModelConfig, kind: str,
              positions: jax.Array, kv_cache=None, cache_pos=None):
    """Train/prefill when kv_cache is None; single-step decode otherwise.

    Decode: x is [B, 1, d]; kv_cache = dict(k=[B, Smax, K, hd], v=...) and
    cache_pos a scalar index; returns (out, new_cache).
    """
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    scale = hd ** -0.5
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cfg.batch_axes and cfg.kv_shard != "none" and kv_cache is None:
        # prefill emits per-layer caches; pin their TP axis so the scan
        # output (the serving artifact) is sharded, not replicated
        from jax.sharding import PartitionSpec as PS
        b = tuple(cfg.batch_axes)
        spec = (PS(b, None, "model", None) if cfg.kv_shard == "heads"
                else PS(b, None, None, "model"))
        k = jax.lax.with_sharding_constraint(k, spec)
        v = jax.lax.with_sharding_constraint(v, spec)

    window = cfg.window_size if kind in (LOCAL, SWA) else 0

    if kv_cache is None:
        causal = cfg.causal
        if cfg.attn_impl == "flash" and causal:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=True, window=window)
        elif cfg.attn_impl == "chunked" and (
                not cfg.batch_axes or cfg.score_shard == "heads"):
            # guard (SS Perf gemma3 refutation): the chunk loop pins q/k/v to
            # a heads-TP layout; when heads don't divide the TP axis that
            # pin REPLICATES them and every chunk recomputes per shard —
            # fall back to the dense masked path for qseq archs
            out = _chunked_sdpa(q, k, v, cfg=cfg, window=window,
                                causal=causal)
        else:
            pos1 = positions if positions.ndim == 1 else positions[0]
            mask = attn_mask(pos1, pos1, causal=causal, window=window)
            out = _sdpa(q, k, v, mask, scale=scale, cfg=cfg)
        new_cache = {"k": k, "v": v}
    else:
        # Ring-buffer cache: windowed layers size their cache to the window,
        # so the write index wraps and every resident entry is in-window by
        # construction; global layers have cache length >= max positions so
        # the modulo is the identity.  Cached keys were RoPE-rotated at their
        # true positions, so scores stay relative-correct after wrapping.
        Smax = kv_cache["k"].shape[1]
        widx = cache_pos % Smax
        ck = jax.lax.dynamic_update_slice(kv_cache["k"], k, (0, widx, 0, 0))
        cv = jax.lax.dynamic_update_slice(kv_cache["v"], v, (0, widx, 0, 0))
        n_valid = jnp.minimum(cache_pos + 1, Smax)
        mask = (jnp.arange(Smax, dtype=jnp.int32) < n_valid)[None, :]
        mask = jnp.broadcast_to(mask, (S, Smax))
        out = _sdpa(q, ck, cv, mask, scale=scale, cfg=cfg)
        new_cache = {"k": ck, "v": cv}

    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, new_cache


def attention_cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    K, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": P((batch, max_len, K, hd),
               ("batch", "cache_seq", "kv_heads", "head_dim"), init="zeros"),
        "v": P((batch, max_len, K, hd),
               ("batch", "cache_seq", "kv_heads", "head_dim"), init="zeros"),
    }


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------

def mlp_struct(d: int, ff: int):
    return {
        "w_gate": P((d, ff), ("embed", "mlp")),
        "w_up": P((d, ff), ("embed", "mlp")),
        "w_down": P((ff, d), ("mlp", "embed")),
    }


def mlp(params, x):
    h = jax.nn.silu(x @ params["w_gate"].astype(x.dtype)) \
        * (x @ params["w_up"].astype(x.dtype))
    return h @ params["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / heads / frontends
# ---------------------------------------------------------------------------

def embed_struct(cfg: ModelConfig):
    s = {"tok": P((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"))}
    if cfg.frontend in ("audio_stub", "vision_stub"):
        s["frontend_proj"] = P((cfg.frontend_dim, cfg.d_model),
                               ("frontend", "embed"))
    return s


def head_struct(cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}
    return {"w": P((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"))}


def lm_logits(head_params, embed_params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = embed_params["tok"].astype(x.dtype).T
    else:
        w = head_params["w"].astype(x.dtype)
    logits = x @ w
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., :cfg.vocab_size]
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean CE over valid positions; logits [.., V], labels int [..]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: a vocab-axis gather
    # forces an all-gather of TP-sharded logits; the einsum partitions clean
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("...v,...v->...", logits, onehot)
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
