"""Recurrent temporal-mix layers: RG-LRU (RecurrentGemma/Griffin) and RWKV-6
(Finch, data-dependent decay).  Both expose a parallel (train/prefill) path
via associative scan / blocked scan and a single-step path for decode.

These are the sub-quadratic families the long_500k shape exercises.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ModelConfig, P

# ---------------------------------------------------------------------------
# RG-LRU (Griffin): h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
# ---------------------------------------------------------------------------

_C_LOG_A = -8.0     # Griffin's  c * softplus(Lambda)  scaling


def rglru_struct(cfg: ModelConfig):
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    cw = cfg.conv_width
    return {
        "in_x": P((d, w), ("embed", "mlp")),
        "in_y": P((d, w), ("embed", "mlp")),
        "conv_w": P((cw, w), ("conv", "mlp"), scale=0.02),
        "conv_b": P((w,), ("mlp",), init="zeros"),
        "gate_a": P((w, w), ("mlp", "mlp2"), scale=0.02),
        "gate_i": P((w, w), ("mlp", "mlp2"), scale=0.02),
        "log_lambda": P((w,), ("mlp",), init="ones"),
        "out": P((w, d), ("mlp", "embed")),
    }


def _rglru_coeffs(params, xb):
    """Per-step recurrence coefficients a_t, b_t from branch input xb."""
    r = jax.nn.sigmoid(xb @ params["gate_a"].astype(xb.dtype))
    i = jax.nn.sigmoid(xb @ params["gate_i"].astype(xb.dtype))
    log_a = _C_LOG_A * jax.nn.softplus(
        params["log_lambda"].astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i.astype(jnp.float32) * xb.astype(jnp.float32))
    return a, b


def _conv1d(params, x, state=None):
    """Causal depthwise conv along time. x: [B, S, w]."""
    cw = params["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * params["conv_w"][i].astype(x.dtype)
              for i in range(cw))
    new_state = xp[:, -(cw - 1):, :] if cw > 1 else xp[:, :0, :]
    return out + params["conv_b"].astype(x.dtype), new_state


def rglru(params, x, *, cfg: ModelConfig, state=None, use_kernel: bool = False):
    """x: [B, S, d].  state = dict(conv=[B,cw-1,w], h=[B,w]) for decode.

    Returns (out [B,S,d], new_state)."""
    gx = jax.nn.gelu(x @ params["in_x"].astype(x.dtype))
    xb = x @ params["in_y"].astype(x.dtype)
    xb, conv_state = _conv1d(params, xb, None if state is None
                             else state["conv"])
    a, b = _rglru_coeffs(params, xb)

    if state is None:
        if use_kernel:
            from repro.kernels import ops as kops
            h = kops.rglru_scan(a, b)
        else:
            def bin_op(p, q):
                a1, b1 = p
                a2, b2 = q
                return a1 * a2, a2 * b1 + b2
            _, h = jax.lax.associative_scan(bin_op, (a, b), axis=1)
        h0 = jnp.zeros((x.shape[0], a.shape[-1]), jnp.float32)
    else:
        h = (a * state["h"][:, None, :] + b)     # S == 1
        h0 = None
    h = h.astype(x.dtype)
    out = (gx * h) @ params["out"].astype(x.dtype)
    new_state = {"conv": conv_state, "h": h[:, -1, :].astype(jnp.float32)}
    return out, new_state


def rglru_state_struct(cfg: ModelConfig, batch: int):
    w, cw = cfg.lru_width or cfg.d_model, cfg.conv_width
    return {"conv": P((batch, cw - 1, w), ("batch", None, "mlp"),
                      init="zeros"),
            "h": P((batch, w), ("batch", "mlp"), init="zeros",
                   dtype="float32")}


# ---------------------------------------------------------------------------
# RWKV-6 (Finch) time-mix + channel-mix
# ---------------------------------------------------------------------------

def rwkv6_struct(cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    lora = max(32, d // 16)
    return {
        "tm": {   # time-mix interpolation deltas (data-dependent, Finch)
            "mu_base": P((5, d), (None, "embed"), init="zeros"),
            "lora_a": P((d, lora), ("embed", "mlp"), scale=0.02),
            "lora_b": P((5, lora, d), (None, "mlp", "embed"), scale=0.02),
            "wr": P((d, d), ("embed", "heads_x")),
            "wk": P((d, d), ("embed", "heads_x")),
            "wv": P((d, d), ("embed", "heads_x")),
            "wg": P((d, d), ("embed", "heads_x")),
            "wo": P((d, d), ("heads_x", "embed")),
            "decay_base": P((d,), ("embed",), init="zeros"),
            "decay_a": P((d, lora), ("embed", "mlp"), scale=0.02),
            "decay_b": P((lora, d), ("mlp", "embed"), scale=0.02),
            "bonus": P((H, hd), ("heads", "head_dim"), init="zeros"),
            "ln_x": P((d,), ("embed",), init="ones"),
        },
        "cm": {   # channel mix
            "mu_k": P((d,), ("embed",), init="zeros"),
            "wk": P((d, cfg.d_ff), ("embed", "mlp")),
            "wv": P((cfg.d_ff, d), ("mlp", "embed")),
            "mu_r": P((d,), ("embed",), init="zeros"),
            "wr": P((d, d), ("embed", "heads_x")),
        },
    }


def _token_shift(x, last):
    """shifted[t] = x[t-1]; position 0 takes `last` (decode state)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def rwkv6_time_mix(p, x, *, cfg: ModelConfig, state=None,
                   use_kernel: bool = False):
    """x: [B, S, d]. state = dict(shift=[B,1,d], wkv=[B,H,hd,hd])."""
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    shift_in = None if state is None else state["shift"]
    xs = _token_shift(x, shift_in)
    dx = xs - x
    # data-dependent interpolation (Finch lora)
    lx = jnp.tanh(x @ p["lora_a"].astype(x.dtype))
    mu = p["mu_base"].astype(x.dtype)[:, None, None, :] \
        + jnp.einsum("bsl,nld->nbsd", lx, p["lora_b"].astype(x.dtype))
    xr, xk, xv, xg, xw = [x + dx * (mu[i]) for i in range(5)]

    r = (xr @ p["wr"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(B, S, H, hd)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    # data-dependent decay  w_t in (0, 1)
    dw = jnp.tanh(xw @ p["decay_a"].astype(x.dtype)) @ p["decay_b"].astype(x.dtype)
    logw = -jnp.exp(jnp.clip(p["decay_base"].astype(jnp.float32)
                             + dw.astype(jnp.float32), -8.0, 4.0))
    w = jnp.exp(logw).reshape(B, S, H, hd)                 # decay per channel
    u = p["bonus"].astype(jnp.float32)                     # [H, hd]

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    wf = w.astype(jnp.float32)

    if state is None and use_kernel:
        from repro.kernels import ops as kops
        out, s_last = kops.rwkv6_scan(rf, kf, vf, wf, u)
    elif (state is None and cfg.rwkv_impl == "chunked"
          and (ch := rwkv6_wkv_chunked(
              rf, kf, vf, logw.reshape(B, S, H, hd), u,
              chunk=cfg.rwkv_chunk)) is not None):
        out, s_last = ch
    else:
        s0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if state is None
              else state["wkv"])
        un = max(1, cfg.rwkv_unroll) if state is None else 1
        if S % un:
            un = 1

        def step(s, inp):
            # `un` tokens per scan body: the [hd, hd] state round-trips HBM
            # once per body instead of once per token (the VMEM-resident
            # Pallas kernel takes this to a full chunk on real TPUs)
            outs = []
            for t in range(un):
                rt, kt, vt, wt = (x[:, t] for x in inp)    # [B, H, hd]
                at = kt[..., :, None] * vt[..., None, :]   # [B,H,hd,hd]
                outs.append(jnp.einsum("bhk,bhkv->bhv", rt,
                                       s + u[None, :, :, None] * at))
            # recompute the state once over the body (fused elementwise)
                s = wt[..., :, None] * s + at
            return s, jnp.stack(outs, axis=1)

        xs_t = tuple(
            jnp.moveaxis(t, 1, 0).reshape(S // un, un, B, H, hd)
            .transpose(0, 2, 1, 3, 4)
            for t in (rf, kf, vf, wf))                      # [S/un,B,un,H,hd]
        s_last, out = jax.lax.scan(step, s0, xs_t)
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)

    out = out.reshape(B, S, d).astype(x.dtype)
    # group norm over heads (ln_x), then gate
    out = out.reshape(B, S, H, hd)
    mean = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = ((out - mean) * jax.lax.rsqrt(var + 64e-5)).reshape(B, S, d)
    out = out * p["ln_x"].astype(x.dtype)
    out = (out * g) @ p["wo"].astype(x.dtype)
    new_state = {"shift": x[:, -1:, :], "wkv": s_last}
    return out, new_state


def rwkv6_channel_mix(p, x, *, state=None):
    xs = _token_shift(x, None if state is None else state["shift"])
    dx = xs - x
    xk = x + dx * p["mu_k"].astype(x.dtype)
    xr = x + dx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    r = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype))
    out = r * (k @ p["wv"].astype(x.dtype))
    return out, {"shift": x[:, -1:, :]}


def rwkv6_state_struct(cfg: ModelConfig, batch: int):
    d, hd = cfg.d_model, cfg.rwkv_head_dim
    H = d // hd
    return {
        "tm_shift": P((batch, 1, d), ("batch", None, "embed"), init="zeros"),
        "wkv": P((batch, H, hd, hd), ("batch", "heads", None, None),
                 init="zeros", dtype="float32"),
        "cm_shift": P((batch, 1, d), ("batch", None, "embed"), init="zeros"),
    }


def rwkv6_wkv_chunked(r, k, v, logw, u, *, chunk: int = 64):
    """Chunked-parallel RWKV-6 wkv: per-chunk MATMULS instead of a per-token
    scan.  The [hd, hd] state round-trips HBM once per CHUNK (the naive scan
    does it per token — the dominant memory term of the rwkv6 cells), and the
    intra-chunk work becomes MXU-shaped [c, c] products.

    Derivation (per head; D_t = diag(w_t), P_t = prod_{j<=t} D_j):
      S_t   = D_t S_{t-1} + k_t v_t^T
      out_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
            = (r_t*P_{t-1}) S_0  +  sum_{i<t} (r_t*P_{t-1}/P_i . k_i) v_i
              + (r_t*u . k_t) v_t
    with P in log space (clw = cumsum(log w), exponents of the pairwise term
    are clw_{t-1}-clw_i <= 0 for i < t: always safe; the factored split
    a = r*exp(clw_shift), b = k*exp(-clw) clips clw at -30 — contributions
    below e^-30 are zero in f32 anyway).

    r,k,v,logw: [B, S, H, hd] f32; u: [H, hd].  Returns (out, s_last).
    """
    B, S, H, hd = r.shape
    c = min(chunk, S)
    if S % c:
        return None                     # caller falls back to the scan
    n = S // c
    rc, kc, vc, lwc = (t.reshape(B, n, c, H, hd).transpose(1, 0, 2, 3, 4)
                       for t in (r, k, v, logw))
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def one(s_in, inp):
        rt, kt, vt, lw = inp                        # [B, c, H, hd]
        clw = jnp.cumsum(lw, axis=1)                # inclusive
        clw_sh = jnp.concatenate(
            [jnp.zeros_like(clw[:, :1]), clw[:, :-1]], axis=1)  # exclusive
        clip = lambda x: jnp.clip(x, -30.0, 0.0)
        a = rt * jnp.exp(clip(clw_sh))              # r * P_{t-1}/P_chunkstart
        b = kt * jnp.exp(-jnp.maximum(clw, -30.0))  # k / P_i   (safe: >= e^-30 ... e^+30? no: -clw in [0, 30])
        # state term: (r*P_{t-1}) . S_in
        out = jnp.einsum("bthd,bhdv->bthv", a, s_in)
        # intra-chunk: strictly-lower-triangular pairwise term
        scores = jnp.einsum("bthd,bihd->bhti", a, b)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        out = out + jnp.einsum("bhti,bihd->bthd", scores, vt)
        # bonus diagonal
        out = out + jnp.einsum("bthd,bthd->bth", rt * u[None, None], kt)[
            ..., None] * vt
        # state update: S_out = P_last S_in + sum_i (k_i P_last/P_i) v_i^T
        decay_all = jnp.exp(clip(clw[:, -1:]))      # [B, 1, H, hd]
        k_dec = kt * jnp.exp(clip(clw[:, -1:] - clw))
        s_out = decay_all[:, 0, :, :, None] * s_in \
            + jnp.einsum("bihd,bihv->bhdv", k_dec, vt)
        return s_out, out

    s_last, outs = jax.lax.scan(one, s0, (rc, kc, vc, lwc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return out, s_last
