"""Hanoi as a vectorized JAX state machine.

This is the TPU-native rendering of the paper's SS VII microarchitecture: all
control flow of the *simulated* machine (WS/REC stacks, Bx file, waiting and
finished masks) is data, the scheduler loop is a ``lax.while_loop`` and the
per-opcode semantics a ``lax.switch`` — so the whole simulator JIT-compiles
and ``vmap``s over warps.  Trace-driven C++ GPU simulators execute one warp
at a time on a scalar host; here thousands of warps step in lockstep on SIMD
hardware, which is exactly the control-flow-to-dataflow transformation the
paper studies, applied to the simulator itself.

Semantics are property-tested for exact equivalence with the numpy reference
(`repro.core.interp.run_hanoi`) over random structured programs and the full
benchmark suite.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .isa import CMP_EQ, CMP_GE, CMP_GT, CMP_LE, CMP_LT, CMP_NE, MachineConfig, Op

U32 = jnp.uint32
I32 = jnp.int32

# error bit flags
ERR_NO_FREE_BX = 1


class HanoiState(NamedTuple):
    # warp-split stack (SS VII: one entry per path; top = executing path)
    ws_pc: jax.Array      # i32[SD]
    ws_mask: jax.Array    # u32[SD]
    ws_top: jax.Array     # i32  (-1 = empty)
    # reconvergence stack (one entry per pending reconvergence point)
    rec_pc: jax.Array     # i32[SD]
    rec_bx: jax.Array     # i32[SD]
    rec_top: jax.Array    # i32
    # Bx register file
    bx_val: jax.Array     # u32[NB]
    bx_valid: jax.Array   # bool[NB]
    waiting: jax.Array    # u32
    finished: jax.Array   # u32
    # architectural state
    regs: jax.Array       # i32[W, NR]
    preds: jax.Array      # bool[W, NP]
    mem: jax.Array        # i32[M]
    lane_ids: jax.Array   # i32[W]
    # trace ring + bookkeeping
    trace_pc: jax.Array   # i32[T]
    trace_mask: jax.Array  # u32[T]
    trace_n: jax.Array    # i32
    steps: jax.Array      # i32
    fuel: jax.Array       # i32
    halted: jax.Array     # bool
    error: jax.Array      # i32 bit flags


def _lane_bits(cfg: MachineConfig) -> jax.Array:
    return (U32(1) << jnp.arange(cfg.n_threads, dtype=U32))


def _mask_to_vec(mask: jax.Array, cfg: MachineConfig) -> jax.Array:
    return (mask & _lane_bits(cfg)) != 0


def _vec_to_mask(vec: jax.Array, cfg: MachineConfig) -> jax.Array:
    return jnp.sum(jnp.where(vec, _lane_bits(cfg), U32(0)), dtype=U32)


def _first_lane(mask: jax.Array, cfg: MachineConfig) -> jax.Array:
    return jnp.argmax(_mask_to_vec(mask, cfg)).astype(I32)


def _popcount(mask: jax.Array) -> jax.Array:
    return lax.population_count(mask).astype(I32)


def init_state(program_len: int, cfg: MachineConfig, *,
               init_regs=None, init_mem=None, lane_ids=None,
               active0: int | None = None) -> HanoiState:
    W, SD, T = cfg.n_threads, cfg.n_threads + 2, cfg.max_steps
    full = U32(cfg.full_mask if active0 is None else active0)
    ws_pc = jnp.zeros(SD, I32)
    ws_mask = jnp.zeros(SD, U32).at[0].set(full)
    regs = (jnp.zeros((W, cfg.n_regs), I32) if init_regs is None
            else jnp.asarray(init_regs, I32).reshape(W, cfg.n_regs))
    mem = (jnp.zeros(cfg.mem_size, I32) if init_mem is None
           else jnp.asarray(init_mem, I32).reshape(cfg.mem_size))
    lanes = (jnp.arange(W, dtype=I32) if lane_ids is None
             else jnp.asarray(lane_ids, I32).reshape(W))
    return HanoiState(
        ws_pc=ws_pc, ws_mask=ws_mask, ws_top=jnp.asarray(0, I32),
        rec_pc=jnp.zeros(SD, I32), rec_bx=jnp.zeros(SD, I32),
        rec_top=jnp.asarray(-1, I32),
        bx_val=jnp.zeros(cfg.n_bx, U32),
        bx_valid=jnp.zeros(cfg.n_bx, bool),
        waiting=U32(0), finished=U32(0),
        regs=regs, preds=jnp.zeros((W, cfg.n_preds), bool), mem=mem,
        lane_ids=lanes,
        trace_pc=jnp.full(T, -1, I32), trace_mask=jnp.zeros(T, U32),
        trace_n=jnp.asarray(0, I32), steps=jnp.asarray(0, I32),
        fuel=jnp.asarray(cfg.max_steps, I32),
        halted=jnp.asarray(False), error=jnp.asarray(0, I32))


def _pred_vec(preds: jax.Array, p: jax.Array, cfg: MachineConfig) -> jax.Array:
    """Predicate guard vector for encoded predicate field p (0 / +k / -k)."""
    idx = jnp.abs(p) - 1
    val = preds[:, jnp.clip(idx, 0, cfg.n_preds - 1)]
    return jnp.where(p == 0, True, jnp.where(p > 0, val, ~val))


def _cmp(a, b, code):
    return lax.switch(jnp.clip(code, 0, 5), [
        lambda: a == b, lambda: a != b, lambda: a < b,
        lambda: a <= b, lambda: a > b, lambda: a >= b])


# ---------------------------------------------------------------------------
# the scheduler step
# ---------------------------------------------------------------------------

def _step(s: HanoiState, program: jax.Array, cfg: MachineConfig,
          skip_vec: jax.Array, majority_first: bool) -> HanoiState:
    W, NB = cfg.n_threads, cfg.n_bx
    FULL = U32(cfg.full_mask)

    # ---- 1) reconvergence check (SS VII-B) --------------------------------
    has_rec = s.rec_top >= 0
    rtop = jnp.clip(s.rec_top, 0)
    rbx = s.rec_bx[rtop]
    rvalid = has_rec & s.bx_valid[rbx]
    live = s.bx_val[rbx] & ~s.finished
    can_reconv = rvalid & ((live & ~s.waiting) == 0)

    def do_reconv(s: HanoiState) -> HanoiState:
        new_top = jnp.where(live != 0, s.ws_top + 1, s.ws_top)
        return s._replace(
            rec_top=s.rec_top - 1,
            bx_valid=s.bx_valid.at[rbx].set(False),
            waiting=s.waiting & ~live,
            ws_pc=jnp.where(live != 0,
                            s.ws_pc.at[s.ws_top + 1].set(s.rec_pc[rtop] + 1),
                            s.ws_pc),
            ws_mask=jnp.where(live != 0,
                              s.ws_mask.at[s.ws_top + 1].set(live),
                              s.ws_mask),
            ws_top=new_top,
            fuel=s.fuel - 1)

    # ---- 2) execute top-of-WS ----------------------------------------------
    def do_exec(s: HanoiState) -> HanoiState:
        empty = s.ws_top < 0
        top = jnp.clip(s.ws_top, 0)
        pc = s.ws_pc[top]
        amask = s.ws_mask[top]
        oob = (pc < 0) | (pc >= program.shape[0])

        def halt(s):
            return s._replace(halted=True, fuel=s.fuel - 1)

        def implicit_exit(s):   # fell off the program: treat as EXIT
            bxv = jnp.where(s.bx_valid, s.bx_val & ~amask, s.bx_val)
            return s._replace(finished=s.finished | amask, bx_val=bxv,
                              ws_top=s.ws_top - 1, fuel=s.fuel - 1)

        def exec_instr(s: HanoiState) -> HanoiState:
            f = program[jnp.clip(pc, 0, program.shape[0] - 1)]
            op, dst, s0, s1, s2, imm, p1, p2 = (f[i] for i in range(8))
            guard = (_pred_vec(s.preds, p1, cfg)
                     & _pred_vec(s.preds, p2, cfg))
            execm = amask & _vec_to_mask(guard, cfg)
            ev = _mask_to_vec(execm, cfg)
            # trace
            s = s._replace(
                trace_pc=s.trace_pc.at[s.trace_n].set(pc),
                trace_mask=s.trace_mask.at[s.trace_n].set(amask),
                trace_n=s.trace_n + 1, steps=s.steps + 1, fuel=s.fuel - 1)

            def set_pc(st, v):
                return st._replace(ws_pc=st.ws_pc.at[top].set(v))

            def h_fallthrough(st):
                return set_pc(st, pc + 1)

            def h_bra(st):
                taken, ft = execm, amask & ~execm
                n_t, n_f = _popcount(taken), _popcount(ft)

                def uniform(st):
                    return set_pc(st, jnp.where(taken == 0, pc + 1, imm))

                def diverge(st):
                    maj_is_ft = jnp.asarray(majority_first) & (n_f > n_t)
                    pc_lo = jnp.where(maj_is_ft, imm, pc + 1)
                    m_lo = jnp.where(maj_is_ft, taken, ft)
                    pc_hi = jnp.where(maj_is_ft, pc + 1, imm)
                    m_hi = jnp.where(maj_is_ft, ft, taken)
                    return st._replace(
                        ws_pc=st.ws_pc.at[top].set(pc_lo)
                                      .at[top + 1].set(pc_hi),
                        ws_mask=st.ws_mask.at[top].set(m_lo)
                                          .at[top + 1].set(m_hi),
                        ws_top=st.ws_top + 1)

                return lax.cond((taken == 0) | (ft == 0), uniform, diverge, st)

            def h_exit(st):
                fin = execm
                bxv = jnp.where(st.bx_valid, st.bx_val & ~fin, st.bx_val)
                rem = amask & ~fin
                st = st._replace(finished=st.finished | fin, bx_val=bxv)
                return lax.cond(
                    rem == 0,
                    lambda st: st._replace(ws_top=st.ws_top - 1),
                    lambda st: st._replace(
                        ws_pc=st.ws_pc.at[top].set(pc + 1),
                        ws_mask=st.ws_mask.at[top].set(rem)),
                    st)

            def h_bssy(st):
                def doit(st):
                    return st._replace(
                        bx_val=st.bx_val.at[dst].set(amask),
                        bx_valid=st.bx_valid.at[dst].set(True),
                        rec_pc=st.rec_pc.at[st.rec_top + 1].set(imm),
                        rec_bx=st.rec_bx.at[st.rec_top + 1].set(dst),
                        rec_top=st.rec_top + 1)
                st = lax.cond(execm != 0, doit, lambda st: st, st)
                return set_pc(st, pc + 1)

            def _park(st):
                """Sync point is not REC-top: retry after the sibling."""
                def swap(st):
                    a, b = st.ws_pc[top], st.ws_pc[top - 1]
                    ma, mb = st.ws_mask[top], st.ws_mask[top - 1]
                    return st._replace(
                        ws_pc=st.ws_pc.at[top].set(b).at[top - 1].set(a),
                        ws_mask=st.ws_mask.at[top].set(mb)
                                          .at[top - 1].set(ma))
                return lax.cond(st.ws_top >= 1, swap, lambda st: st, st)

            def h_bsync(st):
                b = dst
                at_top = (st.rec_top >= 0) & (st.rec_bx[rtop_of(st)] == b)
                lv = st.bx_val[b] & ~st.finished
                skip = skip_vec[jnp.clip(pc, 0, skip_vec.shape[0] - 1)] \
                    & st.bx_valid[b] & (lv != amask)

                def do_skip(st):   # Turing-oracle heuristic (SS IX)
                    return set_pc(st._replace(
                        bx_val=st.bx_val.at[b].set(st.bx_val[b] & ~amask)),
                        pc + 1)

                def do_wait(st):
                    return st._replace(ws_top=st.ws_top - 1,
                                       waiting=st.waiting | amask)

                return lax.cond(skip, do_skip,
                                lambda st: lax.cond(at_top, do_wait, _park,
                                                    st), st)

            def rtop_of(st):
                return jnp.clip(st.rec_top, 0)

            def h_warpsync(st):
                m = jnp.where(
                    s0 == -1, imm.astype(U32),
                    st.regs[_first_lane(jnp.where(execm != 0, execm, amask),
                                        cfg), jnp.clip(s0, 0)].astype(U32)
                ) & FULL
                idx = jnp.arange(st.rec_pc.shape[0])
                present = jnp.any((idx <= st.rec_top) & (st.rec_pc == pc))
                at_top = (st.rec_top >= 0) & (st.rec_pc[rtop_of(st)] == pc)

                def push_new(st):
                    free_any = jnp.any(~st.bx_valid)
                    free = jnp.argmin(st.bx_valid).astype(I32)

                    def ok(st):
                        return st._replace(
                            bx_val=st.bx_val.at[free].set(m & ~st.finished),
                            bx_valid=st.bx_valid.at[free].set(True),
                            rec_pc=st.rec_pc.at[st.rec_top + 1].set(pc),
                            rec_bx=st.rec_bx.at[st.rec_top + 1].set(free),
                            rec_top=st.rec_top + 1,
                            ws_top=st.ws_top - 1,
                            waiting=st.waiting | amask)

                    def err(st):
                        return set_pc(st._replace(
                            error=st.error | ERR_NO_FREE_BX), pc + 1)

                    return lax.cond(free_any, ok, err, st)

                def join(st):
                    return st._replace(ws_top=st.ws_top - 1,
                                       waiting=st.waiting | amask)

                return lax.cond(
                    ~present, push_new,
                    lambda st: lax.cond(at_top, join, _park, st), st)

            def h_break(st):
                return set_pc(st._replace(
                    bx_val=st.bx_val.at[dst].set(st.bx_val[dst] & ~execm)),
                    pc + 1)

            def h_bmov_b2r(st):
                def doit(st):
                    v = st.bx_val[s0].astype(I32)
                    return st._replace(
                        regs=jnp.where(ev[:, None]
                                       & (jnp.arange(cfg.n_regs) == dst),
                                       v, st.regs),
                        bx_valid=st.bx_valid.at[s0].set(False))
                return set_pc(lax.cond(execm != 0, doit, lambda st: st, st),
                              pc + 1)

            def h_bmov_r2b(st):
                def doit(st):
                    v = st.regs[_first_lane(execm, cfg), jnp.clip(s0, 0)]
                    return st._replace(
                        bx_val=st.bx_val.at[dst].set(
                            v.astype(U32) & FULL & ~st.finished),
                        bx_valid=st.bx_valid.at[dst].set(True))
                return set_pc(lax.cond(execm != 0, doit, lambda st: st, st),
                              pc + 1)

            def h_yield(st):
                st = set_pc(st, pc + 1)

                def try_swap(st):
                    rb = st.rec_bx[rtop_of(st)]
                    lv = st.bx_val[rb] & ~st.finished
                    sib = ((st.rec_top >= 0) & st.bx_valid[rb]
                           & (((st.ws_mask[top] | st.ws_mask[top - 1])
                               & ~lv) == 0))

                    def swap(st):
                        a, b = st.ws_pc[top], st.ws_pc[top - 1]
                        ma, mb = st.ws_mask[top], st.ws_mask[top - 1]
                        return st._replace(
                            ws_pc=st.ws_pc.at[top].set(b).at[top - 1].set(a),
                            ws_mask=st.ws_mask.at[top].set(mb)
                                              .at[top - 1].set(ma))
                    return lax.cond(sib, swap, lambda st: st, st)

                return lax.cond(st.ws_top >= 1, try_swap, lambda st: st, st)

            def h_call(st):
                return set_pc(st, jnp.where(execm != 0, imm, pc + 1))

            def h_ret(st):
                tgt = st.regs[_first_lane(jnp.where(execm != 0, execm, amask),
                                          cfg), jnp.clip(s0, 0)]
                return set_pc(st, jnp.where(execm != 0, tgt, pc + 1))

            # ---- ALU / memory ----------------------------------------------
            def upd_reg(st, val_vec):
                return st._replace(regs=jnp.where(
                    ev[:, None] & (jnp.arange(cfg.n_regs) == dst),
                    val_vec[:, None], st.regs))

            R = s.regs

            def h_mov(st):
                return set_pc(upd_reg(st, jnp.full(W, imm, I32)), pc + 1)

            def h_movr(st):
                return set_pc(upd_reg(st, R[:, jnp.clip(s0, 0)]), pc + 1)

            def _bin(fn):
                def h(st):
                    a, b = R[:, jnp.clip(s0, 0)], R[:, jnp.clip(s1, 0)]
                    return set_pc(upd_reg(st, fn(a, b)), pc + 1)
                return h

            def h_iaddi(st):
                return set_pc(upd_reg(st, R[:, jnp.clip(s0, 0)] + imm), pc + 1)

            def h_shl(st):
                return set_pc(
                    upd_reg(st, R[:, jnp.clip(s0, 0)] << (imm & 31)), pc + 1)

            def h_shr(st):
                v = (R[:, jnp.clip(s0, 0)].astype(U32) >> (imm & 31).astype(U32))
                return set_pc(upd_reg(st, v.astype(I32)), pc + 1)

            def h_isetp(st):
                a = R[:, jnp.clip(s0, 0)]
                b = jnp.where(s1 == -1, jnp.full(W, imm, I32),
                              R[:, jnp.clip(s1, 0)])
                res = _cmp(a, b, s2)
                preds = jnp.where(
                    ev[:, None] & (jnp.arange(cfg.n_preds) == dst),
                    res[:, None], st.preds)
                return set_pc(st._replace(preds=preds), pc + 1)

            def h_laneid(st):
                return set_pc(upd_reg(st, st.lane_ids), pc + 1)

            def h_ldg(st):
                addr = (R[:, jnp.clip(s0, 0)] + imm) % cfg.mem_size
                return set_pc(upd_reg(st, st.mem[addr]), pc + 1)

            def h_stg(st):
                def body(t, mem):
                    a = (R[t, jnp.clip(s0, 0)] + imm) % cfg.mem_size
                    return jnp.where(ev[t], mem.at[a].set(R[t, jnp.clip(s1, 0)]),
                                     mem)
                return set_pc(st._replace(
                    mem=lax.fori_loop(0, W, body, st.mem)), pc + 1)

            def _atomic(kind):
                def h(st):
                    def body(t, carry):
                        mem, regs = carry
                        a = (regs[t, jnp.clip(s0, 0)] + imm) % cfg.mem_size
                        old = mem[a]
                        bval = regs[t, jnp.clip(s1, 0)]
                        if kind == "cas":
                            cval = regs[t, jnp.clip(s2, 0)]
                            new = jnp.where(old == bval, cval, old)
                        elif kind == "exch":
                            new = bval
                        else:
                            new = old + bval
                        mem = jnp.where(ev[t], mem.at[a].set(new), mem)
                        regs = jnp.where(
                            ev[t], regs.at[t, jnp.clip(dst, 0)].set(old), regs)
                        return mem, regs
                    mem, regs = lax.fori_loop(0, W, body, (st.mem, st.regs))
                    return set_pc(st._replace(mem=mem, regs=regs), pc + 1)
                return h

            handlers = [
                h_fallthrough,                      # NOP
                h_exit, h_bra, h_bssy, h_bsync,
                h_bmov_b2r, h_bmov_r2b, h_break, h_warpsync, h_yield,
                h_call, h_ret,
                h_mov, h_movr,
                _bin(lambda a, b: a + b),           # IADD
                h_iaddi,
                _bin(lambda a, b: a * b),           # IMUL
                _bin(lambda a, b: a & b),           # AND
                _bin(lambda a, b: a | b),           # OR
                _bin(lambda a, b: a ^ b),           # XOR
                h_shl, h_shr, h_isetp, h_laneid,
                h_ldg, h_stg,
                _atomic("cas"), _atomic("exch"), _atomic("add"),
            ]
            return lax.switch(jnp.clip(op, 0, len(handlers) - 1), handlers, s)

        return lax.cond(empty, halt,
                        lambda s: lax.cond(oob, implicit_exit, exec_instr, s),
                        s)

    return lax.cond(can_reconv, do_reconv, do_exec, s)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "majority_first"))
def _run(program: jax.Array, state: HanoiState, skip_vec: jax.Array,
         cfg: MachineConfig, majority_first: bool) -> HanoiState:
    def cond(s: HanoiState):
        return (~s.halted) & (s.fuel > 0)

    def body(s: HanoiState):
        return _step(s, program, cfg, skip_vec, majority_first)

    return lax.while_loop(cond, body, state)


def run_hanoi_jax(program: np.ndarray,
                  cfg: MachineConfig = MachineConfig(),
                  *, init_regs=None, init_mem=None, lane_ids=None,
                  active0: int | None = None,
                  bsync_skip_pcs=(), majority_first: bool = True,
                  pad_to: int | None = None) -> HanoiState:
    """JIT-compiled single-warp run.  Returns the final :class:`HanoiState`.

    ``pad_to`` pads the program table (with trailing EXITs, unreachable) to a
    fixed length so repeated calls reuse the compiled executable.
    """
    prog = np.asarray(program, dtype=np.int32)
    if pad_to is not None and prog.shape[0] < pad_to:
        pad = np.zeros((pad_to - prog.shape[0], prog.shape[1]), np.int32)
        pad[:, 0] = int(Op.EXIT)
        prog = np.concatenate([prog, pad], axis=0)
    skip = np.zeros(prog.shape[0], bool)
    for pc in bsync_skip_pcs:
        skip[pc] = True
    state = init_state(prog.shape[0], cfg, init_regs=init_regs,
                       init_mem=init_mem, lane_ids=lane_ids, active0=active0)
    return _run(jnp.asarray(prog), state, jnp.asarray(skip), cfg,
                majority_first)


def run_warps_jax(program: np.ndarray, cfg: MachineConfig,
                  init_regs: np.ndarray, init_mem: np.ndarray,
                  lane_ids: np.ndarray | None = None,
                  *, bsync_skip_pcs=(), majority_first: bool = True
                  ) -> HanoiState:
    """vmap over warps: ``init_regs`` is [n_warps, W, NR], ``init_mem`` is
    [n_warps, M] (per-warp memories), lane_ids [n_warps, W]."""
    prog = jnp.asarray(np.asarray(program, dtype=np.int32))
    skip = np.zeros(prog.shape[0], bool)
    for pc in bsync_skip_pcs:
        skip[pc] = True
    skip = jnp.asarray(skip)
    n = init_regs.shape[0]
    if lane_ids is None:
        lane_ids = np.broadcast_to(np.arange(cfg.n_threads, dtype=np.int32),
                                   (n, cfg.n_threads))

    def one(regs, mem, lanes):
        st = init_state(prog.shape[0], cfg, init_regs=regs, init_mem=mem,
                        lane_ids=lanes)
        return _run(prog, st, skip, cfg, majority_first)

    return jax.vmap(one)(jnp.asarray(init_regs, I32),
                         jnp.asarray(init_mem, I32),
                         jnp.asarray(lane_ids, I32))


def state_trace(st: HanoiState) -> list[tuple[int, int]]:
    n = int(st.trace_n)
    # .tolist() gives native ints in one C pass — per-element int() casts
    # dominated batched result assembly at scale
    return list(zip(np.asarray(st.trace_pc[:n]).tolist(),
                    np.asarray(st.trace_mask[:n]).tolist()))


def state_deadlocked(st: HanoiState, cfg: MachineConfig) -> bool:
    return bool((int(st.finished) & cfg.full_mask) != cfg.full_mask
                or int(st.fuel) <= 0)
