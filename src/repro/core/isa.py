"""SASS-lite ISA for the Hanoi control-flow-management engine.

The paper ("Control Flow Management in Modern GPUs") defines semantics for the
control-flow subset of NVIDIA Turing's native ISA (SASS).  We encode a
SASS-like mini ISA ("SASS-lite") sufficient to express every scenario the
paper studies: nested divergence (Fig 5), earlier-than-IPDom reconvergence
with BREAK (Fig 6), spinlocks with YIELD (Figs 3/7), predication (SS V-A),
WARPSYNC, CALL/RET, and enough ALU / memory / atomic ops to build the
benchmark suite.

Programs are dense ``int32[L, N_FIELDS]`` tables so they can be consumed by
both the numpy interpreter and the vectorized JAX engine.

Instruction word fields::

    [opcode, dst, src0, src1, src2, imm, pred1, pred2]

Predicate encoding (paper SS V-A: up to two predicates, AND-ed, each
negatable):  ``0`` = none (always true), ``+k`` = P(k-1), ``-k`` = !P(k-1).
"""
from __future__ import annotations

import enum
from typing import NamedTuple

import numpy as np

N_FIELDS = 8
(F_OP, F_DST, F_SRC0, F_SRC1, F_SRC2, F_IMM, F_PRED1, F_PRED2) = range(N_FIELDS)


class Op(enum.IntEnum):
    """Opcodes.  The control-flow subset mirrors Table I's green entries."""

    NOP = 0
    # --- control flow (paper SS V) -------------------------------------------
    EXIT = 1        # terminate executing threads
    BRA = 2         # [imm=target] conditional/unconditional branch
    BSSY = 3        # [dst=Bx, imm=target(BSYNC pc)] init reconvergence mask
    BSYNC = 4       # [dst=Bx] reconverge threads named in Bx
    BMOV_B2R = 5    # [dst=Rd, src0=Bx]  spill  Bx -> Rd (invalidates Bx)
    BMOV_R2B = 6    # [dst=Bx, src0=Rs]  fill   Rs -> Bx (revalidates Bx)
    BREAK = 7       # [dst=Bx] remove predicated-true threads from Bx mask
    WARPSYNC = 8    # [src0=Rs or -1, imm=mask if src0==-1] sync named threads
    YIELD = 9       # switch to sibling path if one exists
    CALL = 10       # [imm=target] direct call (return addr staged via MOV)
    RET = 11        # [src0=Rs] indirect jump to Rs (uniform across path)
    # --- ALU -----------------------------------------------------------------
    MOV = 12        # Rd = imm
    MOVR = 13       # Rd = Rs0
    IADD = 14       # Rd = Rs0 + Rs1
    IADDI = 15      # Rd = Rs0 + imm
    IMUL = 16       # Rd = Rs0 * Rs1
    AND = 17        # Rd = Rs0 & Rs1
    OR = 18         # Rd = Rs0 | Rs1
    XOR = 19        # Rd = Rs0 ^ Rs1
    SHL = 20        # Rd = Rs0 << imm
    SHR = 21        # Rd = Rs0 >> imm  (logical)
    ISETP = 22      # Pd = cmp(Rs0, Rs1|imm)   [src2=cmp code, src1=-1 -> imm]
    LANEID = 23     # Rd = lane id
    # --- memory / atomics ----------------------------------------------------
    LDG = 24        # Rd = mem[Rs0 + imm]
    STG = 25        # mem[Rs0 + imm] = Rs1     (lane-serialized, lowest first)
    ATOMCAS = 26    # Rd = CAS(mem[Rs0+imm], cmp=Rs1, new=Rs2) (lane-serialized)
    ATOMEXCH = 27   # Rd = EXCH(mem[Rs0+imm], Rs1)
    ATOMADD = 28    # Rd = ADD(mem[Rs0+imm], Rs1) returns old


N_OPS = len(Op)

# ISETP comparison codes (field src2)
CMP_EQ, CMP_NE, CMP_LT, CMP_LE, CMP_GT, CMP_GE = range(6)
CMP_NAMES = {"EQ": CMP_EQ, "NE": CMP_NE, "LT": CMP_LT,
             "LE": CMP_LE, "GT": CMP_GT, "GE": CMP_GE}

CONTROL_OPS = frozenset({
    Op.EXIT, Op.BRA, Op.BSSY, Op.BSYNC, Op.BMOV_B2R, Op.BMOV_R2B,
    Op.BREAK, Op.WARPSYNC, Op.YIELD, Op.CALL, Op.RET,
})
MEMORY_OPS = frozenset({Op.LDG, Op.STG, Op.ATOMCAS, Op.ATOMEXCH, Op.ATOMADD})
ATOMIC_OPS = frozenset({Op.ATOMCAS, Op.ATOMEXCH, Op.ATOMADD})


class Instr(NamedTuple):
    op: int
    dst: int = 0
    src0: int = 0
    src1: int = 0
    src2: int = 0
    imm: int = 0
    pred1: int = 0
    pred2: int = 0

    def encode(self) -> np.ndarray:
        # masks in imm may be given as unsigned 32-bit values; wrap to i32
        return np.array(self, dtype=np.int64).astype(np.int32)


def encode_program(instrs: list[Instr]) -> np.ndarray:
    """Encode a list of instructions into an ``int32[L, N_FIELDS]`` table."""
    if not instrs:
        raise ValueError("empty program")
    return np.stack([i.encode() for i in instrs]).astype(np.int32)


def decode_program(table: np.ndarray) -> list[Instr]:
    return [Instr(*map(int, row)) for row in np.asarray(table)]


class MachineConfig(NamedTuple):
    """Shapes of the simulated machine.  The paper uses 4-thread warps for
    illustration and 32 for the real machine; both are supported."""

    n_threads: int = 32
    n_regs: int = 16
    n_preds: int = 4
    n_bx: int = 8           # paper SS IX-A sizes the design for 8 Bx registers
    mem_size: int = 256
    max_steps: int = 4096   # scheduler-slot fuel; exhaustion => deadlock

    @property
    def full_mask(self) -> int:
        return (1 << self.n_threads) - 1


def hardware_cost_bytes(cfg: MachineConfig) -> dict:
    """Paper SS IX-A storage accounting for Hanoi vs. a SIMT-Stack.

    Hanoi per warp: WS stack (W entries x (PC + mask)), REC stack
    (W entries x (PC + Bx index)), Bx file, waiting + finished masks.
    SIMT-Stack per warp: W entries x (PC + reconvergence PC + mask).
    """
    W = cfg.n_threads
    pc_bits = 32
    mask_bits = W
    bx_idx_bits = max(1, (cfg.n_bx - 1).bit_length())
    # Hanoi (SS IX-A): WS needs at most W entries, REC W-1 (we round to W)
    ws_bits = W * (pc_bits + mask_bits)
    rec_bits = W * (pc_bits + bx_idx_bits)
    bx_bits = cfg.n_bx * (mask_bits + 1)
    masks_bits = 2 * mask_bits
    hanoi_bits = ws_bits + rec_bits + bx_bits + masks_bits
    # SIMT-Stack worst case: every divergence pushes a reconvergence entry
    # plus a path entry -> 2W entries of (PC, reconvergence PC, mask)
    simt_bits = 2 * W * (pc_bits + pc_bits + mask_bits)
    return {
        "hanoi_bytes": hanoi_bits // 8,
        "simt_stack_bytes": simt_bits // 8,
        "saving_frac": 1.0 - (hanoi_bits / simt_bits),
        "ws_bytes": ws_bits // 8,
        "rec_bytes": rec_bits // 8,
        "bx_bytes": (bx_bits + 7) // 8,
    }
