# The paper's primary contribution: Turing control-flow-instruction semantics
# and the Hanoi control-flow-management mechanism, as executable JAX/numpy
# models, plus the analysis stack around them (CFG/IPDom, trace diff, timing).
from .isa import (CONTROL_OPS, Instr, MachineConfig, Op, decode_program,
                  encode_program, hardware_cost_bytes)
from .asm import AsmError, assemble, disassemble
from .interp import (RunResult, popcount, run_hanoi, run_reference,
                     run_simt_stack, simd_utilization)
from .cfg import build_cfg, immediate_postdominators
from .trace import discrepancy, levenshtein, trace_tokens
from .structured import (If, Raw, Seq, While, compile_structured, emit_text,
                         region_depth)

__all__ = [
    "AsmError", "CONTROL_OPS", "If", "Instr", "MachineConfig", "Op", "Raw",
    "RunResult", "Seq", "While", "assemble", "build_cfg", "compile_structured",
    "decode_program", "disassemble", "discrepancy", "emit_text",
    "encode_program", "hardware_cost_bytes", "immediate_postdominators",
    "levenshtein", "popcount", "region_depth", "run_hanoi", "run_reference",
    "run_simt_stack", "simd_utilization", "trace_tokens",
]
