# The paper's primary contribution: Turing control-flow-instruction semantics
# and the Hanoi control-flow-management mechanism, as executable JAX/numpy
# models, plus the analysis stack around them (CFG/IPDom, trace diff, timing).
#
# NOTE: the engine entry points (run_hanoi, run_simt_stack, run_dual_path)
# are still importable from this package but deprecated — `repro.engine` is
# the canonical simulation API (Mechanism registry + Simulator façade with
# run/run_batch/compare).  The shims below keep old imports working for one
# release while emitting DeprecationWarning.
import warnings as _warnings

from .isa import (CONTROL_OPS, Instr, MachineConfig, Op, decode_program,
                  encode_program, hardware_cost_bytes)
from .asm import AsmError, assemble, disassemble
from .interp import (RunResult, popcount, run_reference, simd_utilization)
from .cfg import build_cfg, immediate_postdominators
from .trace import discrepancy, levenshtein, trace_tokens
from .structured import (If, Raw, Seq, While, compile_structured, emit_text,
                         region_depth)

__all__ = [
    "AsmError", "CONTROL_OPS", "If", "Instr", "MachineConfig", "Op", "Raw",
    "RunResult", "Seq", "While", "assemble", "build_cfg", "compile_structured",
    "decode_program", "disassemble", "discrepancy", "emit_text",
    "encode_program", "hardware_cost_bytes", "immediate_postdominators",
    "levenshtein", "popcount", "region_depth", "run_dual_path", "run_hanoi",
    "run_reference", "run_simt_stack", "simd_utilization", "trace_tokens",
]

# --------------------------------------------------------------------------
# deprecation shims: engine-specific entry points moved behind repro.engine
# --------------------------------------------------------------------------

_DEPRECATED = {
    "run_hanoi": ("repro.core.interp", "run_hanoi",
                  "Simulator('hanoi').run(...)"),
    "run_simt_stack": ("repro.core.interp", "run_simt_stack",
                       "Simulator('simt_stack').run(...)"),
    "run_dual_path": ("repro.core.dualpath", "run_dual_path",
                      "Simulator('dualpath').run(...)"),
}


def __getattr__(name: str):
    if name in _DEPRECATED:
        mod_name, attr, hint = _DEPRECATED[name]
        _warnings.warn(
            f"importing {name!r} from repro.core is deprecated and will be "
            f"removed in a future release; use repro.engine ({hint})",
            DeprecationWarning, stacklevel=2)
        import importlib
        return getattr(importlib.import_module(mod_name), attr)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_DEPRECATED))
