"""A tiny assembler for SASS-lite.

Syntax follows the paper's rendering of Turing SASS (SS V-A):

* ``@P0`` / ``@!P0`` guard prefix (first predicate);
* an optional predicate *first operand* (``BRA P1, target`` /
  ``@!P0 BREAK P1, B0``) as the second predicate — both AND together;
* labels (``loop:``), ``;``/``#`` comments;
* registers ``R0..``, predicate regs ``P0..``, convergence-barrier regs
  ``B0..``;
* memory operands ``[R2]`` / ``[R2+8]``;
* ``ISETP.LT P0, R1, R2`` or immediate ``ISETP.GE P0, R1, 7``.

Example (the paper's Fig 3 spinlock, see repro.core.programs)::

    lock_loop:
        ATOMCAS R2, [R0], R3, R4
        ISETP.NE P0, R2, 0
        @P0 BRA lock_loop
    ...
"""
from __future__ import annotations

import re

import numpy as np

from .isa import CMP_NAMES, N_FIELDS, F_IMM, F_OP, Instr, Op, encode_program

_LABEL_RE = re.compile(r"^([A-Za-z_][\w.]*):$")
_MEM_RE = re.compile(r"^\[R(\d+)(?:\s*\+\s*(-?\w+))?\]$")

#: Ops whose ``imm`` field is a code address (an edge or reconvergence
#: target).  MOV is deliberately absent: its imm *can* stage a return
#: address for RET (see programs.CALLS), which is why the transform passes
#: refuse to edit CALL/RET-bearing programs instead of guessing.
TARGET_OPS = frozenset({int(Op.BRA), int(Op.CALL), int(Op.BSSY)})


class AsmError(ValueError):
    """An assembly error with source context.

    ``reason`` is the bare message; ``lineno``/``col`` (1-based) and
    ``source`` (the raw offending source line) are attached by
    :func:`assemble` when the error surfaces through it, and the formatted
    ``str`` then carries a ``line L, col C:`` prefix plus a caret snippet —
    so a one-character typo in a 300-line listing is a one-glance fix.
    """

    def __init__(self, reason: str, *, lineno: "int | None" = None,
                 col: "int | None" = None, source: "str | None" = None,
                 token: "str | None" = None) -> None:
        self.reason = reason
        self.lineno = lineno
        self.col = col
        self.source = source
        self.token = token        # offending token, for column recovery
        super().__init__(self._format())

    def _format(self) -> str:
        loc = ""
        if self.lineno is not None:
            loc = f"line {self.lineno}"
            if self.col is not None:
                loc += f", col {self.col}"
            loc += ": "
        msg = f"{loc}{self.reason}"
        if self.source is not None:
            msg += f"\n    {self.source}"
            if self.col is not None:
                msg += "\n    " + " " * (self.col - 1) + "^"
        return msg

    def with_context(self, lineno: int, source: str) -> "AsmError":
        """A copy of this error annotated with its source coordinates."""
        col = None
        if self.token:
            at = source.find(self.token)
            if at >= 0:
                col = at + 1
        return AsmError(self.reason, lineno=lineno, col=col,
                        source=source, token=self.token)


def _parse_pred(tok: str) -> int:
    """``P3`` -> 4, ``!P3`` -> -4, per the isa.py predicate encoding."""
    neg = tok.startswith("!")
    if neg:
        tok = tok[1:]
    if not re.fullmatch(r"P\d+", tok):
        raise AsmError(f"bad predicate {tok!r}", token=tok)
    return (-1 if neg else 1) * (int(tok[1:]) + 1)


def _is_pred(tok: str) -> bool:
    return bool(re.fullmatch(r"!?P\d+", tok))


def _reg(tok: str, kind: str) -> int:
    if not re.fullmatch(rf"{kind}\d+", tok):
        raise AsmError(f"expected {kind}-register, got {tok!r}", token=tok)
    return int(tok[1:])


def _int(tok: str) -> int:
    return int(tok, 0)


def assemble(text: str) -> np.ndarray:
    """Assemble SASS-lite text into an ``int32[L, 8]`` program table.

    Errors raise :class:`AsmError` annotated with the 1-based source line
    number, the offending column where recoverable, and the raw source line.
    """
    lines: list[tuple[str, list[str]]] = []   # (mnemonic, operand tokens)
    guards: list[int] = []
    labels: dict[str, int] = {}
    srcs: list[tuple[int, str]] = []          # (1-based lineno, raw line)

    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.split(";")[0].split("#")[0].strip()
        if not line:
            continue
        m = _LABEL_RE.match(line)
        if m:
            labels[m.group(1)] = len(lines)
            continue
        guard = 0
        if line.startswith("@"):
            gtok, line = line.split(None, 1)
            try:
                guard = _parse_pred(gtok[1:])
            except AsmError as exc:
                raise exc.with_context(lineno, raw) from None
        parts = line.split(None, 1)
        mnem = parts[0].upper()
        ops = [t.strip() for t in parts[1].split(",")] if len(parts) > 1 else []
        lines.append((mnem, ops))
        guards.append(guard)
        srcs.append((lineno, raw))

    def res(tok: str, pc: int) -> int:
        """Resolve a label or integer literal."""
        if tok in labels:
            return labels[tok]
        try:
            return _int(tok)
        except ValueError:
            raise AsmError(f"unknown label/literal {tok!r} at pc {pc}",
                           token=tok) from None

    def build(pc: int, mnem: str, ops: "list[str]", guard: int) -> Instr:
        p2 = 0
        # a leading predicate operand is the second predicate (SS V-A)
        if ops and _is_pred(ops[0]) and not mnem.startswith("ISETP"):
            p2 = _parse_pred(ops[0])
            ops = ops[1:]

        def mem(tok: str) -> tuple[int, int]:
            m = _MEM_RE.match(tok.replace(" ", ""))
            if not m:
                raise AsmError(f"bad memory operand {tok!r} at pc {pc}",
                               token=tok)
            return int(m.group(1)), (res(m.group(2), pc) if m.group(2) else 0)

        k = dict(pred1=guard, pred2=p2)
        if mnem == "NOP":
            i = Instr(Op.NOP, **k)
        elif mnem == "EXIT":
            i = Instr(Op.EXIT, **k)
        elif mnem == "BRA":
            i = Instr(Op.BRA, imm=res(ops[0], pc), **k)
        elif mnem == "BSSY":
            i = Instr(Op.BSSY, dst=_reg(ops[0], "B"), imm=res(ops[1], pc), **k)
        elif mnem == "BSYNC":
            i = Instr(Op.BSYNC, dst=_reg(ops[0], "B"), **k)
        elif mnem == "BMOV":
            if ops[0].startswith("B"):
                i = Instr(Op.BMOV_R2B, dst=_reg(ops[0], "B"),
                          src0=_reg(ops[1], "R"), **k)
            else:
                i = Instr(Op.BMOV_B2R, dst=_reg(ops[0], "R"),
                          src0=_reg(ops[1], "B"), **k)
        elif mnem == "BREAK":
            i = Instr(Op.BREAK, dst=_reg(ops[0], "B"), **k)
        elif mnem == "WARPSYNC":
            if ops[0].startswith("R"):
                i = Instr(Op.WARPSYNC, src0=_reg(ops[0], "R"), **k)
            else:
                i = Instr(Op.WARPSYNC, src0=-1, imm=_int(ops[0]), **k)
        elif mnem == "YIELD":
            i = Instr(Op.YIELD, **k)
        elif mnem == "CALL":
            i = Instr(Op.CALL, imm=res(ops[0], pc), **k)
        elif mnem == "RET":
            i = Instr(Op.RET, src0=_reg(ops[0], "R"), **k)
        elif mnem == "MOV":
            i = Instr(Op.MOV, dst=_reg(ops[0], "R"), imm=res(ops[1], pc), **k)
        elif mnem == "MOVR":
            i = Instr(Op.MOVR, dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"), **k)
        elif mnem in ("IADD", "IMUL", "AND", "OR", "XOR"):
            i = Instr(Op[mnem], dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"),
                      src1=_reg(ops[2], "R"), **k)
        elif mnem == "IADDI":
            i = Instr(Op.IADDI, dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"),
                      imm=res(ops[2], pc), **k)
        elif mnem in ("SHL", "SHR"):
            i = Instr(Op[mnem], dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"),
                      imm=_int(ops[2]), **k)
        elif mnem.startswith("ISETP."):
            cmp = CMP_NAMES[mnem.split(".")[1]]
            if ops[2].startswith("R"):
                i = Instr(Op.ISETP, dst=_parse_pred(ops[0]) - 1,
                          src0=_reg(ops[1], "R"), src1=_reg(ops[2], "R"),
                          src2=cmp, **k)
            else:
                i = Instr(Op.ISETP, dst=_parse_pred(ops[0]) - 1,
                          src0=_reg(ops[1], "R"), src1=-1, src2=cmp,
                          imm=res(ops[2], pc), **k)
        elif mnem == "LANEID":
            i = Instr(Op.LANEID, dst=_reg(ops[0], "R"), **k)
        elif mnem == "LDG":
            r, off = mem(ops[1])
            i = Instr(Op.LDG, dst=_reg(ops[0], "R"), src0=r, imm=off, **k)
        elif mnem == "STG":
            r, off = mem(ops[0])
            i = Instr(Op.STG, src0=r, src1=_reg(ops[1], "R"), imm=off, **k)
        elif mnem in ("ATOMCAS", "ATOMEXCH", "ATOMADD"):
            r, off = mem(ops[1])
            src2 = _reg(ops[3], "R") if mnem == "ATOMCAS" else 0
            i = Instr(Op[mnem], dst=_reg(ops[0], "R"), src0=r,
                      src1=_reg(ops[2], "R"), src2=src2, imm=off, **k)
        else:
            raise AsmError(f"unknown mnemonic {mnem!r} at pc {pc}",
                           token=mnem)
        return i

    instrs: list[Instr] = []
    for pc, ((mnem, ops), guard) in enumerate(zip(lines, guards)):
        lineno, raw = srcs[pc]
        try:
            instrs.append(build(pc, mnem, ops, guard))
        except AsmError as exc:
            raise (exc if exc.lineno is not None
                   else exc.with_context(lineno, raw)) from None
        except IndexError:
            raise AsmError(f"missing operand(s) for {mnem}", lineno=lineno,
                           source=raw) from None
        except KeyError as exc:
            raise AsmError(f"bad operand {exc.args[0]!r} for {mnem}",
                           lineno=lineno, source=raw) from None

    return encode_program(instrs)


def disassemble_line(row: np.ndarray) -> str:
    """One instruction row rendered as text, without the pc prefix.

    The form analyzer diagnostics quote (``repro.analysis`` pairs it with
    the pc); :func:`disassemble` prefixes each line with its pc.
    """
    op = Op(int(row[0]))
    fields = dict(zip(
        ("op", "dst", "src0", "src1", "src2", "imm", "p1", "p2"),
        map(int, row)))
    g = ""
    if fields["p1"]:
        k = fields["p1"]
        g = f"@{'!' if k < 0 else ''}P{abs(k) - 1} "
    body = " ".join(f"{f}={v}" for f, v in fields.items()
                    if f not in ("op", "p1") and v)
    return f"{g}{op.name} {body}".rstrip()


def disassemble(table: np.ndarray) -> str:
    """Best-effort inverse of :func:`assemble` (for debugging / logs)."""
    return "\n".join(f"{pc:4d}: {disassemble_line(row)}"
                     for pc, row in enumerate(np.asarray(table)))


class EditInstr:
    """One instruction under edit: raw fields plus a symbolic target.

    ``fields`` is the 8-wide isa.py row as a mutable list.  ``target`` is
    the :class:`EditInstr` this instruction's code-address immediate refers
    to (BRA/CALL/BSSY), a raw ``int`` kept verbatim when the encoded target
    was out of range, or ``None`` for ops without a code-address imm.
    Identity is object identity — two nodes with equal fields are distinct
    instructions, so list/dict membership follows the program, not values.
    """

    __slots__ = ("fields", "target")

    def __init__(self, fields: "list[int] | tuple[int, ...]",
                 target: "EditInstr | int | None" = None) -> None:
        if len(fields) != N_FIELDS:
            raise ValueError(f"expected {N_FIELDS} fields, got {len(fields)}")
        self.fields = [int(x) for x in fields]
        self.target = target

    @property
    def op(self) -> int:
        return self.fields[F_OP]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<EditInstr {disassemble_line(np.asarray(self.fields))}>"


class ProgramEditor:
    """Symbolic insert/remove over a program table with target re-resolution.

    Decoding turns every code-address immediate into a node reference, so
    instructions can be inserted or removed anywhere and :meth:`encode`
    re-assigns pcs and re-resolves every BRA/BSSY/CALL immediate — the
    re-assembly substrate under ``repro.analysis.transform``.

    Insertion is deliberately explicit about edge capture: inserting before
    a node does NOT redirect branches to that node unless they are listed in
    ``capture``.  Whether a jump to a loop header should land on a newly
    synthesized BSSY (yes, for an If opening a loop body) or stay on the old
    first instruction (yes, for a region's interior back-edge) is a *policy*
    decision that belongs to the pass, not the editor.
    """

    def __init__(self, program: np.ndarray) -> None:
        table = np.asarray(program, dtype=np.int32)
        if table.ndim != 2 or table.shape[1] != N_FIELDS:
            raise ValueError(f"program must be [L, {N_FIELDS}], got {table.shape}")
        self.nodes: "list[EditInstr]" = [EditInstr(row) for row in table.tolist()]
        n = len(self.nodes)
        for node in self.nodes:
            if node.fields[F_OP] in TARGET_OPS:
                t = node.fields[F_IMM]
                node.target = self.nodes[t] if 0 <= t < n else t

    def index(self, node: EditInstr) -> int:
        """Current position of ``node`` (identity match)."""
        for i, x in enumerate(self.nodes):
            if x is node:
                return i
        raise ValueError("node is not in this editor")

    def refs_to(self, node: EditInstr) -> "list[EditInstr]":
        """All nodes whose target is ``node``."""
        return [x for x in self.nodes if x.target is node]

    def insert_before(self, at: EditInstr, node: EditInstr, *,
                      capture: "tuple[EditInstr, ...] | list[EditInstr]" = ()
                      ) -> None:
        """Insert ``node`` immediately before ``at``.

        Referrers listed in ``capture`` are retargeted to the new node;
        every other reference to ``at`` keeps pointing at ``at``.
        """
        i = self.index(at)
        for ref in capture:
            ref.target = node
        self.nodes.insert(i, node)

    def remove(self, node: EditInstr) -> None:
        """Remove ``node``; references to it fall through to its successor.

        Removing the last instruction leaves referrers pointing one past the
        end (encoded as a raw out-of-range target) — the analyzer will flag
        it, which is the honest outcome of that edit.
        """
        i = self.index(node)
        del self.nodes[i]
        succ: "EditInstr | int" = (self.nodes[i] if i < len(self.nodes)
                                   else len(self.nodes))
        for ref in self.nodes:
            if ref.target is node:
                ref.target = succ

    def positions(self) -> "dict[EditInstr, int]":
        """Node -> current pc (nodes hash by identity)."""
        return {node: pc for pc, node in enumerate(self.nodes)}

    def encode(self) -> np.ndarray:
        """Re-assemble into an ``int32[L, 8]`` table, resolving targets."""
        if not self.nodes:
            raise ValueError("cannot encode an empty program")
        pcs = self.positions()
        rows = []
        for node in self.nodes:
            f = list(node.fields)
            if node.target is not None:
                f[F_IMM] = (pcs[node.target]
                            if isinstance(node.target, EditInstr)
                            else int(node.target))
            rows.append(f)
        return np.array(rows, dtype=np.int32)
