"""A tiny assembler for SASS-lite.

Syntax follows the paper's rendering of Turing SASS (SS V-A):

* ``@P0`` / ``@!P0`` guard prefix (first predicate);
* an optional predicate *first operand* (``BRA P1, target`` /
  ``@!P0 BREAK P1, B0``) as the second predicate — both AND together;
* labels (``loop:``), ``;``/``#`` comments;
* registers ``R0..``, predicate regs ``P0..``, convergence-barrier regs
  ``B0..``;
* memory operands ``[R2]`` / ``[R2+8]``;
* ``ISETP.LT P0, R1, R2`` or immediate ``ISETP.GE P0, R1, 7``.

Example (the paper's Fig 3 spinlock, see repro.core.programs)::

    lock_loop:
        ATOMCAS R2, [R0], R3, R4
        ISETP.NE P0, R2, 0
        @P0 BRA lock_loop
    ...
"""
from __future__ import annotations

import re

import numpy as np

from .isa import CMP_NAMES, Instr, Op, encode_program

_LABEL_RE = re.compile(r"^([A-Za-z_][\w.]*):$")
_MEM_RE = re.compile(r"^\[R(\d+)(?:\s*\+\s*(-?\w+))?\]$")


class AsmError(ValueError):
    pass


def _parse_pred(tok: str) -> int:
    """``P3`` -> 4, ``!P3`` -> -4, per the isa.py predicate encoding."""
    neg = tok.startswith("!")
    if neg:
        tok = tok[1:]
    if not re.fullmatch(r"P\d+", tok):
        raise AsmError(f"bad predicate {tok!r}")
    return (-1 if neg else 1) * (int(tok[1:]) + 1)


def _is_pred(tok: str) -> bool:
    return bool(re.fullmatch(r"!?P\d+", tok))


def _reg(tok: str, kind: str) -> int:
    if not re.fullmatch(rf"{kind}\d+", tok):
        raise AsmError(f"expected {kind}-register, got {tok!r}")
    return int(tok[1:])


def _int(tok: str) -> int:
    return int(tok, 0)


def assemble(text: str) -> np.ndarray:
    """Assemble SASS-lite text into an ``int32[L, 8]`` program table."""
    lines: list[tuple[str, list[str]]] = []   # (mnemonic, operand tokens)
    guards: list[int] = []
    labels: dict[str, int] = {}

    for raw in text.splitlines():
        line = raw.split(";")[0].split("#")[0].strip()
        if not line:
            continue
        m = _LABEL_RE.match(line)
        if m:
            labels[m.group(1)] = len(lines)
            continue
        guard = 0
        if line.startswith("@"):
            gtok, line = line.split(None, 1)
            guard = _parse_pred(gtok[1:])
        parts = line.split(None, 1)
        mnem = parts[0].upper()
        ops = [t.strip() for t in parts[1].split(",")] if len(parts) > 1 else []
        lines.append((mnem, ops))
        guards.append(guard)

    def res(tok: str, pc: int) -> int:
        """Resolve a label or integer literal."""
        if tok in labels:
            return labels[tok]
        try:
            return _int(tok)
        except ValueError:
            raise AsmError(f"unknown label/literal {tok!r} at pc {pc}") from None

    instrs: list[Instr] = []
    for pc, ((mnem, ops), guard) in enumerate(zip(lines, guards)):
        p2 = 0
        # a leading predicate operand is the second predicate (SS V-A)
        if ops and _is_pred(ops[0]) and not mnem.startswith("ISETP"):
            p2 = _parse_pred(ops[0])
            ops = ops[1:]

        def mem(tok: str) -> tuple[int, int]:
            m = _MEM_RE.match(tok.replace(" ", ""))
            if not m:
                raise AsmError(f"bad memory operand {tok!r} at pc {pc}")
            return int(m.group(1)), (res(m.group(2), pc) if m.group(2) else 0)

        k = dict(pred1=guard, pred2=p2)
        if mnem == "NOP":
            i = Instr(Op.NOP, **k)
        elif mnem == "EXIT":
            i = Instr(Op.EXIT, **k)
        elif mnem == "BRA":
            i = Instr(Op.BRA, imm=res(ops[0], pc), **k)
        elif mnem == "BSSY":
            i = Instr(Op.BSSY, dst=_reg(ops[0], "B"), imm=res(ops[1], pc), **k)
        elif mnem == "BSYNC":
            i = Instr(Op.BSYNC, dst=_reg(ops[0], "B"), **k)
        elif mnem == "BMOV":
            if ops[0].startswith("B"):
                i = Instr(Op.BMOV_R2B, dst=_reg(ops[0], "B"),
                          src0=_reg(ops[1], "R"), **k)
            else:
                i = Instr(Op.BMOV_B2R, dst=_reg(ops[0], "R"),
                          src0=_reg(ops[1], "B"), **k)
        elif mnem == "BREAK":
            i = Instr(Op.BREAK, dst=_reg(ops[0], "B"), **k)
        elif mnem == "WARPSYNC":
            if ops[0].startswith("R"):
                i = Instr(Op.WARPSYNC, src0=_reg(ops[0], "R"), **k)
            else:
                i = Instr(Op.WARPSYNC, src0=-1, imm=_int(ops[0]), **k)
        elif mnem == "YIELD":
            i = Instr(Op.YIELD, **k)
        elif mnem == "CALL":
            i = Instr(Op.CALL, imm=res(ops[0], pc), **k)
        elif mnem == "RET":
            i = Instr(Op.RET, src0=_reg(ops[0], "R"), **k)
        elif mnem == "MOV":
            i = Instr(Op.MOV, dst=_reg(ops[0], "R"), imm=res(ops[1], pc), **k)
        elif mnem == "MOVR":
            i = Instr(Op.MOVR, dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"), **k)
        elif mnem in ("IADD", "IMUL", "AND", "OR", "XOR"):
            i = Instr(Op[mnem], dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"),
                      src1=_reg(ops[2], "R"), **k)
        elif mnem == "IADDI":
            i = Instr(Op.IADDI, dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"),
                      imm=res(ops[2], pc), **k)
        elif mnem in ("SHL", "SHR"):
            i = Instr(Op[mnem], dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"),
                      imm=_int(ops[2]), **k)
        elif mnem.startswith("ISETP."):
            cmp = CMP_NAMES[mnem.split(".")[1]]
            if ops[2].startswith("R"):
                i = Instr(Op.ISETP, dst=_parse_pred(ops[0]) - 1,
                          src0=_reg(ops[1], "R"), src1=_reg(ops[2], "R"),
                          src2=cmp, **k)
            else:
                i = Instr(Op.ISETP, dst=_parse_pred(ops[0]) - 1,
                          src0=_reg(ops[1], "R"), src1=-1, src2=cmp,
                          imm=res(ops[2], pc), **k)
        elif mnem == "LANEID":
            i = Instr(Op.LANEID, dst=_reg(ops[0], "R"), **k)
        elif mnem == "LDG":
            r, off = mem(ops[1])
            i = Instr(Op.LDG, dst=_reg(ops[0], "R"), src0=r, imm=off, **k)
        elif mnem == "STG":
            r, off = mem(ops[0])
            i = Instr(Op.STG, src0=r, src1=_reg(ops[1], "R"), imm=off, **k)
        elif mnem in ("ATOMCAS", "ATOMEXCH", "ATOMADD"):
            r, off = mem(ops[1])
            src2 = _reg(ops[3], "R") if mnem == "ATOMCAS" else 0
            i = Instr(Op[mnem], dst=_reg(ops[0], "R"), src0=r,
                      src1=_reg(ops[2], "R"), src2=src2, imm=off, **k)
        else:
            raise AsmError(f"unknown mnemonic {mnem!r} at pc {pc}")
        instrs.append(i)

    return encode_program(instrs)


def disassemble(table: np.ndarray) -> str:
    """Best-effort inverse of :func:`assemble` (for debugging / logs)."""
    out = []
    for pc, row in enumerate(np.asarray(table)):
        op = Op(int(row[0]))
        fields = dict(zip(
            ("op", "dst", "src0", "src1", "src2", "imm", "p1", "p2"),
            map(int, row)))
        g = ""
        if fields["p1"]:
            k = fields["p1"]
            g = f"@{'!' if k < 0 else ''}P{abs(k) - 1} "
        out.append(f"{pc:4d}: {g}{op.name} "
                   + " ".join(f"{f}={v}" for f, v in fields.items()
                              if f not in ("op", "p1") and v))
    return "\n".join(out)
