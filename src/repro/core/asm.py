"""A tiny assembler for SASS-lite.

Syntax follows the paper's rendering of Turing SASS (SS V-A):

* ``@P0`` / ``@!P0`` guard prefix (first predicate);
* an optional predicate *first operand* (``BRA P1, target`` /
  ``@!P0 BREAK P1, B0``) as the second predicate — both AND together;
* labels (``loop:``), ``;``/``#`` comments;
* registers ``R0..``, predicate regs ``P0..``, convergence-barrier regs
  ``B0..``;
* memory operands ``[R2]`` / ``[R2+8]``;
* ``ISETP.LT P0, R1, R2`` or immediate ``ISETP.GE P0, R1, 7``.

Example (the paper's Fig 3 spinlock, see repro.core.programs)::

    lock_loop:
        ATOMCAS R2, [R0], R3, R4
        ISETP.NE P0, R2, 0
        @P0 BRA lock_loop
    ...
"""
from __future__ import annotations

import re

import numpy as np

from .isa import CMP_NAMES, Instr, Op, encode_program

_LABEL_RE = re.compile(r"^([A-Za-z_][\w.]*):$")
_MEM_RE = re.compile(r"^\[R(\d+)(?:\s*\+\s*(-?\w+))?\]$")


class AsmError(ValueError):
    """An assembly error with source context.

    ``reason`` is the bare message; ``lineno``/``col`` (1-based) and
    ``source`` (the raw offending source line) are attached by
    :func:`assemble` when the error surfaces through it, and the formatted
    ``str`` then carries a ``line L, col C:`` prefix plus a caret snippet —
    so a one-character typo in a 300-line listing is a one-glance fix.
    """

    def __init__(self, reason: str, *, lineno: "int | None" = None,
                 col: "int | None" = None, source: "str | None" = None,
                 token: "str | None" = None) -> None:
        self.reason = reason
        self.lineno = lineno
        self.col = col
        self.source = source
        self.token = token        # offending token, for column recovery
        super().__init__(self._format())

    def _format(self) -> str:
        loc = ""
        if self.lineno is not None:
            loc = f"line {self.lineno}"
            if self.col is not None:
                loc += f", col {self.col}"
            loc += ": "
        msg = f"{loc}{self.reason}"
        if self.source is not None:
            msg += f"\n    {self.source}"
            if self.col is not None:
                msg += "\n    " + " " * (self.col - 1) + "^"
        return msg

    def with_context(self, lineno: int, source: str) -> "AsmError":
        """A copy of this error annotated with its source coordinates."""
        col = None
        if self.token:
            at = source.find(self.token)
            if at >= 0:
                col = at + 1
        return AsmError(self.reason, lineno=lineno, col=col,
                        source=source, token=self.token)


def _parse_pred(tok: str) -> int:
    """``P3`` -> 4, ``!P3`` -> -4, per the isa.py predicate encoding."""
    neg = tok.startswith("!")
    if neg:
        tok = tok[1:]
    if not re.fullmatch(r"P\d+", tok):
        raise AsmError(f"bad predicate {tok!r}", token=tok)
    return (-1 if neg else 1) * (int(tok[1:]) + 1)


def _is_pred(tok: str) -> bool:
    return bool(re.fullmatch(r"!?P\d+", tok))


def _reg(tok: str, kind: str) -> int:
    if not re.fullmatch(rf"{kind}\d+", tok):
        raise AsmError(f"expected {kind}-register, got {tok!r}", token=tok)
    return int(tok[1:])


def _int(tok: str) -> int:
    return int(tok, 0)


def assemble(text: str) -> np.ndarray:
    """Assemble SASS-lite text into an ``int32[L, 8]`` program table.

    Errors raise :class:`AsmError` annotated with the 1-based source line
    number, the offending column where recoverable, and the raw source line.
    """
    lines: list[tuple[str, list[str]]] = []   # (mnemonic, operand tokens)
    guards: list[int] = []
    labels: dict[str, int] = {}
    srcs: list[tuple[int, str]] = []          # (1-based lineno, raw line)

    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.split(";")[0].split("#")[0].strip()
        if not line:
            continue
        m = _LABEL_RE.match(line)
        if m:
            labels[m.group(1)] = len(lines)
            continue
        guard = 0
        if line.startswith("@"):
            gtok, line = line.split(None, 1)
            try:
                guard = _parse_pred(gtok[1:])
            except AsmError as exc:
                raise exc.with_context(lineno, raw) from None
        parts = line.split(None, 1)
        mnem = parts[0].upper()
        ops = [t.strip() for t in parts[1].split(",")] if len(parts) > 1 else []
        lines.append((mnem, ops))
        guards.append(guard)
        srcs.append((lineno, raw))

    def res(tok: str, pc: int) -> int:
        """Resolve a label or integer literal."""
        if tok in labels:
            return labels[tok]
        try:
            return _int(tok)
        except ValueError:
            raise AsmError(f"unknown label/literal {tok!r} at pc {pc}",
                           token=tok) from None

    def build(pc: int, mnem: str, ops: "list[str]", guard: int) -> Instr:
        p2 = 0
        # a leading predicate operand is the second predicate (SS V-A)
        if ops and _is_pred(ops[0]) and not mnem.startswith("ISETP"):
            p2 = _parse_pred(ops[0])
            ops = ops[1:]

        def mem(tok: str) -> tuple[int, int]:
            m = _MEM_RE.match(tok.replace(" ", ""))
            if not m:
                raise AsmError(f"bad memory operand {tok!r} at pc {pc}",
                               token=tok)
            return int(m.group(1)), (res(m.group(2), pc) if m.group(2) else 0)

        k = dict(pred1=guard, pred2=p2)
        if mnem == "NOP":
            i = Instr(Op.NOP, **k)
        elif mnem == "EXIT":
            i = Instr(Op.EXIT, **k)
        elif mnem == "BRA":
            i = Instr(Op.BRA, imm=res(ops[0], pc), **k)
        elif mnem == "BSSY":
            i = Instr(Op.BSSY, dst=_reg(ops[0], "B"), imm=res(ops[1], pc), **k)
        elif mnem == "BSYNC":
            i = Instr(Op.BSYNC, dst=_reg(ops[0], "B"), **k)
        elif mnem == "BMOV":
            if ops[0].startswith("B"):
                i = Instr(Op.BMOV_R2B, dst=_reg(ops[0], "B"),
                          src0=_reg(ops[1], "R"), **k)
            else:
                i = Instr(Op.BMOV_B2R, dst=_reg(ops[0], "R"),
                          src0=_reg(ops[1], "B"), **k)
        elif mnem == "BREAK":
            i = Instr(Op.BREAK, dst=_reg(ops[0], "B"), **k)
        elif mnem == "WARPSYNC":
            if ops[0].startswith("R"):
                i = Instr(Op.WARPSYNC, src0=_reg(ops[0], "R"), **k)
            else:
                i = Instr(Op.WARPSYNC, src0=-1, imm=_int(ops[0]), **k)
        elif mnem == "YIELD":
            i = Instr(Op.YIELD, **k)
        elif mnem == "CALL":
            i = Instr(Op.CALL, imm=res(ops[0], pc), **k)
        elif mnem == "RET":
            i = Instr(Op.RET, src0=_reg(ops[0], "R"), **k)
        elif mnem == "MOV":
            i = Instr(Op.MOV, dst=_reg(ops[0], "R"), imm=res(ops[1], pc), **k)
        elif mnem == "MOVR":
            i = Instr(Op.MOVR, dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"), **k)
        elif mnem in ("IADD", "IMUL", "AND", "OR", "XOR"):
            i = Instr(Op[mnem], dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"),
                      src1=_reg(ops[2], "R"), **k)
        elif mnem == "IADDI":
            i = Instr(Op.IADDI, dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"),
                      imm=res(ops[2], pc), **k)
        elif mnem in ("SHL", "SHR"):
            i = Instr(Op[mnem], dst=_reg(ops[0], "R"), src0=_reg(ops[1], "R"),
                      imm=_int(ops[2]), **k)
        elif mnem.startswith("ISETP."):
            cmp = CMP_NAMES[mnem.split(".")[1]]
            if ops[2].startswith("R"):
                i = Instr(Op.ISETP, dst=_parse_pred(ops[0]) - 1,
                          src0=_reg(ops[1], "R"), src1=_reg(ops[2], "R"),
                          src2=cmp, **k)
            else:
                i = Instr(Op.ISETP, dst=_parse_pred(ops[0]) - 1,
                          src0=_reg(ops[1], "R"), src1=-1, src2=cmp,
                          imm=res(ops[2], pc), **k)
        elif mnem == "LANEID":
            i = Instr(Op.LANEID, dst=_reg(ops[0], "R"), **k)
        elif mnem == "LDG":
            r, off = mem(ops[1])
            i = Instr(Op.LDG, dst=_reg(ops[0], "R"), src0=r, imm=off, **k)
        elif mnem == "STG":
            r, off = mem(ops[0])
            i = Instr(Op.STG, src0=r, src1=_reg(ops[1], "R"), imm=off, **k)
        elif mnem in ("ATOMCAS", "ATOMEXCH", "ATOMADD"):
            r, off = mem(ops[1])
            src2 = _reg(ops[3], "R") if mnem == "ATOMCAS" else 0
            i = Instr(Op[mnem], dst=_reg(ops[0], "R"), src0=r,
                      src1=_reg(ops[2], "R"), src2=src2, imm=off, **k)
        else:
            raise AsmError(f"unknown mnemonic {mnem!r} at pc {pc}",
                           token=mnem)
        return i

    instrs: list[Instr] = []
    for pc, ((mnem, ops), guard) in enumerate(zip(lines, guards)):
        lineno, raw = srcs[pc]
        try:
            instrs.append(build(pc, mnem, ops, guard))
        except AsmError as exc:
            raise (exc if exc.lineno is not None
                   else exc.with_context(lineno, raw)) from None
        except IndexError:
            raise AsmError(f"missing operand(s) for {mnem}", lineno=lineno,
                           source=raw) from None
        except KeyError as exc:
            raise AsmError(f"bad operand {exc.args[0]!r} for {mnem}",
                           lineno=lineno, source=raw) from None

    return encode_program(instrs)


def disassemble_line(row: np.ndarray) -> str:
    """One instruction row rendered as text, without the pc prefix.

    The form analyzer diagnostics quote (``repro.analysis`` pairs it with
    the pc); :func:`disassemble` prefixes each line with its pc.
    """
    op = Op(int(row[0]))
    fields = dict(zip(
        ("op", "dst", "src0", "src1", "src2", "imm", "p1", "p2"),
        map(int, row)))
    g = ""
    if fields["p1"]:
        k = fields["p1"]
        g = f"@{'!' if k < 0 else ''}P{abs(k) - 1} "
    body = " ".join(f"{f}={v}" for f, v in fields.items()
                    if f not in ("op", "p1") and v)
    return f"{g}{op.name} {body}".rstrip()


def disassemble(table: np.ndarray) -> str:
    """Best-effort inverse of :func:`assemble` (for debugging / logs)."""
    return "\n".join(f"{pc:4d}: {disassemble_line(row)}"
                     for pc, row in enumerate(np.asarray(table)))
