"""Trace-driven timing model (the paper's Accel-Sim stand-in).

The paper feeds Hanoi's control-flow traces into Accel-Sim to measure the IPC
impact of trace discrepancies (Fig 10).  Accel-Sim itself is not available in
this environment, so we implement a compact trace-driven issue model with the
properties that matter for *relative* IPC between two control-flow schedules
of the same program:

* one issue slot per cycle per scheduler (Table III: 4 schedulers/SM — we
  model one scheduler; warps are those assigned to it);
* Greedy-Then-Oldest (GTO) warp selection (Table III);
* a warp's next instruction is assumed dependent on its previous one
  (trace-level conservatism): ALU/control = short latency, memory = long;
* SIMD utilization = active threads per issued instruction / warp width.

IPC here counts *thread* instructions (popcount of the active mask), so a
schedule with better reconvergence shows both fewer issue slots and higher
IPC — the paper's BFSD effect (+31.9% SIMD utilization => +83% IPC).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .isa import ATOMIC_OPS, F_OP, MEMORY_OPS, Op
from .stepper import popcount


@dataclass(frozen=True)
class TimingConfig:
    alu_latency: int = 2
    control_latency: int = 1
    memory_latency: int = 30
    atomic_latency: int = 40


@dataclass
class TimingResult:
    cycles: int
    issues: int                 # warp-instructions issued
    thread_instructions: int    # sum of active-mask popcounts
    warp_width: int

    @property
    def ipc(self) -> float:
        """Thread-level IPC (the paper's Fig 10 metric)."""
        return self.thread_instructions / max(1, self.cycles)

    @property
    def warp_ipc(self) -> float:
        return self.issues / max(1, self.cycles)

    @property
    def simd_utilization(self) -> float:
        return self.thread_instructions / max(1, self.issues * self.warp_width)


def _latency(op: int, cfg: TimingConfig) -> int:
    if op in ATOMIC_OPS:
        return cfg.atomic_latency
    if op in MEMORY_OPS:
        return cfg.memory_latency
    if op in (Op.BRA, Op.EXIT, Op.BSSY, Op.BSYNC, Op.BMOV_B2R, Op.BMOV_R2B,
              Op.BREAK, Op.WARPSYNC, Op.YIELD, Op.CALL, Op.RET, Op.NOP):
        return cfg.control_latency
    return cfg.alu_latency


def schedule_traces(traces: "list[list[tuple[int, int]]]",
                    prog_ops: "list[np.ndarray]",
                    policy: str = "greedy_then_oldest",
                    cfg: TimingConfig = TimingConfig(),
                    ) -> tuple[list[tuple[int, int, int]], int, int]:
    """The one issue-scheduler loop: per-warp traces through one issue port.

    ``prog_ops`` holds each warp's opcode column (warps may run different
    programs — the per-SM model needs that).  Returns
    ``(order, cycles, thread_instructions)`` with ``order`` the issued
    ``(warp, pc, mask)`` slots.  Policies:

    * ``greedy_then_oldest`` — GTO (Table III): stay on the current warp
      while it is ready; otherwise the oldest (lowest-id) ready warp; if
      none is ready, fast-forward to the earliest ready time;
    * ``round_robin``        — rotate over ready warps every slot.

    :func:`simulate` (the Fig 10 IPC model) and
    :func:`repro.engine.mechanisms.sm.interleave_traces` both delegate
    here, so latency semantics cannot drift apart.
    """
    n = len(traces)
    idx = [0] * n
    ready = [0] * n
    lens = [len(t) for t in traces]
    remaining = sum(lens)
    order: list[tuple[int, int, int]] = []
    tinstr = 0
    cycle = 0
    cur = 0
    rr_next = 0
    while remaining:
        if policy == "round_robin":
            cands = [w for w in range(n) if idx[w] < lens[w]]
            ready_now = [w for w in cands if ready[w] <= cycle]
            if not ready_now:
                cycle = min(ready[w] for w in cands)
                ready_now = [w for w in cands if ready[w] <= cycle]
            cur = min(ready_now, key=lambda w: (w - rr_next) % n)
            rr_next = cur + 1
        elif not (idx[cur] < lens[cur] and ready[cur] <= cycle):
            cands = [w for w in range(n) if idx[w] < lens[w]]
            ready_now = [w for w in cands if ready[w] <= cycle]
            if ready_now:
                cur = ready_now[0]
            else:
                cycle = min(ready[w] for w in cands)
                cur = next(w for w in cands if ready[w] <= cycle)
        pc, mask = traces[cur][idx[cur]]
        ops = prog_ops[cur]
        op = int(ops[pc]) if 0 <= pc < len(ops) else int(Op.NOP)
        idx[cur] += 1
        remaining -= 1
        order.append((cur, pc, mask))
        tinstr += popcount(mask)
        ready[cur] = cycle + _latency(op, cfg)
        cycle += 1
    return order, cycle, tinstr


def simulate(traces: list[list[tuple[int, int]]],
             program: np.ndarray,
             warp_width: int,
             cfg: TimingConfig = TimingConfig()) -> TimingResult:
    """GTO issue simulation over per-warp control-flow traces."""
    prog_ops = np.asarray(program)[:, F_OP]
    order, cycles, tinstr = schedule_traces(
        traces, [prog_ops] * len(traces), "greedy_then_oldest", cfg)
    return TimingResult(cycles=cycles, issues=len(order),
                        thread_instructions=tinstr, warp_width=warp_width)


def ipc_delta(res_a: TimingResult, res_b: TimingResult) -> float:
    """Relative IPC difference of a vs b (the paper reports |delta| avg)."""
    return (res_a.ipc - res_b.ipc) / max(1e-12, res_b.ipc)
