"""Trace-driven timing model (the paper's Accel-Sim stand-in).

The paper feeds Hanoi's control-flow traces into Accel-Sim to measure the IPC
impact of trace discrepancies (Fig 10).  Accel-Sim itself is not available in
this environment, so we model the issue structure that matters for *relative*
IPC between two control-flow schedules of the same program:

* one issue slot per cycle per scheduler (Table III: 4 schedulers/SM — we
  model one scheduler; warps are those assigned to it);
* Greedy-Then-Oldest (GTO) warp selection (Table III);
* a warp's next instruction is assumed dependent on its previous one
  (trace-level conservatism): ALU/control = short latency, memory = long;
* SIMD utilization = active threads per issued instruction / warp width.

IPC here counts *thread* instructions (popcount of the active mask), so a
schedule with better reconvergence shows both fewer issue slots and higher
IPC — the paper's BFSD effect (+31.9% SIMD utilization => +83% IPC).

This module is now the *legacy façade*: :func:`schedule_traces` and
:func:`simulate` are thin shims over the event-driven cycle engine in
:mod:`repro.timing` (trace-conservative, single-issue, fixed-latency mode —
bit-identical to the historical loop, which is preserved below as
:func:`schedule_traces_reference`, the differential oracle).  Pass a
:class:`repro.timing.CycleConfig` instead of a :class:`TimingConfig` to get
register-level scoreboards, memory-latency distributions, and dual issue
through the same entry points.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .isa import ATOMIC_OPS, F_OP, MEMORY_OPS, Op
from .stepper import popcount


@dataclass(frozen=True)
class TimingConfig:
    alu_latency: int = 2
    control_latency: int = 1
    memory_latency: int = 30
    atomic_latency: int = 40


@dataclass
class TimingResult:
    """Issue-schedule outcome.  The stall fields are populated by the
    cycle engine (:mod:`repro.timing`); every ratio is guarded so a
    zero-instruction schedule reports 0.0 instead of dividing by zero."""

    cycles: int
    issues: int                 # warp-instructions issued
    thread_instructions: int    # sum of active-mask popcounts
    warp_width: int
    busy_cycles: int = 0
    issue_stall_cycles: int = 0
    scoreboard_stall_cycles: int = 0
    memory_stall_cycles: int = 0

    @property
    def ipc(self) -> float:
        """Thread-level IPC (the paper's Fig 10 metric)."""
        if self.cycles <= 0:
            return 0.0
        return self.thread_instructions / self.cycles

    @property
    def warp_ipc(self) -> float:
        if self.cycles <= 0:
            return 0.0
        return self.issues / self.cycles

    @property
    def simd_utilization(self) -> float:
        denom = self.issues * self.warp_width
        if denom <= 0:
            return 0.0
        return self.thread_instructions / denom

    @property
    def stall_cycles(self) -> int:
        """Idle cycles (no warp could issue); busy + these == cycles when
        the schedule came from the cycle engine."""
        return self.scoreboard_stall_cycles + self.memory_stall_cycles

    @property
    def stall_breakdown(self) -> dict[str, int]:
        return {"issue": self.issue_stall_cycles,
                "scoreboard": self.scoreboard_stall_cycles,
                "memory": self.memory_stall_cycles}


def _latency(op: int, cfg: TimingConfig) -> int:
    if op in ATOMIC_OPS:
        return cfg.atomic_latency
    if op in MEMORY_OPS:
        return cfg.memory_latency
    if op in (Op.BRA, Op.EXIT, Op.BSSY, Op.BSYNC, Op.BMOV_B2R, Op.BMOV_R2B,
              Op.BREAK, Op.WARPSYNC, Op.YIELD, Op.CALL, Op.RET, Op.NOP):
        return cfg.control_latency
    return cfg.alu_latency


def _as_cycle_config(cfg):
    """TimingConfig -> exact-compat CycleConfig; CycleConfig passes through."""
    from repro.timing import CycleConfig
    return CycleConfig.from_timing(cfg)


def schedule_traces(traces: "list[list[tuple[int, int]]]",
                    prog_ops: "list[np.ndarray]",
                    policy: str = "greedy_then_oldest",
                    cfg: "TimingConfig | object" = TimingConfig(),
                    ) -> tuple[list[tuple[int, int, int]], int, int]:
    """Per-warp traces through one issue port (shim over the cycle engine).

    ``prog_ops`` holds each warp's opcode column (warps may run different
    programs — the per-SM model needs that); full ``[L, N_FIELDS]`` row
    tables are also accepted and are required when ``cfg`` is a scoreboard
    :class:`repro.timing.CycleConfig`.  Returns ``(order, cycles,
    thread_instructions)`` with ``order`` the issued ``(warp, pc, mask)``
    slots.  Policies: ``greedy_then_oldest`` (GTO, Table III),
    ``round_robin``, ``oldest_first`` — see :mod:`repro.timing.policies`.

    With a :class:`TimingConfig` this reproduces
    :func:`schedule_traces_reference` bit-for-bit (differential-tested).
    :func:`simulate` (the Fig 10 IPC model) and
    :func:`repro.engine.mechanisms.sm.interleave_traces` both delegate
    here, so latency semantics cannot drift apart.
    """
    from repro.timing import schedule_cycle
    res = schedule_cycle(traces, prog_ops, policy, _as_cycle_config(cfg))
    return res.order, res.cycles, res.thread_instructions


def schedule_traces_reference(traces: "list[list[tuple[int, int]]]",
                              prog_ops: "list[np.ndarray]",
                              policy: str = "greedy_then_oldest",
                              cfg: TimingConfig = TimingConfig(),
                              ) -> tuple[list[tuple[int, int, int]], int, int]:
    """The historical uniform-cost issue loop, kept verbatim as the
    differential oracle for the cycle engine's trace-conservative mode
    (the role ``levenshtein_dp`` plays for the bit-parallel matcher)."""
    n = len(traces)
    idx = [0] * n
    ready = [0] * n
    lens = [len(t) for t in traces]
    remaining = sum(lens)
    order: list[tuple[int, int, int]] = []
    tinstr = 0
    cycle = 0
    cur = 0
    rr_next = 0
    while remaining:
        if policy == "round_robin":
            cands = [w for w in range(n) if idx[w] < lens[w]]
            ready_now = [w for w in cands if ready[w] <= cycle]
            if not ready_now:
                cycle = min(ready[w] for w in cands)
                ready_now = [w for w in cands if ready[w] <= cycle]
            cur = min(ready_now, key=lambda w: (w - rr_next) % n)
            rr_next = cur + 1
        elif not (idx[cur] < lens[cur] and ready[cur] <= cycle):
            cands = [w for w in range(n) if idx[w] < lens[w]]
            ready_now = [w for w in cands if ready[w] <= cycle]
            if ready_now:
                cur = ready_now[0]
            else:
                cycle = min(ready[w] for w in cands)
                cur = next(w for w in cands if ready[w] <= cycle)
        pc, mask = traces[cur][idx[cur]]
        ops = prog_ops[cur]
        op = int(ops[pc]) if 0 <= pc < len(ops) else int(Op.NOP)
        idx[cur] += 1
        remaining -= 1
        order.append((cur, pc, mask))
        tinstr += popcount(mask)
        ready[cur] = cycle + _latency(op, cfg)
        cycle += 1
    return order, cycle, tinstr


def simulate(traces: list[list[tuple[int, int]]],
             program: np.ndarray,
             warp_width: int,
             cfg: "TimingConfig | object" = TimingConfig()) -> TimingResult:
    """GTO issue simulation over per-warp control-flow traces.

    Shim over :func:`repro.timing.simulate_cycle`: a legacy
    :class:`TimingConfig` runs the exact-compat trace-conservative mode; a
    :class:`repro.timing.CycleConfig` unlocks scoreboards / memory
    distributions / dual issue.  Either way the result carries the stall
    breakdown fields.
    """
    from repro.timing import simulate_cycle
    return simulate_cycle(traces, np.asarray(program), warp_width,
                          _as_cycle_config(cfg))


def ipc_delta(res_a: TimingResult, res_b: TimingResult) -> float:
    """Relative IPC difference of a vs b (the paper reports |delta| avg)."""
    return (res_a.ipc - res_b.ipc) / max(1e-12, res_b.ipc)
