"""Divergence management at tile granularity — the Hanoi insight transferred
to TPU masked execution (DESIGN.md SS2b).

A warp's *active mask* becomes a tile grid's activity classification:

* EMPTY   — path never scheduled (Hanoi: never pushed to the WS stack);
* PARTIAL — predicated execution (threads masked within the path);
* FULL    — the reconverged fast path.

``classify_grid`` produces the census for any (causal, window, kv_len)
attention pattern; the Pallas flash-attention kernel consumes the same
predicate arithmetic at schedule time (repro.kernels.flash_attention), and
the MoE dispatch uses the path/BREAK vocabulary for capacity-dropped tokens
(repro.models.moe).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

EMPTY, PARTIAL, FULL = 0, 1, 2


@dataclass(frozen=True)
class MaskSpec:
    causal: bool = True
    window: int = 0          # <=0: unlimited
    kv_len: int | None = None


def classify_tile(qs: int, ks: int, bq: int, bk: int,
                  spec: MaskSpec, kv_len: int) -> int:
    q_min, q_max = qs, qs + bq - 1
    k_min, k_max = ks, ks + bk - 1
    empty, full = False, True
    if spec.causal:
        empty |= k_min > q_max
        full &= k_max <= q_min
    if spec.window and spec.window > 0:
        empty |= k_max < q_min - spec.window + 1
        full &= k_min >= q_max - spec.window + 1
    empty |= k_min >= kv_len
    full &= k_max < kv_len
    return EMPTY if empty else (FULL if full else PARTIAL)


def classify_grid(sq: int, sk: int, spec: MaskSpec, *,
                  bq: int = 128, bk: int = 128) -> np.ndarray:
    """int8 grid [nq, nk] of EMPTY/PARTIAL/FULL."""
    kv_len = sk if spec.kv_len is None else spec.kv_len
    nq, nk = -(-sq // bq), -(-sk // bk)
    g = np.empty((nq, nk), np.int8)
    for i in range(nq):
        for j in range(nk):
            g[i, j] = classify_tile(i * bq, j * bk, bq, bk, spec, kv_len)
    return g


def census(grid: np.ndarray) -> dict:
    total = grid.size
    empty = int((grid == EMPTY).sum())
    partial = int((grid == PARTIAL).sum())
    full = int((grid == FULL).sum())
    return {
        "total": total, "empty": empty, "partial": partial, "full": full,
        # fraction of tile-FLOPs that must execute (EMPTY skipped = the
        # Hanoi "path never scheduled" saving)
        "flops_kept_frac": (partial + full) / total,
        # predication overhead share (PARTIAL = masked-lane execution)
        "mask_overhead_frac": partial / max(1, partial + full),
        # the SIMD-utilization analogue: useful lanes / scheduled lanes,
        # assuming PARTIAL tiles average half-live lanes
        "tile_utilization": (full + 0.5 * partial) / max(1, full + partial),
    }


def schedule_order(grid: np.ndarray) -> list[tuple[int, int]]:
    """Execution order for live tiles, FULL-majority first per row — the
    WS-stack 'majority path first' policy applied to tile scheduling."""
    order = []
    for i in range(grid.shape[0]):
        row = [(i, j) for j in range(grid.shape[1]) if grid[i, j] != EMPTY]
        row.sort(key=lambda t: 0 if grid[t[0], t[1]] == FULL else 1)
        order.extend(row)
    return order
