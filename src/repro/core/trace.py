"""Control-flow trace comparison (paper SS IX).

A control-flow trace is the sequence of (pc, active-mask) pairs a warp issues
from program start to end.  The paper compares Hanoi's trace against real
hardware with the Levenshtein distance normalized by trace length — we
implement exactly that metric.

Two implementations of the edit distance live here:

* :func:`levenshtein` — Myers' bit-parallel algorithm (1999): the pattern is
  encoded as per-token bitmasks and each text token updates the whole DP
  column with O(1) big-int operations, so the cost is O(n·m/w) word ops
  instead of O(n·m) Python-level cell updates.  Python's arbitrary-precision
  ints serve as the bit vectors, so no blocking is needed at any length.
  This is what makes offline archive replay (``repro.archive``) tractable at
  fleet scale — millions of archived warps with multi-thousand-slot traces.
* :func:`levenshtein_dp` — the classic banded DP in numpy, kept as the
  differential-testing oracle (``tests/test_archive.py`` and the hypothesis
  property in ``tests/test_property_core.py`` assert both agree exactly;
  ``benchmarks/bench_archive.py`` gates the speedup).
"""
from __future__ import annotations

import math

import numpy as np


def levenshtein_dp(a: np.ndarray, b: np.ndarray) -> int:
    """Classic DP edit distance between two token sequences (the oracle)."""
    a = np.asarray(a)
    b = np.asarray(b)
    n, m = len(a), len(b)
    if n == 0:
        return m
    if m == 0:
        return n
    if n < m:                       # keep the inner dimension small
        a, b, n, m = b, a, m, n
    prev = np.arange(m + 1, dtype=np.int64)
    cur = np.empty(m + 1, dtype=np.int64)
    for i in range(1, n + 1):
        cur[0] = i
        sub = prev[:-1] + (b != a[i - 1])
        dele = prev[1:] + 1
        np.minimum(sub, dele, out=cur[1:])
        # insertion needs a sequential scan (prefix dependency)
        ci = cur
        for j in range(1, m + 1):
            v = ci[j - 1] + 1
            if v < ci[j]:
                ci[j] = v
        prev, cur = cur, prev
    return int(prev[m])


def levenshtein(a: np.ndarray, b: np.ndarray) -> int:
    """Myers bit-parallel edit distance between two token sequences.

    Exactly :func:`levenshtein_dp`'s result.  The shorter sequence becomes
    the pattern: its positions are encoded as one arbitrary-precision bitmask
    per distinct token (``peq``), and each token of the longer sequence then
    advances the implicit DP column with a constant number of big-int ops
    (Hyyrö's formulation of Myers 1999).
    """
    a = np.asarray(a)
    b = np.asarray(b)
    n, m = len(a), len(b)
    if n == 0:
        return m
    if m == 0:
        return n
    if n > m:                       # pattern = shorter => smaller bitmasks
        a, b, n, m = b, a, m, n
    peq: dict[int, int] = {}
    for i, tok in enumerate(a.tolist()):
        peq[tok] = peq.get(tok, 0) | (1 << i)
    mask = (1 << n) - 1
    last = 1 << (n - 1)
    vp, vn = mask, 0                # vertical delta +1 / -1 bit columns
    score = n
    get = peq.get
    for tok in b.tolist():
        eq = get(tok, 0)
        xv = eq | vn
        xh = (((eq & vp) + vp) ^ vp) | eq
        ph = vn | ~(xh | vp)        # masked below; ~ is fine on big ints
        mh = vp & xh
        if ph & last:
            score += 1
        elif mh & last:
            score -= 1
        ph = ((ph << 1) | 1)
        vn = ph & xv & mask
        vp = ((mh << 1) | ~(xv | ph)) & mask
    return score


def nearest_rank(sorted_values, p: float) -> float:
    """Nearest-rank percentile — ``ceil(p*n)-1`` — of pre-*sorted* values.

    NaN for an empty sequence.  The one percentile indexing the service
    latency stats and the archive replay aggregates both use (``int(p*n)``
    is one-off-high: p50 of 2 samples would read the max).
    """
    if not sorted_values:
        return float("nan")
    idx = min(len(sorted_values) - 1,
              max(0, math.ceil(p * len(sorted_values)) - 1))
    return sorted_values[idx]


def trace_tokens(trace: list[tuple[int, int]]) -> np.ndarray:
    return np.array([(pc << 32) | m for pc, m in trace], dtype=np.int64)


def discrepancy(trace_a: list[tuple[int, int]],
                trace_b: list[tuple[int, int]]) -> float:
    """Paper's metric: Levenshtein(trace_a, trace_b) / len(reference).

    ``trace_b`` plays the role of the hardware reference.
    """
    ta, tb = trace_tokens(trace_a), trace_tokens(trace_b)
    denom = max(1, len(tb))
    return levenshtein(ta, tb) / denom
