"""Control-flow trace comparison (paper SS IX).

A control-flow trace is the sequence of (pc, active-mask) pairs a warp issues
from program start to end.  The paper compares Hanoi's trace against real
hardware with the Levenshtein distance normalized by trace length — we
implement exactly that metric (banded DP in numpy, O(n*m) worst case with an
early-exit band when only the percentage is needed).
"""
from __future__ import annotations

import numpy as np


def levenshtein(a: np.ndarray, b: np.ndarray) -> int:
    """Classic DP edit distance between two token sequences."""
    a = np.asarray(a)
    b = np.asarray(b)
    n, m = len(a), len(b)
    if n == 0:
        return m
    if m == 0:
        return n
    if n < m:                       # keep the inner dimension small
        a, b, n, m = b, a, m, n
    prev = np.arange(m + 1, dtype=np.int64)
    cur = np.empty(m + 1, dtype=np.int64)
    for i in range(1, n + 1):
        cur[0] = i
        sub = prev[:-1] + (b != a[i - 1])
        dele = prev[1:] + 1
        np.minimum(sub, dele, out=cur[1:])
        # insertion needs a sequential scan (prefix dependency)
        ci = cur
        for j in range(1, m + 1):
            v = ci[j - 1] + 1
            if v < ci[j]:
                ci[j] = v
        prev, cur = cur, prev
    return int(prev[m])


def trace_tokens(trace: list[tuple[int, int]]) -> np.ndarray:
    return np.array([(pc << 32) | m for pc, m in trace], dtype=np.int64)


def discrepancy(trace_a: list[tuple[int, int]],
                trace_b: list[tuple[int, int]]) -> float:
    """Paper's metric: Levenshtein(trace_a, trace_b) / len(reference).

    ``trace_b`` plays the role of the hardware reference.
    """
    ta, tb = trace_tokens(trace_a), trace_tokens(trace_b)
    denom = max(1, len(tb))
    return levenshtein(ta, tb) / denom
