"""Reference interpreters for SASS-lite warps.

Three machines, all operating on the same ``int32[L, 8]`` program tables:

* :func:`run_hanoi`       — the paper's Hanoi mechanism (SS VII): WS stack +
  REC stack + Bx registers + waiting/finished masks.
* :func:`run_simt_stack`  — the pre-Volta SIMT-Stack baseline (SS II) with
  compile-time IPDom reconvergence; BSSY/BSYNC/BREAK/BMOV/WARPSYNC/YIELD are
  treated as NOPs (they do not exist pre-Volta).
* Turing "oracle" mode    — ``run_hanoi(..., bsync_skip_pcs=...)``: Hanoi plus
  the runtime heuristic the paper attributes to real hardware (SS IX): at
  annotated BSYNCs the hardware may *ignore* the reconvergence instead of
  waiting.  Skipping threads are implicitly BREAK-ed out of the mask so late
  arrivals still sync among themselves (deadlock-free by construction).

This module is the executable semantics; the vectorized JAX engine in
``repro.core.hanoi`` is property-tested for exact equivalence against it.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .isa import MachineConfig, Op
# The instruction-execution path (mask helpers, predicate resolution,
# architectural state + ALU) lives in repro.core.stepper and is shared by
# every numpy mechanism; the names are re-exported here because this module
# defined them historically.
from .stepper import (ArchState as _ArchState, _cmp, _pred_vec,  # noqa: F401
                      first_lane, lanes, mask_vec, popcount, vec_mask)


# --------------------------------------------------------------------------
# results
# --------------------------------------------------------------------------

@dataclass
class RunResult:
    regs: np.ndarray            # int32[W, NR] final register file
    preds: np.ndarray           # bool [W, NP]
    mem: np.ndarray             # int32[M]
    finished: int               # mask of threads that executed EXIT
    steps: int                  # scheduler slots consumed
    deadlocked: bool            # fuel exhausted or threads stuck waiting
    error: str | None           # structural error (Bx exhaustion, ...)
    trace: list[tuple[int, int]] = field(default_factory=list)  # (pc, mask)
    fuel_left: int = -1         # scheduler-slot budget remaining (-1: unknown)

    @property
    def out_of_fuel(self) -> bool:
        """True when the run stopped because the fuel budget expired (as
        opposed to a structural deadlock with fuel to spare).  The trace is
        truncated at the last fueled slot — identical across the numpy and
        JAX engines (property-tested)."""
        return self.fuel_left == 0

    def trace_tokens(self) -> np.ndarray:
        """Encode the control-flow trace as int64 tokens for Levenshtein."""
        return np.array([(pc << 32) | m for pc, m in self.trace],
                        dtype=np.int64)

    @property
    def sim_util(self) -> float:
        """SIMD-lane utilization over the trace (active threads / issued)."""
        if not self.trace:
            return 0.0
        w = max(1, max(popcount(m) for _, m in self.trace))
        # width inferred poorly from trace alone; caller usually recomputes
        return float(sum(popcount(m) for _, m in self.trace)) / (
            len(self.trace) * w)


def simd_utilization(trace: list[tuple[int, int]], w: int) -> float:
    if not trace:
        return 0.0
    return sum(popcount(m) for _, m in trace) / (len(trace) * w)


_I32 = np.int32


# --------------------------------------------------------------------------
# Hanoi (paper SS VII) + Turing-oracle heuristic (SS IX)
# --------------------------------------------------------------------------

def run_hanoi(program: np.ndarray,
              cfg: MachineConfig = MachineConfig(),
              *,
              init_regs=None, init_mem=None, lane_ids=None,
              active0: int | None = None,
              majority_first: bool = True,
              bsync_skip_pcs: frozenset[int] | tuple = (),
              record_trace: bool = True) -> RunResult:
    prog = np.asarray(program, dtype=np.int64)
    L = prog.shape[0]
    W, NB, FULL = cfg.n_threads, cfg.n_bx, cfg.full_mask
    st = _ArchState(cfg, init_regs, init_mem, lane_ids)
    skip_pcs = frozenset(bsync_skip_pcs)

    ws: list[list[int]] = [[0, FULL if active0 is None else active0]]  # [pc, mask]
    rec: list[list[int]] = []                                          # [pc, bx]
    bx_val = [0] * NB
    bx_valid = [False] * NB
    waiting = 0
    finished = 0
    error: str | None = None
    trace: list[tuple[int, int]] = []

    fuel = cfg.max_steps
    steps = 0
    while fuel > 0:
        fuel -= 1
        # 1) reconvergence check first (SS VII-B): REC top ready -> reconverge.
        if rec:
            rpc, b = rec[-1]
            if bx_valid[b]:
                live = bx_val[b] & ~finished
                if (live & ~waiting) == 0:
                    rec.pop()
                    bx_valid[b] = False
                    waiting &= ~live
                    if live:
                        ws.append([rpc + 1, live])
                    continue
        if not ws:
            break
        pc, amask = ws[-1]
        if pc < 0 or pc >= L:           # fell off program: implicit EXIT
            finished |= amask
            for x in range(NB):
                if bx_valid[x]:
                    bx_val[x] &= ~amask
            ws.pop()
            continue

        f = tuple(int(v) for v in prog[pc])
        op = f[0]
        exec_m = st.exec_mask(amask, f[6], f[7])
        if record_trace:
            trace.append((pc, amask))
        steps += 1

        if op == Op.BRA:
            target = f[5]
            taken, ft = exec_m, amask & ~exec_m
            if taken == 0:
                ws[-1][0] = pc + 1
            elif ft == 0:
                ws[-1][0] = target
            else:
                ws.pop()
                ent_t, ent_f = [target, taken], [pc + 1, ft]
                # SS VII-C: the majority path executes first (ties: taken).
                if majority_first and popcount(ft) > popcount(taken):
                    first, second = ent_f, ent_t
                else:
                    first, second = ent_t, ent_f
                ws.append(second)
                ws.append(first)
        elif op == Op.EXIT:
            fin = exec_m
            finished |= fin
            for x in range(NB):             # SS VII-A: strip finished threads
                if bx_valid[x]:
                    bx_val[x] &= ~fin
            rem = amask & ~fin
            if rem == 0:
                ws.pop()
            else:                            # predicated-off threads continue
                ws[-1] = [pc + 1, rem]
        elif op == Op.BSSY:
            if exec_m:
                b = f[1]
                bx_val[b] = amask
                bx_valid[b] = True
                rec.append([f[5], b])
            ws[-1][0] = pc + 1
        elif op == Op.BSYNC:
            b = f[1]
            if (pc in skip_pcs and bx_valid[b]
                    and (bx_val[b] & ~finished) != amask):
                # Turing-oracle heuristic: ignore the reconvergence; the
                # skipping subset is implicitly BREAK-ed out of the mask so
                # the remaining threads still sync among themselves.
                bx_val[b] &= ~amask
                ws[-1][0] = pc + 1
            elif rec and rec[-1][1] == b:
                ws.pop()
                waiting |= amask
            else:
                # The waiting mask only tracks the TOP REC entry (Fig 8);
                # a path reaching a deeper sync point parks: retry after the
                # sibling (swap), or spin if it is the only path.  If no
                # progress is possible this drains the fuel -> deadlock,
                # exactly the paper's Fig 6 without-BREAK scenario.
                if len(ws) >= 2:
                    ws[-1], ws[-2] = ws[-2], ws[-1]
        elif op == Op.WARPSYNC:
            m = (f[5] if f[2] == -1
                 else int(st.regs[first_lane(exec_m or amask), f[2]])) & FULL
            if not any(e[0] == pc for e in rec):     # first arriving subset
                free = next((x for x in range(NB) if not bx_valid[x]), None)
                if free is None:
                    error = error or "WARPSYNC: no free Bx register"
                    ws[-1][0] = pc + 1
                    continue
                bx_val[free] = m & ~finished
                bx_valid[free] = True
                rec.append([pc, free])
                ws.pop()
                waiting |= amask
            elif rec and rec[-1][0] == pc:
                ws.pop()
                waiting |= amask
            else:                                    # deeper entry: park
                if len(ws) >= 2:
                    ws[-1], ws[-2] = ws[-2], ws[-1]
        elif op == Op.BREAK:
            bx_val[f[1]] &= ~exec_m
            ws[-1][0] = pc + 1
        elif op == Op.BMOV_B2R:
            if exec_m:
                ev = mask_vec(exec_m, W)
                # reconvergence masks are unsigned; wrap into the i32 regfile
                st.regs[ev, f[1]] = np.int64(bx_val[f[2]]).astype(_I32)
                bx_valid[f[2]] = False        # spill invalidates (SS VII-A)
            ws[-1][0] = pc + 1
        elif op == Op.BMOV_R2B:
            if exec_m:
                v = int(st.regs[first_lane(exec_m), f[2]])
                bx_val[f[1]] = v & FULL & ~finished   # strip finished on fill
                bx_valid[f[1]] = True
            ws[-1][0] = pc + 1
        elif op == Op.YIELD:
            ws[-1][0] = pc + 1                 # resume after YIELD (SS VI-C)
            if len(ws) >= 2 and rec:
                rpc, b = rec[-1]
                if bx_valid[b]:
                    live = bx_val[b] & ~finished
                    if ((ws[-1][1] | ws[-2][1]) & ~live) == 0:  # siblings
                        ws[-1], ws[-2] = ws[-2], ws[-1]
        elif op == Op.CALL:
            ws[-1][0] = f[5] if exec_m else pc + 1
        elif op == Op.RET:
            ws[-1][0] = (int(st.regs[first_lane(exec_m), f[2]])
                         if exec_m else pc + 1)
        else:
            st.alu(op, f, exec_m)
            ws[-1][0] = pc + 1

    deadlocked = (finished & FULL) != FULL
    if fuel <= 0:
        deadlocked = True
    return RunResult(st.regs, st.preds, st.mem, finished, steps, deadlocked,
                     error, trace, fuel_left=max(0, fuel))


# --------------------------------------------------------------------------
# pre-Volta SIMT-Stack baseline (SS II)
# --------------------------------------------------------------------------

def run_simt_stack(program: np.ndarray,
                   cfg: MachineConfig = MachineConfig(),
                   *,
                   init_regs=None, init_mem=None, lane_ids=None,
                   ipdom: dict[int, int] | None = None,
                   record_trace: bool = True) -> RunResult:
    """Classic single-stack machine with IPDom reconvergence.

    Entries are ``[pc, rpc, mask]``; a divergent branch converts the top entry
    into the reconvergence entry at the IPDom and pushes both paths (taken
    executes first, as in the paper's Fig 1).  Post-Volta instructions are
    NOPs.  SIMT-induced deadlocks (SS III) manifest as fuel exhaustion.
    """
    from .cfg import immediate_postdominators
    prog = np.asarray(program, dtype=np.int64)
    L = prog.shape[0]
    W, FULL = cfg.n_threads, cfg.full_mask
    st = _ArchState(cfg, init_regs, init_mem, lane_ids)
    if ipdom is None:
        ipdom = immediate_postdominators(prog)

    NOPS = {Op.BSSY, Op.BSYNC, Op.BMOV_B2R, Op.BMOV_R2B, Op.BREAK,
            Op.WARPSYNC, Op.YIELD}
    stack: list[list[int]] = [[0, -1, FULL]]
    finished = 0
    trace: list[tuple[int, int]] = []
    fuel = cfg.max_steps
    steps = 0
    error = None

    while fuel > 0 and stack:
        fuel -= 1
        # reconvergence: pop entries whose pc reached their rpc or died out
        pc, rpc, amask = stack[-1]
        if amask == 0 or (rpc >= 0 and pc == rpc):
            stack.pop()
            continue
        if pc < 0 or pc >= L:
            finished |= amask
            stack.pop()
            continue

        f = tuple(int(v) for v in prog[pc])
        op = f[0]
        exec_m = st.exec_mask(amask, f[6], f[7])
        if record_trace:
            trace.append((pc, amask))
        steps += 1

        if op == Op.BRA:
            target = f[5]
            taken, ft = exec_m, amask & ~exec_m
            if taken == 0:
                stack[-1][0] = pc + 1
            elif ft == 0:
                stack[-1][0] = target
            else:
                r = ipdom.get(pc, -1)
                stack[-1] = [r, rpc, amask]      # reconvergence entry
                stack.append([pc + 1, r, ft])    # not-taken
                stack.append([target, r, taken])  # taken executes first (Fig 1)
        elif op == Op.EXIT:
            fin = exec_m
            finished |= fin
            for e in stack:                      # drop finished everywhere
                e[2] &= ~fin
            if stack[-1][2] != 0:
                stack[-1][0] = pc + 1
        elif op in NOPS:
            stack[-1][0] = pc + 1
        elif op == Op.CALL:
            stack[-1][0] = f[5] if exec_m else pc + 1
        elif op == Op.RET:
            stack[-1][0] = (int(st.regs[first_lane(exec_m), f[2]])
                            if exec_m else pc + 1)
        else:
            st.alu(op, f, exec_m)
            stack[-1][0] = pc + 1

    deadlocked = (finished & FULL) != FULL or fuel <= 0
    return RunResult(st.regs, st.preds, st.mem, finished, steps, deadlocked,
                     error, trace, fuel_left=max(0, fuel))


# --------------------------------------------------------------------------
# per-thread scalar reference (the architectural-semantics oracle)
# --------------------------------------------------------------------------

def run_reference(program: np.ndarray,
                  cfg: MachineConfig = MachineConfig(),
                  *,
                  init_regs=None, init_mem=None) -> RunResult:
    """Execute each thread to completion, one at a time, sharing memory.

    For data-race-free programs this is the architectural ground truth any
    control-flow-management mechanism must match (the paper's correctness
    criterion).  Programs that *require* inter-thread interleaving (spinlocks)
    are out of scope here by construction — they are validated behaviorally.
    """
    W = cfg.n_threads
    scfg = cfg._replace(n_threads=1)
    regs = (np.zeros((W, cfg.n_regs), _I32) if init_regs is None
            else np.array(init_regs, _I32))
    mem = (np.zeros(cfg.mem_size, _I32) if init_mem is None
           else np.array(init_mem, _I32))
    out_regs = np.zeros_like(regs)
    out_preds = np.zeros((W, cfg.n_preds), dtype=bool)
    finished = 0
    deadlocked = False
    steps = 0
    fuel_left = cfg.max_steps
    for t in range(W):
        r = run_hanoi(program, scfg, init_regs=regs[t:t + 1], init_mem=mem,
                      lane_ids=np.array([t], _I32), record_trace=False)
        out_regs[t] = r.regs[0]
        out_preds[t] = r.preds[0]
        mem = r.mem
        steps += r.steps
        deadlocked |= r.deadlocked
        fuel_left = min(fuel_left, r.fuel_left)
        if r.finished:
            finished |= (1 << t)
    return RunResult(out_regs, out_preds, mem, finished, steps, deadlocked,
                     None, [], fuel_left=fuel_left)
