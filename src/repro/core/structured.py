"""Structured-program compiler for SASS-lite.

NVIDIA's compiler algorithms for placing BSSY/BSYNC/BMOV/BREAK/YIELD are not
disclosed (paper SS X: "we do not know the detailed algorithms NVIDIA's
compiler uses").  This module implements a *plausible* pass with the exact
properties the paper observes:

* every divergence region is bracketed ``BSSY Bx, sync`` ... ``BSYNC Bx`` with
  the BSSY target pointing AT the BSYNC instruction (SS V-E);
* Bx registers are allocated round-robin over the small Bx file; a region
  whose subtree will reuse its physical Bx spills it to a high-numbered Rx
  right after BSSY and refills right before its BSYNC (SS VI-A / Fig 5).
  Spilling is demand-driven: a resident (unspilled) Bx is required both for
  BREAK (it edits the live mask, SS VI-B) and for YIELD's sibling check
  (SS VII-C) — spilling everything would starve both, which is why the paper's
  compiler also keeps masks resident when it can;
* loops whose body contains atomics get a YIELD at the loop head so a thread
  holding a lock can make progress (SS VI-C / Fig 7);
* ``break_pred`` on a loop lowers to BREAK + a jump PAST the loop's BSYNC:
  broken threads never reach that reconvergence point, exactly the Fig 6
  early-reconvergence shape.

The pass emits assembler text (readable in failure logs) and assembles it.
Property tests drive random ASTs through this pass and check that Hanoi
matches the per-thread scalar reference exactly.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

from .asm import assemble
from .isa import MachineConfig

# ---------------------------------------------------------------------------
# AST
# ---------------------------------------------------------------------------


@dataclass
class Raw:
    """Straight-line assembler lines (no control flow)."""
    lines: list[str]


@dataclass
class If:
    """``if (P<pred>) then_ else else_`` — cond lines must set P<pred>."""
    cond: list[str]
    pred: int
    then_: "Node"
    else_: "Node | None" = None


@dataclass
class While:
    """``while (P<pred>) body`` — cond lines re-evaluated every iteration."""
    cond: list[str]
    pred: int
    body: "Node"
    yield_at_head: bool = False     # forced; auto-set when body has atomics
    break_pred: int | None = None   # early exit past the BSYNC via BREAK


@dataclass
class Seq:
    items: list["Node"]


Node = Raw | If | While | Seq


_ATOMICS = ("ATOMCAS", "ATOMEXCH", "ATOMADD")


def _has_atomics(n: Node) -> bool:
    if isinstance(n, Raw):
        return any(a in ln.upper() for ln in n.lines for a in _ATOMICS)
    if isinstance(n, Seq):
        return any(_has_atomics(i) for i in n.items)
    if isinstance(n, If):
        return (_has_atomics(n.then_)
                or (n.else_ is not None and _has_atomics(n.else_))
                or any(a in ln.upper() for ln in n.cond for a in _ATOMICS))
    if isinstance(n, While):
        return (_has_atomics(n.body)
                or any(a in ln.upper() for ln in n.cond for a in _ATOMICS))
    raise TypeError(n)


def region_depth(n: Node) -> int:
    """Maximum number of nested divergence regions within ``n``."""
    if isinstance(n, Raw):
        return 0
    if isinstance(n, Seq):
        return max((region_depth(i) for i in n.items), default=0)
    if isinstance(n, If):
        inner = max(region_depth(n.then_),
                    region_depth(n.else_) if n.else_ is not None else 0)
        return 1 + inner
    if isinstance(n, While):
        return 1 + region_depth(n.body)
    raise TypeError(n)


def count_breaks(n: Node) -> int:
    if isinstance(n, Raw):
        return 0
    if isinstance(n, Seq):
        return sum(count_breaks(i) for i in n.items)
    if isinstance(n, If):
        return (count_breaks(n.then_)
                + (count_breaks(n.else_) if n.else_ is not None else 0))
    if isinstance(n, While):
        return (1 if n.break_pred is not None else 0) + count_breaks(n.body)
    raise TypeError(n)


@dataclass
class _Ctx:
    """Bx allocation: BREAK-bearing loops let broken threads race past the
    loop's BSYNC while its REC entry is still live, so their reconvergence
    mask must never be clobbered by a later sibling region.  NVIDIA's
    register-allocation strategy is undisclosed (SS X); we conservatively
    DEDICATE one Bx per BREAK loop (allocated from the top of the file) and
    cycle the remaining pool over regular regions, spilling on reuse."""
    cfg: MachineConfig
    pool: int = 0                   # regular registers: indices [0, pool)
    labels: "itertools.count" = field(default_factory=itertools.count)
    dedicated: "itertools.count" = field(default_factory=itertools.count)
    depth: int = 0
    loop_depth: int = 0

    def label(self, stem: str) -> str:
        return f"{stem}_{next(self.labels)}"

    def bx(self) -> int:
        return self.depth % self.pool

    def dedicated_bx(self) -> int:
        return self.cfg.n_bx - 1 - next(self.dedicated)

    def spill_reg(self) -> int:
        r = self.cfg.n_regs - 1 - self.depth
        if r < 0:
            raise ValueError("divergence nesting exceeds spill registers")
        return r

    def needs_spill(self, inner_depth: int) -> bool:
        # the physical Bx is reused by a descendant iff nesting >= pool size
        return inner_depth >= self.pool


def _emit(n: Node, ctx: _Ctx, out: list[str]) -> None:
    if isinstance(n, Raw):
        out.extend(n.lines)
        return
    if isinstance(n, Seq):
        for item in n.items:
            _emit(item, ctx, out)
        return

    bx, sr = ctx.bx(), ctx.spill_reg()
    if isinstance(n, If):
        inner = max(region_depth(n.then_),
                    region_depth(n.else_) if n.else_ is not None else 0)
        spill = ctx.needs_spill(inner)
        then_l, rest_l, sync_l = (ctx.label("then"), ctx.label("rest"),
                                  ctx.label("sync"))
        out += [f"BSSY B{bx}, {sync_l}"]
        if spill:
            out += [f"BMOV R{sr}, B{bx}"]
        out += n.cond
        out += [f"@P{n.pred} BRA {then_l}"]
        ctx.depth += 1
        if n.else_ is not None:
            _emit(n.else_, ctx, out)
        out += [f"BRA {rest_l}", f"{then_l}:"]
        _emit(n.then_, ctx, out)
        ctx.depth -= 1
        out += [f"{rest_l}:"]
        if spill:
            out += [f"BMOV B{bx}, R{sr}"]
        out += [f"{sync_l}:", f"BSYNC B{bx}"]
        return

    if isinstance(n, While):
        inner = region_depth(n.body)
        if n.break_pred is not None:
            if ctx.loop_depth > 0:
                # broken threads jump past this loop's BSYNC; inside an outer
                # loop they would race around the back-edge and re-enter the
                # region while its REC entry is live.  BREAK is only used for
                # FORWARD unstructured exits (Fig 6) — structured breaks pass
                # through the BSYNC instead.
                raise ValueError("BREAK loop may not nest inside another loop")
            bx, spill = ctx.dedicated_bx(), False
        else:
            spill = ctx.needs_spill(inner)
        loop_l, body_l = ctx.label("loop"), ctx.label("body")
        rest_l, sync_l, post_l = (ctx.label("wrest"), ctx.label("wsync"),
                                  ctx.label("wpost"))
        out += [f"BSSY B{bx}, {sync_l}"]
        if spill:
            out += [f"BMOV R{sr}, B{bx}"]
        out += [f"{loop_l}:"]
        if n.yield_at_head or _has_atomics(n.body):
            out += ["YIELD"]               # deadlock avoidance (SS VI-C)
        out += n.cond
        out += [f"@P{n.pred} BRA {body_l}", f"BRA {rest_l}", f"{body_l}:"]
        ctx.depth += 1
        ctx.loop_depth += 1
        if n.break_pred is not None:
            # remove early-exiting threads from the reconvergence mask and
            # route them PAST the BSYNC (SS VI-B / Fig 6)
            out += [f"@P{n.break_pred} BREAK P{n.break_pred}, B{bx}",
                    f"@P{n.break_pred} BRA {post_l}"]
        _emit(n.body, ctx, out)
        ctx.depth -= 1
        ctx.loop_depth -= 1
        out += [f"BRA {loop_l}", f"{rest_l}:"]
        if spill:
            out += [f"BMOV B{bx}, R{sr}"]
        out += [f"{sync_l}:", f"BSYNC B{bx}", f"{post_l}:"]
        return

    raise TypeError(f"unknown node {n!r}")


def emit_text(node: Node, cfg: MachineConfig = MachineConfig(),
              *, add_exit: bool = True) -> str:
    n_breaks = count_breaks(node)
    pool = cfg.n_bx - n_breaks
    if pool < 1:
        raise ValueError(
            f"{n_breaks} BREAK loops need dedicated Bx registers but the "
            f"file only has {cfg.n_bx}; enlarge n_bx or reduce breaks")
    out: list[str] = []
    _emit(node, _Ctx(cfg, pool=pool), out)
    if add_exit:
        out.append("EXIT")
    return "\n".join(out)


def compile_structured(node: Node,
                       cfg: MachineConfig = MachineConfig(),
                       *, add_exit: bool = True) -> np.ndarray:
    """Lower a structured AST to an assembled SASS-lite program table."""
    return assemble(emit_text(node, cfg, add_exit=add_exit))
