"""Instruction-level CFG analysis: immediate post-dominators (IPDom).

Pre-Volta control-flow management reconverges at IPDom points (paper SS II);
the compiler assist there was a per-branch reconvergence PC.  We compute it
from the program table — this stands in for the SSY annotations an NVIDIA
compiler would have emitted for a pre-Volta target.
"""
from __future__ import annotations

import networkx as nx
import numpy as np

from .isa import F_IMM, F_OP, F_PRED1, F_PRED2, Op

SINK = -1


def build_cfg(program: np.ndarray) -> nx.DiGraph:
    prog = np.asarray(program)
    L = prog.shape[0]
    g = nx.DiGraph()
    g.add_node(SINK)
    # RET is an indirect jump through a register, but the calling convention
    # (programs stage `pc+1` of the CALL into the return register) means it
    # resolves to some call site's continuation.  Modeling RET as an edge to
    # every continuation — instead of straight to SINK — keeps the function
    # body on the path between a call site and its join, so IPDoms
    # downstream of a call site are the actual reconvergence points rather
    # than SINK.  With no CALL in the program, RET degrades to an exit.
    returns = [pc + 1 if pc + 1 < L else SINK
               for pc in range(L) if int(prog[pc, F_OP]) == Op.CALL]
    for pc in range(L):
        op = int(prog[pc, F_OP])
        predicated = int(prog[pc, F_PRED1]) != 0 or int(prog[pc, F_PRED2]) != 0
        nxt = pc + 1 if pc + 1 < L else SINK
        if op == Op.BRA:
            g.add_edge(pc, int(prog[pc, F_IMM]))
            if predicated:
                g.add_edge(pc, nxt)
        elif op == Op.EXIT:
            g.add_edge(pc, SINK)
            if predicated:
                g.add_edge(pc, nxt)
        elif op == Op.RET:
            for r in (returns or [SINK]):
                g.add_edge(pc, r)
            if predicated:
                g.add_edge(pc, nxt)
        elif op == Op.CALL:
            g.add_edge(pc, int(prog[pc, F_IMM]))
            g.add_edge(pc, nxt)     # return continuation / predicated skip
        else:
            g.add_edge(pc, nxt)
    return g


def immediate_postdominators(program: np.ndarray) -> dict[int, int]:
    """``{branch_pc: ipdom_pc}`` for every conditional BRA in the program.

    IPDom(pc) is the immediate dominator of pc in the reversed CFG rooted at
    the virtual SINK.  Unreachable code maps to SINK (-1).
    """
    prog = np.asarray(program)
    g = build_cfg(prog)
    # restrict to nodes reachable from entry, else idom is undefined
    reachable = set(nx.descendants(g, 0)) | {0}
    rg = g.subgraph(reachable).reverse(copy=True)
    idom = nx.immediate_dominators(rg, SINK)
    out: dict[int, int] = {}
    for pc in range(prog.shape[0]):
        if int(prog[pc, F_OP]) == Op.BRA and pc in reachable:
            d = idom.get(pc, SINK)
            # the ipdom of the branch node itself is the join point
            out[pc] = int(d) if d is not None else SINK
    return out
