"""The one instruction-execution path shared by every numpy mechanism.

Historically each reference machine (Hanoi, SIMT-Stack, Dual-Path) carried
its own copy of the mask helpers, predicate resolution, and the ALU —
``interp.py`` owned them and the others imported its privates.  This module
is the extraction: architectural state (:class:`ArchState`), mask helpers,
and — new with the Volta-style per-thread-PC scheduler — a *lane-PC
stepper* (:func:`step_group`) that executes one instruction for a group of
lanes at a common PC and reports per-lane control-flow outcomes, so
stackless mechanisms do not re-implement instruction semantics either.

Division of responsibility:

* this module knows what every instruction DOES to architectural state and
  where each lane WANTS to go next;
* a mechanism (SIMT-Stack, Hanoi, Dual-Path, per-thread-PC, ...) decides
  which lanes issue together and how reconvergence is managed — that is the
  whole design space the paper studies, and the only part mechanisms may
  legitimately differ in.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .isa import (ATOMIC_OPS, CMP_EQ, CMP_GE, CMP_GT, CMP_LE, CMP_LT, CMP_NE,
                  MachineConfig, Op)

_I32 = np.int32


# --------------------------------------------------------------------------
# mask helpers (masks are python ints, thread t <-> bit (1 << t))
# --------------------------------------------------------------------------

def popcount(m: int) -> int:
    return int(m).bit_count()


def first_lane(m: int) -> int:
    """Index of the lowest set bit (first active lane)."""
    assert m, "first_lane of empty mask"
    return (m & -m).bit_length() - 1


def lanes(m: int):
    """Iterate active lane indices, lowest first (atomics serialize this way)."""
    t = 0
    while m:
        if m & 1:
            yield t
        m >>= 1
        t += 1


def mask_vec(m: int, w: int) -> np.ndarray:
    return np.array([(m >> t) & 1 for t in range(w)], dtype=bool)


def vec_mask(v: np.ndarray) -> int:
    return int(sum(1 << t for t, b in enumerate(v) if b))


# --------------------------------------------------------------------------
# predicate / comparison resolution
# --------------------------------------------------------------------------

def _pred_vec(preds: np.ndarray, p: int, w: int) -> np.ndarray:
    if p == 0:
        return np.ones(w, dtype=bool)
    if p > 0:
        return preds[:, p - 1]
    return ~preds[:, -p - 1]


def _cmp(a: np.ndarray, b: np.ndarray, code: int) -> np.ndarray:
    if code == CMP_EQ:
        return a == b
    if code == CMP_NE:
        return a != b
    if code == CMP_LT:
        return a < b
    if code == CMP_LE:
        return a <= b
    if code == CMP_GT:
        return a > b
    if code == CMP_GE:
        return a >= b
    raise ValueError(f"bad cmp code {code}")


# --------------------------------------------------------------------------
# architectural state + ALU
# --------------------------------------------------------------------------

class ArchState:
    """Architectural state shared by all machines."""

    def __init__(self, cfg: MachineConfig, init_regs, init_mem, lane_ids):
        self.cfg = cfg
        w = cfg.n_threads
        self.regs = (np.zeros((w, cfg.n_regs), _I32) if init_regs is None
                     else np.array(init_regs, _I32).reshape(w, cfg.n_regs))
        self.preds = np.zeros((w, cfg.n_preds), dtype=bool)
        self.mem = (np.zeros(cfg.mem_size, _I32) if init_mem is None
                    else np.array(init_mem, _I32).reshape(cfg.mem_size))
        self.lane_ids = (np.arange(w, dtype=_I32) if lane_ids is None
                         else np.array(lane_ids, _I32).reshape(w))

    def exec_mask(self, amask: int, p1: int, p2: int) -> int:
        g = (_pred_vec(self.preds, p1, self.cfg.n_threads)
             & _pred_vec(self.preds, p2, self.cfg.n_threads))
        return amask & vec_mask(g)

    def alu(self, op: int, f, exec_m: int) -> None:
        """Execute a non-control op for lanes in ``exec_m``.  ``f`` = fields."""
        cfg = self.cfg
        ev = mask_vec(exec_m, cfg.n_threads)
        R, M = self.regs, self.mem
        dst, s0, s1, s2, imm = f[1], f[2], f[3], f[4], f[5]
        if op == Op.NOP:
            return
        if op == Op.MOV:
            R[ev, dst] = _I32(imm)
        elif op == Op.MOVR:
            R[ev, dst] = R[ev, s0]
        elif op == Op.IADD:
            R[ev, dst] = R[ev, s0] + R[ev, s1]
        elif op == Op.IADDI:
            R[ev, dst] = R[ev, s0] + _I32(imm)
        elif op == Op.IMUL:
            R[ev, dst] = R[ev, s0] * R[ev, s1]
        elif op == Op.AND:
            R[ev, dst] = R[ev, s0] & R[ev, s1]
        elif op == Op.OR:
            R[ev, dst] = R[ev, s0] | R[ev, s1]
        elif op == Op.XOR:
            R[ev, dst] = R[ev, s0] ^ R[ev, s1]
        elif op == Op.SHL:
            R[ev, dst] = R[ev, s0] << (imm & 31)
        elif op == Op.SHR:
            R[ev, dst] = (R[ev, s0].astype(np.uint32) >> (imm & 31)).astype(_I32)
        elif op == Op.ISETP:
            b = _I32(imm) if s1 == -1 else R[ev, s1]
            self.preds[ev, dst] = _cmp(R[ev, s0], b, s2)
        elif op == Op.LANEID:
            R[ev, dst] = self.lane_ids[ev]
        elif op == Op.LDG:
            addr = (R[ev, s0] + imm) % cfg.mem_size
            R[ev, dst] = M[addr]
        elif op == Op.STG:
            for t in lanes(exec_m):
                M[(int(R[t, s0]) + imm) % cfg.mem_size] = R[t, s1]
        elif op in ATOMIC_OPS:
            for t in lanes(exec_m):
                a = (int(R[t, s0]) + imm) % cfg.mem_size
                old = M[a]
                if op == Op.ATOMCAS:
                    if old == R[t, s1]:
                        M[a] = R[t, s2]
                elif op == Op.ATOMEXCH:
                    M[a] = R[t, s1]
                else:  # ATOMADD
                    M[a] = _I32(int(old) + int(R[t, s1]))
                R[t, dst] = old
        else:
            raise ValueError(f"alu cannot handle op {Op(op).name}")


# --------------------------------------------------------------------------
# lane-PC stepper: per-lane control-flow outcomes for stackless mechanisms
# --------------------------------------------------------------------------

@dataclass
class GroupOutcome:
    """What happened when a group of lanes issued one instruction together.

    ``next_pcs`` gives each surviving lane's next PC (lanes that retired via
    EXIT appear in ``exited`` instead).  ``sync_mask`` is set for WARPSYNC:
    the issuing mechanism must hold the executing lanes at this PC until
    every unfinished lane named in the mask has arrived (however the
    mechanism chooses to represent "arrived").
    """

    next_pcs: dict[int, int] = field(default_factory=dict)
    exited: int = 0
    sync_mask: int | None = None
    sync_lanes: int = 0          # the subset of the group that must wait


#: Convergence-management ops that are no-ops on a per-thread-PC machine:
#: there is no reconvergence stack to maintain, so BSSY/BSYNC bracketing,
#: Bx spills and BREAK mask edits have nothing to act on, and YIELD's
#: "switch to the sibling path" is subsumed by the fair scheduler.
STACKLESS_NOPS = frozenset({Op.BSSY, Op.BSYNC, Op.BMOV_B2R, Op.BMOV_R2B,
                            Op.BREAK, Op.YIELD})


def step_group(prog: np.ndarray, st: ArchState, pc: int, group: int,
               *, full_mask: int) -> GroupOutcome:
    """Execute the instruction at ``pc`` for the lanes in ``group``.

    Architectural effects (ALU, memory, atomics, predicates) are applied to
    ``st`` exactly as on every other machine — this is the shared execution
    path.  Control flow is reported *per lane* so a per-thread-PC mechanism
    can scatter the group; stack mechanisms use their own aggregate handling
    and only share :class:`ArchState`.
    """
    out = GroupOutcome()
    L = prog.shape[0]
    if pc < 0 or pc >= L:            # fell off the program: implicit EXIT
        out.exited = group
        return out
    f = tuple(int(v) for v in prog[pc])
    op = f[0]
    exec_m = st.exec_mask(group, f[6], f[7])

    if op == Op.BRA:
        target = f[5]
        for t in lanes(group):
            out.next_pcs[t] = target if (exec_m >> t) & 1 else pc + 1
    elif op == Op.EXIT:
        out.exited = exec_m
        for t in lanes(group & ~exec_m):     # predicated-off lanes continue
            out.next_pcs[t] = pc + 1
    elif op == Op.WARPSYNC:
        m = (f[5] if f[2] == -1
             else int(st.regs[first_lane(exec_m or group), f[2]])) & full_mask
        out.sync_mask = m
        out.sync_lanes = exec_m
        for t in lanes(group & ~exec_m):     # predicated-off lanes skip it
            out.next_pcs[t] = pc + 1
        for t in lanes(exec_m):              # released lanes resume after it
            out.next_pcs[t] = pc + 1
    elif op == Op.CALL:
        for t in lanes(group):
            out.next_pcs[t] = f[5] if (exec_m >> t) & 1 else pc + 1
    elif op == Op.RET:
        for t in lanes(group):               # indirect: per-lane register
            out.next_pcs[t] = (int(st.regs[t, f[2]]) if (exec_m >> t) & 1
                               else pc + 1)
    elif op in STACKLESS_NOPS:
        for t in lanes(group):
            out.next_pcs[t] = pc + 1
    else:
        st.alu(op, f, exec_m)
        for t in lanes(group):
            out.next_pcs[t] = pc + 1
    return out
