"""Dual-Path execution model (Rhu & Erez, HPCA'13) — the paper's SS X
comparison point.

Each stack entry holds TWO concurrently schedulable paths (the two sides of
one divergence) plus the IPDom reconvergence PC; the warp scheduler may
interleave them (we alternate).  This solves same-branch SIMT-induced
deadlocks (the spinlock) WITHOUT Turing's YIELD — but, as the paper argues
(SS X), it cannot support the Turing ISA:

* BREAK needs to edit a reconvergence mask that may be buried in the stack
  (Dual-Path stores masks positionally, not in Bx registers) -> treated as
  NOP here, so earlier-than-IPDom reconvergence is impossible;
* WARPSYNC has no prior BSSY-like marker, so the stack cannot be set up for
  it -> NOP (synchronization semantics silently lost);
* BSSY/BSYNC/BMOV/YIELD likewise have no mechanism -> NOP; reconvergence is
  hard-wired to the IPDom.

`repro.core` uses this model to reproduce the paper's comparative claims:
same architectural results on structured programs, completed spinlocks, but
IPDom-late reconvergence (lower SIMD utilization on Fig-6-like flows) and
broken WARPSYNC guarantees.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .interp import RunResult
from .isa import MachineConfig, Op
from .stepper import ArchState as _ArchState, first_lane, popcount

_NOPS = {Op.BSSY, Op.BSYNC, Op.BMOV_B2R, Op.BMOV_R2B, Op.BREAK,
         Op.WARPSYNC, Op.YIELD}


@dataclass
class _Entry:
    rpc: int                     # IPDom reconvergence pc (-1 for root)
    parent_slot: int             # which slot of the parent spawned us
    pcs: list                    # [pcA or None, pcB or None]
    masks: list                  # [maskA, maskB]
    last: int = 0                # last-scheduled slot (for alternation)

    def live_slots(self):
        return [i for i in (0, 1)
                if self.masks[i] and self.pcs[i] is not None
                and self.pcs[i] != self.rpc]

    def finished(self):
        return all(self.masks[i] == 0 or self.pcs[i] == self.rpc
                   for i in (0, 1))


def run_dual_path(program: np.ndarray,
                  cfg: MachineConfig = MachineConfig(),
                  *, init_regs=None, init_mem=None, lane_ids=None,
                  ipdom: dict[int, int] | None = None,
                  record_trace: bool = True) -> RunResult:
    from .cfg import immediate_postdominators
    prog = np.asarray(program, dtype=np.int64)
    L = prog.shape[0]
    FULL = cfg.full_mask
    st = _ArchState(cfg, init_regs, init_mem, lane_ids)
    if ipdom is None:
        ipdom = immediate_postdominators(prog)

    stack: list[_Entry] = [_Entry(-1, 0, [0, None], [FULL, 0])]
    finished = 0
    trace: list[tuple[int, int]] = []
    fuel = cfg.max_steps
    steps = 0

    def strip(mask):
        for e in stack:
            e.masks = [m & ~mask for m in e.masks]

    while fuel > 0 and stack:
        fuel -= 1
        top = stack[-1]
        # reconvergence: both paths at rpc (or dead) -> merge into parent
        if top.finished():
            stack.pop()
            merged = top.masks[0] | top.masks[1]
            if not stack:
                if merged:
                    # root refill (shouldn't happen: root rpc = -1)
                    stack.append(_Entry(-1, 0, [top.rpc, None], [merged, 0]))
                continue
            parent = stack[-1]
            s = top.parent_slot
            parent.pcs[s] = top.rpc
            parent.masks[s] = merged | (parent.masks[s] & ~FULL)
            continue
        live = top.live_slots()
        if not live:
            # paths stuck at rpc but masks empty handled above; a lone path
            # waiting at rpc with its sibling dead is also 'finished'
            break
        # alternate between the two paths (the Dual-Path scheduler freedom)
        slot = live[0] if len(live) == 1 else (1 - top.last
                                               if (1 - top.last) in live
                                               else live[0])
        top.last = slot
        pc, amask = top.pcs[slot], top.masks[slot]
        if pc < 0 or pc >= L:
            finished |= amask
            strip(amask)
            continue

        f = tuple(int(v) for v in prog[pc])
        op = f[0]
        exec_m = st.exec_mask(amask, f[6], f[7])
        if record_trace:
            trace.append((pc, amask))
        steps += 1

        if op == Op.BRA:
            target = f[5]
            taken, ft = exec_m, amask & ~exec_m
            if taken == 0:
                top.pcs[slot] = pc + 1
            elif ft == 0:
                top.pcs[slot] = target
            else:
                r = ipdom.get(pc, -1)
                top.pcs[slot] = r            # this slot waits at the IPDom
                top.masks[slot] = 0          # mass moves to the child entry
                stack.append(_Entry(r, slot, [target, pc + 1], [taken, ft]))
        elif op == Op.EXIT:
            fin = exec_m
            finished |= fin
            strip(fin)
            if top.masks[slot]:
                top.pcs[slot] = pc + 1
        elif op in _NOPS:                    # unsupported Turing instrs
            top.pcs[slot] = pc + 1
        elif op == Op.CALL:
            top.pcs[slot] = f[5] if exec_m else pc + 1
        elif op == Op.RET:
            top.pcs[slot] = (int(st.regs[first_lane(exec_m), f[2]])
                             if exec_m else pc + 1)
        else:
            st.alu(op, f, exec_m)
            top.pcs[slot] = pc + 1

    deadlocked = (finished & FULL) != FULL or fuel <= 0
    return RunResult(st.regs, st.preds, st.mem, finished, steps, deadlocked,
                     None, trace, fuel_left=max(0, fuel))
