"""SASS-lite programs: the paper's figures + a benchmark suite.

Hand-written programs reproduce the paper's walkthrough figures exactly
(Fig 3/7 spinlock, Fig 5 nested divergence with BMOV spilling, Fig 6 early
reconvergence with BREAK).  The generated suite mimics the control-flow
character of the paper's benchmark families (Table II): regular compute
kernels (Rodinia-like), data-dependent loops (graph-like BFS), atomics-heavy
kernels, and deep nesting — each parameterized by input data, so one program
yields several "executions" as in the paper's 59-execution methodology.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .asm import assemble
from .isa import MachineConfig
from .structured import If, Raw, Seq, While, compile_structured

# ---------------------------------------------------------------------------
# Paper figures
# ---------------------------------------------------------------------------

# Fig 3 / Fig 7: spinlock.  mem[0] = mutex, mem[1] = shared counter.
# The critical section uses a plain load/inc/store so mutual exclusion is
# *observable*: the final counter equals W only if the lock works.
SPINLOCK_ASM = """
    MOV R0, 0           ; mutex address
    MOV R1, 1           ; counter address
    MOV R3, 0           ; CAS compare value
    MOV R4, 1           ; CAS swap value
    BSSY B0, esync
loop:
    YIELD               ; SS VI-C: switch to the sibling (lock holder) path
    ATOMCAS R2, [R0], R3, R4
    ISETP.NE P0, R2, 0  ; P0 true -> failed to acquire
    @P0 BRA loop
    LDG R5, [R1]        ; critical section: counter++ (non-atomic on purpose)
    IADDI R5, R5, 1
    STG [R1], R5
    ATOMEXCH R6, [R0], R3   ; release the lock
esync:
    BSYNC B0
    EXIT
"""

# Same program with the YIELD removed — the paper's SS V-G ablation: on real
# Turing (and on Hanoi) this must hang.
SPINLOCK_NO_YIELD_ASM = SPINLOCK_ASM.replace("    YIELD", "    NOP  ")


def spinlock_program() -> np.ndarray:
    return assemble(SPINLOCK_ASM)


def spinlock_no_yield_program() -> np.ndarray:
    return assemble(SPINLOCK_NO_YIELD_ASM)


# Fig 5: nested divergence; B0 serves two reconvergence points, spilled to R0.
# Threads {2,3} take the outer branch; thread 3 takes the inner branch.
FIG5_ASM = """
    LANEID R1
    BSSY B0, fsync      ; outer reconvergence (F), B0 = full mask
    BMOV R0, B0         ; spill: R0 <- B0  (Fig 5 step 2)
    ISETP.GE P0, R1, 2
    @P0 BRA bblk
    MOV R2, 100         ; not-taken path (threads 0,1)
    BRA fblk
bblk:
    BSSY B0, esync      ; inner reconvergence (E), B0 = {2,3}  (step 3)
    ISETP.EQ P1, R1, 3
    @P1 BRA dblk
    MOV R2, 20          ; C: thread 2
    BRA esync
dblk:
    MOV R2, 30          ; D: thread 3
esync:
    BSYNC B0            ; reunites threads 2,3
    MOV R3, 5           ; E tail, executed by {2,3} together
fblk:
    BMOV B0, R0         ; refill: B0 <- R0  (steps 4,5)
fsync:
    BSYNC B0            ; reunites all threads
    EXIT
"""


def fig5_program() -> np.ndarray:
    return assemble(FIG5_ASM)


# Fig 6: early reconvergence (B is NOT the IPDom of the branch in A); BREAK in
# C removes thread 0 from B0 so threads 1-3 reunite early at B.
FIG6_ASM = """
    LANEID R1
    BSSY B1, dsync      ; outer (IPDom) reconvergence — pushed first
    BSSY B0, bsync      ; early reconvergence at B — pushed on top
    ISETP.GE P0, R1, 1
    @P0 BRA bblk        ; threads 1,2,3 -> B ; thread 0 falls through to C
    ISETP.GE P1, R1, 1  ; C: P1 false exactly for thread 0
    BREAK !P1, B0       ; remove thread 0 from B0 (Fig 6 step 2)
    @!P1 BRA dblk       ; thread 0 heads to D, never executing B
    BRA bblk
bblk:
    MOV R2, 7           ; B body
bsync:
    BSYNC B0            ; early reconvergence: threads 1,2,3 (step 3)
    MOV R3, 8           ; B tail, executed by {1,2,3} together
dblk:
dsync:
    BSYNC B1            ; full reconvergence at D (step 4)
    MOV R4, 9
    EXIT
"""

FIG6_NO_BREAK_ASM = FIG6_ASM.replace("    BREAK !P1, B0", "    NOP")


def fig6_program() -> np.ndarray:
    return assemble(FIG6_ASM)


def fig6_no_break_program() -> np.ndarray:
    """Without the BREAK the BSYNC at B waits for thread 0 forever (SS VI-B)."""
    return assemble(FIG6_NO_BREAK_ASM)


# Fig 1/4 basic diamond: if (lane < W/2) A else B; join.
def diamond_program() -> np.ndarray:
    return assemble("""
    LANEID R1
    BSSY B0, sync
    ISETP.LT P0, R1, 2
    @P0 BRA taken
    MOV R2, 200
    BRA join
taken:
    MOV R2, 111
join:
sync:
    BSYNC B0
    IADDI R3, R2, 1
    EXIT
""")


# WARPSYNC: divergent paths meet at a WARPSYNC with an immediate full mask.
def warpsync_program(w: int = 4) -> np.ndarray:
    full = (1 << w) - 1
    return assemble(f"""
    LANEID R1
    ISETP.GE P0, R1, {w // 2}
    @P0 BRA x
    MOV R2, 1
    BRA w
x:
    MOV R2, 2
w:
    WARPSYNC {full}
    MOV R3, 9
    EXIT
""")


# ---------------------------------------------------------------------------
# Generated benchmark suite (Table II analogue)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Benchmark:
    """A program plus its machine/memory setup and oracle annotations."""
    name: str
    family: str                      # rodinia | graph | atomic | synthetic
    program: np.ndarray
    init_mem: np.ndarray | None = None
    # BSYNC pcs where the Turing-oracle heuristic may skip reconvergence
    skip_bsync_pcs: tuple[int, ...] = ()
    race_free: bool = True           # scalar-reference comparable

    def __repr__(self) -> str:  # keep pytest ids short
        return f"Benchmark({self.name})"


def _find_bsync_pcs(program: np.ndarray) -> list[int]:
    from .isa import F_OP, Op
    return [pc for pc in range(program.shape[0])
            if int(program[pc, F_OP]) == Op.BSYNC]


def _mem(cfg: MachineConfig, seed: int, lo: int = 0, hi: int = 8) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(lo, hi, size=cfg.mem_size, dtype=np.int32)


def make_suite(cfg: MachineConfig = MachineConfig(n_threads=32),
               datasets: int = 2) -> list[Benchmark]:
    """Build the benchmark suite; ``datasets`` input sets per data-dependent
    program (the paper runs 18 extra executions by varying inputs)."""
    W = cfg.n_threads
    out: list[Benchmark] = []

    # -- rodinia-like: branchy vector compute (hotspot/srad flavor) ---------
    # out[i] = data[i] > 3 ? data[i]*2 : data[i]+1, strided loop
    branchy = Seq([
        Raw(["LANEID R1", "MOV R2, 0"]),  # R2 = loop induction (i = lane)
        Raw(["MOVR R3, R1"]),
        While(
            cond=[f"ISETP.LT P0, R3, {4 * W}"], pred=0,
            body=Seq([
                Raw(["LDG R4, [R3+0]"]),
                If(cond=["ISETP.GT P1, R4, 3"], pred=1,
                   then_=Raw(["IADD R5, R4, R4"]),
                   else_=Raw(["IADDI R5, R4, 1"])),
                Raw([f"IADDI R6, R3, {cfg.mem_size // 2}",
                     "STG [R6+0], R5",
                     f"IADDI R3, R3, {W}"])]),
        )])
    prog = compile_structured(branchy, cfg)
    for d in range(datasets):
        out.append(Benchmark(f"HOTS{d}", "rodinia", prog,
                             init_mem=_mem(cfg, 11 + d)))

    # -- rodinia-like: nested conditionals (lud/gaussian flavor) ------------
    nested = Seq([
        Raw(["LANEID R1", "LDG R4, [R1+0]"]),
        If(cond=["ISETP.GT P0, R4, 1"], pred=0,
           then_=Seq([
               If(cond=["ISETP.GT P1, R4, 4"], pred=1,
                  then_=If(cond=["ISETP.GT P2, R4, 6"], pred=2,
                           then_=Raw(["MOV R5, 3"]),
                           else_=Raw(["MOV R5, 2"])),
                  else_=Raw(["MOV R5, 1"]))]),
           else_=Raw(["MOV R5, 0"])),
        Raw([f"IADDI R6, R1, {cfg.mem_size // 2}", "STG [R6+0], R5"]),
    ])
    prog = compile_structured(nested, cfg)
    for d in range(datasets):
        out.append(Benchmark(f"GAUS{d}", "rodinia", prog,
                             init_mem=_mem(cfg, 23 + d)))

    # -- graph-like: data-dependent inner loop (BFS neighbor expansion) -----
    # each lane walks mem[deg[lane]] neighbors; degrees are skewed so warps
    # diverge heavily — the paper's graph suites (Lonestar/GraphBIG) flavor.
    bfs = Seq([
        Raw(["LANEID R1", "LDG R2, [R1+0]",      # R2 = degree
             "MOV R3, 0",                         # R3 = j
             "MOV R7, 0"]),                       # R7 = acc
        While(cond=["ISETP.LT P0, R3, R2"], pred=0,
              body=Seq([
                  Raw([f"IADDI R4, R3, {W}",      # neighbor index
                       "LDG R5, [R4+0]",
                       "IADD R7, R7, R5",
                       "IADDI R3, R3, 1"])])),
        Raw([f"IADDI R6, R1, {cfg.mem_size // 2}", "STG [R6+0], R7"]),
    ])
    prog = compile_structured(bfs, cfg)
    # the heuristic skip candidates: every BSYNC in the loop region
    skips = tuple(_find_bsync_pcs(prog))
    for d in range(datasets):
        out.append(Benchmark(f"RBFS{d}", "graph", prog,
                             init_mem=_mem(cfg, 37 + d, 0, 6)))
    # BFSD analogue: same program, hardware-oracle skips reconvergence
    out.append(Benchmark("BFSD", "graph", prog,
                         init_mem=_mem(cfg, 40, 0, 6),
                         skip_bsync_pcs=skips))

    # -- graph-like: frontier loop with early BREAK exit ---------------------
    brk = Seq([
        Raw(["LANEID R1", "LDG R2, [R1+0]", "MOV R3, 0"]),
        While(cond=[f"ISETP.LT P0, R3, {2 * W}"], pred=0,
              break_pred=1,
              body=Seq([
                  Raw(["IADD R4, R3, R1", "LDG R5, [R4+0]",
                       "IADD R2, R2, R5", "IADDI R3, R3, 1",
                       # break when acc passes a threshold (data dependent)
                       "ISETP.GT P1, R2, 9"])])),
        Raw([f"IADDI R6, R1, {cfg.mem_size // 2}", "STG [R6+0], R2"]),
    ])
    # note: break_pred is evaluated at the loop head of the NEXT iteration,
    # so P1 must be (re)set inside the body before looping — done above.
    prog = compile_structured(brk, cfg)
    for d in range(datasets):
        out.append(Benchmark(f"BFSW{d}", "graph", prog,
                             init_mem=_mem(cfg, 53 + d)))

    # -- atomics: histogram (races by design -> behavioral checks only) -----
    hist = Seq([
        Raw(["LANEID R1", "LDG R2, [R1+0]",
             f"AND R2, R2, R2",                  # no-op, keep shape
             f"IADDI R3, R2, {cfg.mem_size // 2}",
             "MOV R4, 1",
             "ATOMADD R5, [R3+0], R4"]),
    ])
    prog = compile_structured(hist, cfg)
    for d in range(datasets):
        out.append(Benchmark(f"HIST{d}", "atomic", prog,
                             init_mem=_mem(cfg, 67 + d, 0, 8),
                             race_free=False))

    # -- atomics: spinlock (Fig 3/7) -----------------------------------------
    out.append(Benchmark("SLOCK", "atomic", spinlock_program(),
                         race_free=False))

    # -- rodinia-like: triangular nested loops (LUD flavor) ------------------
    lud = Seq([
        Raw(["LANEID R1", "MOV R2, 0", "MOV R7, 0"]),
        While(cond=["ISETP.LE P0, R2, R1"], pred=0,        # i <= lane
              body=Seq([
                  Raw(["MOV R3, 0"]),
                  While(cond=["ISETP.LT P1, R3, R2"], pred=1,   # j < i
                        body=Raw(["IADD R4, R2, R3",
                                  "LDG R5, [R4+0]",
                                  "IADD R7, R7, R5",
                                  "IADDI R3, R3, 1"])),
                  Raw(["IADDI R2, R2, 1"])])),
        Raw([f"IADDI R6, R1, {cfg.mem_size // 2}", "STG [R6+0], R7"]),
    ])
    prog = compile_structured(lud, cfg)
    for d in range(datasets):
        out.append(Benchmark(f"LUD{d}", "rodinia", prog,
                             init_mem=_mem(cfg, 81 + d)))

    # -- rodinia-like: wavefront with predicated updates (NW flavor) --------
    nw = Seq([
        Raw(["LANEID R1", "MOV R3, 0", "LDG R7, [R1+0]"]),
        While(cond=[f"ISETP.LT P0, R3, {W // 2}"], pred=0,
              body=Seq([
                  Raw(["IADD R4, R1, R3", "LDG R5, [R4+0]"]),
                  If(cond=["ISETP.GT P1, R5, R7"], pred=1,
                     then_=Raw(["MOVR R7, R5"]),
                     else_=Raw(["IADDI R7, R7, 1"])),
                  Raw(["IADDI R3, R3, 1"])])),
        Raw([f"IADDI R6, R1, {cfg.mem_size // 2}", "STG [R6+0], R7"]),
    ])
    prog = compile_structured(nw, cfg)
    for d in range(datasets):
        out.append(Benchmark(f"NW{d}", "rodinia", prog,
                             init_mem=_mem(cfg, 95 + d)))

    # -- graph-like: iterative prune with flag convergence (KCORE flavor) ---
    kcore = Seq([
        Raw(["LANEID R1", "LDG R2, [R1+0]",      # R2 = degree
             "MOV R3, 0"]),
        While(cond=[f"ISETP.LT P0, R3, {W // 4}"], pred=0,
              body=Seq([
                  If(cond=["ISETP.GT P1, R2, 2"], pred=1,
                     then_=Raw(["IADDI R2, R2, -1"]),
                     else_=Raw(["NOP"])),
                  Raw(["IADDI R3, R3, 1"])])),
        Raw([f"IADDI R6, R1, {cfg.mem_size // 2}", "STG [R6+0], R2"]),
    ])
    prog = compile_structured(kcore, cfg)
    for d in range(datasets):
        out.append(Benchmark(f"KCOR{d}", "graph", prog,
                             init_mem=_mem(cfg, 103 + d)))

    # -- functions: CALL/RET under divergence (Tango/NN flavor) -------------
    fn = assemble(f"""
        LANEID R1
        MOV R9, ret1
        BSSY B0, callsync
        ISETP.GE P0, R1, {W // 2}
        @P0 BRA docall
        MOV R2, 5
        BRA callsync
    docall:
        CALL square
    ret1:
    callsync:
        BSYNC B0
        IADDI R4, R2, {cfg.mem_size // 2}
        STG [R4+0], R2
        EXIT
    square:
        MOVR R2, R1
        IMUL R2, R2, R2
        RET R9
    """)
    out.append(Benchmark("CALLS", "synthetic", fn, race_free=False))

    # -- synthetic: paper walkthrough figures also join the suite -----------
    out.append(Benchmark("FIG5", "synthetic", fig5_program()))
    out.append(Benchmark("FIG6", "synthetic", fig6_program()))
    out.append(Benchmark("DIAMOND", "synthetic", diamond_program()))
    out.append(Benchmark("WSYNC", "synthetic", warpsync_program(W)))
    return out
