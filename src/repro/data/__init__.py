from .pipeline import DataConfig, SyntheticPipeline, synthetic_batch

__all__ = ["DataConfig", "SyntheticPipeline", "synthetic_batch"]
