"""Deterministic synthetic data pipeline.

Real corpora are not available offline, so the pipeline synthesizes token
streams with a counter-based PRNG (Philox via numpy) keyed by
(seed, step, shard).  Determinism properties the training runtime relies on:

* restart safety: batch(step) is a pure function of (seed, step), so a
  resumed run replays the exact stream (checkpoint/restart tests assert
  bit-identical batches);
* elastic resharding: the global batch is always materialized as the same
  logical array regardless of host count; hosts slice their shard, so a run
  rescaled to a different mesh sees the same data order;
* packing: documents of geometric length are packed back-to-back with EOS
  separators, mimicking LM pretraining pipelines (loss masks included).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

import numpy as np

from repro.models.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    mean_doc_len: int = 512
    eos_id: int = 1
    pad_id: int = 0


def _rng(seed: int, step: int, tag: int = 0) -> np.random.Generator:
    key = (seed << 40) ^ (step << 8) ^ tag ^ 0x5eed
    return np.random.default_rng(np.random.Philox(key=[key, 0x9e3779b9]))


def _packed_tokens(rng: np.random.Generator, batch: int, seq: int,
                   vocab: int, dc: DataConfig) -> tuple[np.ndarray, np.ndarray]:
    """Pack 'documents' with LEARNABLE structure: Zipfian unigrams plus
    phrase repetition (each document repeats a short random phrase), so a
    model that learns to copy context drops its loss well below the uniform
    entropy — giving the examples/tests a real convergence signal."""
    V = max(4, vocab)
    # zipf-ish unigram table (deterministic per vocab)
    ranks = np.arange(2, V, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = np.empty((batch, seq), np.int32)
    for b in range(batch):
        pos = 0
        while pos < seq:
            plen = int(rng.integers(4, 17))
            phrase = rng.choice(ranks.astype(np.int64), size=plen,
                                p=probs).astype(np.int32)
            reps = int(rng.integers(2, 6))
            doc = np.concatenate([np.tile(phrase, reps), [dc.eos_id]])
            n = min(len(doc), seq - pos)
            toks[b, pos:pos + n] = doc[:n]
            pos += n
    mask = np.ones((batch, seq), np.float32)
    return toks, mask


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, *,
                    step: int = 0, dc: DataConfig = DataConfig()) -> dict:
    """One global batch as numpy arrays (host side, shardable)."""
    rng = _rng(dc.seed, step)
    if cfg.frontend == "audio_stub":
        frames = rng.standard_normal(
            (batch, seq, cfg.frontend_dim)).astype(np.float32)
        labels = rng.integers(0, cfg.vocab_size, size=(batch, seq),
                              dtype=np.int32)
        return {"frames": frames, "labels": labels}
    if cfg.frontend == "vision_stub":
        n_txt = seq - cfg.n_patches
        toks, mask = _packed_tokens(rng, batch, n_txt, cfg.vocab_size, dc)
        patches = rng.standard_normal(
            (batch, cfg.n_patches, cfg.frontend_dim)).astype(np.float32)
        return {"tokens": toks, "patches": patches, "labels": toks,
                "loss_mask": mask}
    toks, mask = _packed_tokens(rng, batch, seq, cfg.vocab_size, dc)
    return {"tokens": toks, "labels": toks, "loss_mask": mask}


class SyntheticPipeline:
    """Step-indexed pipeline with background prefetch and host sharding."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 dc: DataConfig = DataConfig(), host_index: int = 0,
                 host_count: int = 1, prefetch: int = 2):
        assert batch % host_count == 0
        self.cfg, self.batch, self.seq, self.dc = cfg, batch, seq, dc
        self.host_index, self.host_count = host_index, host_count
        self._cache: dict[int, dict] = {}
        self._lock = threading.Lock()
        self._prefetch = prefetch

    def _shard(self, full: dict) -> dict:
        n = self.batch // self.host_count
        lo = self.host_index * n
        return {k: v[lo:lo + n] for k, v in full.items()}

    def get(self, step: int) -> dict:
        with self._lock:
            if step in self._cache:
                return self._cache.pop(step)
        out = self._shard(synthetic_batch(self.cfg, self.batch, self.seq,
                                          step=step, dc=self.dc))
        # opportunistic synchronous prefetch of the next batches
        with self._lock:
            for s in range(step + 1, step + 1 + self._prefetch):
                if s not in self._cache and len(self._cache) < 4:
                    self._cache[s] = self._shard(synthetic_batch(
                        self.cfg, self.batch, self.seq, step=s, dc=self.dc))
        return out
