"""repro.service — the queue-fed, coalescing, sharded simulation service.

The :mod:`repro.engine` façade answers "run these requests"; this package
answers "keep answering that at scale".  It is the ROADMAP's
production-service layer over the mechanism registry:

* **admission + coalescing** — :class:`~repro.service.coalescer
  .BatchCoalescer` buckets incoming requests by *execution signature*
  (:func:`~repro.service.signature.signature_of`: mechanism, resolved
  machine config, program padding class, scheduling options, mechanism
  meta) and flushes groups on size or deadline;
* **planning/dispatch** — :mod:`repro.service.planner` routes
  signature-homogeneous groups to a mechanism's native ``batch_runner``
  (the vmap-batched JAX path) and the remainder to per-request execution;
  it is the **same** dispatch path ``Simulator.run_batch`` uses;
* **the service** — :class:`~repro.service.core.SimulationService`: worker
  pool, per-(SM, policy) sharded ``run_sm`` cells, durable trace archival
  through any :class:`~repro.engine.sinks.TraceSink` (rotation via
  :class:`~repro.engine.sinks.RotatingJsonlSink`), and frozen
  :class:`~repro.service.core.ServiceStats` metrics.

Quick start
-----------
::

    from repro.service import SimulationService
    from repro.engine import RotatingJsonlSink

    with SimulationService(default_mechanism="hanoi_jax",
                           archive=RotatingJsonlSink("sim-archive"),
                           max_batch=64, workers=4) as svc:
        tickets = [svc.submit(prog, cfg) for prog in programs]     # async
        mixed   = svc.run(requests, mechanism="hanoi")             # sync
        sm      = svc.submit_sm(bench, cfg, n_warps=8,
                                policy="greedy_then_oldest").result()
        print(svc.stats().native_batches, svc.stats().warps_per_s)

``repro.launch.serve --mode sim`` and ``serve_simulations`` are thin
clients of this package.
"""
from .coalescer import Admission, BatchCoalescer, FlushedGroup
from .core import (ServiceStats, ServiceStopped, ShardStats, SimTicket,
                   SimulationService)
from .planner import DispatchGroup, execute_plan, plan_dispatch, run_group
from .procpool import ArchiveSpec, ProcPool
from .signature import ExecSignature, meta_key, shard_of, signature_of

__all__ = [
    "Admission", "ArchiveSpec", "BatchCoalescer", "DispatchGroup",
    "ExecSignature", "FlushedGroup", "ProcPool", "ServiceStats",
    "ServiceStopped", "ShardStats", "SimTicket", "SimulationService",
    "execute_plan", "meta_key", "plan_dispatch", "run_group", "shard_of",
    "signature_of",
]
