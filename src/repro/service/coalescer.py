"""Admission queue + batch coalescer.

Requests are admitted one at a time but executed in signature-homogeneous
groups (that is where the vmap-batched JAX path earns its keep), so the
service buffers admissions briefly and flushes a group when either

* **size**     — the group reaches ``max_batch`` requests (flushed
  synchronously on the admitting thread: no reason to wait once a full
  native batch is assembled), or
* **deadline** — the group's *oldest* entry has waited ``max_wait_s``
  (flushed by the service's flusher thread: bounded admission latency), or
* **manual**   — :meth:`BatchCoalescer.flush_all` (service ``flush()`` /
  shutdown).

The coalescer is pure bookkeeping — it never executes anything and is
safe to drive from multiple admitting threads plus one flusher.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Generic, TypeVar

from .signature import ExecSignature

__all__ = ["Admission", "BatchCoalescer", "FlushedGroup"]

T = TypeVar("T")


@dataclass
class Admission(Generic[T]):
    """One admitted request: the payload plus its admission timestamp."""

    payload: T
    submitted_at: float


@dataclass(frozen=True)
class FlushedGroup(Generic[T]):
    """A signature-homogeneous group handed to the dispatcher."""

    signature: ExecSignature
    entries: tuple[Admission[T], ...]
    cause: str                    # "size" | "deadline" | "manual"

    @property
    def size(self) -> int:
        return len(self.entries)


@dataclass
class _Pending(Generic[T]):
    entries: list[Admission[T]] = field(default_factory=list)
    oldest_at: float = 0.0


class BatchCoalescer(Generic[T]):
    """Thread-safe signature-keyed admission buffer with flush rules."""

    def __init__(self, *, max_batch: int = 64,
                 max_wait_s: float = 0.005,
                 clock: Callable[[], float] = time.monotonic) -> None:
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._pending: dict[ExecSignature, _Pending[T]] = {}

    # -- admission ----------------------------------------------------------

    def add(self, sig: ExecSignature, payload: T
            ) -> tuple[FlushedGroup[T] | None, bool]:
        """Admit one payload.

        Returns ``(flushed, created)``: a size-triggered flush (or None),
        and whether a new bucket was created.  ``created`` lets the caller
        wake its deadline timer only when the earliest deadline can have
        moved — appending to an existing bucket never does (all buckets
        share ``max_wait_s`` and age from their oldest entry).
        """
        now = self._clock()
        with self._lock:
            bucket = self._pending.get(sig)
            created = bucket is None
            if created:
                bucket = self._pending[sig] = _Pending(oldest_at=now)
            bucket.entries.append(Admission(payload, now))
            if len(bucket.entries) >= self.max_batch:
                del self._pending[sig]
                return FlushedGroup(sig, tuple(bucket.entries), "size"), \
                    created
        return None, created

    # -- flush rules --------------------------------------------------------

    def due(self, now: float | None = None) -> list[FlushedGroup[T]]:
        """Pop every group whose oldest entry has waited ``max_wait_s``."""
        if now is None:
            now = self._clock()
        flushed: list[FlushedGroup[T]] = []
        with self._lock:
            for sig in [s for s, b in self._pending.items()
                        if now - b.oldest_at >= self.max_wait_s]:
                bucket = self._pending.pop(sig)
                flushed.append(FlushedGroup(sig, tuple(bucket.entries),
                                            "deadline"))
        return flushed

    def flush_all(self) -> list[FlushedGroup[T]]:
        """Pop every pending group regardless of age."""
        with self._lock:
            flushed = [FlushedGroup(sig, tuple(b.entries), "manual")
                       for sig, b in self._pending.items()]
            self._pending.clear()
        return flushed

    # -- introspection ------------------------------------------------------

    def next_deadline(self) -> float | None:
        """Absolute clock time of the earliest pending deadline, or None."""
        with self._lock:
            if not self._pending:
                return None
            return min(b.oldest_at
                       for b in self._pending.values()) + self.max_wait_s

    def depth(self) -> int:
        """Number of admitted-but-unflushed requests."""
        with self._lock:
            return sum(len(b.entries) for b in self._pending.values())

    def group_count(self) -> int:
        with self._lock:
            return len(self._pending)
