"""Dispatch planning: one grouping/routing path for façade and service.

Historically :meth:`repro.engine.Simulator.run_batch` owned this logic as
private internals (an all-or-nothing ``_homogeneous`` check plus an opt-in
thread-pool fan-out).  The planner generalizes it and is now the **single**
dispatch path:

* the Simulator façade calls :func:`execute_plan` on every ``run_batch``;
* the queue-fed :class:`~repro.service.core.SimulationService` coalesces
  admissions into signature-homogeneous groups and executes each through
  :func:`run_group`;
* the SM composites dispatch their warps here too —
  ``Simulator.run_sm`` and the registered ``sm_interleave`` runner both
  call :func:`execute_plan` on the cell, so an inner mechanism with a
  native ``batch_runner`` (``sm_inner="hanoi_jax"``) executes the whole
  homogeneous cell as ONE cached ``jit(vmap)`` batch instead of a serial
  Python loop over warps.

Routing rules:

* a group whose mechanism has a native ``batch_runner`` and whose signature
  is ``batchable`` executes as **one** native batch (the vmap-over-warps-
  and-programs JAX path) — including mixed program lengths within one
  padding class;
* everything else runs per-request — sequentially, or through a thread
  pool when ``max_workers`` is given and the mechanism is a numpy engine
  with more than one request (see ``Simulator``'s docstring for why the
  default is sequential).

Unlike the old ``_homogeneous`` check, a *mixed* batch no longer falls back
entirely to per-request execution: each homogeneous sub-group still takes
the native path, and :func:`execute_plan` reassembles results in submission
order.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Sequence

from repro.engine.registry import Mechanism
from repro.engine.types import SimRequest, SimResult

from .signature import ExecSignature, signature_of

__all__ = ["DispatchGroup", "plan_dispatch", "run_group", "execute_plan"]


@dataclass(frozen=True)
class DispatchGroup:
    """One signature-homogeneous slice of a batch, with its route."""

    signature: ExecSignature
    indices: tuple[int, ...]      # positions in the submitted batch
    native: bool                  # True -> mechanism.batch_runner

    @property
    def size(self) -> int:
        return len(self.indices)


def group_is_native(mech: Mechanism, sig: ExecSignature) -> bool:
    """Whether a signature-homogeneous group takes the native batch path."""
    return mech.batch_runner is not None and sig.batchable


def plan_dispatch(mech: Mechanism,
                  reqs: Sequence[SimRequest]) -> list[DispatchGroup]:
    """Group ``reqs`` by execution signature, in first-seen order."""
    buckets: dict[ExecSignature, list[int]] = {}
    for i, req in enumerate(reqs):
        buckets.setdefault(signature_of(mech, req), []).append(i)
    return [DispatchGroup(signature=sig, indices=tuple(idx),
                          native=group_is_native(mech, sig))
            for sig, idx in buckets.items()]


def run_group(mech: Mechanism, reqs: Sequence[SimRequest], *,
              native: bool, max_workers: int | None = None
              ) -> list[SimResult]:
    """Execute one signature-homogeneous group, preserving order."""
    reqs = list(reqs)
    if not reqs:
        return []
    if native:
        results = list(mech.batch_runner(reqs))
        if len(results) != len(reqs):
            # a plugin batch_runner that drops results would otherwise
            # silently truncate downstream zips — hanging service tickets
            # instead of surfacing a diagnosable error
            raise RuntimeError(
                f"{mech.name}.batch_runner returned {len(results)} results "
                f"for {len(reqs)} requests")
        return results
    if (mech.backend == "numpy" and len(reqs) > 1
            and max_workers is not None):
        with ThreadPoolExecutor(max_workers=max_workers) as pool:
            return list(pool.map(mech, reqs))
    return [mech(r) for r in reqs]


def execute_plan(mech: Mechanism, reqs: Sequence[SimRequest], *,
                 max_workers: int | None = None,
                 plan: Sequence[DispatchGroup] | None = None
                 ) -> list[SimResult]:
    """Plan, execute, and reassemble a batch in submission order.

    Native groups run as one ``batch_runner`` call each; the per-request
    remainder is pooled *across* groups (a heterogeneous numpy batch would
    otherwise degenerate into size-1 groups and never reach the pool).
    """
    if plan is None:
        plan = plan_dispatch(mech, reqs)
    out: list[SimResult | None] = [None] * len(reqs)
    scalar_idx: list[int] = []
    for g in plan:
        if g.native:
            for i, res in zip(g.indices,
                              run_group(mech, [reqs[i] for i in g.indices],
                                        native=True)):
                out[i] = res
        else:
            scalar_idx.extend(g.indices)
    scalar_idx.sort()
    if scalar_idx:
        for i, res in zip(scalar_idx,
                          run_group(mech, [reqs[i] for i in scalar_idx],
                                    native=False, max_workers=max_workers)):
            out[i] = res
    return out  # type: ignore[return-value]
