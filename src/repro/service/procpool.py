"""Process-backed execution tier: signature-affine shard processes.

The thread-based worker pool has a hard ceiling: JAX batches release the
GIL inside XLA, but the five numpy mechanisms (``simt_stack``, ``hanoi``,
``dualpath``, ``turing_oracle``, ``volta_itps``) are pure-Python loops that
serialize behind it, so no ``workers=`` setting buys the service more than
~1 core for them.  :class:`ProcPool` breaks that ceiling with N **spawned**
worker processes (spawn, never fork — forking a process with a live JAX
runtime is unsafe) and a routing discipline that preserves what made the
single process fast:

* **Signature-affine routing** — jax-backed groups hash their
  :meth:`~repro.service.signature.ExecSignature.token` (mechanism +
  canonical cfg + scheduling flavor + padding class) to one shard via a
  stable crc32, so each process accumulates its *own* hot jit/executable
  cache and pad-class locality instead of every shard re-compiling every
  signature.  SM cells route the same way on a cell-shape token.
* **Chunked spreading for cacheless work** — a numpy group has no compiled
  state to keep warm, and affine routing would pin a homogeneous numpy mix
  to ONE shard (exactly the single-core ceiling again).  The service
  splits such groups into per-shard chunks instead — that is where the
  ≥1.5x 1→2 process scaling gate in ``bench_service.py --smoke`` comes
  from.
* **Picklable envelopes** — jobs (:class:`GroupJob` / :class:`SmJob`) and
  replies (:class:`Reply`) carry the frozen request/result dataclasses,
  which pickle via ``_PicklableMeta``; exceptions cross the boundary as
  :class:`RemoteError` and are rebuilt parent-side.
* **Cross-boundary tickets** — the parent keeps a ``job_id -> pending``
  registry; one collector thread drains the shared reply queue and hands
  each reply to the service's resolution callback, so
  :class:`~repro.service.core.SimTicket` futures resolve exactly as in the
  thread tier.
* **Per-shard archives** — each shard owns a
  ``{prefix}-shard{K}-NNNNN.jsonl`` rotated family written by its own
  :class:`~repro.engine.sinks.RotatingJsonlSink`, with disjoint SM-cell id
  ranges, so archival needs no cross-process lock and every family
  replays independently.
* **Warm start** — a shard with a ``warm_start`` cache directory replays
  *its* slice of the persistent compile-cache manifest (same affinity
  hash) before signalling ready, so a restarted pool re-traces hot
  signatures off the serving path.

Shutdown (:meth:`ProcPool.stop`) honors one shared deadline: sentinels, a
bounded join, then ``terminate()`` for stragglers — which are reported by
process name — and every ticket still pending resolves with
:class:`ServiceStopped` instead of hanging forever.
"""
from __future__ import annotations

import itertools
import multiprocessing as mp
import os
import queue
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.isa import MachineConfig
from repro.engine.compile_cache import shard_of_token

__all__ = ["ServiceStopped", "ArchiveSpec", "GroupJob", "SmJob", "Reply",
           "RemoteError", "ProcPool"]


class ServiceStopped(RuntimeError):
    """The service shut down before this ticket's work completed.

    Raised from :meth:`SimTicket.result` for jobs that were in flight on a
    shard which missed the stop deadline (and was terminated), or that
    were still queued when the pool went down.
    """


@dataclass(frozen=True)
class ArchiveSpec:
    """Rotated-archive coordinates a shard can rebuild a sink from."""

    directory: str
    prefix: str = "traces"
    max_bytes: int = 8 << 20

    def shard_prefix(self, shard: int) -> str:
        return f"{self.prefix}-shard{shard}"


@dataclass(frozen=True)
class _ShardSpec:
    """Everything a spawned shard needs to reconstruct its serving env."""

    shard: int
    n_shards: int
    default_mechanism: str
    annotate: bool
    archive: ArchiveSpec | None
    warm_start: str | None
    init: Callable[[int], None] | None    # module-level fn, pickled by ref


@dataclass
class GroupJob:
    """One flushed (or chunked) signature-homogeneous group."""

    job_id: int
    mechanism: str
    native: bool
    cause: str
    sig_key: str
    requests: list            # list[SimRequest]


@dataclass
class SmJob:
    """One (SM, policy) cell, executed as a single ``Simulator.run_sm``."""

    job_id: int
    programs: Any
    cfg: MachineConfig | None
    kwargs: dict


@dataclass
class RemoteError:
    """A shard-side exception, flattened for the trip home."""

    type_name: str
    message: str
    tb: str

    @staticmethod
    def from_exception(exc: BaseException) -> "RemoteError":
        return RemoteError(type_name=type(exc).__name__, message=str(exc),
                           tb=traceback.format_exc())

    def to_exception(self) -> Exception:
        import builtins
        et = getattr(builtins, self.type_name, None)
        if isinstance(et, type) and issubclass(et, Exception):
            try:
                return et(self.message)
            except Exception:
                pass
        return RuntimeError(f"{self.type_name}: {self.message}\n{self.tb}")


@dataclass
class Reply:
    job_id: int
    shard: int
    payload: Any = None               # list[SimResult] | SmResult
    error: RemoteError | None = None
    cache: dict | None = None         # adapters.batch_cache_stats snapshot


@dataclass
class _Ready:
    shard: int
    pid: int
    warm: dict | None = None          # WarmReport.as_dict()


@dataclass
class _Bye:
    shard: int
    cache: dict | None = None


# ---------------------------------------------------------------------------
# shard process main
# ---------------------------------------------------------------------------

def _shard_main(spec: _ShardSpec, job_q, result_q) -> None:
    """Entry point of one spawned shard process."""
    import dataclasses

    from repro.engine import sinks as sinks_mod
    from repro.engine.adapters import batch_cache_stats
    from repro.engine.registry import get_mechanism
    from repro.engine.simulator import Simulator
    from repro.engine.sinks import (RotatingJsonlSink, feed_result,
                                    next_sm_cell_id, run_meta, sm_run_meta,
                                    timing_meta)
    from repro.service.planner import run_group

    # disjoint per-shard SM-cell id ranges: two shards archiving cells
    # concurrently must never collide on (cell, warp) coordinates
    sinks_mod._sm_cell_ids = itertools.count(spec.shard * 1_000_000)

    if spec.init is not None:
        spec.init(spec.shard)

    sink = None
    if spec.archive is not None:
        sink = RotatingJsonlSink(spec.archive.directory,
                                 prefix=spec.archive.shard_prefix(spec.shard),
                                 max_bytes=spec.archive.max_bytes)

    warm = None
    if spec.warm_start:
        from repro.engine.compile_cache import install_compile_cache
        cache = install_compile_cache(spec.warm_start)
        warm = cache.warm(shard=spec.shard, n_shards=spec.n_shards).as_dict()

    result_q.put(_Ready(shard=spec.shard, pid=os.getpid(), warm=warm))
    sim = Simulator(spec.default_mechanism)

    def _cache_stamp() -> dict:
        s = batch_cache_stats()
        return {"hits": s["hits"], "misses": s["misses"],
                "disk_hits": s["disk_hits"],
                "trace_time_s": round(s["trace_time_s"], 6)}

    def _exec_group(job: GroupJob) -> list:
        mech = get_mechanism(job.mechanism)
        results = run_group(mech, job.requests, native=job.native)
        if spec.annotate:
            svc_meta = {"batch_size": len(job.requests), "native": job.native,
                        "flush": job.cause, "signature": job.sig_key,
                        "shard": spec.shard}
            results = [dataclasses.replace(r, meta={**r.meta,
                                                    "service": svc_meta})
                       for r in results]
        if sink is not None:
            stamp = _cache_stamp()
            for req, res in zip(job.requests, results):
                meta = {**run_meta(mech.name, req), "shard": spec.shard,
                        "compile_cache": stamp}
                feed_result(sink, res, meta)
        return results

    def _exec_sm(job: SmJob):
        sm = sim.run_sm(job.programs, job.cfg, **job.kwargs)
        if sink is not None:
            cell = next_sm_cell_id()
            tmeta = timing_meta(sm)
            stamp = _cache_stamp()
            for w, (wreq, wres) in enumerate(zip(sm.requests, sm.warps)):
                meta = {**sm_run_meta(sm.inner, wreq, warp=w,
                                      n_warps=sm.n_warps, policy=sm.policy,
                                      cell=cell, timing=tmeta),
                        "shard": spec.shard, "compile_cache": stamp}
                feed_result(sink, wres, meta)
        return sm

    try:
        while True:
            job = job_q.get()
            if job is None:
                break
            try:
                payload = (_exec_sm(job) if isinstance(job, SmJob)
                           else _exec_group(job))
                reply = Reply(job_id=job.job_id, shard=spec.shard,
                              payload=payload, cache=batch_cache_stats())
            except Exception as exc:
                reply = Reply(job_id=job.job_id, shard=spec.shard,
                              error=RemoteError.from_exception(exc),
                              cache=batch_cache_stats())
            result_q.put(reply)
    finally:
        if sink is not None:
            sink.close()
        result_q.put(_Bye(shard=spec.shard, cache=batch_cache_stats()))


# ---------------------------------------------------------------------------
# parent-side pool
# ---------------------------------------------------------------------------

@dataclass
class _ShardState:
    proc: Any
    job_q: Any
    pid: int | None = None
    ready: bool = False
    warm: dict | None = None
    cache: dict = field(default_factory=dict)
    jobs: int = 0


class ProcPool:
    """N spawned shard processes + one collector thread.

    ``on_reply(ctx, payload, error)`` is the service's resolution hook: it
    runs on the collector thread with the pending context registered at
    submit time, ``payload`` the shard's result (or ``None``), and
    ``error`` an :class:`Exception` (or ``None``).  The pool never touches
    tickets or stats itself — ownership of those stays with the service.
    """

    def __init__(self, n_procs: int, *, default_mechanism: str,
                 annotate: bool, archive: ArchiveSpec | None = None,
                 warm_start: str | None = None,
                 shard_init: Callable[[int], None] | None = None,
                 on_reply: Callable[[Any, Any, Exception | None], None]
                 = lambda ctx, payload, error: None) -> None:
        if n_procs < 1:
            raise ValueError(f"procs must be >= 1, got {n_procs}")
        self.n = int(n_procs)
        self.shard_archival = archive is not None
        self._on_reply = on_reply
        self._ctx = mp.get_context("spawn")
        self._result_q = self._ctx.Queue()
        self._job_ids = itertools.count()
        self._pending: dict[int, Any] = {}
        self._pending_lock = threading.Lock()
        self._cursor = itertools.count()      # round-robin base for chunks
        self._ready_event = threading.Event()
        self._stop_event = threading.Event()
        self._shards: list[_ShardState] = []
        for k in range(self.n):
            spec = _ShardSpec(shard=k, n_shards=self.n,
                              default_mechanism=default_mechanism,
                              annotate=annotate, archive=archive,
                              warm_start=warm_start, init=shard_init)
            job_q = self._ctx.Queue()
            proc = self._ctx.Process(
                target=_shard_main, args=(spec, job_q, self._result_q),
                name=f"sim-shard-{k}", daemon=True)
            proc.start()
            self._shards.append(_ShardState(proc=proc, job_q=job_q))
        self._collector = threading.Thread(target=self._collect,
                                           daemon=True,
                                           name="sim-shard-collector")
        self._collector.start()

    # -- routing ---------------------------------------------------------

    def shard_for_token(self, token: str) -> int:
        return shard_of_token(token, self.n)

    def next_chunk_base(self) -> int:
        return next(self._cursor) % self.n

    # -- submission ------------------------------------------------------

    def submit_group(self, shard: int, *, mechanism: str, native: bool,
                     cause: str, sig_key: str, requests: list,
                     ctx: Any) -> int:
        job_id = next(self._job_ids)
        job = GroupJob(job_id=job_id, mechanism=mechanism, native=native,
                       cause=cause, sig_key=sig_key, requests=requests)
        self._put(shard, job, ctx)
        return job_id

    def submit_sm(self, shard: int, *, programs: Any,
                  cfg: MachineConfig | None, kwargs: dict, ctx: Any) -> int:
        job_id = next(self._job_ids)
        job = SmJob(job_id=job_id, programs=programs, cfg=cfg, kwargs=kwargs)
        self._put(shard, job, ctx)
        return job_id

    def _put(self, shard: int, job, ctx: Any) -> None:
        st = self._shards[shard % self.n]
        with self._pending_lock:
            self._pending[job.job_id] = ctx
            st.jobs += 1
        try:
            st.job_q.put(job)
        except Exception:
            with self._pending_lock:
                self._pending.pop(job.job_id, None)
            raise

    # -- collection ------------------------------------------------------

    def _collect(self) -> None:
        while True:
            try:
                msg = self._result_q.get(timeout=0.1)
            except queue.Empty:
                if self._stop_event.is_set():
                    return
                continue
            except (OSError, EOFError, ValueError):
                return                        # queue torn down under us
            if isinstance(msg, _Ready):
                st = self._shards[msg.shard]
                st.pid, st.ready, st.warm = msg.pid, True, msg.warm
                if all(s.ready for s in self._shards):
                    self._ready_event.set()
                continue
            if isinstance(msg, _Bye):
                if msg.cache:
                    self._shards[msg.shard].cache = msg.cache
                continue
            if msg.cache:
                self._shards[msg.shard].cache = msg.cache
            with self._pending_lock:
                ctx = self._pending.pop(msg.job_id, None)
            if ctx is None:
                continue                       # already resolved by stop()
            error = msg.error.to_exception() if msg.error else None
            try:
                self._on_reply(ctx, msg.payload, error)
            except Exception:
                traceback.print_exc()          # keep the collector alive

    def wait_ready(self, timeout: float | None = None) -> bool:
        """Block until every shard signalled ready (warm-start complete)."""
        return self._ready_event.wait(timeout)

    # -- introspection ---------------------------------------------------

    def pending_count(self) -> int:
        with self._pending_lock:
            return len(self._pending)

    def shard_info(self) -> list[dict[str, Any]]:
        out = []
        for k, st in enumerate(self._shards):
            out.append({"shard": k, "pid": st.pid,
                        "alive": st.proc.is_alive(), "jobs": st.jobs,
                        "warm": st.warm, "cache": dict(st.cache)})
        return out

    def warm_reports(self) -> list[dict[str, Any]]:
        return [dict(st.warm) for st in self._shards if st.warm]

    def cache_totals(self) -> dict[str, float]:
        tot = {"hits": 0, "misses": 0, "disk_hits": 0, "entries": 0,
               "evictions": 0, "trace_time_s": 0.0}
        for st in self._shards:
            for k in tot:
                tot[k] += st.cache.get(k, 0)
        return tot

    # -- shutdown --------------------------------------------------------

    def stop(self, *, deadline: float) -> list[str]:
        """Drain against one shared deadline; terminate and report shards
        that miss it; resolve every still-pending ticket with
        :class:`ServiceStopped`.  Returns the terminated shards' names."""
        for st in self._shards:
            try:
                st.job_q.put(None)             # sentinel: drain then exit
            except Exception:
                pass
        for st in self._shards:
            st.proc.join(timeout=max(0.0, deadline - time.monotonic()))
        # a cleanly-exited shard has replied to everything it ran, but the
        # collector may still be draining the reply queue — give it the
        # remaining budget before declaring tickets abandoned
        while self.pending_count() and time.monotonic() < deadline:
            time.sleep(0.005)
        stragglers = []
        for st in self._shards:
            if st.proc.is_alive():
                stragglers.append(st.proc.name)
                st.proc.terminate()
        for st in self._shards:
            if st.proc.is_alive():
                st.proc.join(timeout=0.5)
        self._stop_event.set()
        self._collector.join(timeout=1.0)
        with self._pending_lock:
            leftover = list(self._pending.items())
            self._pending.clear()
        for _job_id, ctx in leftover:
            try:
                self._on_reply(ctx, None, ServiceStopped(
                    "service stopped before this job completed"))
            except Exception:
                traceback.print_exc()
        for st in self._shards:
            st.job_q.cancel_join_thread()
            st.job_q.close()
        self._result_q.cancel_join_thread()
        self._result_q.close()
        return stragglers
