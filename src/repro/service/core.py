"""The queue-fed simulation service: admission, coalescing, sharded dispatch.

Architecture (thread tier; see ``docs/service.md``)::

    submit()/submit_sm()                 service threads
        |                                   |
        v                                   v
    BatchCoalescer --size flush--> dispatch queue --> worker pool
        |                              ^                 |
        +--deadline flush (flusher)----+                 v
                                             planner.run_group /
                                             Simulator.run_sm
                                                  |
                                                  v
                                      tickets resolved + archive sink

With ``procs=N`` the dispatch queue + worker pool is replaced by the
**process tier** (:mod:`repro.service.procpool`): flushed groups and SM
cells route to N spawned shard processes — jax groups by signature
affinity, numpy groups chunked across shards — and one collector thread
resolves tickets from the reply queue.  ``warm_start=`` points both tiers
at a persistent :mod:`repro.engine.compile_cache` directory that is
replayed before traffic is admitted.

* **Admission**: ``submit`` coerces the request, derives its
  :class:`~repro.service.signature.ExecSignature`, hands it to the
  :class:`~repro.service.coalescer.BatchCoalescer`, and returns a
  :class:`SimTicket` immediately.
* **Coalescing**: a group flushes when it reaches ``max_batch`` (on the
  admitting thread) or when its oldest entry has waited ``max_wait_s``
  (the flusher thread) — see the coalescer module for the exact rules.
* **Dispatch**: workers execute flushed groups through
  :func:`repro.service.planner.run_group` — the same routing the
  ``Simulator.run_batch`` façade uses — so signature-homogeneous
  ``hanoi_jax`` groups hit the native vmap ``batch_runner``.
* **Sharding**: per-SM jobs bypass the coalescer; each ``submit_sm`` call
  is one (SM, policy) cell executed as a single ``Simulator.run_sm`` on
  the worker pool, and :meth:`SimulationService.run_sm_grid` fans a grid
  of cells out across it.
* **Archival**: every completed warp is replayed into the ``archive``
  sink (e.g. a :class:`~repro.engine.sinks.RotatingJsonlSink`) under a
  lock, so any TraceSink — thread-safe or not — sees whole runs.
* **Metrics**: :meth:`SimulationService.stats` snapshots a frozen
  :class:`ServiceStats` (queue depth, latency percentiles, warps/s,
  batch-fill histogram, native-batch routing counters).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import Counter, deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Any, Mapping, Sequence

from repro.core.isa import MachineConfig
from repro.core.timing import TimingConfig
from repro.core.trace import nearest_rank
from repro.engine.compile_cache import (compile_cache_stats,
                                        install_compile_cache, shard_of_token)
from repro.engine.registry import get_mechanism
from repro.engine.simulator import ProgramLike, Simulator, as_request
from repro.engine.sinks import (RotatingJsonlSink, TraceSink, feed_result,
                                next_sm_cell_id, run_meta, sm_run_meta,
                                timing_meta)
from repro.engine.types import SimRequest, SimResult, SmResult

from .coalescer import BatchCoalescer, FlushedGroup
from .planner import group_is_native, run_group
from .procpool import ArchiveSpec, ProcPool, ServiceStopped
from .signature import ExecSignature, shard_of, signature_of

__all__ = ["ServiceStats", "ShardStats", "SimTicket", "SimulationService",
           "ServiceStopped"]

_SENTINEL = object()


class SimTicket:
    """Future-like handle for one admitted request (or one SM cell).

    ``result(timeout)`` blocks until the service resolves it; ``done()`` /
    ``exception()`` mirror :class:`concurrent.futures.Future`.
    """

    def __init__(self, signature: ExecSignature | None = None) -> None:
        self.signature = signature
        self.submitted_at = time.monotonic()
        self._future: "Future[Any]" = Future()

    def result(self, timeout: float | None = None):
        return self._future.result(timeout)

    def done(self) -> bool:
        return self._future.done()

    def exception(self, timeout: float | None = None):
        return self._future.exception(timeout)


@dataclass(frozen=True)
class ShardStats:
    """Per-process view merged into :class:`ServiceStats` (process tier).

    Latency percentiles here are computed over *this shard's* reservoir;
    the service-level percentiles are nearest-rank over the merged union
    of every shard's reservoir — never an average of averages.
    """

    shard: int
    pid: int | None
    alive: bool
    jobs: int                     # jobs routed to this shard
    completed: int                # warps resolved from this shard
    failed: int
    latency_p50_s: float
    latency_p99_s: float
    cache_hits: int = 0
    cache_misses: int = 0         # fresh XLA re-traces in the shard
    cache_disk_hits: int = 0
    cache_entries: int = 0
    cache_evictions: int = 0
    cache_trace_time_s: float = 0.0


@dataclass(frozen=True)
class ServiceStats:
    """Frozen snapshot of service health and throughput.

    Latency percentiles cover admission -> resolution for the most recent
    requests (bounded window); ``warps_per_s`` is completed warp requests
    over service uptime.  ``submitted`` / ``completed`` / ``failed`` count
    *warps*: an (SM, policy) cell contributes one warp per member — so
    ``warps_per_s`` measures real SM traffic, not cells — while its cell
    latency is recorded once and ``sm_jobs`` counts the cell.
    ``batch_fill`` is the coalescing histogram: ``(batch_size, count)``
    pairs, ascending — a service soaking enough homogeneous traffic shows
    mass at ``max_batch``.

    The ``sm_*_cycles`` fields aggregate the cycle-level stall taxonomy
    (:mod:`repro.timing`, see ``docs/timing.md``) over every SM cell this
    service executed — the fleet-level view of where issue slots went.
    """

    uptime_s: float
    submitted: int
    completed: int
    failed: int
    rejected: int                 # refused at admission by static analysis
    repaired: int                 # auto-annotate rewrites admitted (warps)
    queue_depth: int              # admitted, not yet flushed to dispatch
    inflight: int                 # flushed, not yet resolved
    batches: int                  # flushed groups executed
    native_batches: int           # groups routed to a native batch_runner
    native_warps: int             # requests executed inside native batches
    sm_jobs: int                  # (SM, policy) cells executed
    flush_size: int               # flushes triggered by max_batch
    flush_deadline: int           # flushes triggered by max_wait_s
    flush_manual: int             # flushes triggered by flush()/stop()
    batch_fill: tuple[tuple[int, int], ...]
    latency_p50_s: float
    latency_p99_s: float
    warps_per_s: float
    sm_cycles: int = 0                    # total SM-cell schedule cycles
    sm_busy_cycles: int = 0
    sm_issue_stall_cycles: int = 0
    sm_scoreboard_stall_cycles: int = 0
    sm_memory_stall_cycles: int = 0
    # process tier (0 shard processes = classic thread tier)
    procs: int = 0
    shards: tuple[ShardStats, ...] = ()
    # compile-cache counters, summed across this process and every shard:
    # cache_misses counts fresh XLA re-traces (the warm-start gate drives
    # this to zero for hot signatures), cache_disk_hits deserialized AOT
    # executables, cache_trace_time_s cumulative trace+compile wall time
    cache_hits: int = 0
    cache_misses: int = 0
    cache_disk_hits: int = 0
    cache_entries: int = 0
    cache_evictions: int = 0
    cache_trace_time_s: float = 0.0
    # warm-start replay outcome, summed across shards
    warm_signatures: int = 0
    warm_loaded: int = 0
    warm_retraced: int = 0

    @property
    def mean_fill(self) -> float:
        """Mean coalesced batch size (1.0 = no coalescing happening)."""
        n = sum(c for _, c in self.batch_fill)
        if n == 0:
            return float("nan")
        return sum(s * c for s, c in self.batch_fill) / n

    @property
    def sm_stall_breakdown(self) -> dict[str, int]:
        return {"issue": self.sm_issue_stall_cycles,
                "scoreboard": self.sm_scoreboard_stall_cycles,
                "memory": self.sm_memory_stall_cycles}


@dataclass
class _WarpEntry:
    ticket: SimTicket
    request: SimRequest


@dataclass
class _SmJob:
    ticket: SimTicket
    programs: Any
    cfg: MachineConfig | None
    kwargs: dict
    warps: int = 1      # cell width, counted into the warp-level stats


@dataclass
class _PendingGroup:
    """Parent-side context for one group job in flight on a shard."""

    entries: list                 # coalescer entries (ticket + request)
    mechanism: str
    native: bool
    shard: int


@dataclass
class _PendingSm:
    """Parent-side context for one SM cell in flight on a shard."""

    job: _SmJob
    shard: int


class SimulationService:
    """Queue-fed, coalescing, sharded control-flow simulation service.

    >>> with SimulationService(default_mechanism="hanoi_jax") as svc:
    ...     tickets = [svc.submit(prog, cfg) for prog in programs]
    ...     svc.flush()
    ...     results = [t.result() for t in tickets]

    Parameters
    ----------
    default_mechanism:
        Mechanism for requests that do not name one (``submit(...,
        mechanism=...)`` overrides per request — the service is
        multi-mechanism by design; DARM-style plugins registered via
        ``register_mechanism`` are served with no service changes).
    max_batch / max_wait_s:
        Coalescer flush thresholds (size / admission-latency deadline).
    workers:
        Worker threads executing flushed groups and SM cells.  Native JAX
        batches release the GIL inside XLA; numpy groups are pure-Python
        loops, so more workers mostly helps mixed/JAX traffic.
    procs:
        Shard *processes* (the process tier; ``0`` = classic thread tier).
        Flushed groups and SM cells route to spawned shard processes:
        jax-backed groups by signature affinity (each shard keeps its own
        hot jit/executable cache), numpy groups split into per-shard
        chunks (no compiled state to keep local — spreading them is what
        breaks the GIL's single-core ceiling).  See ``docs/service.md``.
    warm_start:
        Directory of a persistent :class:`~repro.engine.compile_cache.
        CompileCache`.  Fresh compiles are recorded there; at start-up the
        hot-signature manifest is replayed (each shard warms its affine
        slice) *before* traffic is admitted, so restarts do not re-trace
        on the serving path.
    archive:
        Optional :class:`~repro.engine.sinks.TraceSink` that receives every
        completed warp (whole runs, serialized under a service lock).  In
        the process tier a :class:`~repro.engine.sinks.RotatingJsonlSink`
        is re-homed per shard: shard K writes its own rotated
        ``{prefix}-shard{K}`` family into the same directory (the parent
        sink itself stays unwritten); any other sink type is fed
        parent-side from the returned results.
    annotate:
        Attach ``meta["service"]`` (batch size, native routing, flush
        cause, signature key — plus the shard id in the process tier) to
        every result — instrumentation for tests and callers;
        architectural fields are never touched.
    verify:
        Static pre-admission analysis (:mod:`repro.analysis`, default on):
        programs with ``error``-level diagnostics are *rejected at
        admission* — the ticket resolves immediately with a
        :class:`~repro.analysis.StaticAnalysisError` carrying the full
        diagnostic report, nothing is dispatched to a shard, and the
        ``rejected`` stats counter is bumped.  ``"strict"`` also rejects
        on warnings; ``False`` admits everything (the façade default —
        use it to study intentionally-broken programs).
    shard_init:
        Optional module-level callable, pickled by reference and invoked
        as ``shard_init(shard)`` inside every spawned shard before it
        serves — the hook for registering plugin mechanisms in shard
        processes (a parent-process ``register_mechanism`` call does not
        cross the spawn boundary).
    """

    def __init__(self, *, default_mechanism: str = "hanoi_jax",
                 max_batch: int = 64, max_wait_s: float = 0.005,
                 workers: int = 2, procs: int = 0,
                 warm_start: str | None = None,
                 archive: TraceSink | None = None,
                 annotate: bool = True,
                 verify: "bool | str" = True,
                 auto_annotate: bool = False,
                 shard_init=None) -> None:
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if procs < 0:
            raise ValueError(f"procs must be >= 0, got {procs}")
        self._default = get_mechanism(default_mechanism).name
        self._coalescer: BatchCoalescer[_WarpEntry] = BatchCoalescer(
            max_batch=max_batch, max_wait_s=max_wait_s)
        # serializes admission against shutdown: stop() flips _stopping
        # under this lock, so no submit can slip an entry into the
        # coalescer (or a job behind the worker sentinels) after the final
        # flush/drain has begun — that entry's ticket would never resolve
        self._admission_lock = threading.Lock()
        self._n_workers = int(workers)
        self._archive = archive
        self._archive_lock = threading.Lock()
        self._annotate = annotate
        self._verify = verify
        self._auto_annotate = auto_annotate
        self._sim = Simulator(self._default)      # SM cells / shared façade
        self._dispatch: "queue.Queue[Any]" = queue.Queue()
        self._threads: list[threading.Thread] = []
        self._flusher_wake = threading.Event()
        self._started = False
        self._stopping = False
        self._lock = threading.Lock()             # stats + lifecycle
        self._stats = {
            "submitted": 0, "completed": 0, "failed": 0, "rejected": 0,
            "repaired": 0, "inflight": 0,
            "batches": 0, "native_batches": 0, "native_warps": 0,
            "sm_jobs": 0, "flush_size": 0, "flush_deadline": 0,
            "flush_manual": 0,
            "sm_cycles": 0, "sm_busy_cycles": 0, "sm_issue_stall_cycles": 0,
            "sm_scoreboard_stall_cycles": 0, "sm_memory_stall_cycles": 0,
        }
        self._fill: Counter = Counter()
        self._latencies: deque = deque(maxlen=4096)
        self._started_at = time.monotonic()
        # process tier
        self._n_procs = int(procs)
        self._warm_start = warm_start
        self._shard_init = shard_init
        self._pool: ProcPool | None = None
        # per-shard latency reservoirs; stats() merges their union with
        # self._latencies and takes nearest-rank percentiles over the whole
        # merged sample — averaging per-shard percentiles would be wrong
        self._shard_latencies: dict[int, deque] = {}
        self._shard_counters: dict[int, Counter] = {}
        self._warm_reports: list[dict] = []       # thread-tier warm outcome
        self._last_shards: tuple[ShardStats, ...] = ()
        self._last_cache: dict[str, float] = {}

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "SimulationService":
        with self._lock:
            if self._started:
                return self
            self._started = True
            self._stopping = False
            self._started_at = time.monotonic()
        if self._n_procs > 0:
            archive_spec = None
            if isinstance(self._archive, RotatingJsonlSink):
                # re-home the rotated archive per shard: shard K writes its
                # own {prefix}-shardK family into the same directory; the
                # parent's sink object stays unwritten
                archive_spec = ArchiveSpec(
                    directory=self._archive.directory,
                    prefix=self._archive.prefix,
                    max_bytes=self._archive.max_bytes)
            self._pool = ProcPool(
                self._n_procs, default_mechanism=self._default,
                annotate=self._annotate, archive=archive_spec,
                warm_start=self._warm_start, shard_init=self._shard_init,
                on_reply=self._on_pool_reply)
            if self._warm_start:
                # warm-start contract: every shard replays its affine slice
                # of the hot-signature manifest *before* traffic is admitted
                self._pool.wait_ready(timeout=300.0)
        elif self._warm_start:
            cache = install_compile_cache(self._warm_start)
            self._warm_reports = [cache.warm(shard=0, n_shards=1).as_dict()]
        flusher = threading.Thread(target=self._flusher_loop, daemon=True,
                                   name="sim-service-flusher")
        flusher.start()
        self._threads.append(flusher)
        if self._pool is None:
            for i in range(self._n_workers):
                w = threading.Thread(target=self._worker_loop, daemon=True,
                                     name=f"sim-service-worker-{i}")
                w.start()
                self._threads.append(w)
        return self

    def stop(self, *, timeout: float = 30.0) -> list[str]:
        """Flush all pending work, drain it, and join the threads.

        ``timeout`` is ONE shared deadline across every join — not a
        per-thread/per-shard budget (which would make the worst-case
        shutdown ``(workers + 1) x timeout``).  Returns the names of
        threads — and, in the process tier, shard processes — still alive
        when the deadline expired (empty list = clean shutdown).  A shard
        that misses the deadline is **terminated**, and every ticket still
        in flight on the pool resolves with :class:`ServiceStopped`
        instead of hanging forever.
        """
        with self._admission_lock:
            with self._lock:
                if not self._started:
                    return []
                self._stopping = True
        self.flush()
        deadline = time.monotonic() + timeout
        stragglers: list[str] = []
        if self._pool is not None:
            self._flusher_wake.set()
            stragglers += self._pool.stop(deadline=deadline)
            self._snapshot_pool()
            self._pool = None
        else:
            self._dispatch.join()                 # drain in-flight jobs
            for _ in range(self._n_workers):
                self._dispatch.put(_SENTINEL)
            self._flusher_wake.set()
        for t in self._threads:
            t.join(timeout=max(0.0, deadline - time.monotonic()))
        stragglers += [t.name for t in self._threads if t.is_alive()]
        self._threads.clear()
        with self._lock:
            self._started = False
        return stragglers

    def __enter__(self) -> "SimulationService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _ensure_started(self) -> None:
        if not self._started:
            self.start()
        if self._stopping:
            raise RuntimeError("SimulationService is stopping")

    # -- admission ----------------------------------------------------------

    def _admission_error(self, req: SimRequest):
        """The :class:`~repro.analysis.StaticAnalysisError` for ``req``,
        or None when it passes (or verification is off)."""
        if not self._verify:
            return None
        from repro.analysis import StaticAnalysisError, verify_program
        try:
            verify_program(req.program, req.resolved_cfg(), name=req.name,
                           strict=(self._verify == "strict"))
        except StaticAnalysisError as exc:
            return exc
        return None

    def _repair(self, req: SimRequest) -> "SimRequest | None":
        """``auto_annotate`` path: a synthesized copy of ``req`` that
        passes admission, or None when the synthesizer refuses
        (CALL/RET-crossing regions), changes nothing, or the rewrite
        still fails verification (e.g. ``reconvergence`` errors the
        synthesizer cannot undo)."""
        from repro.analysis import TransformError, synthesize_annotations
        try:
            syn = synthesize_annotations(req.program, req.resolved_cfg(),
                                         name=req.name)
        except TransformError:
            return None
        if not syn.changed:
            return None
        fixed = dataclasses.replace(req, program=syn.program)
        if self._admission_error(fixed) is not None:
            return None
        return fixed

    def _reject(self, ticket: SimTicket, exc: Exception, warps: int) -> None:
        """Resolve a ticket with a rejection — nothing is dispatched."""
        with self._lock:
            self._stats["submitted"] += warps
            self._stats["rejected"] += warps
        ticket._future.set_exception(exc)

    def submit(self, program: ProgramLike,
               cfg: MachineConfig | None = None, *,
               mechanism: str | None = None, **request_kw) -> SimTicket:
        """Admit one warp request; returns immediately with a ticket.

        Statically-invalid programs (see the ``verify`` constructor knob)
        are rejected here: the ticket carries the analysis report as its
        exception and no shard ever sees the request.  With
        ``auto_annotate=True`` a rejection is first routed through the
        annotation synthesizer — repaired programs are admitted (and
        counted in ``ServiceStats.repaired``); only programs the
        synthesizer cannot fix are rejected.
        """
        mech = get_mechanism(mechanism or self._default)
        req = as_request(program, cfg, **request_kw)
        exc = self._admission_error(req)
        repaired = False
        if exc is not None and self._auto_annotate:
            fixed = self._repair(req)
            if fixed is not None:
                req, exc, repaired = fixed, None, True
        # signature after repair: the admitted program is what coalesces
        sig = signature_of(mech, req)
        ticket = SimTicket(sig)
        if exc is not None:
            self._reject(ticket, exc, 1)
            return ticket
        with self._admission_lock:
            self._ensure_started()
            with self._lock:
                self._stats["submitted"] += 1
                if repaired:
                    self._stats["repaired"] += 1
            full, created = self._coalescer.add(sig, _WarpEntry(ticket, req))
            if full is not None:
                self._enqueue_group(full)
            elif created:
                self._flusher_wake.set()          # new earliest deadline
        return ticket

    def submit_many(self, programs: Sequence[ProgramLike],
                    cfg: MachineConfig | None = None, *,
                    mechanism: str | None = None,
                    **request_kw) -> list[SimTicket]:
        return [self.submit(p, cfg, mechanism=mechanism, **request_kw)
                for p in programs]

    def submit_sm(self, programs: "ProgramLike | Sequence[ProgramLike]",
                  cfg: MachineConfig | None = None, *,
                  n_warps: int | None = None, inner: str | None = None,
                  policy: str = "round_robin",
                  timing_cfg: TimingConfig = TimingConfig(),
                  **request_kw) -> SimTicket:
        """Admit one (SM, policy) cell — executed as a single sharded
        ``Simulator.run_sm`` call on the worker pool, bypassing the
        coalescer (an SM cell is already a batch of warps).

        Stats count the cell's *warps* into ``submitted`` / ``completed``
        (``warps_per_s`` measures SM traffic, not cells); ``sm_jobs`` and
        the latency window record the cell once.
        """
        from repro.engine.mechanisms.sm import per_warp_programs, warp_count
        warps = warp_count(programs, n_warps)
        ticket = SimTicket()
        if self._verify:
            try:
                per_warp = per_warp_programs(programs, n_warps)
            except ValueError:
                # programs/n_warps conflict: not a static-analysis matter —
                # admit and let run_sm fail it per warp, as without verify
                per_warp = ()
            fixed_warps: list = []
            n_repaired = 0
            for p in per_warp:
                req = as_request(p, cfg, **request_kw)
                exc = self._admission_error(req)
                if exc is not None and self._auto_annotate:
                    fixed = self._repair(req)
                    if fixed is not None:
                        fixed_warps.append(fixed.program)
                        n_repaired += 1
                        continue
                if exc is not None:
                    self._reject(ticket, exc, max(1, warps))
                    return ticket
                fixed_warps.append(p)
            if n_repaired:
                # admit the repaired cell: the per-warp expansion *is*
                # the program list now, so pin n_warps to its length
                programs, n_warps = fixed_warps, len(fixed_warps)
        else:
            n_repaired = 0
        job = _SmJob(ticket=ticket, programs=programs, cfg=cfg,
                     kwargs=dict(n_warps=n_warps, inner=inner, policy=policy,
                                 timing_cfg=timing_cfg, **request_kw),
                     warps=max(1, warps))
        with self._admission_lock:
            self._ensure_started()
            with self._lock:
                self._stats["submitted"] += job.warps
                self._stats["inflight"] += job.warps
                self._stats["repaired"] += n_repaired
            if self._pool is not None:
                # cell-shape affinity: cells sharing (inner, policy, cfg,
                # width) land on one shard and reuse its compiled SM state
                token = (f"sm|{job.kwargs.get('inner') or self._default}"
                         f"|{job.kwargs.get('policy')}|{job.cfg!r}"
                         f"|w{job.warps}")
                shard = self._pool.shard_for_token(token)
                self._pool.submit_sm(
                    shard, programs=job.programs, cfg=job.cfg,
                    kwargs=job.kwargs, ctx=_PendingSm(job=job, shard=shard))
            else:
                self._dispatch.put(job)
        return ticket

    # -- synchronous conveniences -------------------------------------------

    def run(self, requests: Sequence[ProgramLike],
            cfg: MachineConfig | None = None, *,
            mechanism: str | None = None, timeout: float | None = None,
            **request_kw) -> list[SimResult]:
        """Submit a batch, flush, and wait — results in submission order.

        Mixed batches are fine: requests are coalesced by signature and may
        execute out of order across groups, but the returned list always
        matches the order of ``requests``.
        """
        tickets = self.submit_many(requests, cfg, mechanism=mechanism,
                                   **request_kw)
        self.flush()
        return [t.result(timeout) for t in tickets]

    def run_sm_grid(self, cells: Sequence[Mapping[str, Any]], *,
                    timeout: float | None = None) -> list[SmResult]:
        """Fan a grid of (SM, policy) cells out over the worker pool.

        Each cell is a mapping of :meth:`submit_sm` arguments, e.g.
        ``{"programs": bench, "cfg": cfg, "n_warps": 8, "policy":
        "greedy_then_oldest"}`` — one ``run_sm`` call per cell, the
        ROADMAP's sharding unit.
        """
        tickets = [self.submit_sm(**dict(cell)) for cell in cells]
        return [t.result(timeout) for t in tickets]

    def flush(self) -> None:
        """Force-flush every pending coalescer group to the dispatcher."""
        for group in self._coalescer.flush_all():
            self._enqueue_group(group)

    # -- metrics ------------------------------------------------------------

    def _shard_stats_snapshot(self) -> tuple[ShardStats, ...]:
        """Live per-shard views (process tier); saved snapshot after stop."""
        pool = self._pool
        if pool is None:
            return self._last_shards
        out = []
        for info in pool.shard_info():
            k = info["shard"]
            with self._lock:
                lat = sorted(self._shard_latencies.get(k, ()))
                counters = self._shard_counters.get(k, Counter())
            cache = info["cache"]
            out.append(ShardStats(
                shard=k, pid=info["pid"], alive=info["alive"],
                jobs=info["jobs"],
                completed=int(counters.get("completed", 0)),
                failed=int(counters.get("failed", 0)),
                latency_p50_s=nearest_rank(lat, 0.50),
                latency_p99_s=nearest_rank(lat, 0.99),
                cache_hits=int(cache.get("hits", 0)),
                cache_misses=int(cache.get("misses", 0)),
                cache_disk_hits=int(cache.get("disk_hits", 0)),
                cache_entries=int(cache.get("entries", 0)),
                cache_evictions=int(cache.get("evictions", 0)),
                cache_trace_time_s=float(cache.get("trace_time_s", 0.0))))
        return tuple(out)

    def _snapshot_pool(self) -> None:
        """Preserve shard + cache views so stats() stays truthful post-stop."""
        self._last_shards = self._shard_stats_snapshot()
        if self._pool is not None:
            self._last_cache = self._pool.cache_totals()
            self._warm_reports = self._pool.warm_reports()

    def stats(self) -> ServiceStats:
        now = time.monotonic()
        with self._lock:
            s = dict(self._stats)
            # merged latency sample: the parent reservoir plus every
            # shard's reservoir — percentiles are nearest-rank over the
            # union, never an average of per-shard percentiles
            merged = list(self._latencies)
            for d in self._shard_latencies.values():
                merged.extend(d)
            lat = sorted(merged)
            fill = tuple(sorted(self._fill.items()))
            uptime = max(1e-9, now - self._started_at)

        shards = self._shard_stats_snapshot()
        # compile-cache counters of the *execution tier*: the shard
        # processes in the process tier (the parent executes nothing
        # there — mixing in its unrelated cache history would corrupt the
        # zero-re-trace gate), this process's own caches otherwise
        keys = ("hits", "misses", "disk_hits", "entries", "evictions",
                "trace_time_s")
        if self._pool is not None:
            pooled = self._pool.cache_totals()
        elif self._last_shards:
            pooled = self._last_cache
        else:
            pooled = compile_cache_stats()
        cache = {k: pooled.get(k, 0) for k in keys}
        warm = {"signatures": 0, "loaded": 0, "retraced": 0}
        warm_reports = (self._pool.warm_reports() if self._pool is not None
                        else self._warm_reports)
        for rep in warm_reports:
            for k in warm:
                warm[k] += int(rep.get(k, 0))

        return ServiceStats(
            uptime_s=uptime,
            submitted=s["submitted"], completed=s["completed"],
            failed=s["failed"], rejected=s["rejected"],
            repaired=s["repaired"],
            queue_depth=self._coalescer.depth(),
            inflight=s["inflight"],
            batches=s["batches"], native_batches=s["native_batches"],
            native_warps=s["native_warps"], sm_jobs=s["sm_jobs"],
            flush_size=s["flush_size"], flush_deadline=s["flush_deadline"],
            flush_manual=s["flush_manual"],
            batch_fill=fill,
            latency_p50_s=nearest_rank(lat, 0.50),
            latency_p99_s=nearest_rank(lat, 0.99),
            warps_per_s=s["completed"] / uptime,
            sm_cycles=s["sm_cycles"], sm_busy_cycles=s["sm_busy_cycles"],
            sm_issue_stall_cycles=s["sm_issue_stall_cycles"],
            sm_scoreboard_stall_cycles=s["sm_scoreboard_stall_cycles"],
            sm_memory_stall_cycles=s["sm_memory_stall_cycles"],
            procs=self._n_procs if (self._pool is not None
                                    or self._last_shards) else 0,
            shards=shards,
            cache_hits=int(cache["hits"]),
            cache_misses=int(cache["misses"]),
            cache_disk_hits=int(cache["disk_hits"]),
            cache_entries=int(cache["entries"]),
            cache_evictions=int(cache["evictions"]),
            cache_trace_time_s=float(cache["trace_time_s"]),
            warm_signatures=warm["signatures"], warm_loaded=warm["loaded"],
            warm_retraced=warm["retraced"])

    # -- internals: flusher -------------------------------------------------

    def _enqueue_group(self, group: FlushedGroup[_WarpEntry]) -> None:
        with self._lock:
            self._stats[f"flush_{group.cause}"] += 1
            self._stats["inflight"] += group.size
        if self._pool is not None:
            self._route_group_to_pool(group)
        else:
            self._dispatch.put(group)

    def _route_group_to_pool(self, group: FlushedGroup[_WarpEntry]) -> None:
        """Process-tier routing of one flushed group.

        Jax-backed groups go whole to their signature-affine shard — the
        shard that owns (and stays hot on) that signature's jit/executable
        cache state.  Numpy groups have no compiled state to keep local
        and would serialize on one core if pinned, so they split into
        per-shard chunks (round-robin base so successive groups cover
        different shards even when the pool is wider than the group).
        """
        mech = get_mechanism(group.signature.mechanism)
        native = group_is_native(mech, group.signature)
        entries = list(group.entries)
        with self._lock:
            # coalesced fill is recorded per flushed group (pre-chunking):
            # the histogram measures coalescing quality, not shard fan-out
            self._fill[group.size] += 1
        if mech.backend == "numpy" and len(entries) > 1 and self._pool.n > 1:
            n_chunks = min(self._pool.n, len(entries))
            base = self._pool.next_chunk_base()
            for j in range(n_chunks):
                chunk = entries[j::n_chunks]
                shard = (base + j) % self._pool.n
                self._pool.submit_group(
                    shard, mechanism=mech.name, native=False,
                    cause=group.cause, sig_key=group.signature.key,
                    requests=[e.payload.request for e in chunk],
                    ctx=_PendingGroup(entries=chunk, mechanism=mech.name,
                                      native=False, shard=shard))
        else:
            shard = shard_of(group.signature, self._pool.n)
            self._pool.submit_group(
                shard, mechanism=mech.name, native=native,
                cause=group.cause, sig_key=group.signature.key,
                requests=[e.payload.request for e in entries],
                ctx=_PendingGroup(entries=entries, mechanism=mech.name,
                                  native=native, shard=shard))

    def _on_pool_reply(self, ctx, payload, error) -> None:
        """Collector-thread resolution of one shard reply (or abandonment).

        Mirrors the thread tier's ``_execute_group`` / ``_execute_sm``
        bookkeeping: stats, per-shard latency reservoirs, parent-side
        archival for sink types that cannot be re-homed per shard, and
        ticket resolution — success, the rebuilt shard exception, or
        :class:`ServiceStopped` at shutdown.
        """
        now = time.monotonic()
        if isinstance(ctx, _PendingSm):
            job = ctx.job
            counters = self._shard_counters.setdefault(ctx.shard, Counter())
            if error is not None:
                with self._lock:
                    self._stats["failed"] += job.warps
                    self._stats["inflight"] -= job.warps
                    counters["failed"] += job.warps
                job.ticket._future.set_exception(error)
                return
            sm = payload
            if self._archive is not None and not self._pool.shard_archival:
                cell = next_sm_cell_id()
                tmeta = timing_meta(sm)
                for w, (wreq, wres) in enumerate(zip(sm.requests, sm.warps)):
                    self._archive_result(
                        wres, sm.inner,
                        meta=sm_run_meta(sm.inner, wreq, warp=w,
                                         n_warps=sm.n_warps,
                                         policy=sm.policy, cell=cell,
                                         timing=tmeta))
            job.ticket._future.set_result(sm)
            with self._lock:
                self._stats["completed"] += job.warps
                self._stats["inflight"] -= job.warps
                self._stats["sm_jobs"] += 1
                self._stats["sm_cycles"] += sm.cycles
                self._stats["sm_busy_cycles"] += sm.busy_cycles
                self._stats["sm_issue_stall_cycles"] += sm.issue_stall_cycles
                self._stats["sm_scoreboard_stall_cycles"] += \
                    sm.scoreboard_stall_cycles
                self._stats["sm_memory_stall_cycles"] += sm.memory_stall_cycles
                counters["completed"] += job.warps
                self._shard_latencies.setdefault(
                    ctx.shard, deque(maxlen=4096)).append(
                        now - job.ticket.submitted_at)
            return
        # group reply
        n = len(ctx.entries)
        counters = self._shard_counters.setdefault(ctx.shard, Counter())
        if error is not None:
            with self._lock:
                self._stats["failed"] += n
                self._stats["inflight"] -= n
                counters["failed"] += n
            for e in ctx.entries:
                e.payload.ticket._future.set_exception(error)
            return
        results = payload
        if self._archive is not None and not self._pool.shard_archival:
            for e, res in zip(ctx.entries, results):
                self._archive_result(res, ctx.mechanism, e.payload.request)
        for e, res in zip(ctx.entries, results):
            e.payload.ticket._future.set_result(res)
        with self._lock:
            self._stats["completed"] += n
            self._stats["inflight"] -= n
            self._stats["batches"] += 1
            if ctx.native:
                self._stats["native_batches"] += 1
                self._stats["native_warps"] += n
            counters["completed"] += n
            lat = self._shard_latencies.setdefault(ctx.shard,
                                                   deque(maxlen=4096))
            for e in ctx.entries:
                lat.append(now - e.submitted_at)

    def _flusher_loop(self) -> None:
        while True:
            deadline = self._coalescer.next_deadline()
            if deadline is None:
                self._flusher_wake.wait()
            else:
                self._flusher_wake.wait(
                    timeout=max(0.0, deadline - time.monotonic()))
            self._flusher_wake.clear()
            # the admission lock makes pop->enqueue atomic w.r.t. stop():
            # without it, a group popped by due() here could be enqueued
            # *behind* the worker sentinels (stop's flush_all sees an empty
            # coalescer, join() returns, sentinels go in, workers exit) and
            # its tickets would never resolve
            with self._admission_lock:
                with self._lock:
                    if self._stopping:
                        return
                for group in self._coalescer.due():
                    self._enqueue_group(group)

    # -- internals: workers -------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            job = self._dispatch.get()
            try:
                if job is _SENTINEL:
                    return
                if isinstance(job, _SmJob):
                    self._execute_sm(job)
                else:
                    self._execute_group(job)
            finally:
                self._dispatch.task_done()

    def _execute_group(self, group: FlushedGroup[_WarpEntry]) -> None:
        mech = get_mechanism(group.signature.mechanism)
        native = group_is_native(mech, group.signature)
        reqs = [e.payload.request for e in group.entries]
        try:
            results = run_group(mech, reqs, native=native)
        except Exception as exc:                  # resolve the whole group
            with self._lock:
                self._stats["failed"] += group.size
                self._stats["inflight"] -= group.size
            for e in group.entries:
                e.payload.ticket._future.set_exception(exc)
            return
        now = time.monotonic()
        if self._annotate:
            svc_meta = {"batch_size": group.size, "native": native,
                        "flush": group.cause, "signature":
                        group.signature.key}
            results = [dataclasses.replace(
                r, meta={**r.meta, "service": svc_meta}) for r in results]
        for entry, req, res in zip(group.entries, reqs, results):
            self._archive_result(res, mech.name, req)
            entry.payload.ticket._future.set_result(res)
        with self._lock:
            self._stats["completed"] += group.size
            self._stats["inflight"] -= group.size
            self._stats["batches"] += 1
            if native:
                self._stats["native_batches"] += 1
                self._stats["native_warps"] += group.size
            self._fill[group.size] += 1
            for e in group.entries:
                self._latencies.append(now - e.submitted_at)

    def _execute_sm(self, job: _SmJob) -> None:
        try:
            sm = self._sim.run_sm(job.programs, job.cfg, **job.kwargs)
        except Exception as exc:
            with self._lock:
                self._stats["failed"] += job.warps
                self._stats["inflight"] -= job.warps
            job.ticket._future.set_exception(exc)
            return
        now = time.monotonic()
        # archive each warp through the same replayable meta builder the
        # façade uses (sm_run_meta: replay payload + cell coordinates) —
        # a service-archived SM cell replays bit-equal to a live run
        cell = next_sm_cell_id()
        tmeta = timing_meta(sm)
        for w, (warp_req, warp_res) in enumerate(zip(sm.requests, sm.warps)):
            self._archive_result(
                warp_res, sm.inner,
                meta=sm_run_meta(sm.inner, warp_req, warp=w,
                                 n_warps=sm.n_warps, policy=sm.policy,
                                 cell=cell, timing=tmeta))
        job.ticket._future.set_result(sm)
        with self._lock:
            self._stats["completed"] += job.warps
            self._stats["inflight"] -= job.warps
            self._stats["sm_jobs"] += 1
            self._stats["sm_cycles"] += sm.cycles
            self._stats["sm_busy_cycles"] += sm.busy_cycles
            self._stats["sm_issue_stall_cycles"] += sm.issue_stall_cycles
            self._stats["sm_scoreboard_stall_cycles"] += \
                sm.scoreboard_stall_cycles
            self._stats["sm_memory_stall_cycles"] += sm.memory_stall_cycles
            self._latencies.append(now - job.ticket.submitted_at)

    def _archive_result(self, result: SimResult, mechanism: str,
                        req: SimRequest | None = None,
                        meta: Mapping[str, Any] | None = None) -> None:
        if self._archive is None:
            return
        if meta is None:
            assert req is not None
            meta = run_meta(mechanism, req)   # replayable begin event
        from repro.engine.compile_cache import installed_cache
        if installed_cache() is not None:
            # warm-start deployments stamp the compile-cache counters onto
            # every archived run, so an operator can read re-trace behavior
            # straight off the archive
            from repro.engine.adapters import batch_cache_stats
            s = batch_cache_stats()
            meta = {**meta, "compile_cache": {
                "hits": s["hits"], "misses": s["misses"],
                "disk_hits": s["disk_hits"],
                "trace_time_s": round(s["trace_time_s"], 6)}}
        with self._archive_lock:
            feed_result(self._archive, result, meta)
