"""Execution signatures: the grouping key for coalescing and dispatch.

Two requests may share one native batch execution (the vmap-batched JAX
path, one compiled executable) exactly when everything the compiled state
machine closes over is equal: the mechanism, the resolved
:class:`~repro.core.isa.MachineConfig` (fuel folded in), the program's
*padding class* (length rounded up to
:data:`~repro.engine.adapters.PAD_QUANTUM` — programs in one class batch
into the same padded shape), the scheduling options
(``majority_first``), the oracle skip set, and any mechanism-specific
``meta`` options.  Per-request *data* — registers, memory image, lane ids —
is deliberately **not** part of the signature: the batch runner carries it
as vmapped operands.

:func:`signature_of` derives that key from a request; the coalescer buckets
admissions by it and the planner routes each bucket either to the
mechanism's native ``batch_runner`` (``sig.batchable`` and a runner exists)
or to the per-request path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping

import numpy as np

from repro.core.isa import MachineConfig
from repro.engine.adapters import padded_len
from repro.engine.compile_cache import affinity_token, shard_of_token
from repro.engine.registry import Mechanism, get_mechanism
from repro.engine.types import SimRequest

__all__ = ["ExecSignature", "signature_of", "meta_key", "shard_of"]


def meta_key(meta: Mapping[str, Any]) -> tuple[tuple[str, str], ...]:
    """A hashable, order-independent key for a ``meta`` mapping.

    Values are keyed by ``repr`` so unhashable option values (lists, dicts)
    still coalesce; two requests whose options merely *print* differently
    are conservatively kept apart, which can only split batches, never
    merge incompatible ones.
    """
    return tuple(sorted((str(k), repr(v)) for k, v in meta.items()))


@dataclass(frozen=True)
class ExecSignature:
    """Everything that must match for two requests to share one execution.

    ``batchable`` is request-side eligibility for a native batch runner
    (currently: a default entry mask — ``active0 is None`` — which the
    vmapped JAX path assumes).  Whether a batch runner actually exists is
    a property of the mechanism, not the request; the planner combines
    both (see :func:`repro.service.planner.plan_dispatch`).
    """

    mechanism: str
    cfg: MachineConfig                     # resolved: fuel folded into max_steps
    pad_len: int                           # program-length padding class
    majority_first: bool
    batchable: bool                        # active0 is None
    record_trace: bool
    skip_pcs: tuple[int, ...]
    meta: tuple[tuple[str, str], ...]

    @property
    def key(self) -> str:
        """Compact human-readable form for logs / stats."""
        opts = ",".join(f"{k}={v}" for k, v in self.meta)
        return (f"{self.mechanism}/w{self.cfg.n_threads}"
                f"/L{self.pad_len}/f{self.cfg.max_steps}"
                + ("" if self.majority_first else "/minor")
                + ("" if self.batchable else "/masked")
                + ("" if self.record_trace else "/notrace")
                + (f"/skip{len(self.skip_pcs)}" if self.skip_pcs else "")
                + (f"/{opts}" if opts else ""))

    @property
    def token(self) -> str:
        """The compiled-state locality token of this signature — the same
        string the persistent compile cache stamps into its manifest, so
        process-tier routing and warm-start sharding agree on which shard
        owns which hot jit/executable cache state."""
        return affinity_token(self.mechanism, self.cfg, self.majority_first,
                              self.pad_len)


def shard_of(sig: ExecSignature, n_shards: int) -> int:
    """Signature-affine shard assignment: a stable crc32 of the locality
    token, mod the pool size.  Stable across processes and runs (unlike the
    builtin ``hash``, which is salted per interpreter)."""
    return shard_of_token(sig.token, n_shards)


def signature_of(mechanism: "str | Mechanism", req: SimRequest) -> ExecSignature:
    """Derive the coalescing/dispatch signature of one request."""
    name = mechanism.name if isinstance(mechanism, Mechanism) \
        else get_mechanism(mechanism).name
    return ExecSignature(
        mechanism=name,
        cfg=req.resolved_cfg(),
        pad_len=padded_len(int(np.asarray(req.program).shape[0])),
        majority_first=bool(req.majority_first),
        batchable=req.active0 is None,
        record_trace=bool(req.record_trace),
        skip_pcs=tuple(req.bsync_skip_pcs),
        meta=meta_key(req.meta))
