"""Sharding rule engine: logical axes -> mesh axes per (arch x shape).

Baseline policy (the SS Perf loop iterates on this):

* 2-D weight sharding everywhere: TP on 'model' (mlp/vocab/heads/experts) x
  FSDP on 'data' (the d_model axis) — optimizer moments inherit it (ZeRO-3);
* activations: batch on ('pod', 'data') (pure DP across pods);
* GQA: shard the q-head axis when divisible by the model-axis size, else
  the head_dim axis (all assigned archs have hd % 16 == 0);
* MoE: expert-parallel on 'model' when n_experts divides, else TP inside the
  expert ffn (Mixtral's 8 experts on a 16-way axis);
* decode: KV caches shard batch on data and head_dim on model; the
  batch=1 long-context cell flips to sequence-parallel caches (SP) on 'data'.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models.base import ModelConfig, P


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def logical_rules(cfg: ModelConfig, mesh: Mesh, *,
                  fsdp: bool = True, overrides: dict | None = None) -> dict:
    """Map logical param axes to mesh axes for this arch."""
    tp = _axis_size(mesh, "model")
    dax = data_axes(mesh)
    fsdp_ax = "data" if (fsdp and "data" in mesh.axis_names) else None
    rules: dict = {
        "embed": fsdp_ax,
        "mlp": "model",
        "mlp2": None,
        "vocab": "model" if cfg.padded_vocab % tp == 0 else None,
        "heads": "model" if cfg.n_heads % tp == 0 else None,
        "kv_heads": "model" if cfg.n_kv_heads % tp == 0 else None,
        "head_dim": ("model" if (cfg.n_heads % tp and cfg.hd % tp == 0)
                     else None),
        "heads_x": "model",          # rwkv fused d x d projections
        "experts": "model" if (cfg.n_experts and cfg.n_experts % tp == 0)
                   else None,
        "frontend": None,
        "conv": None,
        "layers": None,
    }
    if overrides:
        rules.update(overrides)
    return rules


def param_pspecs(struct, cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = True,
                 overrides: dict | None = None):
    from repro.models.base import partition_specs
    return partition_specs(struct,
                           logical_rules(cfg, mesh, fsdp=fsdp,
                                         overrides=overrides))


def batch_pspec(cfg: ModelConfig, mesh: Mesh, batch: int) -> dict:
    """PartitionSpecs for each batch field (tokens/labels/frames/...)."""
    dax = data_axes(mesh)
    n = 1
    for a in dax:
        n *= _axis_size(mesh, a)
    bspec = dax if (dax and batch % n == 0) else None
    b = bspec if bspec is None else tuple(bspec)
    specs = {
        "tokens": PartitionSpec(b, None),
        "labels": PartitionSpec(b, None),
        "loss_mask": PartitionSpec(b, None),
        "frames": PartitionSpec(b, None, None),
        "patches": PartitionSpec(b, None, None),
    }
    return specs


def cache_pspecs(cstruct, cfg: ModelConfig, mesh: Mesh, batch: int,
                 *, overrides: dict | None = None):
    """Decode-cache sharding.  batch-shardable -> DP over batch + TP over
    head_dim/embed; batch=1 (long-context) -> sequence-parallel cache."""
    from repro.models.base import partition_specs
    dax = data_axes(mesh)
    n = 1
    for a in dax:
        n *= _axis_size(mesh, a)
    batch_ok = bool(dax) and batch % n == 0
    tp = _axis_size(mesh, "model")
    rules = {
        "batch": tuple(dax) if batch_ok else None,
        "cache_seq": None if batch_ok else "data",     # SP for batch=1
        "kv_heads": "model" if cfg.n_kv_heads % tp == 0 else None,
        "head_dim": ("model" if cfg.n_kv_heads % tp else None),
        "embed": "model" if cfg.d_model % tp == 0 else None,
        "mlp": "model",
        "heads": "model" if cfg.n_heads % tp == 0 else None,
        "layers": None,
    }
    if overrides:
        rules.update(overrides)
    return [partition_specs(cs, rules) for cs in cstruct]


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
