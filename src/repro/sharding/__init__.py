from .specs import (batch_pspec, cache_pspecs, data_axes, logical_rules,
                    param_pspecs)

__all__ = ["batch_pspec", "cache_pspecs", "data_axes", "logical_rules",
           "param_pspecs"]
