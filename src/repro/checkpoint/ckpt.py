"""Sharded, atomic, async checkpointing.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json            # tree structure, global shapes/dtypes
        proc00.npz               # this process's addressable shards
        ...
        COMMIT                   # written last: partial ckpts never load

* Every process writes only its addressable shards (scales to any host
  count; on the single-process CPU runtime that is simply every shard).
* Restore is ELASTIC: shards are reassembled into global arrays and
  re-device_put with the TARGET sharding, which may come from a different
  mesh shape than the one that saved (node loss / scale-up).
* ``CheckpointManager`` adds async saves (background thread) and keep-last-k
  garbage collection.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import numpy as np


def _flat(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _key(i: int) -> str:
    return f"leaf{i:05d}"


def save_checkpoint(ckpt_dir: str, step: int, tree, *,
                    process_index: int | None = None) -> str:
    """Write one checkpoint; returns the step directory path."""
    pidx = jax.process_index() if process_index is None else process_index
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp_dir = step_dir + f".tmp{pidx}"
    os.makedirs(tmp_dir, exist_ok=True)
    leaves, treedef = _flat(tree)

    shards: dict[str, np.ndarray] = {}
    meta: dict = {"treedef": str(treedef), "leaves": [], "step": step}
    for i, leaf in enumerate(leaves):
        arr = leaf
        meta["leaves"].append({
            "key": _key(i),
            "shape": list(np.shape(arr)),
            "dtype": str(np.asarray(jax.ShapeDtypeStruct(
                np.shape(arr), arr.dtype).dtype) if hasattr(arr, "dtype")
                else np.asarray(arr).dtype),
        })
        if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
            for sh in arr.addressable_shards:
                idx = sh.index
                tag = "_".join(
                    f"{'' if s.start is None else s.start}-"
                    f"{'' if s.stop is None else s.stop}"
                    for s in idx) or "full"
                shards[f"{_key(i)}__{tag}"] = np.asarray(sh.data)
        else:
            shards[f"{_key(i)}__full"] = np.asarray(arr)

    np.savez(os.path.join(tmp_dir, f"proc{pidx:02d}.npz"), **shards)
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(meta, f)
    # single-process commit protocol (multi-host would barrier here)
    os.makedirs(step_dir, exist_ok=True)
    for name in os.listdir(tmp_dir):
        os.replace(os.path.join(tmp_dir, name), os.path.join(step_dir, name))
    shutil.rmtree(tmp_dir, ignore_errors=True)
    with open(os.path.join(step_dir, "COMMIT"), "w") as f:
        f.write("ok")
    return step_dir


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "COMMIT")):
            best = max(best or 0, int(m.group(1)))
    return best


def _parse_tag(tag: str, shape) -> tuple:
    if tag == "full":
        return tuple(slice(None) for _ in shape)
    out = []
    for part in tag.split("_"):
        a, b = part.split("-")
        out.append(slice(int(a) if a else None, int(b) if b else None))
    return tuple(out)


def restore_checkpoint(ckpt_dir: str, step: int, like_tree, *,
                       shardings=None):
    """Reassemble global arrays and place them with ``shardings`` (a tree of
    jax.sharding.Sharding or None -> default device placement).  ``like_tree``
    supplies structure and dtypes (params or abstract tree)."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    assert os.path.exists(os.path.join(step_dir, "COMMIT")), \
        f"no committed checkpoint at {step_dir}"
    leaves, treedef = _flat(like_tree)
    shard_specs = (None if shardings is None
                   else jax.tree_util.tree_flatten(shardings)[0])

    data: dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(step_dir)):
        if name.endswith(".npz"):
            with np.load(os.path.join(step_dir, name)) as z:
                for k in z.files:
                    data[k] = z[k]

    out = []
    for i, like in enumerate(leaves):
        shape = tuple(np.shape(like))
        dtype = like.dtype if hasattr(like, "dtype") else np.asarray(like).dtype
        full = np.zeros(shape, dtype)
        found = False
        for k, v in data.items():
            if not k.startswith(_key(i) + "__"):
                continue
            tag = k.split("__", 1)[1]
            full[_parse_tag(tag, shape)] = v
            found = True
        if not found:
            raise FileNotFoundError(f"leaf {i} missing from {step_dir}")
        if shard_specs is not None and shard_specs[i] is not None:
            out.append(jax.device_put(full, shard_specs[i]))
        else:
            out.append(jax.device_put(full))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async save + keep-last-k retention."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._last: Future | None = None
        self._lock = threading.Lock()

    def save(self, step: int, tree) -> Future:
        # snapshot to host memory synchronously (the caller may donate these
        # buffers into the next step); only the disk write is async
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            save_checkpoint(self.ckpt_dir, step, host_tree)
            self._gc()
            return step

        with self._lock:
            if self._last is not None:
                self._last.result()          # serialize saves
            self._last = self._pool.submit(work)
            return self._last

    def wait(self):
        with self._lock:
            if self._last is not None:
                self._last.result()

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for name in os.listdir(self.ckpt_dir)
            if (m := re.fullmatch(r"step_(\d+)", name))
            and os.path.exists(os.path.join(self.ckpt_dir, name, "COMMIT")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:09d}"),
                          ignore_errors=True)
