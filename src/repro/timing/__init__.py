"""repro.timing — event-driven, cycle-level SM timing subsystem.

The Fig 10 IPC evaluation's engine room: a discrete-event simulator core
(:mod:`.events`), pluggable warp issue policies shared with the
``sm_interleave`` mechanism (:mod:`.policies`), and the cycle-level SM
model with per-warp scoreboards, configurable memory-latency
distributions, and optional dual issue (:mod:`.sm_model`).

The legacy :mod:`repro.core.timing` API (``schedule_traces`` /
``simulate``) is a thin shim over this package; in trace-conservative
single-issue fixed-latency mode the engine reproduces the legacy numbers
bit-for-bit (differential-tested).  See ``docs/timing.md``.
"""
from .events import Delay, EventQueue, Process, Scheduler, Signal
from .policies import (POLICY_NAMES, GreedyThenOldest, IssuePolicy,
                       OldestFirst, RoundRobin, get_policy,
                       resolve_policy_name)
from .sm_model import (CycleConfig, CycleResult, instr_deps, schedule_cycle,
                       simulate_cycle)

__all__ = [
    "CycleConfig", "CycleResult", "Delay", "EventQueue", "GreedyThenOldest",
    "IssuePolicy", "OldestFirst", "POLICY_NAMES", "Process", "RoundRobin",
    "Scheduler", "Signal", "get_policy", "instr_deps", "resolve_policy_name",
    "schedule_cycle", "simulate_cycle",
]
