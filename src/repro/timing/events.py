"""Discrete-event simulator core for the cycle-level SM timing model.

Two layers, both deliberately tiny:

* :class:`EventQueue` — a stable priority queue of ``(time, payload)``
  events.  Same-time events pop in push order (FIFO), which is what makes
  the SM model's warp wake-ups deterministic: ties never depend on heap
  internals or payload comparability.
* :class:`Scheduler` + generator *processes* — a coroutine-style layer in
  the style of Paladin's ``@task`` simulator: a process is a generator that
  ``yield``\\ s :class:`Delay` (sleep N cycles) or :class:`Signal` (park
  until fired).  The SM issue loop itself drives :class:`EventQueue`
  directly (its per-cycle policy arbitration is clearer as an explicit
  loop), but co-simulated models — a memory pipe, a DMA engine, a second
  SM — compose as processes on the same clock.

Nothing here knows about warps or instructions; :mod:`repro.timing.sm_model`
is the SM-specific consumer.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Generator, Iterator

__all__ = ["Delay", "EventQueue", "Process", "Scheduler", "Signal"]


class EventQueue:
    """Stable min-heap of ``(time, payload)`` events.

    >>> q = EventQueue()
    >>> q.push(5, "b"); q.push(5, "a"); q.push(1, "c")
    >>> q.pop()
    (1, 'c')
    >>> q.pop()           # same-time events keep push order
    (5, 'b')
    """

    def __init__(self) -> None:
        self._heap: list[tuple[int, int, Any]] = []
        self._seq = itertools.count()

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def push(self, time: int, payload: Any) -> None:
        heapq.heappush(self._heap, (int(time), next(self._seq), payload))

    def peek_time(self) -> int:
        """Time of the earliest event; raises IndexError when empty."""
        return self._heap[0][0]

    def pop(self) -> tuple[int, Any]:
        time, _, payload = heapq.heappop(self._heap)
        return time, payload

    def pop_until(self, time: int) -> Iterator[Any]:
        """Drain (in order) every event with ``event_time <= time``."""
        while self._heap and self._heap[0][0] <= time:
            yield heapq.heappop(self._heap)[2]


@dataclass(frozen=True)
class Delay:
    """Process yield value: sleep for ``cycles`` (>= 0) simulated cycles."""

    cycles: int


@dataclass
class Signal:
    """Process yield value: park until some other process ``fire()``\\ s it.

    ``fire`` releases every currently-parked waiter at the scheduler's
    current time; a process yielding an already-fired one-shot signal
    (``sticky=True``) resumes immediately.
    """

    sticky: bool = False
    fired: bool = field(default=False, init=False)
    _waiters: list = field(default_factory=list, init=False)

    def fire(self, scheduler: "Scheduler") -> None:
        if self.sticky:
            self.fired = True
        waiters, self._waiters = self._waiters, []
        for proc in waiters:
            scheduler._resume(proc, scheduler.now)


class Process:
    """One running generator coroutine (created via Scheduler.spawn)."""

    def __init__(self, gen: Generator, name: str = "") -> None:
        self.gen = gen
        self.name = name or getattr(gen, "__name__", "process")
        self.done = False


class Scheduler:
    """Runs generator processes against one shared clock.

    >>> sched = Scheduler()
    >>> log = []
    >>> def ticker(n):
    ...     for i in range(n):
    ...         yield Delay(2)
    ...         log.append((sched.now, i))
    >>> _ = sched.spawn(ticker(3))
    >>> sched.run()
    >>> log
    [(2, 0), (4, 1), (6, 2)]
    """

    def __init__(self) -> None:
        self.now = 0
        self._queue = EventQueue()
        self._live = 0

    def spawn(self, gen: Generator, name: str = "") -> Process:
        proc = Process(gen, name)
        self._live += 1
        self._queue.push(self.now, proc)
        return proc

    def _resume(self, proc: Process, time: int) -> None:
        self._queue.push(time, proc)

    def _step_process(self, proc: Process) -> None:
        try:
            yielded = next(proc.gen)
        except StopIteration:
            proc.done = True
            self._live -= 1
            return
        if isinstance(yielded, Delay):
            if yielded.cycles < 0:
                raise ValueError(f"negative delay: {yielded.cycles}")
            self._queue.push(self.now + yielded.cycles, proc)
        elif isinstance(yielded, Signal):
            if yielded.sticky and yielded.fired:
                self._queue.push(self.now, proc)
            else:
                yielded._waiters.append(proc)
        else:
            raise TypeError(f"process {proc.name!r} yielded "
                            f"{type(yielded).__name__}; expected Delay or "
                            f"Signal")

    def run(self, until: int | None = None) -> int:
        """Run until no runnable process remains (or past ``until``).

        Returns the final clock.  Processes parked on a never-fired signal
        do not keep the scheduler alive — a co-simulation that ends with a
        stuck consumer terminates instead of spinning.
        """
        while self._queue:
            time = self._queue.peek_time()
            if until is not None and time > until:
                break
            self.now = max(self.now, time)
            _, proc = self._queue.pop()
            self._step_process(proc)
        return self.now
