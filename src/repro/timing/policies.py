"""Pluggable warp issue policies — the ONE policy layer for every scheduler.

Both the legacy Fig 10 model (:mod:`repro.core.timing`, via its shim over
the cycle engine) and the per-SM interleaver
(:mod:`repro.engine.mechanisms.sm`) select warps through these classes, so
the semantics of ``greedy_then_oldest`` cannot drift between the IPC
evaluation and the SM mechanism — the asymmetry this package was built to
close.

A policy is a small stateful object: ``select(ready)`` picks one warp id
out of the ready set, ``issued(w)`` notifies it of the grant (so GTO can
stay greedy and round-robin can advance its cursor).  Policies never see
latencies or scoreboards — readiness is the model's job; arbitration is
the policy's.

Registered policies:

* ``greedy_then_oldest`` (alias ``gto``) — stay on the last-granted warp
  while it is ready, else the oldest (lowest-id) ready warp.  The paper's
  Table III scheduler.
* ``round_robin`` — rotate a cursor over ready warps every grant.
* ``oldest_first`` — always the lowest-id ready warp (no greedy
  stickiness); the degenerate baseline that makes GTO's locality win
  measurable.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

__all__ = ["IssuePolicy", "GreedyThenOldest", "RoundRobin", "OldestFirst",
           "POLICY_NAMES", "get_policy", "resolve_policy_name",
           "priority_keys"]


def priority_keys(name: str, n_warps: int, *, last: "int | None" = None,
                  cursor: int = 0) -> np.ndarray:
    """The argmin-vector formulation of an issue policy's ``select``.

    Returns an ``int32[n_warps]`` key vector such that, for any non-empty
    ready set *R* and the policy state ``(last, cursor)``,
    ``select(R) == argmin over w in R of keys[w]`` — ties are impossible
    because every vector below is injective over ``[0, n_warps)``:

    * ``oldest_first``:       ``keys[w] = w``;
    * ``greedy_then_oldest``: ``keys[w] = w + 1`` except ``keys[last] = 0``
      (``last=None`` — post-stall — leaves the vector monotone, so the
      minimum falls back to the oldest ready warp);
    * ``round_robin``:        ``keys[w] = (w - cursor) mod n_warps``.

    This is the *one* formulation array schedulers (``sm_jax``) mirror with
    ``argmin(where(ready, keys, INF))``; a drift test pins it against the
    stateful classes below so the two can never diverge.
    """
    name = resolve_policy_name(name)
    n = max(1, int(n_warps))
    w = np.arange(n, dtype=np.int32)
    if name == OldestFirst.name:
        return w
    if name == GreedyThenOldest.name:
        keys = w + 1
        if last is not None and 0 <= last < n:
            keys[last] = 0
        return keys
    return (w - np.int32(cursor)) % n          # round_robin


class IssuePolicy:
    """Base class: subclasses implement ``select``; ``issued`` is optional."""

    name = "abstract"

    def __init__(self, n_warps: int) -> None:
        if n_warps < 0:
            raise ValueError(f"n_warps must be >= 0, got {n_warps}")
        self.n_warps = n_warps

    def select(self, ready: Sequence[int]) -> int:
        raise NotImplementedError

    def issued(self, warp: int) -> None:   # pragma: no cover - trivial hook
        pass

    def stalled(self) -> None:             # pragma: no cover - trivial hook
        """The scheduler sat idle (no ready warp) before this selection."""
        pass

    def priority_keys(self) -> np.ndarray:
        """This policy's :func:`priority_keys` vector at its current state."""
        return priority_keys(self.name, self.n_warps)


class GreedyThenOldest(IssuePolicy):
    """GTO: greedy on the current warp, else oldest ready (lowest id)."""

    name = "greedy_then_oldest"

    def __init__(self, n_warps: int) -> None:
        super().__init__(n_warps)
        self._last: int | None = 0   # legacy loop's initial ``cur = 0``

    def select(self, ready: Sequence[int]) -> int:
        if self._last is not None and self._last in ready:
            return self._last
        return min(ready)

    def issued(self, warp: int) -> None:
        self._last = warp

    def stalled(self) -> None:
        # After an idle gap the legacy loop re-picks the oldest ready warp
        # even when the greedy warp woke at the same instant; drop the
        # stickiness so the shim stays bit-identical to it.
        self._last = None

    def priority_keys(self) -> np.ndarray:
        return priority_keys(self.name, self.n_warps, last=self._last)


class RoundRobin(IssuePolicy):
    """Fair rotation: the ready warp closest after the last grant."""

    name = "round_robin"

    def __init__(self, n_warps: int) -> None:
        super().__init__(n_warps)
        self._next = 0

    def select(self, ready: Sequence[int]) -> int:
        n = max(1, self.n_warps)
        return min(ready, key=lambda w: (w - self._next) % n)

    def issued(self, warp: int) -> None:
        self._next = warp + 1

    def priority_keys(self) -> np.ndarray:
        return priority_keys(self.name, self.n_warps, cursor=self._next)


class OldestFirst(IssuePolicy):
    """Always the lowest-id ready warp — GTO without the greedy half."""

    name = "oldest_first"

    def select(self, ready: Sequence[int]) -> int:
        return min(ready)


_POLICIES = {
    GreedyThenOldest.name: GreedyThenOldest,
    RoundRobin.name: RoundRobin,
    OldestFirst.name: OldestFirst,
}
_ALIASES = {"gto": GreedyThenOldest.name}

#: Canonical policy names, stable order (aliases not included).
POLICY_NAMES = tuple(_POLICIES)


def resolve_policy_name(name: str) -> str:
    """Canonical name for ``name`` (aliases resolved); raises ValueError."""
    canon = _ALIASES.get(name, name)
    if canon not in _POLICIES:
        known = POLICY_NAMES + tuple(_ALIASES)
        raise ValueError(f"unknown issue policy {name!r}; known: {known}")
    return canon


def get_policy(name: str, n_warps: int) -> IssuePolicy:
    """A fresh policy instance for one schedule run."""
    return _POLICIES[resolve_policy_name(name)](n_warps)
