"""Pluggable warp issue policies — the ONE policy layer for every scheduler.

Both the legacy Fig 10 model (:mod:`repro.core.timing`, via its shim over
the cycle engine) and the per-SM interleaver
(:mod:`repro.engine.mechanisms.sm`) select warps through these classes, so
the semantics of ``greedy_then_oldest`` cannot drift between the IPC
evaluation and the SM mechanism — the asymmetry this package was built to
close.

A policy is a small stateful object: ``select(ready)`` picks one warp id
out of the ready set, ``issued(w)`` notifies it of the grant (so GTO can
stay greedy and round-robin can advance its cursor).  Policies never see
latencies or scoreboards — readiness is the model's job; arbitration is
the policy's.

Registered policies:

* ``greedy_then_oldest`` (alias ``gto``) — stay on the last-granted warp
  while it is ready, else the oldest (lowest-id) ready warp.  The paper's
  Table III scheduler.
* ``round_robin`` — rotate a cursor over ready warps every grant.
* ``oldest_first`` — always the lowest-id ready warp (no greedy
  stickiness); the degenerate baseline that makes GTO's locality win
  measurable.
"""
from __future__ import annotations

from typing import Sequence

__all__ = ["IssuePolicy", "GreedyThenOldest", "RoundRobin", "OldestFirst",
           "POLICY_NAMES", "get_policy", "resolve_policy_name"]


class IssuePolicy:
    """Base class: subclasses implement ``select``; ``issued`` is optional."""

    name = "abstract"

    def __init__(self, n_warps: int) -> None:
        if n_warps < 0:
            raise ValueError(f"n_warps must be >= 0, got {n_warps}")
        self.n_warps = n_warps

    def select(self, ready: Sequence[int]) -> int:
        raise NotImplementedError

    def issued(self, warp: int) -> None:   # pragma: no cover - trivial hook
        pass

    def stalled(self) -> None:             # pragma: no cover - trivial hook
        """The scheduler sat idle (no ready warp) before this selection."""
        pass


class GreedyThenOldest(IssuePolicy):
    """GTO: greedy on the current warp, else oldest ready (lowest id)."""

    name = "greedy_then_oldest"

    def __init__(self, n_warps: int) -> None:
        super().__init__(n_warps)
        self._last: int | None = 0   # legacy loop's initial ``cur = 0``

    def select(self, ready: Sequence[int]) -> int:
        if self._last is not None and self._last in ready:
            return self._last
        return min(ready)

    def issued(self, warp: int) -> None:
        self._last = warp

    def stalled(self) -> None:
        # After an idle gap the legacy loop re-picks the oldest ready warp
        # even when the greedy warp woke at the same instant; drop the
        # stickiness so the shim stays bit-identical to it.
        self._last = None


class RoundRobin(IssuePolicy):
    """Fair rotation: the ready warp closest after the last grant."""

    name = "round_robin"

    def __init__(self, n_warps: int) -> None:
        super().__init__(n_warps)
        self._next = 0

    def select(self, ready: Sequence[int]) -> int:
        n = max(1, self.n_warps)
        return min(ready, key=lambda w: (w - self._next) % n)

    def issued(self, warp: int) -> None:
        self._next = warp + 1


class OldestFirst(IssuePolicy):
    """Always the lowest-id ready warp — GTO without the greedy half."""

    name = "oldest_first"

    def select(self, ready: Sequence[int]) -> int:
        return min(ready)


_POLICIES = {
    GreedyThenOldest.name: GreedyThenOldest,
    RoundRobin.name: RoundRobin,
    OldestFirst.name: OldestFirst,
}
_ALIASES = {"gto": GreedyThenOldest.name}

#: Canonical policy names, stable order (aliases not included).
POLICY_NAMES = tuple(_POLICIES)


def resolve_policy_name(name: str) -> str:
    """Canonical name for ``name`` (aliases resolved); raises ValueError."""
    canon = _ALIASES.get(name, name)
    if canon not in _POLICIES:
        known = POLICY_NAMES + tuple(_ALIASES)
        raise ValueError(f"unknown issue policy {name!r}; known: {known}")
    return canon


def get_policy(name: str, n_warps: int) -> IssuePolicy:
    """A fresh policy instance for one schedule run."""
    return _POLICIES[resolve_policy_name(name)](n_warps)
