"""Event-driven, cycle-level SM issue model (the Fig 10 engine).

The legacy Fig 10 model (:mod:`repro.core.timing`) charged every
instruction a class latency and assumed each instruction depends on its
predecessor — trace-level conservatism.  This module is the real model
underneath: per-warp **scoreboards** with register/predicate dependence
checks, **configurable memory-latency distributions** (fixed / uniform /
bimodal hit-miss, deterministically seeded), an optional **dual-issue**
port, and pluggable issue policies (:mod:`repro.timing.policies`).  Time
advances through an :class:`~repro.timing.events.EventQueue` of completion
events — idle gaps are skipped in one hop, never walked cycle by cycle.

Dependence modes
----------------
``CycleConfig.scoreboard`` selects the hazard model:

* ``scoreboard=False`` — *trace conservatism*: a warp's next instruction
  waits for its previous one.  With ``issue_width=1`` and the ``fixed``
  memory model this reproduces the legacy
  :func:`repro.core.timing.schedule_traces` loop **bit-for-bit** (the
  legacy functions are now shims over this engine; a differential test
  gates the equivalence).  Programs may be given as opcode columns.
* ``scoreboard=True`` — register-level dependence: an instruction issues
  once its source and destination registers/predicates have no outstanding
  writes (RAW + WAW; WAR is safe under in-order issue with read-at-issue).
  Requires full ``int32[L, N_FIELDS]`` program rows.

Stall taxonomy (see ``docs/timing.md``)
---------------------------------------
Every cycle is either *busy* (>= 1 instruction issued) or a stall cycle:

* ``memory_stall_cycles``     — no warp could issue and the earliest
  blocked warp waits on an in-flight memory/atomic producer;
* ``scoreboard_stall_cycles`` — no warp could issue and the earliest
  blocked warp waits on a short-latency (ALU/control) producer;
* ``issue_stall_cycles``      — cycles where at least one *ready* warp was
  left unissued because the issue port was full (port contention; overlaps
  busy cycles, so it is reported separately from the partition).

Invariant: ``cycles == busy_cycles + scoreboard_stall_cycles +
memory_stall_cycles``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.isa import (ATOMIC_OPS, F_DST, F_OP, F_PRED1, F_PRED2,
                            F_SRC0, F_SRC1, F_SRC2, MEMORY_OPS, Op)
from repro.core.stepper import popcount

from .events import EventQueue
from .policies import get_policy, resolve_policy_name

__all__ = ["CycleConfig", "CycleResult", "instr_deps", "schedule_cycle",
           "simulate_cycle"]

_MEMORY_MODELS = ("fixed", "uniform", "bimodal")


@dataclass(frozen=True)
class CycleConfig:
    """Latency + structure configuration for the cycle-level SM model.

    The four class latencies mirror the legacy
    :class:`~repro.core.timing.TimingConfig`.  ``memory_model`` selects how
    LDG/STG latency is drawn (atomics always pay ``atomic_latency`` — the
    L2 round trip has no hit path):

    * ``fixed``   — every access costs ``memory_latency``;
    * ``uniform`` — integer-uniform in ``[memory_latency_lo,
      memory_latency_hi]``;
    * ``bimodal`` — ``memory_hit_latency`` with probability
      ``memory_hit_rate``, else ``memory_latency`` (an L1 hit/miss mix).

    Draws come from ``numpy.random.default_rng(seed)`` consumed in issue
    order, so a fixed config is fully deterministic (property-tested).
    ``issue_width`` > 1 enables dual issue: up to that many independent
    instructions per cycle, possibly back-to-back from one warp.
    """

    alu_latency: int = 2
    control_latency: int = 1
    memory_latency: int = 30
    atomic_latency: int = 40
    memory_model: str = "fixed"
    memory_latency_lo: int = 10
    memory_latency_hi: int = 60
    memory_hit_latency: int = 8
    memory_hit_rate: float = 0.6
    seed: int = 0
    issue_width: int = 1
    scoreboard: bool = True

    def __post_init__(self) -> None:
        if self.memory_model not in _MEMORY_MODELS:
            raise ValueError(f"unknown memory_model {self.memory_model!r}; "
                             f"known: {_MEMORY_MODELS}")
        if self.issue_width < 1:
            raise ValueError(f"issue_width must be >= 1, "
                             f"got {self.issue_width}")
        if self.memory_latency_lo > self.memory_latency_hi:
            raise ValueError("memory_latency_lo > memory_latency_hi")
        if not 0.0 <= self.memory_hit_rate <= 1.0:
            raise ValueError(f"memory_hit_rate must be in [0, 1], "
                             f"got {self.memory_hit_rate}")

    @classmethod
    def from_timing(cls, cfg: Any, *, scoreboard: bool = False,
                    issue_width: int = 1) -> "CycleConfig":
        """Lift a legacy ``TimingConfig`` (or pass a CycleConfig through).

        The default (``scoreboard=False``, single issue, fixed memory) is
        the exact-compatibility mode the :mod:`repro.core.timing` shims
        use; ``scoreboard=True`` is the realistic lift ``timing="cycle"``
        evaluation paths use.
        """
        if isinstance(cfg, cls):
            return cfg
        return cls(alu_latency=cfg.alu_latency,
                   control_latency=cfg.control_latency,
                   memory_latency=cfg.memory_latency,
                   atomic_latency=cfg.atomic_latency,
                   scoreboard=scoreboard, issue_width=issue_width)


def _memory_sampler(cfg: CycleConfig) -> Callable[[], int]:
    if cfg.memory_model == "fixed":
        lat = int(cfg.memory_latency)
        return lambda: lat
    rng = np.random.default_rng(cfg.seed)
    if cfg.memory_model == "uniform":
        lo, hi = int(cfg.memory_latency_lo), int(cfg.memory_latency_hi)
        return lambda: int(rng.integers(lo, hi + 1))
    hit, miss = int(cfg.memory_hit_latency), int(cfg.memory_latency)
    rate = float(cfg.memory_hit_rate)
    return lambda: hit if rng.random() < rate else miss


_CONTROL_LAT_OPS = frozenset({
    Op.BRA, Op.EXIT, Op.BSSY, Op.BSYNC, Op.BMOV_B2R, Op.BMOV_R2B,
    Op.BREAK, Op.WARPSYNC, Op.YIELD, Op.CALL, Op.RET, Op.NOP,
})

# (register-read fields, register-write fields) per opcode; predicates and
# conditional fields are handled in instr_deps.  Bx registers are control
# state, not scoreboarded (their hazards are what BSSY/BSYNC *are*).
_REG_READS = {
    Op.MOVR: (F_SRC0,), Op.IADDI: (F_SRC0,), Op.SHL: (F_SRC0,),
    Op.SHR: (F_SRC0,),
    Op.IADD: (F_SRC0, F_SRC1), Op.IMUL: (F_SRC0, F_SRC1),
    Op.AND: (F_SRC0, F_SRC1), Op.OR: (F_SRC0, F_SRC1),
    Op.XOR: (F_SRC0, F_SRC1),
    Op.ISETP: (F_SRC0,),           # + F_SRC1 unless it encodes "imm" (-1)
    Op.LDG: (F_SRC0,),
    Op.STG: (F_SRC0, F_SRC1),
    Op.ATOMCAS: (F_SRC0, F_SRC1, F_SRC2),
    Op.ATOMEXCH: (F_SRC0, F_SRC1), Op.ATOMADD: (F_SRC0, F_SRC1),
    Op.BMOV_R2B: (F_SRC0,), Op.RET: (F_SRC0,),
}
_REG_WRITES = frozenset({
    Op.MOV, Op.MOVR, Op.IADD, Op.IADDI, Op.IMUL, Op.AND, Op.OR, Op.XOR,
    Op.SHL, Op.SHR, Op.LANEID, Op.LDG, Op.ATOMCAS, Op.ATOMEXCH, Op.ATOMADD,
    Op.BMOV_B2R,
})


def instr_deps(row: Sequence[int]) -> tuple[tuple[int, ...], tuple[int, ...],
                                            tuple[int, ...], tuple[int, ...]]:
    """``(reads_regs, writes_regs, reads_preds, writes_preds)`` of one
    instruction word — the scoreboard's view of the ISA.

    Predication (``pred1``/``pred2``, SS V-A encoding: 0 = none,
    ``+-k`` = [!]P(k-1)) reads the named predicate on *every* opcode;
    ISETP writes its destination predicate.  WARPSYNC reads its mask
    register only in the register form (``src0 != -1``).
    """
    op = int(row[F_OP])
    reads: list[int] = []
    if op == int(Op.WARPSYNC):
        if int(row[F_SRC0]) != -1:
            reads.append(int(row[F_SRC0]))
    else:
        for f in _REG_READS.get(op, ()):
            r = int(row[f])
            if r >= 0:
                reads.append(r)
        if op == int(Op.ISETP) and int(row[F_SRC1]) != -1:
            reads.append(int(row[F_SRC1]))
    writes: tuple[int, ...] = ()
    if op in _REG_WRITES and op != int(Op.ISETP):
        writes = (int(row[F_DST]),)
    reads_preds = tuple(abs(int(row[f])) - 1 for f in (F_PRED1, F_PRED2)
                        if int(row[f]) != 0)
    writes_preds = (int(row[F_DST]),) if op == int(Op.ISETP) else ()
    return tuple(reads), writes, reads_preds, writes_preds


def _class_latency(op: int, cfg: CycleConfig) -> int:
    """Latency of a non-memory op (memory goes through the sampler)."""
    if op in _CONTROL_LAT_OPS:
        return cfg.control_latency
    return cfg.alu_latency


# per-program dependence tables, keyed by the ndarray's identity — warps of
# one SM usually share a program, so the decode is done once per cell
_DEPS_CACHE: dict[int, tuple[Any, list]] = {}


def _dep_table(program: np.ndarray) -> list:
    key = id(program)
    hit = _DEPS_CACHE.get(key)
    if hit is not None and hit[0] is program:
        return hit[1]
    table = [instr_deps(row) for row in np.asarray(program)]
    if len(_DEPS_CACHE) > 256:        # bound: this is a cache, not a leak
        _DEPS_CACHE.clear()
    _DEPS_CACHE[key] = (program, table)
    return table


@dataclass
class CycleResult:
    """Outcome of one cycle-level schedule (see module docstring).

    ``order`` is the issue order as ``(warp, pc, mask)``; the stall fields
    follow the taxonomy above.  All ratio properties are guarded: a
    zero-instruction schedule reports 0.0, never a ZeroDivisionError.
    """

    order: list[tuple[int, int, int]]
    cycles: int
    thread_instructions: int
    warp_width: int
    busy_cycles: int = 0
    issue_stall_cycles: int = 0
    scoreboard_stall_cycles: int = 0
    memory_stall_cycles: int = 0
    policy: str = "greedy_then_oldest"
    per_warp_issues: tuple[int, ...] = ()

    @property
    def issues(self) -> int:
        return len(self.order)

    @property
    def ipc(self) -> float:
        """Thread-level IPC (the paper's Fig 10 metric)."""
        if self.cycles <= 0:
            return 0.0
        return self.thread_instructions / self.cycles

    @property
    def warp_ipc(self) -> float:
        if self.cycles <= 0:
            return 0.0
        return self.issues / self.cycles

    @property
    def simd_utilization(self) -> float:
        denom = self.issues * self.warp_width
        if denom <= 0:
            return 0.0
        return self.thread_instructions / denom

    @property
    def stall_cycles(self) -> int:
        return self.scoreboard_stall_cycles + self.memory_stall_cycles

    @property
    def stall_breakdown(self) -> dict[str, int]:
        return {"issue": self.issue_stall_cycles,
                "scoreboard": self.scoreboard_stall_cycles,
                "memory": self.memory_stall_cycles}

    def to_timing_result(self) -> "Any":
        """This schedule as a legacy :class:`~repro.core.timing.TimingResult`
        (carrying the new stall fields)."""
        from repro.core.timing import TimingResult
        return TimingResult(
            cycles=self.cycles, issues=self.issues,
            thread_instructions=self.thread_instructions,
            warp_width=self.warp_width,
            busy_cycles=self.busy_cycles,
            issue_stall_cycles=self.issue_stall_cycles,
            scoreboard_stall_cycles=self.scoreboard_stall_cycles,
            memory_stall_cycles=self.memory_stall_cycles)


def _normalize_programs(programs: Sequence[Any], n: int,
                        scoreboard: bool) -> tuple[list, list]:
    """Per-warp ``(opcode list, dep table | None)`` from program inputs.

    Accepts full ``[L, N_FIELDS]`` row tables or bare opcode columns; the
    scoreboard needs operands, so it insists on full rows.
    """
    if len(programs) != n:
        raise ValueError(f"{len(programs)} programs for {n} warp traces")
    ops_list, deps_list = [], []
    for p in programs:
        arr = np.asarray(p)
        if arr.ndim == 2:
            ops_list.append([int(o) for o in arr[:, F_OP]])
            deps_list.append(_dep_table(p if isinstance(p, np.ndarray)
                                        else arr) if scoreboard else None)
        elif arr.ndim == 1:
            if scoreboard:
                raise ValueError(
                    "scoreboard mode needs full [L, N_FIELDS] program rows "
                    "(got a bare opcode column); pass scoreboard=False or "
                    "the full program table")
            ops_list.append([int(o) for o in arr])
            deps_list.append(None)
        else:
            raise ValueError(f"program must be 1-D opcodes or 2-D rows, "
                             f"got ndim={arr.ndim}")
    return ops_list, deps_list


def schedule_cycle(traces: Sequence[Sequence[tuple[int, int]]],
                   programs: Sequence[Any],
                   policy: str = "greedy_then_oldest",
                   cfg: CycleConfig = CycleConfig(),
                   *, warp_width: int = 0) -> CycleResult:
    """Schedule per-warp traces through one SM issue port, cycle-level.

    ``traces[w]`` is warp *w*'s finished control-flow trace of
    ``(pc, mask)`` slots; ``programs[w]`` its program (full rows, or opcode
    column in trace-conservative mode).  Returns a :class:`CycleResult`
    whose ``order``/``cycles``/``thread_instructions`` are, in
    trace-conservative single-issue fixed-memory mode, bit-identical to the
    legacy ``schedule_traces`` loop — the differential suite gates this.
    """
    policy_name = resolve_policy_name(policy)
    n = len(traces)
    traces = [list(t) for t in traces]
    lens = [len(t) for t in traces]
    ops_list, deps_list = _normalize_programs(programs, n, cfg.scoreboard)
    pol = get_policy(policy_name, n)
    mem_draw = _memory_sampler(cfg)

    idx = [0] * n
    in_order = [0] * n               # in-order floor: last issue cycle + 1
    # trace-conservatism state: completion time + class of the previous
    # instruction; scoreboard state: per-reg/pred (ready time, is_mem)
    t_ready = [0] * n
    t_mem = [False] * n
    reg_ready: list[dict[int, tuple[int, bool]]] = [dict() for _ in range(n)]
    pred_ready: list[dict[int, tuple[int, bool]]] = [dict() for _ in range(n)]

    wake = EventQueue()              # completion events: payload = warp
    order: list[tuple[int, int, int]] = []
    per_warp = [0] * n
    tinstr = 0
    cycle = 0
    busy = issue_stall = sb_stall = mem_stall = 0
    remaining = sum(lens)
    scoreboard = cfg.scoreboard

    def ready_info(w: int, now: int, floor: bool = True
                   ) -> tuple[int, bool]:
        """(earliest issue time, blocked-by-memory?) for warp w's next
        instruction.  ``floor=False`` drops the in-order constraint — used
        for same-cycle dual issue of a warp that already issued."""
        rt = in_order[w] if floor else 0
        is_mem = False
        if not scoreboard:
            if t_ready[w] > rt:
                rt, is_mem = t_ready[w], t_mem[w]
            elif t_ready[w] == rt:
                is_mem = is_mem or t_mem[w]
            return rt, is_mem
        pc = traces[w][idx[w]][0]
        deps = deps_list[w]
        if not (0 <= pc < len(deps)):
            return rt, is_mem
        reads, writes, p_reads, p_writes = deps[pc]
        regs, preds = reg_ready[w], pred_ready[w]
        for r in reads + writes:                       # RAW + WAW
            t, m = regs.get(r, (0, False))
            if t > rt:
                rt, is_mem = t, m
            elif t == rt:
                is_mem = is_mem or (m and t > 0)
        for p in p_reads + p_writes:
            t, m = preds.get(p, (0, False))
            if t > rt:
                rt, is_mem = t, m
        return rt, is_mem

    def ready_set(now: int, issued_now: set) -> list[int]:
        out = []
        for w in range(n):
            if idx[w] >= lens[w]:
                continue
            rt, _ = ready_info(w, now, floor=w not in issued_now)
            if rt <= now:
                out.append(w)
        return out

    while remaining:
        issued_now: set[int] = set()
        ready = ready_set(cycle, issued_now)
        if not ready:
            # idle: hop along completion events until some warp wakes,
            # then classify the whole gap by the earliest blocked warp(s)
            start = cycle
            while not ready:
                if not wake:         # pragma: no cover - defensive
                    raise RuntimeError("timing model wedged: pending warps "
                                       "but no completion events")
                nt, _ = wake.pop()
                if nt <= cycle:
                    continue
                cycle = nt
                ready = ready_set(cycle, issued_now)
            gap_mem = False
            for w in range(n):
                if idx[w] >= lens[w]:
                    continue
                rt, m = ready_info(w, cycle)
                if rt <= cycle and m:
                    gap_mem = True
                    break
            if gap_mem:
                mem_stall += cycle - start
            else:
                sb_stall += cycle - start
            pol.stalled()
        busy += 1
        slots = cfg.issue_width
        while slots > 0 and ready:
            w = pol.select(ready)
            pc, mask = traces[w][idx[w]]
            idx[w] += 1
            remaining -= 1
            ops = ops_list[w]
            op = ops[pc] if 0 <= pc < len(ops) else int(Op.NOP)
            if op in ATOMIC_OPS:
                lat, is_mem = cfg.atomic_latency, True
            elif op in MEMORY_OPS:
                lat, is_mem = mem_draw(), True
            else:
                lat, is_mem = _class_latency(op, cfg), False
            done = cycle + lat
            if scoreboard:
                deps = deps_list[w]
                if 0 <= pc < len(deps):
                    _, writes, _, p_writes = deps[pc]
                    for r in writes:
                        reg_ready[w][r] = (done, is_mem)
                    for p in p_writes:
                        pred_ready[w][p] = (done, is_mem)
            else:
                t_ready[w] = done
                t_mem[w] = is_mem
            wake.push(done, w)
            order.append((w, pc, mask))
            per_warp[w] += 1
            tinstr += popcount(mask)
            pol.issued(w)
            issued_now.add(w)
            slots -= 1
            ready = ready_set(cycle, issued_now)
        if ready:                    # ready warps stranded by the port
            issue_stall += 1
        for w in issued_now:
            in_order[w] = cycle + 1
        cycle += 1

    return CycleResult(order=order, cycles=cycle,
                       thread_instructions=tinstr, warp_width=warp_width,
                       busy_cycles=busy, issue_stall_cycles=issue_stall,
                       scoreboard_stall_cycles=sb_stall,
                       memory_stall_cycles=mem_stall,
                       policy=policy_name, per_warp_issues=tuple(per_warp))


def simulate_cycle(traces: Sequence[Sequence[tuple[int, int]]],
                   program: Any, warp_width: int,
                   cfg: CycleConfig = CycleConfig(),
                   policy: str = "greedy_then_oldest") -> "Any":
    """Fig 10 entry point: N warps of one program through the cycle model.

    The cycle-model analogue of :func:`repro.core.timing.simulate`;
    returns an extended :class:`~repro.core.timing.TimingResult` carrying
    the stall breakdown.
    """
    res = schedule_cycle(traces, [program] * len(traces), policy, cfg,
                         warp_width=warp_width)
    return res.to_timing_result()
