"""Elastic scaling: rebuild the mesh after node loss / scale-up and reshard
state onto it.

The recovery path after a hardware failure is:

1. the training driver catches the failure (timeout / unreachable host),
2. ``survivors_mesh`` builds the largest well-formed mesh from remaining
   devices (keeping the model axis intact — TP groups must stay whole, so
   recovery drops whole data-parallel rows),
3. optimizer/params are restored from the last committed checkpoint with
   ``restore_checkpoint(..., shardings=new_specs)`` (the checkpoint layout is
   mesh-agnostic), or — if state is still live — ``reshard_tree`` device_puts
   it onto the new mesh directly,
4. the data pipeline re-slices the SAME global batch order by host count, so
   sample order is preserved across the re-shape (determinism tests).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def survivors_mesh(devices, axis_names: tuple[str, ...],
                   model_axis_size: int) -> Mesh:
    """Largest (data, model) mesh from surviving devices; whole TP groups
    only.  ``devices`` is the flat surviving device list."""
    n = len(devices)
    rows = n // model_axis_size
    if rows < 1:
        raise ValueError("not enough devices for one model-parallel group")
    dev = np.array(devices[: rows * model_axis_size]).reshape(
        rows, model_axis_size)
    return Mesh(dev, axis_names)


def reshard_tree(tree, mesh: Mesh, spec_tree):
    """device_put a live tree onto a (new) mesh with the given specs."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, spec_tree,
        is_leaf=lambda x: isinstance(x, (jax.Array, np.ndarray)))
