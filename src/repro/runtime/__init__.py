from .compression import (compressed_allreduce, dequantize_int8,
                          ef_compress_grads, quantize_int8)
from .straggler import StragglerMonitor, rebalance_batches
from .elastic import reshard_tree, survivors_mesh

__all__ = ["StragglerMonitor", "compressed_allreduce", "dequantize_int8",
           "ef_compress_grads", "quantize_int8", "rebalance_batches",
           "reshard_tree", "survivors_mesh"]
