"""Gradient compression: int8 quantization with error feedback.

Two pieces:

* :func:`compressed_allreduce` — a shard_map collective that moves int8 on
  the wire instead of f32: phase 1 all_to_all of int8 chunks + local f32
  reduction, phase 2 all_gather of the requantized partial sums.  Wire bytes
  = 2 * n/4 vs. 2n for a ring f32 all-reduce (~4x compression).
* :func:`ef_compress_grads` — error-feedback wrapper (Seide et al.): the
  quantization residual is carried to the next step, preserving convergence
  (sum of applied updates telescopes to the true gradient sum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as PS


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _c_allreduce_local(x, *, axis: str, n: int):
    """Body run per-shard under shard_map; x: local f32 [m] with m % n == 0."""
    m = x.shape[0]
    chunk = m // n
    q, s = quantize_int8(x)
    # phase 1: each peer receives its chunk from everyone (int8 on the wire)
    qx = q.reshape(n, chunk)
    recv = jax.lax.all_to_all(qx[None], axis, split_axis=1,
                              concat_axis=0, tiled=False)[:, 0]
    scales = jax.lax.all_gather(s, axis)                 # [n] f32 (tiny)
    partial = jnp.sum(recv.astype(jnp.float32)
                      * scales[:, None], axis=0)         # my chunk, reduced
    # phase 2: requantize the reduced chunk, all_gather int8
    q2, s2 = quantize_int8(partial)
    allq = jax.lax.all_gather(q2, axis)                  # [n, chunk] int8
    alls = jax.lax.all_gather(s2, axis)                  # [n]
    return (allq.astype(jnp.float32) * alls[:, None]).reshape(m)


def compressed_allreduce(x: jax.Array, mesh: Mesh, axis: str = "data"):
    """All-reduce x (replicated result) over ``axis`` with int8 wire format.

    x is flattened and zero-padded to a multiple of the axis size."""
    n = mesh.shape[axis]
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    other = tuple(a for a in mesh.axis_names if a != axis)
    fn = shard_map(
        functools.partial(_c_allreduce_local, axis=axis, n=n),
        mesh=mesh, in_specs=PS(), out_specs=PS(),
        check_rep=False)
    out = fn(flat)
    return out[:flat.shape[0] - pad if pad else None].reshape(x.shape)


def ef_compress_grads(grads, error_state):
    """Error feedback: returns (compressed_grads, new_error_state).

    compressed = deQ(Q(g + e));  e' = (g + e) - compressed.
    """
    if error_state is None:
        error_state = jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), corrected - deq

    pairs = jax.tree_util.tree_map(one, grads, error_state)
    comp = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                  is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return comp, err
