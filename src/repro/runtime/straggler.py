"""Straggler detection and mitigation.

At thousand-node scale a single slow host gates every synchronous step.  The
monitor keeps a rolling window of per-host step times; hosts whose median
exceeds ``threshold`` x the fleet median are flagged.  Mitigation is data
rebalancing: shift per-host batch shares away from stragglers (the pipeline
accepts weighted shard sizes), a softer first response than eviction —
eviction (elastic re-mesh) is the escalation path (see elastic.py).
"""
from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass, field

import numpy as np


@dataclass
class StragglerMonitor:
    window: int = 20
    threshold: float = 1.5
    _times: dict = field(default_factory=lambda: defaultdict(deque))

    def record(self, host: int, step_time_s: float) -> None:
        dq = self._times[host]
        dq.append(step_time_s)
        if len(dq) > self.window:
            dq.popleft()

    def host_medians(self) -> dict[int, float]:
        return {h: float(np.median(list(dq)))
                for h, dq in self._times.items() if dq}

    def stragglers(self) -> list[int]:
        med = self.host_medians()
        if len(med) < 2:
            return []
        fleet = float(np.median(list(med.values())))
        return [h for h, m in med.items() if m > self.threshold * fleet]

    def relative_speed(self) -> dict[int, float]:
        """1.0 = fleet median; higher = faster host."""
        med = self.host_medians()
        if not med:
            return {}
        fleet = float(np.median(list(med.values())))
        return {h: fleet / max(m, 1e-9) for h, m in med.items()}


def rebalance_batches(global_batch: int, speeds: dict[int, float],
                      *, quantum: int = 1) -> dict[int, int]:
    """Split ``global_batch`` proportionally to host speeds (bounded below by
    one quantum so no host is starved), preserving the total exactly."""
    hosts = sorted(speeds)
    w = np.array([max(speeds[h], 1e-3) for h in hosts], dtype=np.float64)
    raw = w / w.sum() * (global_batch / quantum)
    alloc = np.maximum(1, np.floor(raw)).astype(int)
    # distribute the remainder to the largest fractional parts
    rem = global_batch // quantum - int(alloc.sum())
    if rem > 0:
        order = np.argsort(-(raw - np.floor(raw)))
        for i in order[:rem]:
            alloc[i] += 1
    elif rem < 0:
        order = np.argsort(raw - np.floor(raw))
        for i in order:
            if rem == 0:
                break
            if alloc[i] > 1:
                alloc[i] -= 1
                rem += 1
    return {h: int(a) * quantum for h, a in zip(hosts, alloc)}
