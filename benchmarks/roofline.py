"""Render the SS Roofline table from results/dryrun.json.

Usage: python -m benchmarks.roofline [--json results/dryrun.json] [--mesh single]
"""
from __future__ import annotations

import argparse
import json


def fmt_table(results: list[dict], mesh: str = "single") -> str:
    rows = [r for r in results if r.get("mesh") == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = []
    out.append(
        "| arch | shape | mb | compute_s | memory_s | collective_s | "
        "dominant | roofline_bound_s | MODEL_FLOPS/dev | useful_frac | "
        "temp GiB | fits |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                       f"skipped | - | - | - | - | ({r['reason']}) |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                       f"ERROR | - | - | - | - | {r.get('error','')[:40]} |")
            continue
        ro = r["roofline"]
        temp = r["memory"]["temp_bytes"] / 2**30
        fits = "yes" if temp <= 16 else f"NO ({temp:.0f}G)"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('microbatches') or '-'} "
            f"| {ro['compute_s']*1e3:.1f}ms | {ro['memory_s']*1e3:.1f}ms "
            f"| {ro['collective_s']*1e3:.1f}ms | {ro['dominant']} "
            f"| {ro['step_time_s']*1e3:.1f}ms "
            f"| {r['model_flops_per_dev']/1e12:.1f}T "
            f"| {r['useful_flop_frac']:.2f} | {temp:.1f} | {fits} |")
    return "\n".join(out)


def summarize(results: list[dict]) -> str:
    ok = [r for r in results if r["status"] == "ok"]
    dominant = {}
    for r in ok:
        d = r["roofline"]["dominant"]
        dominant[d] = dominant.get(d, 0) + 1
    lines = [f"cells ok: {len(ok)}; dominant terms: {dominant}"]
    worst = sorted(
        (r for r in ok if r["shape"] == "train_4k" and r["mesh"] == "single"),
        key=lambda r: -(r["roofline"]["step_time_s"]
                        / max(r["roofline"]["compute_s"], 1e-12)))
    if worst:
        lines.append("most roofline-distant train cells: " + ", ".join(
            f"{r['arch']} ({r['roofline']['step_time_s']/max(r['roofline']['compute_s'],1e-12):.1f}x compute)"
            for r in worst[:3]))
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    results = json.load(open(args.json))
    print(fmt_table(results, args.mesh))
    print()
    print(summarize(results))


if __name__ == "__main__":
    main()
