"""Archive-path benchmarks: Levenshtein, write/read/replay, indexed lookup.

Three sections:

* **levenshtein** — the Myers bit-parallel edit distance
  (``repro.core.trace.levenshtein``) against the classic DP
  (``levenshtein_dp``) on token streams shaped like real control-flow
  traces (long runs of matching prefix with scattered divergence, plus a
  worst-case random pair).  The acceptance gate (ISSUE 4) asserts a >=5x
  speedup at trace length >= 2k — this is what makes offline Fig 9 diffing
  tractable over millions of archived warps.
* **archive** — end-to-end throughput of the durable path: write runs
  through ``RotatingJsonlSink``, read them back with ``ArchiveReader``,
  self-replay with ``Replayer`` (asserting 0.0 discrepancy), reporting
  runs/s per stage.
* **index** — ``ArchiveReader.get(run_id)`` through the sidecar index
  versus locating the same run by scanning.  The acceptance gate (ISSUE 5)
  asserts the indexed lookup is >=10x faster than the full scan on a
  1k-run archive — i.e. ``get`` really seeks instead of scanning.

Run:   PYTHONPATH=src python benchmarks/bench_archive.py
CI:    PYTHONPATH=src python benchmarks/bench_archive.py --smoke
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from repro.archive import ArchiveIndex, ArchiveReader, Replayer
from repro.core import MachineConfig
from repro.core.programs import make_suite
from repro.core.trace import levenshtein, levenshtein_dp
from repro.engine import (RotatingJsonlSink, Simulator, as_request,
                          feed_result, run_meta)

GATE_LEN = 2048          # acceptance: >=5x speedup at traces >= 2k tokens
GATE_SPEEDUP = 5.0
INDEX_GATE_RUNS = 1000   # acceptance: >=10x indexed get vs full scan at 1k
INDEX_GATE_SPEEDUP = 10.0


def _trace_like_pair(rng: np.random.Generator, n: int,
                     mutate: float) -> tuple[np.ndarray, np.ndarray]:
    """Two token streams with trace statistics: mostly-shared content with
    ``mutate`` fraction of substitutions/indels (a mechanism pair diverges
    locally, not uniformly)."""
    base = rng.integers(0, 200, size=n).astype(np.int64)
    other = base.copy()
    n_mut = max(1, int(mutate * n))
    idx = rng.choice(n, size=n_mut, replace=False)
    other[idx] = rng.integers(200, 400, size=n_mut)
    drop = rng.choice(n, size=n_mut // 2, replace=False)
    other = np.delete(other, drop)
    return base, other


def bench_levenshtein(lengths: tuple[int, ...], *, repeats: int = 3) -> None:
    rng = np.random.default_rng(0)
    print("== levenshtein: Myers bit-parallel vs DP ==")
    print(f"{'len':>6} {'kind':>8} {'dist':>7} {'myers_s':>9} "
          f"{'dp_s':>9} {'speedup':>8}")
    gate_ok = []
    for n in lengths:
        for kind, (a, b) in (
                ("trace", _trace_like_pair(rng, n, mutate=0.05)),
                ("random", (rng.integers(0, 1000, n).astype(np.int64),
                            rng.integers(0, 1000, n).astype(np.int64)))):
            t_my = _timed(levenshtein, a, b, repeats=repeats)
            t_dp = _timed(levenshtein_dp, a, b, repeats=1)
            d_my, d_dp = levenshtein(a, b), levenshtein_dp(a, b)
            assert d_my == d_dp, (n, kind, d_my, d_dp)
            speedup = t_dp / max(t_my, 1e-9)
            print(f"{n:>6} {kind:>8} {d_my:>7} {t_my:>9.4f} "
                  f"{t_dp:>9.4f} {speedup:>7.1f}x")
            if n >= GATE_LEN:
                gate_ok.append(speedup)
    assert gate_ok and min(gate_ok) >= GATE_SPEEDUP, (
        f"acceptance gate: Myers must be >={GATE_SPEEDUP}x the DP at "
        f"length >={GATE_LEN}; measured {gate_ok}")
    print(f"gate OK: >= {GATE_SPEEDUP}x at length >= {GATE_LEN} "
          f"(worst {min(gate_ok):.1f}x)")


def _timed(fn, *args, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_archive(n_runs: int) -> None:
    cfg = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
    suite = make_suite(cfg, datasets=1)
    sim = Simulator("hanoi")
    # pre-run once per program; archival replays results into the sink, so
    # the write benchmark measures the sink, not the interpreter
    results = [(b, sim.run(b, cfg)) for b in suite]
    print(f"\n== archive: write -> read -> self-replay "
          f"({n_runs} runs over {len(results)} programs) ==")
    with tempfile.TemporaryDirectory() as tmp:
        sink = RotatingJsonlSink(tmp, max_bytes=1 << 20)
        t0 = time.perf_counter()
        for i in range(n_runs):
            bench, res = results[i % len(results)]
            feed_result(sink, res, run_meta("hanoi", as_request(bench, cfg)))
        sink.flush()
        t_write = time.perf_counter() - t0
        sink.close()

        reader = ArchiveReader(tmp)
        t0 = time.perf_counter()
        runs = reader.runs()
        t_read = time.perf_counter() - t0
        assert len(runs) == n_runs and reader.report.clean

        t0 = time.perf_counter()
        report = Replayer(simulator=sim).replay(runs)
        t_replay = time.perf_counter() - t0
        assert report.replayed == n_runs
        assert report.mean_discrepancy() == 0.0

        print(f"{'stage':>8} {'runs/s':>10} {'wall_s':>9}")
        for stage, dt in (("write", t_write), ("read", t_read),
                          ("replay", t_replay)):
            print(f"{stage:>8} {n_runs / max(dt, 1e-9):>10.0f} {dt:>9.3f}")
        print(f"archive files: {len(sink.paths)}, "
              f"{sink.bytes_written / 1e6:.2f} MB, "
              f"self-replay discrepancy: "
              f"{report.mean_discrepancy():.4f}")


def bench_index(n_runs: int = INDEX_GATE_RUNS) -> None:
    """Indexed get vs full-scan locate of the same (last) run."""
    cfg = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
    bench = next(b for b in make_suite(cfg, datasets=1)
                 if b.name == "DIAMOND")
    sim = Simulator("hanoi")
    res = sim.run(bench, cfg)
    meta = run_meta("hanoi", as_request(bench, cfg))
    print(f"\n== index: O(1) get vs full scan ({n_runs} runs) ==")
    with tempfile.TemporaryDirectory() as tmp:
        sink = RotatingJsonlSink(tmp, max_bytes=1 << 20)
        for _ in range(n_runs):
            feed_result(sink, res, meta)
        sink.flush()
        sink.close()

        t0 = time.perf_counter()
        idx = ArchiveIndex.build(tmp)
        t_build = time.perf_counter() - t0
        assert len(idx) == n_runs
        target = idx.entries[-1].run_id      # worst case for the scan

        reader = ArchiveReader(tmp)
        t0 = time.perf_counter()
        scanned = None
        for run in reader:                   # sequential locate
            scanned = run
        t_scan = time.perf_counter() - t0

        repeats = 20
        t0 = time.perf_counter()
        for _ in range(repeats):
            got = reader.get(target)         # seek + read one span
        t_get = (time.perf_counter() - t0) / repeats
        assert got.trace == scanned.trace and dict(got.meta) == \
            dict(scanned.meta), "indexed get must be bit-equal to the scan"

        speedup = t_scan / max(t_get, 1e-9)
        print(f"{'op':>10} {'wall_s':>10}")
        print(f"{'build':>10} {t_build:>10.4f}")
        print(f"{'scan':>10} {t_scan:>10.4f}")
        print(f"{'get':>10} {t_get:>10.6f}")
        print(f"indexed speedup: {speedup:.0f}x")
        if n_runs >= INDEX_GATE_RUNS:
            assert speedup >= INDEX_GATE_SPEEDUP, (
                f"acceptance gate: indexed get must be "
                f">={INDEX_GATE_SPEEDUP}x a full scan at {INDEX_GATE_RUNS} "
                f"runs; measured {speedup:.1f}x")
            print(f"gate OK: >= {INDEX_GATE_SPEEDUP}x at >= "
                  f"{INDEX_GATE_RUNS} runs")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (still enforces the >=5x and >=10x "
                         "gates)")
    args = ap.parse_args()
    if args.smoke:
        bench_levenshtein((512, GATE_LEN), repeats=1)
        bench_archive(n_runs=60)
        bench_index(n_runs=INDEX_GATE_RUNS)
    else:
        bench_levenshtein((512, GATE_LEN, 4096))
        bench_archive(n_runs=400)
        bench_index(n_runs=2 * INDEX_GATE_RUNS)


if __name__ == "__main__":
    main()
