"""Paper-table benchmarks for the Hanoi control-flow engine.

All measurements flow through the unified ``repro.engine`` API:

* Fig 9  — control-flow trace discrepancy (Levenshtein %) Hanoi vs. the
           Turing-oracle ("hardware") traces across the benchmark suite,
           via ``Simulator.compare``;
* Fig 10 — relative IPC difference via the trace-driven timing model,
           including the BFSD outlier (+SIMD-utilization gain);
* SS IX-A — hardware storage cost vs. a SIMT-Stack (432 B / ~43% claim);
* engine throughput: vectorized JAX mechanism (vmap ``run_batch``) vs. the
  numpy reference mechanism, warps/second.
"""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.core import MachineConfig, hardware_cost_bytes
from repro.core.programs import make_suite
from repro.core.timing import TimingConfig
from repro.engine import CompareReport, Simulator

CFG = MachineConfig(n_threads=32, mem_size=256, max_steps=60_000)
PAIR = ("hanoi", "turing_oracle")

_SIM = Simulator("hanoi")


@functools.lru_cache(maxsize=1)
def _suite():
    # benchmarks are frozen and engines never mutate the shared program /
    # init_mem arrays, so one suite instance serves every table
    return make_suite(CFG, datasets=2)


def compare_report() -> CompareReport:
    """One engine-API call computes both Fig 9 and Fig 10 inputs."""
    return _SIM.compare(list(PAIR), _suite(), CFG, pairs=[PAIR],
                        timing_warps=4, timing_cfg=TimingConfig())


def trace_discrepancy_rows(report: CompareReport | None = None) -> list[dict]:
    """Fig 9: per-execution trace discrepancy vs the hardware oracle."""
    report = report or compare_report()
    families = {b.name: b.family for b in _suite()}
    return [{"bench": row.program, "family": families[row.program],
             "discrepancy_pct": row.discrepancy_pct,
             "trace_len": row.trace_len_b}
            for row in report.pair(*PAIR)]


def ipc_rows(report: CompareReport | None = None) -> list[dict]:
    """Fig 10: relative IPC (trace-driven GTO model) Hanoi vs hardware."""
    report = report or compare_report()
    return [{"bench": row.program,
             "ipc_hanoi": row.ipc_a, "ipc_hw": row.ipc_b,
             "ipc_delta_pct": row.ipc_delta_pct,
             "util_hanoi": row.util_a, "util_hw": row.util_b}
            for row in report.pair(*PAIR)]


def summary() -> dict:
    """The paper's headline numbers on our suite."""
    report = compare_report()
    dd = trace_discrepancy_rows(report)
    ii = ipc_rows(report)
    zero = sum(1 for r in dd if r["discrepancy_pct"] == 0.0)
    nonzero = [r for r in dd if r["discrepancy_pct"] > 0]
    bfsd_i = next(r for r in ii if r["bench"] == "BFSD")
    return {
        "executions": len(dd),
        "zero_discrepancy": zero,
        "avg_discrepancy_pct": float(np.mean([r["discrepancy_pct"]
                                              for r in dd])),
        "max_discrepancy_pct": float(max(r["discrepancy_pct"] for r in dd)),
        "avg_abs_ipc_delta_pct": float(np.mean([abs(r["ipc_delta_pct"])
                                                for r in ii])),
        "bfsd_ipc_gain_pct": bfsd_i["ipc_delta_pct"],
        "bfsd_util_gain_pct": 100.0 * (bfsd_i["util_hanoi"]
                                       - bfsd_i["util_hw"])
        / max(bfsd_i["util_hw"], 1e-9),
        "nonzero_benches": [r["bench"] for r in nonzero],
    }


def hw_cost_rows() -> list[dict]:
    out = []
    for n_bx in (4, 8, 16):
        c = hardware_cost_bytes(MachineConfig(n_threads=32, n_bx=n_bx))
        out.append({"n_bx": n_bx, **c})
    return out


def engine_throughput(n_warps: int = 32, reps: int = 3) -> dict:
    """Vectorized JAX mechanism vs numpy mechanism, warps/second.

    Both arms use the same per-warp requests (one randomized memory image
    per warp).  The JAX arm is one ``run_batch`` call (the vmap path,
    including result materialization — the price a service actually pays);
    the numpy arm runs sequentially via ``run`` so the ratio stays
    comparable to the historical single-threaded interpreter numbers
    rather than measuring the thread-pool fan-out.
    """
    cfg = MachineConfig(n_threads=8, mem_size=64, max_steps=2048)
    bench = next(b for b in make_suite(cfg, datasets=1) if b.name == "GAUS0")
    rng = np.random.default_rng(0)
    from repro.engine import SimRequest
    reqs = [SimRequest(program=bench.program, cfg=cfg,
                       init_mem=rng.integers(0, 8, size=cfg.mem_size)
                       .astype(np.int32),
                       record_trace=False, name=f"warp{w}")
            for w in range(n_warps)]

    _SIM.run_batch(reqs, mechanism="hanoi_jax")            # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        _SIM.run_batch(reqs, mechanism="hanoi_jax")
    jax_s = (time.perf_counter() - t0) / reps

    t0 = time.perf_counter()
    for req in reqs:
        _SIM.run(req, mechanism="hanoi")
    np_s = time.perf_counter() - t0
    return {"n_warps": n_warps,
            "jax_warps_per_s": n_warps / jax_s,
            "numpy_warps_per_s": n_warps / np_s,
            "speedup": np_s / jax_s}


def main() -> None:
    s = summary()
    print("== Fig 9 (trace discrepancy vs hardware oracle) ==")
    for k, v in s.items():
        print(f"  {k}: {v}")
    print("== SS IX-A hardware cost ==")
    for r in hw_cost_rows():
        print(f"  n_bx={r['n_bx']}: hanoi={r['hanoi_bytes']}B "
              f"simt={r['simt_stack_bytes']}B saving={r['saving_frac']:.1%}")
    print("== engine throughput ==")
    print(f"  {engine_throughput()}")


if __name__ == "__main__":
    main()
