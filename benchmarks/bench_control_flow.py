"""Paper-table benchmarks for the Hanoi control-flow engine.

* Fig 9  — control-flow trace discrepancy (Levenshtein %) Hanoi vs. the
           Turing-oracle ("hardware") traces across the benchmark suite;
* Fig 10 — relative IPC difference via the trace-driven timing model,
           including the BFSD outlier (+SIMD-utilization gain);
* SS IX-A — hardware storage cost vs. a SIMT-Stack (432 B / ~43% claim);
* SIMD utilization per benchmark (suite-wide);
* engine throughput: vectorized JAX engine (vmap over warps) vs. the numpy
  reference interpreter.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (MachineConfig, hardware_cost_bytes, run_hanoi,
                        simd_utilization)
from repro.core.programs import make_suite
from repro.core.timing import TimingConfig, ipc_delta, simulate
from repro.core.trace import discrepancy

CFG = MachineConfig(n_threads=32, mem_size=256, max_steps=60_000)


def _suite():
    return make_suite(CFG, datasets=2)


def trace_discrepancy_rows() -> list[dict]:
    """Fig 9: per-execution trace discrepancy vs the hardware oracle."""
    rows = []
    for bench in _suite():
        hanoi = run_hanoi(bench.program, CFG, init_mem=bench.init_mem)
        hw = run_hanoi(bench.program, CFG, init_mem=bench.init_mem,
                       bsync_skip_pcs=bench.skip_bsync_pcs)
        d = discrepancy(hanoi.trace, hw.trace)
        rows.append({"bench": bench.name, "family": bench.family,
                     "discrepancy_pct": 100.0 * d,
                     "trace_len": len(hw.trace)})
    return rows


def ipc_rows() -> list[dict]:
    """Fig 10: relative IPC (trace-driven GTO model) Hanoi vs hardware."""
    rows = []
    for bench in _suite():
        hanoi = run_hanoi(bench.program, CFG, init_mem=bench.init_mem)
        hw = run_hanoi(bench.program, CFG, init_mem=bench.init_mem,
                       bsync_skip_pcs=bench.skip_bsync_pcs)
        t_h = simulate([hanoi.trace] * 4, bench.program, CFG.n_threads)
        t_o = simulate([hw.trace] * 4, bench.program, CFG.n_threads)
        rows.append({
            "bench": bench.name,
            "ipc_hanoi": t_h.ipc, "ipc_hw": t_o.ipc,
            "ipc_delta_pct": 100.0 * ipc_delta(t_h, t_o),
            "util_hanoi": t_h.simd_utilization,
            "util_hw": t_o.simd_utilization,
        })
    return rows


def summary() -> dict:
    """The paper's headline numbers on our suite."""
    dd = trace_discrepancy_rows()
    ii = ipc_rows()
    zero = sum(1 for r in dd if r["discrepancy_pct"] == 0.0)
    nonzero = [r for r in dd if r["discrepancy_pct"] > 0]
    bfsd_i = next(r for r in ii if r["bench"] == "BFSD")
    return {
        "executions": len(dd),
        "zero_discrepancy": zero,
        "avg_discrepancy_pct": float(np.mean([r["discrepancy_pct"]
                                              for r in dd])),
        "max_discrepancy_pct": float(max(r["discrepancy_pct"] for r in dd)),
        "avg_abs_ipc_delta_pct": float(np.mean([abs(r["ipc_delta_pct"])
                                                for r in ii])),
        "bfsd_ipc_gain_pct": bfsd_i["ipc_delta_pct"],
        "bfsd_util_gain_pct": 100.0 * (bfsd_i["util_hanoi"]
                                       - bfsd_i["util_hw"])
        / max(bfsd_i["util_hw"], 1e-9),
        "nonzero_benches": [r["bench"] for r in nonzero],
    }


def hw_cost_rows() -> list[dict]:
    out = []
    for n_bx in (4, 8, 16):
        c = hardware_cost_bytes(MachineConfig(n_threads=32, n_bx=n_bx))
        out.append({"n_bx": n_bx, **c})
    return out


def engine_throughput(n_warps: int = 32, reps: int = 3) -> dict:
    """Vectorized JAX engine vs numpy interpreter, warps/second."""
    from repro.core.hanoi import run_warps_jax
    import jax
    cfg = MachineConfig(n_threads=8, mem_size=64, max_steps=2048)
    from tests.test_property_core import make_program
    built = None
    seed = 0
    while built is None:
        built, _ = make_program(seed, 8)
        seed += 1
    prog, mem = built
    rng = np.random.default_rng(0)
    regs = np.zeros((n_warps, cfg.n_threads, cfg.n_regs), np.int32)
    mems = rng.integers(0, 8, size=(n_warps, cfg.mem_size)).astype(np.int32)

    st = run_warps_jax(prog, cfg, regs, mems)          # compile
    jax.block_until_ready(st.regs)
    t0 = time.perf_counter()
    for _ in range(reps):
        st = run_warps_jax(prog, cfg, regs, mems)
        jax.block_until_ready(st.regs)
    jax_s = (time.perf_counter() - t0) / reps

    t0 = time.perf_counter()
    for w in range(n_warps):
        run_hanoi(prog, cfg, init_regs=regs[w], init_mem=mems[w],
                  record_trace=False)
    np_s = time.perf_counter() - t0
    return {"n_warps": n_warps,
            "jax_warps_per_s": n_warps / jax_s,
            "numpy_warps_per_s": n_warps / np_s,
            "speedup": np_s / jax_s}


def main() -> None:
    s = summary()
    print("== Fig 9 (trace discrepancy vs hardware oracle) ==")
    for k, v in s.items():
        print(f"  {k}: {v}")
    print("== SS IX-A hardware cost ==")
    for r in hw_cost_rows():
        print(f"  n_bx={r['n_bx']}: hanoi={r['hanoi_bytes']}B "
              f"simt={r['simt_stack_bytes']}B saving={r['saving_frac']:.1%}")
    print("== engine throughput ==")
    print(f"  {engine_throughput()}")


if __name__ == "__main__":
    main()
