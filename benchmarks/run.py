"""Benchmark aggregator: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract), then the
human-readable sections.  The multi-pod dry-run / roofline tables are produced
separately by ``python -m repro.launch.dryrun --all`` +
``python -m benchmarks.roofline`` (they need the 512-device flag set at
process start).
"""
from __future__ import annotations

import time


def main() -> None:
    t_all = time.perf_counter()
    rows: list[tuple[str, float, str]] = []

    from benchmarks import bench_control_flow as bcf
    t0 = time.perf_counter()
    s = bcf.summary()
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("fig9_trace_discrepancy", dt,
                 f"avg={s['avg_discrepancy_pct']:.2f}%;"
                 f"zero={s['zero_discrepancy']}/{s['executions']}"))
    rows.append(("fig10_ipc_delta", dt,
                 f"avg_abs={s['avg_abs_ipc_delta_pct']:.2f}%;"
                 f"bfsd_gain={s['bfsd_ipc_gain_pct']:.1f}%;"
                 f"bfsd_util_gain={s['bfsd_util_gain_pct']:.1f}%"))

    t0 = time.perf_counter()
    hw = bcf.hw_cost_rows()
    dt = (time.perf_counter() - t0) * 1e6
    h8 = next(r for r in hw if r["n_bx"] == 8)
    rows.append(("sec9a_hw_cost", dt,
                 f"hanoi={h8['hanoi_bytes']}B;simt={h8['simt_stack_bytes']}B;"
                 f"saving={h8['saving_frac']:.1%}"))

    t0 = time.perf_counter()
    thr = bcf.engine_throughput()
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("engine_throughput", dt,
                 f"jax={thr['jax_warps_per_s']:.0f}w/s;"
                 f"numpy={thr['numpy_warps_per_s']:.0f}w/s;"
                 f"speedup={thr['speedup']:.2f}x"))

    from benchmarks import bench_kernels as bk
    t0 = time.perf_counter()
    census = bk.tile_census_rows()
    dt = (time.perf_counter() - t0) * 1e6
    for r in census:
        rows.append((f"tiles[{r['case']}]", dt / len(census),
                     f"kept={r['flops_kept_frac']:.3f};"
                     f"partial={r['partial']};empty={r['empty']}"))
    for r in bk.kernel_timing_rows():
        rows.append((f"kernel[{r['kernel']}]", r["us"], ""))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"# total {time.perf_counter() - t_all:.1f}s")


if __name__ == "__main__":
    main()
