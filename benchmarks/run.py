"""Benchmark aggregator: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract), then the
human-readable sections.  The multi-pod dry-run / roofline tables are produced
separately by ``python -m repro.launch.dryrun --all`` +
``python -m benchmarks.roofline`` (they need the 512-device flag set at
process start).

``--engine-api`` runs only a tiny end-to-end smoke of the unified
``repro.engine`` API (one ``Simulator.compare`` call on a reduced machine) —
the CI entry point.
"""
from __future__ import annotations

import argparse
import time


def engine_api_smoke() -> list[tuple[str, float, str]]:
    """One tiny end-to-end ``compare()`` through the unified engine API.

    Exits non-zero when any mechanism fails to complete a benchmark, so the
    CI step is a real regression gate, not just a printout.
    """
    from repro.core import MachineConfig
    from repro.core.programs import make_suite
    from repro.engine import Simulator

    cfg = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
    suite = [b for b in make_suite(cfg, datasets=1)
             if b.name in ("GAUS0", "BFSD", "DIAMOND")]
    t0 = time.perf_counter()
    report = Simulator("hanoi").compare(
        ["simt_stack", "hanoi", "turing_oracle"], suite, cfg,
        pairs=[("simt_stack", "hanoi"), ("hanoi", "turing_oracle")],
        timing=False)
    dt = (time.perf_counter() - t0) * 1e6
    sh = report.mean_discrepancy("simt_stack", "hanoi")
    ho = report.mean_discrepancy("hanoi", "turing_oracle")
    ok = all(r.status_a == "ok" and r.status_b == "ok" for r in report.rows)
    rows = [("engine_api_smoke", dt,
             f"simt_vs_hanoi={100 * sh:.2f}%;"
             f"hanoi_vs_oracle={100 * ho:.2f}%;all_ok={ok}")]
    if not ok:
        bad = [(r.program, r.mech_a, r.status_a, r.mech_b, r.status_b)
               for r in report.rows
               if r.status_a != "ok" or r.status_b != "ok"]
        raise SystemExit(f"engine API smoke failed: non-ok statuses {bad}")
    return rows


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine-api", action="store_true",
                    help="run only the repro.engine end-to-end smoke "
                         "(tiny compare() call; used by CI)")
    args = ap.parse_args(argv)

    t_all = time.perf_counter()
    rows: list[tuple[str, float, str]] = []

    if args.engine_api:
        rows += engine_api_smoke()
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        print(f"# total {time.perf_counter() - t_all:.1f}s")
        return

    from benchmarks import bench_control_flow as bcf
    t0 = time.perf_counter()
    s = bcf.summary()
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("fig9_trace_discrepancy", dt,
                 f"avg={s['avg_discrepancy_pct']:.2f}%;"
                 f"zero={s['zero_discrepancy']}/{s['executions']}"))
    rows.append(("fig10_ipc_delta", dt,
                 f"avg_abs={s['avg_abs_ipc_delta_pct']:.2f}%;"
                 f"bfsd_gain={s['bfsd_ipc_gain_pct']:.1f}%;"
                 f"bfsd_util_gain={s['bfsd_util_gain_pct']:.1f}%"))

    t0 = time.perf_counter()
    hw = bcf.hw_cost_rows()
    dt = (time.perf_counter() - t0) * 1e6
    h8 = next(r for r in hw if r["n_bx"] == 8)
    rows.append(("sec9a_hw_cost", dt,
                 f"hanoi={h8['hanoi_bytes']}B;simt={h8['simt_stack_bytes']}B;"
                 f"saving={h8['saving_frac']:.1%}"))

    t0 = time.perf_counter()
    thr = bcf.engine_throughput()
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("engine_throughput", dt,
                 f"jax={thr['jax_warps_per_s']:.0f}w/s;"
                 f"numpy={thr['numpy_warps_per_s']:.0f}w/s;"
                 f"speedup={thr['speedup']:.2f}x"))

    rows += engine_api_smoke()

    from benchmarks import bench_kernels as bk
    t0 = time.perf_counter()
    census = bk.tile_census_rows()
    dt = (time.perf_counter() - t0) * 1e6
    for r in census:
        rows.append((f"tiles[{r['case']}]", dt / len(census),
                     f"kept={r['flops_kept_frac']:.3f};"
                     f"partial={r['partial']};empty={r['empty']}"))
    t0 = time.perf_counter()
    mech = bk.mechanism_utilization_rows()
    dt = (time.perf_counter() - t0) * 1e6
    for r in mech:
        rows.append((f"mech_util[{r['mechanism']}]", dt / len(mech),
                     f"util={r['utilization']:.3f};"
                     f"steps={r['steps']}"))
    for r in bk.kernel_timing_rows():
        rows.append((f"kernel[{r['kernel']}]", r["us"], ""))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"# total {time.perf_counter() - t_all:.1f}s")


if __name__ == "__main__":
    main()
