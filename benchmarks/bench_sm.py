"""Per-SM multi-warp interleaving sweep (``sm_interleave`` / ``run_sm``).

Sweeps warp count x warp-scheduler policy x inner mechanism over a slice of
the benchmark suite and reports the SM-level schedule metrics: issue slots,
latency-aware cycles, thread IPC, and SIMD utilization.  The headline
effects to look for:

* more warps per SM hide memory latency — cycles grow sublinearly in
  warp count, so thread-IPC rises (the classic occupancy curve);
* ``greedy_then_oldest`` (GTO) beats ``round_robin`` on IPC when traces
  are memory-heavy (it keeps issuing from a ready warp instead of
  rotating onto stalled ones);
* a reconvergence-enforcing inner mechanism (``hanoi``) out-utilizes the
  stackless per-thread-PC scheduler (``volta_itps``) at equal warp count.

Run:  PYTHONPATH=src python benchmarks/bench_sm.py
"""
from __future__ import annotations

import argparse

from repro.core import MachineConfig
from repro.core.programs import make_suite
from repro.engine import Simulator

CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=20_000)
BENCHES = ("GAUS0", "RBFS0", "LUD0", "DIAMOND")
WARP_COUNTS = (1, 2, 4, 8)
POLICIES = ("round_robin", "greedy_then_oldest")
INNERS = ("hanoi", "volta_itps")


def sm_sweep_rows(benches=BENCHES, warp_counts=WARP_COUNTS,
                  policies=POLICIES, inners=INNERS) -> list[dict]:
    sim = Simulator("hanoi")
    suite = {b.name: b for b in make_suite(CFG, datasets=1)}
    rows = []
    for name in benches:
        bench = suite[name]
        for inner in inners:
            for n_warps in warp_counts:
                for policy in policies:
                    sm = sim.run_sm(bench, CFG, n_warps=n_warps,
                                    inner=inner, policy=policy)
                    rows.append({
                        "bench": name, "inner": inner, "policy": policy,
                        "n_warps": n_warps, "status": sm.status.value,
                        "sm_slots": sm.steps, "cycles": sm.cycles,
                        "ipc": sm.ipc, "warp_ipc": sm.warp_ipc,
                        "utilization": sm.utilization,
                    })
    return rows


def occupancy_summary(rows: list[dict]) -> list[dict]:
    """Cycles-vs-warps scaling per (bench, inner): how sublinear is it?"""
    out = []
    for (bench, inner) in {(r["bench"], r["inner"]) for r in rows}:
        gto = {r["n_warps"]: r for r in rows
               if r["bench"] == bench and r["inner"] == inner
               and r["policy"] == "greedy_then_oldest"}
        lo, hi = min(gto), max(gto)
        scale = gto[hi]["cycles"] / max(1, gto[lo]["cycles"])
        out.append({"bench": bench, "inner": inner,
                    "warps": f"{lo}->{hi}",
                    "cycles_scale": scale,
                    "linear_scale": hi / lo,
                    "latency_hidden_frac": 1.0 - scale / (hi / lo),
                    "ipc_gain": gto[hi]["ipc"] / max(1e-9, gto[lo]["ipc"])})
    return sorted(out, key=lambda r: (r["bench"], r["inner"]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--benches", default=",".join(BENCHES))
    args = ap.parse_args()
    rows = sm_sweep_rows(benches=tuple(args.benches.split(",")))
    hdr = ("bench", "inner", "policy", "n_warps", "sm_slots", "cycles",
           "ipc", "utilization")
    print(",".join(hdr))
    for r in rows:
        print(",".join(f"{r[k]:.3f}" if isinstance(r[k], float) else str(r[k])
                       for k in hdr))
    print("\n== occupancy (GTO, cycles scaling vs warp count) ==")
    for r in occupancy_summary(rows):
        print(f"  {r['bench']:8s} inner={r['inner']:10s} "
              f"warps {r['warps']}: cycles x{r['cycles_scale']:.2f} "
              f"(linear would be x{r['linear_scale']:.0f}; "
              f"{100 * r['latency_hidden_frac']:.0f}% latency hidden), "
              f"IPC x{r['ipc_gain']:.2f}")


if __name__ == "__main__":
    main()
