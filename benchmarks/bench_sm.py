"""Per-SM multi-warp interleaving sweep (``sm_interleave`` / ``run_sm``).

Sweeps warp count x warp-scheduler policy x inner mechanism over a slice of
the benchmark suite and reports the SM-level schedule metrics: issue slots,
latency-aware cycles, thread IPC, and SIMD utilization.  The headline
effects to look for:

* more warps per SM hide memory latency — cycles grow sublinearly in
  warp count, so thread-IPC rises (the classic occupancy curve);
* ``greedy_then_oldest`` (GTO) beats ``round_robin`` on IPC when traces
  are memory-heavy (it keeps issuing from a ready warp instead of
  rotating onto stalled ones);
* a reconvergence-enforcing inner mechanism (``hanoi``) out-utilizes the
  stackless per-thread-PC scheduler (``volta_itps``) at equal warp count.

Run:  PYTHONPATH=src python benchmarks/bench_sm.py

``--smoke`` is the CI gate for the ``sm_jax`` lane-parallel SM engine:
it runs the same grid of SM cells through ``sm_jax`` (one ``jit(vmap)``
batch, warmed so compile time is excluded) and through the Python
interleaver (``sm_interleave`` + ``hanoi``), asserts bit-identical
``(warp, pc, mask)`` SM traces / cycles / stall taxonomies for every
policy, and requires >= 10x speedup at >= 8 warps.
"""
from __future__ import annotations

import argparse
import time

from repro.core import MachineConfig
from repro.core.programs import make_suite
from repro.engine import Simulator
from repro.engine.types import SimRequest
from repro.timing.policies import POLICY_NAMES

CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=20_000)
BENCHES = ("GAUS0", "RBFS0", "LUD0", "DIAMOND")
WARP_COUNTS = (1, 2, 4, 8)
POLICIES = ("round_robin", "greedy_then_oldest")
INNERS = ("hanoi", "volta_itps")


def sm_sweep_rows(benches=BENCHES, warp_counts=WARP_COUNTS,
                  policies=POLICIES, inners=INNERS) -> list[dict]:
    sim = Simulator("hanoi")
    suite = {b.name: b for b in make_suite(CFG, datasets=1)}
    rows = []
    for name in benches:
        bench = suite[name]
        for inner in inners:
            for n_warps in warp_counts:
                for policy in policies:
                    sm = sim.run_sm(bench, CFG, n_warps=n_warps,
                                    inner=inner, policy=policy)
                    rows.append({
                        "bench": name, "inner": inner, "policy": policy,
                        "n_warps": n_warps, "status": sm.status.value,
                        "sm_slots": sm.steps, "cycles": sm.cycles,
                        "ipc": sm.ipc, "warp_ipc": sm.warp_ipc,
                        "utilization": sm.utilization,
                    })
    return rows


def occupancy_summary(rows: list[dict]) -> list[dict]:
    """Cycles-vs-warps scaling per (bench, inner): how sublinear is it?"""
    out = []
    for (bench, inner) in {(r["bench"], r["inner"]) for r in rows}:
        gto = {r["n_warps"]: r for r in rows
               if r["bench"] == bench and r["inner"] == inner
               and r["policy"] == "greedy_then_oldest"}
        lo, hi = min(gto), max(gto)
        scale = gto[hi]["cycles"] / max(1, gto[lo]["cycles"])
        out.append({"bench": bench, "inner": inner,
                    "warps": f"{lo}->{hi}",
                    "cycles_scale": scale,
                    "linear_scale": hi / lo,
                    "latency_hidden_frac": 1.0 - scale / (hi / lo),
                    "ipc_gain": gto[hi]["ipc"] / max(1e-9, gto[lo]["ipc"])})
    return sorted(out, key=lambda r: (r["bench"], r["inner"]))


def sm_jax_smoke(n_warps: int = 8, benches=BENCHES,
                 policies=POLICY_NAMES, min_speedup: float = 10.0,
                 timed_cells: int = 192) -> dict:
    """The sm_jax acceptance gate: trace equality + wall-clock speedup.

    Two parts.  **Equality**: every bench (including the long-trace LUD0)
    under every policy through the registered ``sm_jax`` batch runner vs
    the Python interleaver — the ``(warp, pc, mask)`` SM traces, cycles and
    stall taxonomies must be bit-identical.  **Timing**: a ``timed_cells``
    grid of short-trace SM cells under GTO, sm_jax warmed first so the
    timed pass measures cached-executable wall only (matching how a sweep
    amortizes), against the serial Python interleaver.  Returns the
    measurement; ``main(--smoke)`` turns it into a pass/fail exit code.
    """
    sim = Simulator("hanoi")
    suite = {b.name: b for b in make_suite(CFG, datasets=1)}

    def cell_reqs(inner: str, names, policy_set) -> list[SimRequest]:
        return [SimRequest(program=suite[n].program, cfg=CFG,
                           init_mem=suite[n].init_mem, name=n,
                           meta={"sm_warps": n_warps, "sm_inner": inner,
                                 "sm_policy": policy})
                for policy in policy_set for n in names]

    # equality sweep: every policy x every bench
    jax_res = sim.run_batch(cell_reqs("hanoi_jax", benches, policies),
                            mechanism="sm_jax")
    py_res = sim.run_batch(cell_reqs("hanoi", benches, policies),
                           mechanism="sm_interleave")
    mismatches = [
        (a.meta["sm"].policy, a.meta["sm"].requests[0].name)
        for a, b in zip(jax_res, py_res)
        if a.meta["sm"].sm_trace != b.meta["sm"].sm_trace
        or a.meta["sm"].cycles != b.meta["sm"].cycles
        or a.meta["sm"].stall_breakdown != b.meta["sm"].stall_breakdown
        or a.meta["sm"].thread_instructions
        != b.meta["sm"].thread_instructions]

    # timed grid: short-trace cells so the fixed lane-execution cost
    # amortizes over cells, GTO only (one compiled scheduler)
    short = tuple(n for n in benches if n != "LUD0") or benches
    names = [f"{short[i % len(short)]}" for i in range(timed_cells)]
    timed_jax = [SimRequest(program=suite[n].program, cfg=CFG,
                            init_mem=suite[n].init_mem, name=f"{n}#{i}",
                            meta={"sm_warps": n_warps,
                                  "sm_inner": "hanoi_jax",
                                  "sm_policy": "greedy_then_oldest"})
                 for i, n in enumerate(names)]
    timed_py = [SimRequest(program=q.program, cfg=CFG, init_mem=q.init_mem,
                           name=q.name,
                           meta={**dict(q.meta), "sm_inner": "hanoi"})
                for q in timed_jax]
    sim.run_batch(timed_jax, mechanism="sm_jax")     # warm the compile cache
    t0 = time.perf_counter()
    sim.run_batch(timed_jax, mechanism="sm_jax")
    t_jax = time.perf_counter() - t0
    t0 = time.perf_counter()
    sim.run_batch(timed_py, mechanism="sm_interleave")
    t_py = time.perf_counter() - t0
    return {"n_warps": n_warps, "cells": timed_cells,
            "equality_cells": len(jax_res), "policies": tuple(policies),
            "t_sm_jax_s": t_jax, "t_sm_interleave_s": t_py,
            "speedup": t_py / max(1e-9, t_jax),
            "min_speedup": min_speedup, "mismatches": mismatches,
            "ok": not mismatches and t_py / max(1e-9, t_jax) >= min_speedup}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--benches", default=",".join(BENCHES))
    ap.add_argument("--smoke", action="store_true",
                    help="sm_jax gate: bit-equal SM traces + >=10x speedup")
    ap.add_argument("--smoke-warps", type=int, default=8)
    args = ap.parse_args()
    if args.smoke:
        res = sm_jax_smoke(n_warps=args.smoke_warps)
        print(f"sm_jax smoke: {res['equality_cells']} equality cells over "
              f"{len(res['policies'])} policies; timed grid "
              f"{res['cells']} cells x {res['n_warps']} warps")
        print(f"  sm_jax        {res['t_sm_jax_s']:.4f}s (warmed)")
        print(f"  sm_interleave {res['t_sm_interleave_s']:.4f}s")
        print(f"  speedup x{res['speedup']:.1f} "
              f"(gate x{res['min_speedup']:.0f}), "
              f"trace mismatches: {len(res['mismatches'])}")
        if res["mismatches"]:
            raise SystemExit(f"FAIL: sm_jax diverged from sm_interleave on "
                             f"{res['mismatches']}")
        if not res["ok"]:
            raise SystemExit(f"FAIL: speedup x{res['speedup']:.1f} below "
                             f"gate x{res['min_speedup']:.0f}")
        print("PASS")
        return
    rows = sm_sweep_rows(benches=tuple(args.benches.split(",")))
    hdr = ("bench", "inner", "policy", "n_warps", "sm_slots", "cycles",
           "ipc", "utilization")
    print(",".join(hdr))
    for r in rows:
        print(",".join(f"{r[k]:.3f}" if isinstance(r[k], float) else str(r[k])
                       for k in hdr))
    print("\n== occupancy (GTO, cycles scaling vs warp count) ==")
    for r in occupancy_summary(rows):
        print(f"  {r['bench']:8s} inner={r['inner']:10s} "
              f"warps {r['warps']}: cycles x{r['cycles_scale']:.2f} "
              f"(linear would be x{r['linear_scale']:.0f}; "
              f"{100 * r['latency_hidden_frac']:.0f}% latency hidden), "
              f"IPC x{r['ipc_gain']:.2f}")


if __name__ == "__main__":
    main()
