"""Kernel benchmarks: divergence-aware tile census per assigned-arch
attention pattern (the Hanoi EMPTY/PARTIAL/FULL saving at MXU granularity),
warp-level SIMD utilization per control-flow mechanism (via the unified
``repro.engine`` API — the same EMPTY/PARTIAL/FULL economics one level
down), and interpret-mode wall times vs the jnp reference (correct-path
costs; TPU wall times are a dry-run quantity here, see EXPERIMENTS.md
SS Roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref, tile_stats


def tile_census_rows() -> list[dict]:
    cases = [
        ("llama/minitron/internlm causal 4k", 4096, 4096, True, 0),
        ("gemma3 local (w=1024) 4k", 4096, 4096, True, 1024),
        ("gemma3 local (w=1024) 32k", 32768, 32768, True, 1024),
        ("mixtral SWA (w=4096) 32k", 32768, 32768, True, 4096),
        ("recurrentgemma local (w=2048) 32k", 32768, 32768, True, 2048),
        ("hubert bidirectional 32k", 32768, 32768, False, 0),
    ]
    rows = []
    for name, sq, sk, causal, w in cases:
        st = tile_stats(sq, sk, causal=causal, window=w, bq=128, bk=128)
        rows.append({"case": name, **st})
    return rows


def mechanism_utilization_rows() -> list[dict]:
    """Warp-level SIMD utilization of each control-flow mechanism on the
    divergence-heavy BFS benchmark — the lane-granularity analogue of the
    tile census above, computed through the unified engine API."""
    from repro.core import MachineConfig
    from repro.core.programs import make_suite
    from repro.engine import Simulator, available_mechanisms

    cfg = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
    bench = next(b for b in make_suite(cfg, datasets=1) if b.name == "BFSD")
    sim = Simulator()
    rows = []
    for mech in available_mechanisms():
        res = sim.run(bench, cfg, mechanism=mech)
        rows.append({"mechanism": mech, "utilization": res.utilization,
                     "steps": res.steps, "status": res.status.value})
    return rows


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6      # us


def kernel_timing_rows() -> list[dict]:
    key = jax.random.PRNGKey(0)
    rows = []
    B, S, H, hd = 1, 256, 4, 64
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    v = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    rows.append({"kernel": "flash_attention(interp)",
                 "us": _time(ops.flash_attention, q, k, v, causal=True,
                             bq=64, bk=64, interpret=True, reps=1)})
    rows.append({"kernel": "attention_ref",
                 "us": _time(ref.attention_ref, q, k, v, causal=True)})
    a = jax.random.uniform(key, (2, 256, 128), jnp.float32, 0.5, 0.99)
    b = jax.random.normal(key, (2, 256, 128), jnp.float32)
    rows.append({"kernel": "rglru_scan(interp)",
                 "us": _time(ops.rglru_scan, a, b, bs=64, bw=64,
                             interpret=True, reps=1)})
    rows.append({"kernel": "rglru_ref",
                 "us": _time(ref.rglru_scan_ref, a, b)})
    r = jax.random.normal(key, (1, 128, 2, 16), jnp.float32)
    w = jax.random.uniform(key, (1, 128, 2, 16), jnp.float32, 0.8, 0.99)
    u = jax.random.normal(key, (2, 16), jnp.float32) * 0.1
    rows.append({"kernel": "rwkv6_scan(interp)",
                 "us": _time(ops.rwkv6_scan, r, r, r, w, u, bs=32,
                             interpret=True, reps=1)})
    rows.append({"kernel": "rwkv6_ref",
                 "us": _time(ref.rwkv6_scan_ref, r, r, r, w, u)})
    return rows


def main() -> None:
    print("== divergence-aware tile census (Hanoi EMPTY-tile skipping) ==")
    for r in tile_census_rows():
        print(f"  {r['case']:38s} kept={r['flops_kept_frac']:6.1%} "
              f"(empty={r['empty']}, partial={r['partial']}, "
              f"full={r['full']})")
    print("== SIMD utilization per mechanism (BFSD, repro.engine) ==")
    for r in mechanism_utilization_rows():
        print(f"  {r['mechanism']:14s} util={r['utilization']:6.1%} "
              f"steps={r['steps']:5d} status={r['status']}")
    print("== kernel wall times (CPU; interpret mode for Pallas) ==")
    for r in kernel_timing_rows():
        print(f"  {r['kernel']:28s} {r['us']:12.0f} us")


if __name__ == "__main__":
    main()
