"""Static-analysis benchmarks: analyzer + synthesizer + similarity.

Three sections:

* **analyzer** — cold-cache ``analyze_program`` over the full benchmark
  suite plus every ``tests/progen.py`` distribution (the same corpus the
  conformance gate walks), reporting programs/s.  The acceptance gate
  (ISSUE 9) asserts >= 1k programs/s *with caches cleared* — static
  admission must be invisible next to simulation cost, and the service
  runs it on every submit.
* **synthesizer** — cold-cache ``strip_annotations`` →
  ``synthesize_annotations`` round-trips over the same corpus, gating
  both throughput (>= 500 programs/s: repair-at-admission must stay
  cheap) and correctness (every round-trip bit-equal to the compiler's
  own annotation — the known FIG5 deviation excepted — and error-free
  under re-analysis).
* **similarity** — "find archived runs whose control flow resembles this
  program", both ways: ranking CFG fingerprints straight from the sidecar
  index (``ArchiveIndex.rank_similar``, nothing replayed, no archive file
  opened) versus the replay-based baseline (re-execute every archived run
  and Levenshtein-diff its trace against the query's).  The acceptance
  gate asserts the index path is >= 100x faster — what makes "search the
  fleet's archive for this pathology" interactive instead of a batch job.

Run:   PYTHONPATH=src python benchmarks/bench_analysis.py
CI:    PYTHONPATH=src python benchmarks/bench_analysis.py --smoke
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time
from pathlib import Path

from repro.analysis import analyze_program, fingerprint
from repro.analysis.fingerprint import _CACHE as _FP_CACHE
from repro.analysis.passes import _analyze_cached
from repro.archive import ArchiveIndex, ArchiveReader, request_from_meta
from repro.core import MachineConfig
from repro.core.programs import make_suite, spinlock_program
from repro.core.trace import levenshtein, trace_tokens
from repro.engine import RotatingJsonlSink, Simulator

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from tests.progen import corpus  # noqa: E402  (repo-root import, like tests)

GATE_PROGRAMS_PER_S = 1000.0     # acceptance: cold analyzer throughput
GATE_SYNTH_PROGRAMS_PER_S = 500.0   # acceptance: strip+synthesize round-trip
GATE_SIM_SPEEDUP = 100.0         # acceptance: sidecar rank vs replay+diff

# round-trips that are equivalent but deliberately not bit-equal: FIG5
# hand-forces B0 reuse + an R0 spill the allocator improves away
KNOWN_DEVIATIONS = {"FIG5"}


def _clear_caches() -> None:
    _analyze_cached.cache_clear()
    _FP_CACHE.clear()


def bench_analyzer(n_seeds: int, *, repeats: int = 3) -> None:
    cfg = MachineConfig(n_threads=8)
    progs = [(b.name, b.program, cfg) for b in make_suite(cfg)]
    progs += corpus(n_seeds)
    print(f"== analyzer: cold-cache analyze_program over "
          f"{len(progs)} programs (suite + progen x{n_seeds} seeds) ==")
    best = float("inf")
    n_diags = n_errors = 0
    for _ in range(repeats):
        _clear_caches()
        t0 = time.perf_counter()
        reports = [analyze_program(p, c, name=name) for name, p, c in progs]
        best = min(best, time.perf_counter() - t0)
        n_diags = sum(len(r.diagnostics) for r in reports)
        n_errors = sum(len(r.errors) for r in reports)
    rate = len(progs) / max(best, 1e-9)
    print(f"{'programs':>9} {'wall_s':>9} {'progs/s':>10} "
          f"{'diags':>6} {'errors':>7}")
    print(f"{len(progs):>9} {best:>9.3f} {rate:>10.0f} "
          f"{n_diags:>6} {n_errors:>7}")
    assert n_errors == 0, "conformance: suite + progen must be error-free"
    assert rate >= GATE_PROGRAMS_PER_S, (
        f"acceptance gate: cold analyzer must sustain "
        f">={GATE_PROGRAMS_PER_S:.0f} programs/s; measured {rate:.0f}")
    print(f"gate OK: >= {GATE_PROGRAMS_PER_S:.0f} programs/s cold "
          f"({rate:.0f}/s), zero errors")

    # warm path (the service's steady state: repeated signatures)
    t0 = time.perf_counter()
    for name, p, c in progs:
        analyze_program(p, c, name=name)
    t_warm = time.perf_counter() - t0
    print(f"warm (cached): {len(progs) / max(t_warm, 1e-9):.0f} progs/s")


def bench_synthesizer(n_seeds: int, *, repeats: int = 3) -> None:
    """Strip → synthesize over suite + every progen distribution.

    Throughput gate (>= 500 programs/s cold) plus the round-trip
    equivalence gate: every resynthesized program must be bit-equal to
    the structured compiler's annotation (KNOWN_DEVIATIONS excepted) and
    re-analyze with zero errors — the same contract the service's
    ``auto_annotate`` admission repair leans on.
    """
    import numpy as np

    from repro.analysis import (strip_annotations, synthesize_annotations,
                                verify_program)

    cfg = MachineConfig(n_threads=8)
    progs = [(b.name, b.program, cfg) for b in make_suite(cfg)]
    progs += corpus(n_seeds)
    print(f"\n== synthesizer: cold strip+synthesize round-trip over "
          f"{len(progs)} programs (suite + progen x{n_seeds} seeds) ==")
    best = float("inf")
    for _ in range(repeats):
        _clear_caches()
        t0 = time.perf_counter()
        results = [(name, p, c,
                    synthesize_annotations(strip_annotations(p, c).program,
                                           c))
                   for name, p, c in progs]
        best = min(best, time.perf_counter() - t0)
    rate = len(progs) / max(best, 1e-9)
    n_regions = sum(r.regions for _, _, _, r in results)
    n_yields = sum(r.yields for _, _, _, r in results)
    deviations = [name for name, p, c, r in results
                  if not np.array_equal(r.program, np.asarray(p))]
    for name, p, c, r in results:
        assert not verify_program(r.program, c).errors, name
    print(f"{'programs':>9} {'wall_s':>9} {'progs/s':>10} "
          f"{'regions':>8} {'yields':>7}")
    print(f"{len(progs):>9} {best:>9.3f} {rate:>10.0f} "
          f"{n_regions:>8} {n_yields:>7}")
    unexpected = [n for n in deviations
                  if n.split(":")[-1] not in KNOWN_DEVIATIONS]
    assert not unexpected, (
        f"acceptance gate: round-trip must be bit-equal outside "
        f"{sorted(KNOWN_DEVIATIONS)}; deviated: {unexpected}")
    # bit-equal programs are trivially trace-equivalent; the known
    # deviations must still prove it by execution (memory + status)
    sim = Simulator("hanoi")
    for name, p, c, r in results:
        if name not in deviations:
            continue
        ra = sim.run(p, c)
        rb = sim.run(r.program, c)
        assert ra.status == rb.status and np.array_equal(ra.mem, rb.mem), (
            f"{name}: deviating round-trip is not execution-equivalent")
    assert rate >= GATE_SYNTH_PROGRAMS_PER_S, (
        f"acceptance gate: cold strip+synthesize must sustain "
        f">={GATE_SYNTH_PROGRAMS_PER_S:.0f} programs/s; measured {rate:.0f}")
    print(f"gate OK: >= {GATE_SYNTH_PROGRAMS_PER_S:.0f} programs/s cold "
          f"({rate:.0f}/s), bit-equal outside {sorted(KNOWN_DEVIATIONS)}")


def bench_similarity(n_runs: int) -> None:
    """Sidecar fingerprint ranking vs replay-every-run-and-diff."""
    cfg = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
    suite = make_suite(cfg, datasets=1)
    sim = Simulator("hanoi")
    query = spinlock_program()
    print(f"\n== similarity: sidecar rank vs replay+diff "
          f"({n_runs} archived runs) ==")
    with tempfile.TemporaryDirectory() as tmp:
        sink = RotatingJsonlSink(tmp, max_bytes=1 << 22)
        for i in range(n_runs):
            sim.run(suite[i % len(suite)], cfg, sink=sink)
        sink.flush()
        sink.close()
        idx = ArchiveIndex.ensure(tmp)               # built once, off-path
        assert len(idx) == n_runs
        assert all(e.fp is not None for e in idx.entries)

        # index path: fingerprint the query, rank from the sidecar alone
        repeats = 10
        t0 = time.perf_counter()
        for _ in range(repeats):
            _clear_caches()                          # no free rides
            ranked = idx.rank_similar(fingerprint(query))
        t_index = (time.perf_counter() - t0) / repeats
        assert len(ranked) == n_runs

        # replay baseline: re-execute every archived run, Levenshtein its
        # trace against the query's (how you'd compare without fingerprints)
        q_tokens = trace_tokens(list(sim.run(query, cfg).trace))
        runs = ArchiveReader(tmp).runs()
        t0 = time.perf_counter()
        scored = []
        for run in runs:
            req = request_from_meta(run.meta)
            res = sim.run(req.program, req.cfg)
            dist = int(levenshtein(trace_tokens(list(res.trace)), q_tokens))
            scored.append((dist, run.meta.get("program", "")))
        t_replay = time.perf_counter() - t0
        scored.sort()

        speedup = t_replay / max(t_index, 1e-9)
        print(f"{'path':>12} {'wall_s':>10}")
        print(f"{'sidecar':>12} {t_index:>10.5f}")
        print(f"{'replay+diff':>12} {t_replay:>10.3f}")
        print(f"nearest by fingerprint: {ranked[0][0]} d={ranked[0][1]:.4f}; "
              f"nearest by replay: {scored[0][1]} lev={scored[0][0]}")
        print(f"speedup: {speedup:.0f}x")
        assert speedup >= GATE_SIM_SPEEDUP, (
            f"acceptance gate: sidecar similarity must be "
            f">={GATE_SIM_SPEEDUP:.0f}x replay-based comparison; "
            f"measured {speedup:.1f}x")
        print(f"gate OK: >= {GATE_SIM_SPEEDUP:.0f}x over replay")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (still enforces the >=1k programs/s "
                         "and >=100x gates)")
    args = ap.parse_args()
    if args.smoke:
        bench_analyzer(n_seeds=40, repeats=1)
        bench_synthesizer(n_seeds=40, repeats=2)
        bench_similarity(n_runs=120)
    else:
        bench_analyzer(n_seeds=120)
        bench_synthesizer(n_seeds=120)
        bench_similarity(n_runs=200)


if __name__ == "__main__":
    main()
