"""Simulation-service throughput sweep: batch size x request mix x mechanism.

Three arms per cell, all producing identical results (the service test
suite asserts that); what differs is dispatch:

* ``loop``    — the pre-service baseline: one ``Simulator.run`` per request;
* ``batch``   — the planner path: one ``Simulator.run_batch`` call
  (signature grouping, native vmap for homogeneous JAX groups);
* ``service`` — the full queue: admission -> coalescer -> worker pool.

Headline effects to look for:

* on the **homogeneous hanoi_jax sweep** the coalesced arms beat the
  per-request loop and the gap widens with batch size (one vmap executable
  amortizes dispatch across the whole group) — the ISSUE 3 acceptance
  criterion;
* on the **mixed sweep** the service still routes each homogeneous
  sub-group natively; the numpy remainder bounds the speedup (GIL-bound
  reference interpreters);
* service-over-batch overhead (queue + ticket hops) stays small and fixed,
  i.e. it amortizes to noise at production batch sizes.

Run:   PYTHONPATH=src python benchmarks/bench_service.py
CI:    PYTHONPATH=src python benchmarks/bench_service.py --smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import MachineConfig
from repro.core.programs import make_suite
from repro.engine import SimRequest, Simulator
from repro.service import SimulationService

CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
BATCH_SIZES = (4, 16, 64)
MIXES = {
    "hanoi_jax": ("hanoi_jax",),                      # homogeneous, native
    "hanoi": ("hanoi",),                              # homogeneous, numpy
    "mixed": ("hanoi_jax", "hanoi", "simt_stack"),    # round-robin mix
}


def _requests(n: int, benches, seed: int = 0, *,
              rotate: bool = False) -> list[SimRequest]:
    """``n`` requests over fresh memory images.

    The homogeneous sweeps replicate ONE kernel over many datasets (the
    service's target traffic shape — the batched while_loop runs all warps
    in lockstep until the slowest halts, so same-program batches waste no
    work); ``rotate=True`` cycles programs for the mixed sweep.
    """
    rng = np.random.default_rng(seed)
    return [SimRequest(program=benches[i % len(benches)].program
                       if rotate else benches[0].program, cfg=CFG,
                       init_mem=rng.integers(0, 8, size=CFG.mem_size)
                       .astype(np.int32),
                       record_trace=False, name=f"req{i}")
            for i in range(n)]


def _time(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def sweep_rows(batch_sizes=BATCH_SIZES, mixes=MIXES, *, workers: int = 2,
               repeats: int = 3) -> list[dict]:
    benches = [b for b in make_suite(CFG, datasets=1)
               if b.name in ("HOTS0", "GAUS0", "RBFS0", "DIAMOND")]
    sim = Simulator("hanoi")
    rows = []
    for mix_name, mechs in mixes.items():
        for n in batch_sizes:
            reqs = _requests(n, benches, rotate=len(mechs) > 1)
            assign = [mechs[i % len(mechs)] for i in range(n)]

            def loop_arm():
                return [sim.run(r, mechanism=m)
                        for r, m in zip(reqs, assign)]

            def batch_arm():
                out = []
                for mech in mechs:        # one run_batch per mechanism lane
                    sub = [r for r, m in zip(reqs, assign) if m == mech]
                    out.extend(sim.run_batch(sub, mechanism=mech))
                return out

            def service_arm():
                with SimulationService(default_mechanism=mechs[0],
                                       max_batch=n, max_wait_s=0.05,
                                       workers=workers,
                                       annotate=False) as svc:
                    tickets = [svc.submit(r, mechanism=m)
                               for r, m in zip(reqs, assign)]
                    svc.flush()
                    return [t.result() for t in tickets]

            loop_arm(); batch_arm(); service_arm()        # warm-up/compile
            t_loop = _time(loop_arm, repeats)
            t_batch = _time(batch_arm, repeats)
            t_service = _time(service_arm, repeats)
            rows.append({
                "mix": mix_name, "batch": n,
                "loop_warps_s": n / t_loop,
                "batch_warps_s": n / t_batch,
                "service_warps_s": n / t_service,
                "coalesced_speedup": t_loop / t_service,
            })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sweep (one batch size per mix)")
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args()
    # best-of-3 even in smoke mode: JAX's background threads occasionally
    # stall Python thread wakeups ~300ms on small containers, and a single
    # repeat can land entirely inside one such stall
    sizes = (16,) if args.smoke else BATCH_SIZES
    repeats = 3
    rows = sweep_rows(batch_sizes=sizes, workers=args.workers,
                      repeats=repeats)
    hdr = ("mix", "batch", "loop_warps_s", "batch_warps_s",
           "service_warps_s", "coalesced_speedup")
    print(",".join(hdr))
    for r in rows:
        print(",".join(f"{r[k]:.1f}" if isinstance(r[k], float) else str(r[k])
                       for k in hdr))
    homog = [r for r in rows if r["mix"] == "hanoi_jax"]
    print(f"\n== homogeneous hanoi_jax: coalesced vs per-request loop ==")
    for r in homog:
        print(f"  batch {r['batch']:3d}: service {r['service_warps_s']:8.1f} "
              f"warps/s vs loop {r['loop_warps_s']:8.1f} "
              f"({r['coalesced_speedup']:.2f}x)")
    # the acceptance gate sits at the largest batch size: coalescing is a
    # batch-amortization play (at batch 4 there is nothing to coalesce and
    # queue overhead shows); the speedup must be >= 1 where batching is in
    # play and should grow with batch size
    at_scale = max(homog, key=lambda r: r["batch"])
    status = "OK" if at_scale["coalesced_speedup"] >= 1.0 else "BELOW PAR"
    print(f"  at batch {at_scale['batch']}: "
          f"{at_scale['coalesced_speedup']:.2f}x -> {status} "
          f"(acceptance: coalesced >= per-request loop)")


if __name__ == "__main__":
    main()
