"""Simulation-service throughput sweep: batch size x request mix x mechanism.

Three arms per cell, all producing identical results (the service test
suite asserts that); what differs is dispatch:

* ``loop``    — the pre-service baseline: one ``Simulator.run`` per request;
* ``batch``   — the planner path: one ``Simulator.run_batch`` call
  (signature grouping, native vmap for homogeneous JAX groups);
* ``service`` — the full queue: admission -> coalescer -> worker pool.

Headline effects to look for:

* on the **homogeneous hanoi_jax sweep** the coalesced arms beat the
  per-request loop and the gap widens with batch size (one vmap executable
  amortizes dispatch across the whole group) — the ISSUE 3 acceptance
  criterion;
* on the **mixed sweep** the service still routes each homogeneous
  sub-group natively; the numpy remainder bounds the speedup (GIL-bound
  reference interpreters);
* service-over-batch overhead (queue + ticket hops) stays small and fixed,
  i.e. it amortizes to noise at production batch sizes.

The ``--procs`` sweep adds the process-backed execution tier (ISSUE 8):
the same numpy-heavy traffic through 1..N shard processes.  Numpy
mechanisms serialize behind the GIL, so the thread pool cannot scale them
— the proc tier chunks homogeneous numpy groups across shards and must
deliver real scaling.  ``--smoke --procs 2`` enforces two hard gates
(exit 1 on failure):

* **scaling** — the numpy mix at 2 procs sustains >= 1.5x the warps/s of
  1 proc (request work dwarfs pickle + queue overhead).  Enforced only
  when the host exposes >= 2 CPUs to this process — two shard processes
  pinned to one core cannot scale, so a 1-CPU runner reports the sweep
  and marks the gate SKIPPED rather than failing on missing hardware;
* **warm start** — a restarted ``warm_start=`` service admits traffic
  with zero serve-time re-traces, proven by the service's own cache
  counters (``cache_misses == warm_retraced``, and ``== 0`` outright
  when the jaxlib supports executable serialization).

Run:   PYTHONPATH=src python benchmarks/bench_service.py
       PYTHONPATH=src python benchmarks/bench_service.py --procs 2
CI:    PYTHONPATH=src python benchmarks/bench_service.py --smoke --procs 2
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from repro.core import MachineConfig
from repro.core.programs import make_suite
from repro.engine import SimRequest, Simulator
from repro.service import SimulationService

CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
BATCH_SIZES = (4, 16, 64)
MIXES = {
    "hanoi_jax": ("hanoi_jax",),                      # homogeneous, native
    "hanoi": ("hanoi",),                              # homogeneous, numpy
    "mixed": ("hanoi_jax", "hanoi", "simt_stack"),    # round-robin mix
}


def _requests(n: int, benches, seed: int = 0, *,
              rotate: bool = False) -> list[SimRequest]:
    """``n`` requests over fresh memory images.

    The homogeneous sweeps replicate ONE kernel over many datasets (the
    service's target traffic shape — the batched while_loop runs all warps
    in lockstep until the slowest halts, so same-program batches waste no
    work); ``rotate=True`` cycles programs for the mixed sweep.
    """
    rng = np.random.default_rng(seed)
    return [SimRequest(program=benches[i % len(benches)].program
                       if rotate else benches[0].program, cfg=CFG,
                       init_mem=rng.integers(0, 8, size=CFG.mem_size)
                       .astype(np.int32),
                       record_trace=False, name=f"req{i}")
            for i in range(n)]


def _time(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def sweep_rows(batch_sizes=BATCH_SIZES, mixes=MIXES, *, workers: int = 2,
               repeats: int = 3) -> list[dict]:
    benches = [b for b in make_suite(CFG, datasets=1)
               if b.name in ("HOTS0", "GAUS0", "RBFS0", "DIAMOND")]
    sim = Simulator("hanoi")
    rows = []
    for mix_name, mechs in mixes.items():
        for n in batch_sizes:
            reqs = _requests(n, benches, rotate=len(mechs) > 1)
            assign = [mechs[i % len(mechs)] for i in range(n)]

            def loop_arm():
                return [sim.run(r, mechanism=m)
                        for r, m in zip(reqs, assign)]

            def batch_arm():
                out = []
                for mech in mechs:        # one run_batch per mechanism lane
                    sub = [r for r, m in zip(reqs, assign) if m == mech]
                    out.extend(sim.run_batch(sub, mechanism=mech))
                return out

            def service_arm():
                with SimulationService(default_mechanism=mechs[0],
                                       max_batch=n, max_wait_s=0.05,
                                       workers=workers,
                                       annotate=False) as svc:
                    tickets = [svc.submit(r, mechanism=m)
                               for r, m in zip(reqs, assign)]
                    svc.flush()
                    return [t.result() for t in tickets]

            loop_arm(); batch_arm(); service_arm()        # warm-up/compile
            t_loop = _time(loop_arm, repeats)
            t_batch = _time(batch_arm, repeats)
            t_service = _time(service_arm, repeats)
            rows.append({
                "mix": mix_name, "batch": n,
                "loop_warps_s": n / t_loop,
                "batch_warps_s": n / t_batch,
                "service_warps_s": n / t_service,
                "coalesced_speedup": t_loop / t_service,
            })
    return rows


def _available_cpus() -> int:
    """CPUs this process may actually run on (affinity-aware)."""
    import os
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:                       # non-Linux fallback
        return os.cpu_count() or 1


def proc_scaling_rows(procs_list=(1, 2), n: int = 64,
                      repeats: int = 3) -> list[dict]:
    """Numpy-mix throughput through the process tier, per shard count.

    The workload is the suite's heaviest numpy kernel (LUD0, ~4.4 ms per
    request at this config) replicated over fresh memory images, so the
    per-request interpreter work dwarfs the pickle + queue overhead the
    spawn boundary adds — that is what makes the >= 1.5x gate fair.  The
    service is started once per shard count; only ``svc.run`` is timed.
    """
    benches = [b for b in make_suite(CFG, datasets=1) if b.name == "LUD0"]
    reqs = _requests(n, benches)
    rows = []
    for procs in procs_list:
        with SimulationService(default_mechanism="hanoi", procs=procs,
                               max_batch=n, max_wait_s=0.05,
                               annotate=False) as svc:
            svc.run(reqs, timeout=300)                      # warm-up
            t = _time(lambda: svc.run(reqs, timeout=300), repeats)
            st = svc.stats()
        rows.append({"procs": procs, "batch": n, "warps_s": n / t,
                     "scaling": (n / t) / rows[0]["warps_s"] if rows
                     else 1.0,
                     "shards_used": sum(1 for s in st.shards
                                        if s.completed > 0)})
    return rows


def warm_start_report(n: int = 8) -> dict:
    """Cold-serve then restart-warm-serve one hot hanoi_jax signature.

    Returns the counters the zero-re-trace gate is judged on: the second
    (restarted, warm-started) service must admit and serve the same
    traffic shape without a single serve-time XLA trace.
    """
    from repro.engine.compile_cache import supports_serialization
    cache_dir = tempfile.mkdtemp(prefix="repro-warm-bench-")
    benches = [b for b in make_suite(CFG, datasets=1) if b.name == "GAUS0"]
    reqs = _requests(n, benches)
    with SimulationService(default_mechanism="hanoi_jax", procs=1,
                           warm_start=cache_dir, max_batch=n,
                           annotate=False) as svc:
        t0 = time.perf_counter()
        cold = svc.run(reqs, timeout=600)
        cold_s = time.perf_counter() - t0
        st1 = svc.stats()
    with SimulationService(default_mechanism="hanoi_jax", procs=1,
                           warm_start=cache_dir, max_batch=n,
                           annotate=False) as svc:
        t0 = time.perf_counter()
        warm = svc.run(reqs, timeout=600)
        warm_s = time.perf_counter() - t0
        st2 = svc.stats()
    serializable = supports_serialization()
    zero_retrace = st2.cache_misses == st2.warm_retraced
    if serializable:
        zero_retrace = zero_retrace and st2.cache_misses == 0 \
            and st2.warm_loaded >= 1
    return {"cold_s": cold_s, "warm_s": warm_s,
            "cold_ok": sum(r.ok for r in cold),
            "warm_ok": sum(r.ok for r in warm),
            "cold_misses": st1.cache_misses,
            "warm_signatures": st2.warm_signatures,
            "warm_loaded": st2.warm_loaded,
            "warm_retraced": st2.warm_retraced,
            "serve_misses": st2.cache_misses,
            "serializable": serializable,
            "zero_retrace": zero_retrace}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sweep (one batch size per mix); with "
                         "--procs, enforces the scaling + warm-start gates")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--procs", type=int, default=0,
                    help="also sweep the process tier at 1..N shard "
                         "processes on the numpy mix")
    args = ap.parse_args()
    # best-of-3 even in smoke mode: JAX's background threads occasionally
    # stall Python thread wakeups ~300ms on small containers, and a single
    # repeat can land entirely inside one such stall
    sizes = (16,) if args.smoke else BATCH_SIZES
    repeats = 3
    rows = sweep_rows(batch_sizes=sizes, workers=args.workers,
                      repeats=repeats)
    hdr = ("mix", "batch", "loop_warps_s", "batch_warps_s",
           "service_warps_s", "coalesced_speedup")
    print(",".join(hdr))
    for r in rows:
        print(",".join(f"{r[k]:.1f}" if isinstance(r[k], float) else str(r[k])
                       for k in hdr))
    homog = [r for r in rows if r["mix"] == "hanoi_jax"]
    print(f"\n== homogeneous hanoi_jax: coalesced vs per-request loop ==")
    for r in homog:
        print(f"  batch {r['batch']:3d}: service {r['service_warps_s']:8.1f} "
              f"warps/s vs loop {r['loop_warps_s']:8.1f} "
              f"({r['coalesced_speedup']:.2f}x)")
    # the acceptance gate sits at the largest batch size: coalescing is a
    # batch-amortization play (at batch 4 there is nothing to coalesce and
    # queue overhead shows); the speedup must be >= 1 where batching is in
    # play and should grow with batch size
    at_scale = max(homog, key=lambda r: r["batch"])
    status = "OK" if at_scale["coalesced_speedup"] >= 1.0 else "BELOW PAR"
    print(f"  at batch {at_scale['batch']}: "
          f"{at_scale['coalesced_speedup']:.2f}x -> {status} "
          f"(acceptance: coalesced >= per-request loop)")

    if not args.procs:
        return
    failures = []

    print(f"\n== process tier: numpy mix (LUD0 x64) across shard "
          f"processes ==")
    prows = proc_scaling_rows(procs_list=tuple(range(1, args.procs + 1)),
                              repeats=repeats)
    for r in prows:
        print(f"  procs {r['procs']}: {r['warps_s']:8.1f} warps/s "
              f"({r['scaling']:.2f}x vs 1 proc, "
              f"{r['shards_used']} shard(s) serving)")
    if args.procs >= 2:
        two = next(r for r in prows if r["procs"] == 2)
        cpus = _available_cpus()
        if cpus < 2:
            print(f"  gate: 2-proc scaling {two['scaling']:.2f}x — "
                  f"SKIPPED ({cpus} CPU visible; two shard processes "
                  f"cannot scale on one core)")
        else:
            gate = two["scaling"] >= 1.5
            print(f"  gate: 2-proc scaling {two['scaling']:.2f}x >= "
                  f"1.50x -> {'OK' if gate else 'FAIL'}")
            if not gate:
                failures.append(
                    f"proc scaling {two['scaling']:.2f}x < 1.5x")

    print(f"\n== warm start: restarted service, hot hanoi_jax "
          f"signature ==")
    w = warm_start_report()
    print(f"  cold serve: {w['cold_s']:.2f}s ({w['cold_ok']} ok, "
          f"{w['cold_misses']} trace(s))")
    print(f"  warm serve: {w['warm_s']:.2f}s ({w['warm_ok']} ok) — "
          f"manifest {w['warm_signatures']} sig(s), "
          f"{w['warm_loaded']} deserialized + {w['warm_retraced']} "
          f"re-traced at warm time, {w['serve_misses']} serve-time "
          f"trace(s), serializable={w['serializable']}")
    print(f"  gate: zero serve-time re-trace -> "
          f"{'OK' if w['zero_retrace'] else 'FAIL'}")
    if not w["zero_retrace"]:
        failures.append("warm-start restart re-traced at serve time")

    if args.smoke and failures:
        raise SystemExit("bench gates FAILED: " + "; ".join(failures))


if __name__ == "__main__":
    main()
