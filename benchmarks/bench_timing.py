"""Cycle-engine benchmarks: event throughput + the Fig 10 IPC-delta smoke.

Two sections:

* **throughput** — ``repro.timing.schedule_cycle`` over multi-warp,
  memory-heavy warp sets (the progen ``mem_features`` distribution),
  reporting issue slots/s and completion events/s through the event queue
  for every mode that changes the hot loop: trace-conservative,
  scoreboard, dual-issue, and a sampled memory distribution.  The
  acceptance gate asserts a floor on events/s — the cycle engine is pure
  Python and the Fig 10 sweep re-prices every (program, mechanism)
  schedule, so a regression here multiplies straight into evaluation
  wall-time.
* **fig10** — ``Simulator.compare(..., timing="cycle")`` hanoi vs
  simt_stack over a suite slice: the paper's IPC-delta evaluation on the
  cycle engine.  Gates: every delta finite, self-comparison exactly 0.0,
  and every per-schedule result partitions its cycles into
  busy + scoreboard-stall + memory-stall.

A quick differential spot-check (unit-latency cycle engine ==
``schedule_traces_reference`` bit-for-bit) runs in both modes — the full
gate lives in ``tests/test_timing.py``.

Run:   PYTHONPATH=src python benchmarks/bench_timing.py
CI:    PYTHONPATH=src python benchmarks/bench_timing.py --smoke
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import MachineConfig
from repro.core.programs import make_suite
from repro.core.timing import TimingConfig, schedule_traces_reference
from repro.engine import Simulator
from repro.timing import CycleConfig, schedule_cycle

GATE_EVENTS_PER_S = 20_000     # floor on completion events/s (pure Python)

CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=20_000)
FIG10_BENCHES = ("HOTS0", "GAUS0", "DIAMOND", "BFSD")


def _warp_sets(n_warps: int, n_sets: int):
    """Memory-heavy multi-warp sets from the progen distribution."""
    sys.path.insert(0, "tests")
    from progen import make_program
    sim = Simulator("simt_stack")
    sets = []
    seed = 0
    while len(sets) < n_sets:
        out, cfg = make_program(seed, 8, mem_features=True)
        seed += 1
        if out is None:
            continue
        prog, mem = out
        res = sim.run(prog, cfg, init_mem=mem)
        trace = list(res.trace)
        sets.append(([trace] * n_warps, [np.asarray(prog)] * n_warps))
    return sets


def bench_throughput(*, n_warps: int = 8, n_sets: int = 6,
                     repeats: int = 3) -> None:
    sets = _warp_sets(n_warps, n_sets)
    modes = [
        ("trace", CycleConfig(scoreboard=False)),
        ("scoreboard", CycleConfig(scoreboard=True)),
        ("dual_issue", CycleConfig(scoreboard=True, issue_width=2)),
        ("bimodal_mem", CycleConfig(scoreboard=True, memory_model="bimodal",
                                    seed=7)),
    ]
    print(f"== schedule_cycle throughput ({n_warps} warps x {n_sets} "
          f"sets) ==")
    print(f"{'mode':>12} {'slots':>8} {'cycles':>8} {'sched_s':>9} "
          f"{'slots/s':>10} {'events/s':>10}")
    worst = float("inf")
    for name, ccfg in modes:
        slots = cycles = 0
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            slots = cycles = 0
            for traces, progs in sets:
                res = schedule_cycle(traces, progs, "greedy_then_oldest",
                                     ccfg)
                slots += res.issues
                cycles += res.cycles
            best = min(best, time.perf_counter() - t0)
        # every issued slot pushes exactly one completion event; idle
        # fast-forwards pop (and may discard) them — slots/s is the
        # conservative events/s proxy
        rate = slots / max(best, 1e-9)
        worst = min(worst, rate)
        print(f"{name:>12} {slots:>8} {cycles:>8} {best:>9.4f} "
              f"{rate:>10.0f} {rate:>10.0f}")
    assert worst >= GATE_EVENTS_PER_S, (
        f"cycle-engine throughput regressed: {worst:.0f} events/s < gate "
        f"{GATE_EVENTS_PER_S}")
    print(f"[gate] min {worst:.0f} events/s >= {GATE_EVENTS_PER_S} OK")


def bench_fig10(*, benches=FIG10_BENCHES) -> None:
    sim = Simulator("hanoi")
    suite = [b for b in make_suite(CFG, datasets=1) if b.name in benches]
    t0 = time.perf_counter()
    rep = sim.compare(["hanoi", "simt_stack"], suite, CFG, timing="cycle")
    dt = time.perf_counter() - t0
    print(f"== Fig 10 (cycle engine): hanoi vs simt_stack "
          f"({dt:.2f}s) ==")
    print(f"{'bench':>10} {'disc%':>7} {'ipc_delta%':>11} "
          f"{'hanoi_ipc':>10} {'stack_ipc':>10}")
    for row in rep.rows:
        if row.mech_b != "simt_stack" or row.mech_a != "hanoi":
            continue
        ta = rep.timing_results[(row.program, "hanoi")]
        tb = rep.timing_results[(row.program, "simt_stack")]
        print(f"{row.program:>10} {100 * row.discrepancy:>7.2f} "
              f"{row.ipc_delta_pct:>11.2f} {ta.ipc:>10.3f} "
              f"{tb.ipc:>10.3f}")
    assert rep.rows, "compare produced no rows"
    assert all(np.isfinite(r.ipc_delta) for r in rep.rows)
    for tres in rep.timing_results.values():
        assert tres.cycles == (tres.busy_cycles
                               + tres.scoreboard_stall_cycles
                               + tres.memory_stall_cycles), tres
    self_rep = sim.compare(["hanoi"], suite, CFG,
                           pairs=[("hanoi", "hanoi")], timing="cycle")
    assert all(r.ipc_delta == 0.0 for r in self_rep.rows)
    print("[gate] deltas finite, self-delta 0.0, stall partition OK")


def differential_spot_check(*, n_sets: int = 3) -> None:
    sets = _warp_sets(3, n_sets)
    for traces, progs in sets:
        ops = [p[:, 0] for p in progs]
        for policy in ("greedy_then_oldest", "round_robin"):
            ref = schedule_traces_reference(traces, ops, policy,
                                            TimingConfig())
            res = schedule_cycle(traces, progs, policy,
                                 CycleConfig.from_timing(TimingConfig()))
            assert (res.order, res.cycles, res.thread_instructions) == ref, \
                f"cycle engine drifted from reference under {policy}"
    print(f"[gate] unit-latency == reference over {n_sets} warp sets OK")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run with the same gates (CI)")
    args = ap.parse_args(argv)
    if args.smoke:
        differential_spot_check(n_sets=2)
        bench_throughput(n_warps=4, n_sets=3, repeats=1)
        bench_fig10(benches=("HOTS0", "DIAMOND"))
    else:
        differential_spot_check()
        bench_throughput()
        bench_fig10()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
