"""SS Perf hypothesis->change->measure loop over the three chosen cells.

Cells (chosen per the brief from the baseline roofline table):
* internlm2-20b x train_4k   — worst roofline fraction & most collective-
                               bound dense-train cell (auto-fit mb=16 makes
                               weight re-gathers dominate);
* mixtral-8x7b  x train_4k   — MoE train, collective + memory bound;
* hubert-xlarge x prefill_32k — memory-bound, and the cell most
                               representative of the paper's technique (the
                               divergence-aware attention tiling).

Variants are cumulative hypothesis steps; each records the three roofline
terms so EXPERIMENTS.md SS Perf can show before/after per hypothesis.

Must run in a fresh process:
    PYTHONPATH=src python -m benchmarks.perf_iter [--out results/perf.json]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

# (cell, variant-name, build_cell kwargs, hypothesis text)
PLAN = [
    # ---------------- internlm2-20b x train_4k -----------------------------
    ("internlm2-20b", "train_4k", "V1_zero1",
     dict(param_mode="zero1", microbatches=16),
     "ZeRO-1 bf16 compute params (TP-only, data-replicated) remove the "
     "per-use FSDP weight all-gathers. REFUTED: collectives unchanged — "
     "the dominant traffic is the SP activation all-gather x microbatches, "
     "not weight gathers."),
    ("internlm2-20b", "train_4k", "V5_zero1_chunked_mb8",
     dict(param_mode="zero1", attn_impl="chunked", microbatches=8),
     "Chunked attention removes the O(S^2) buffers so mb can drop 16->8; "
     "SP all-gather traffic halves (the bf16-wire reduce-scatter fix for "
     "the f32 grad materialization bug is part of this step)."),
    ("internlm2-20b", "train_4k", "V6_zero1_chunked_mb4",
     dict(param_mode="zero1", attn_impl="chunked", microbatches=4),
     "mb=4 halves SP traffic again (49.8s) but measures 16.1 GiB — just "
     "over HBM; blocked on f32 scan-carry copies (checkpoint+scan "
     "artifact), recorded as the next-step boundary."),
    # ---------------- mixtral-8x7b x train_4k ------------------------------
    ("mixtral-8x7b", "train_4k", "V1_zero1",
     dict(param_mode="zero1", microbatches=8),
     "Weight-gather elimination for the 47B MoE. REFUTED: replicated bf16 "
     "params (5.8G) + grad buffer (5.8G) blow HBM; auto-fit escalates mb "
     "and SP traffic grows — ZeRO-1 needs params/TP to fit."),
    ("mixtral-8x7b", "train_4k", "V4_fsdp_chunked_mb2",
     dict(attn_impl="chunked", microbatches=2),
     "Keep FSDP, shrink activations with chunked attention to cut mb. "
     "PARTIAL: auto-fit lands at mb=4; temp 12.5->9.9G, collectives flat "
     "(the expert-combine all-reduce dominates, not scores)."),
    # ---------------- hubert-xlarge x prefill_32k --------------------------
    ("hubert-xlarge", "prefill_32k", "V1_chunked",
     dict(attn_impl="chunked"),
     "Chunked attention: no 32k x 32k materialization. CONFIRMED: temp "
     "16.4 -> 0.8 GiB (20x); bidirectional = all tiles FULL so FLOPs "
     "unchanged, exactly the tile-census prediction."),
    # ---------------- bonus cells ------------------------------------------
    ("internlm2-20b", "decode_32k", "V1_no_fsdp",
     dict(fsdp=False),
     "Keep bf16 weights TP-resident for decode. MOSTLY REFUTED: collective "
     "2159 -> 2062 ms; decode collectives are KV/activation resharding."),
    ("rwkv6-3b", "train_4k", "V1_unroll8",
     dict(rwkv_unroll=8),
     "The naive per-token wkv scan round-trips the [hd,hd] state through "
     "HBM every token (memory term ~2500s); 8-token scan bodies amortize "
     "it — the XLA analogue of the VMEM-resident Pallas rwkv6 kernel. "
     "CONFIRMED: 2516 -> 711s."),
    ("rwkv6-3b", "train_4k", "V2_unroll32",
     dict(rwkv_unroll=32),
     "Unroll 32. CONFIRMED with diminishing returns: 711 -> 314s (r/k/v/w "
     "streaming starts to dominate)."),
    ("rwkv6-3b", "train_4k", "V3_chunked_matmul",
     dict(rwkv_impl="chunked"),
     "Chunked-parallel wkv (state term + strict-lower-triangular pairwise "
     "matmul + diagonal bonus, log-space decays): state HBM traffic / 64 "
     "and the recurrence becomes MXU work. CONFIRMED: memory 2516 -> 23.3s "
     "(108x), temp 10.5 -> 6.8G, compute +36%."),
    ("internlm2-20b", "prefill_32k", "V1_chunked",
     dict(attn_impl="chunked"),
     "CONFIRMED (fit): temp 53.1 -> 6.6 GiB; bytes flat (causal chunking "
     "keeps FULL tiles)."),
    ("mixtral-8x7b", "prefill_32k", "V1_chunked",
     dict(attn_impl="chunked"),
     "CONFIRMED: SWA EMPTY-band skipping is REAL FLOP reduction (compute "
     "1.00 -> 0.70s, memory 11.1 -> 4.7s, temp 38.4 -> 8.9G) — the Hanoi "
     "path-never-scheduled saving at MXU granularity."),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/perf.json")
    ap.add_argument("--only", help="substring filter on variant name")
    args = ap.parse_args()

    import jax
    from repro.launch.dryrun import run_cell

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["variant"]) for r in results}

    for arch, shape, variant, kw, hypothesis in PLAN:
        if (arch, shape, variant) in done:
            continue
        if args.only and args.only not in variant:
            continue
        print(f"[perf] {arch} x {shape} :: {variant}", flush=True)
        try:
            rec = run_cell(arch, shape, False, **kw)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
        rec["variant"] = variant
        rec["kwargs"] = {k: str(v) for k, v in kw.items()}
        rec["hypothesis"] = hypothesis
        results.append(rec)
        json.dump(results, open(args.out, "w"), indent=1)
        jax.clear_caches()
    print(f"[perf] wrote {args.out}")


if __name__ == "__main__":
    main()
