"""Property-based validation of the control-flow machinery.

Random structured programs (If/While nests with data-dependent conditions,
BREAK early exits, Bx spilling pressure) are lowered by the compiler pass and
must satisfy, on every machine:

* Hanoi == per-thread scalar reference on all architectural state
  (the paper's correctness criterion);
* pre-Volta SIMT-Stack == reference too (these programs are deadlock-free);
* the Turing-oracle heuristic (skip ALL BSYNCs) still produces correct
  architectural results — reconvergence is a performance feature, not a
  correctness one, for race-free programs;
* trace invariants: non-empty masks, no lane in two paths at once.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import compile_structured, run_reference
from repro.core.isa import Op
from repro.core.structured import If, Raw, Seq, While
from repro.engine import Simulator
# program generator shared with test_hanoi_jax (and importable without
# hypothesis); names re-exported here for backwards compatibility
from tests.progen import (BASE_CFG, CHECK_REGS, MEM, W, _node,  # noqa: F401
                          make_program)

# every mechanism under test runs through the canonical engine façade (the
# interp.run_* entry points are deprecated shims)
SIM = Simulator("hanoi")


@settings(max_examples=120, deadline=None)
@given(seed=st.integers(0, 10_000), n_bx=st.sampled_from([1, 2, 8]))
def test_hanoi_matches_scalar_reference(seed, n_bx):
    built, cfg = make_program(seed, n_bx)
    if built is None:
        return
    prog, mem = built
    h = SIM.run(prog, cfg, init_mem=mem)
    assert not h.deadlocked, "structured programs must not deadlock"
    assert h.error is None
    ref = run_reference(prog, cfg, init_mem=mem)
    np.testing.assert_array_equal(h.regs[:, CHECK_REGS], ref.regs[:, CHECK_REGS])
    np.testing.assert_array_equal(h.mem, ref.mem)
    assert h.finished == cfg.full_mask


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_simt_stack_matches_reference(seed):
    built, cfg = make_program(seed, 8)
    if built is None:
        return
    prog, mem = built
    s = SIM.run(prog, cfg, init_mem=mem, mechanism="simt_stack")
    assert not s.deadlocked
    ref = run_reference(prog, cfg, init_mem=mem)
    np.testing.assert_array_equal(s.regs[:, CHECK_REGS], ref.regs[:, CHECK_REGS])
    np.testing.assert_array_equal(s.mem, ref.mem)


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_oracle_skip_heuristic_is_correctness_preserving(seed):
    """Skipping reconvergence (the hardware heuristic, SS IX) may change the
    schedule but never architectural results on race-free programs.

    The heuristic is only sound where the skipping threads cannot race into
    a region that reuses the Bx register (the paper observes it 'in some rare
    occasions' only) — i.e. a trailing loop region, the BFSD shape.  We
    generate exactly that shape and skip the loop's own BSYNC.
    """
    rng = np.random.default_rng(seed)
    cfg = BASE_CFG
    ast = Seq([Raw(["LANEID R1", "MOVR R2, R1", "MOV R8, 0"]),
               While(cond=[f"ISETP.LT P0, R8, {int(rng.integers(1, 5))}"],
                     pred=0,
                     body=Seq([Raw(["IADDI R8, R8, 1"]),
                               _node(rng, 1, 1)]))])
    try:
        prog = compile_structured(ast, cfg)
    except ValueError:       # break-while nested in the loop: rejected shape
        return
    mem = rng.integers(0, 8, size=MEM).astype(np.int32)
    last_bsync = max(pc for pc in range(prog.shape[0])
                     if prog[pc, 0] == Op.BSYNC)
    o = SIM.run(prog, cfg, init_mem=mem, mechanism="turing_oracle",
                bsync_skip_pcs=(last_bsync,))
    assert not o.deadlocked
    ref = run_reference(prog, cfg, init_mem=mem)
    np.testing.assert_array_equal(o.regs[:, CHECK_REGS], ref.regs[:, CHECK_REGS])
    np.testing.assert_array_equal(o.mem, ref.mem)


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_trace_invariants(seed):
    built, cfg = make_program(seed, 2)
    if built is None:
        return
    prog, mem = built
    h = SIM.run(prog, cfg, init_mem=mem)
    L = prog.shape[0]
    for pc, m in h.trace:
        assert 0 <= pc < L
        assert 0 < m <= cfg.full_mask, "issued with an empty mask"
    # every thread must issue the final EXIT exactly once (possibly in
    # different subsets); count per-lane EXIT issues
    exits = np.zeros(W, np.int64)
    for pc, m in h.trace:
        if prog[pc, 0] == Op.EXIT:
            for t in range(W):
                if m >> t & 1:
                    exits[t] += 1
    np.testing.assert_array_equal(exits, np.ones(W, np.int64))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_path_priority_is_correctness_neutral(seed):
    """The paper: 'correct execution does not depend on which path is
    prioritized' (SS VI-A) — flip majority-first off and results must hold."""
    built, cfg = make_program(seed, 8)
    if built is None:
        return
    prog, mem = built
    a = SIM.run(prog, cfg, init_mem=mem, majority_first=True)
    b = SIM.run(prog, cfg, init_mem=mem, majority_first=False)
    assert not a.deadlocked and not b.deadlocked
    np.testing.assert_array_equal(a.regs[:, CHECK_REGS], b.regs[:, CHECK_REGS])
    np.testing.assert_array_equal(a.mem, b.mem)


@settings(max_examples=200, deadline=None)
@given(a=st.lists(st.integers(0, 9), max_size=48),
       b=st.lists(st.integers(0, 9), max_size=48))
def test_levenshtein_myers_equals_dp(a, b):
    """The Myers bit-parallel edit distance (what archive replay runs at
    fleet scale) must agree exactly with the classic DP oracle."""
    from repro.core.trace import levenshtein, levenshtein_dp
    ta = np.asarray(a, dtype=np.int64)
    tb = np.asarray(b, dtype=np.int64)
    d = levenshtein(ta, tb)
    assert d == levenshtein_dp(ta, tb)
    assert d == levenshtein(tb, ta)                 # metric symmetry
    assert (d == 0) == (list(a) == list(b))
