"""Optimizer + sharding-spec unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.configs import ARCH_NAMES, get_config
from jax.sharding import AbstractMesh


def make_spec_mesh():
    # the rule engine only reads shape/axis_names: an AbstractMesh works
    # in the single-device test process
    return AbstractMesh((16, 16), ("data", "model"))
from repro.models import model_struct, partition_specs
from repro.models.base import P
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.sharding import logical_rules, param_pspecs


def test_adamw_quadratic_convergence():
    A = jnp.eye(4) * jnp.asarray([1.0, 2.0, 3.0, 4.0])
    b = jnp.asarray([1.0, -2.0, 0.5, 3.0])
    params = {"x": jnp.zeros(4)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(300):
        g = {"x": A @ params["x"] - b}
        params, state, _ = adamw_update(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["x"]),
                               np.asarray(jnp.linalg.solve(A, b)), atol=1e-2)


def test_adamw_grad_clip_bounds_update():
    params = {"x": jnp.zeros(3)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    _, _, gnorm = adamw_update(params, {"x": jnp.full(3, 1e6)}, state, cfg)
    assert float(gnorm) > 1e5      # reported norm is pre-clip


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(10, peak_lr=1.0, warmup=10,
                                 total=100)) == pytest.approx(1.0)
    end = float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100))
    assert end == pytest.approx(0.1, abs=1e-6)


def test_partition_specs_no_duplicate_axes():
    """A mesh axis must never appear twice in one PartitionSpec."""
    mesh = make_spec_mesh()
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        struct = model_struct(cfg)
        specs = param_pspecs(struct, cfg, mesh)
        for spec in jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, PartitionSpec)):
            flat = []
            for s in spec:
                if s is None:
                    continue
                flat.extend(s if isinstance(s, tuple) else (s,))
            assert len(flat) == len(set(flat)), (arch, spec)


def test_partition_specs_divisibility():
    """Sharded dims must divide by the mesh axis size for every arch."""
    mesh = make_spec_mesh()
    sizes = dict(mesh.shape)
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        struct = model_struct(cfg)
        specs = param_pspecs(struct, cfg, mesh)

        def check(leaf: P, spec: PartitionSpec):
            for dim, s in zip(leaf.shape, tuple(spec)):
                if s is None:
                    continue
                n = 1
                for ax in (s if isinstance(s, tuple) else (s,)):
                    n *= sizes[ax]
                assert dim % n == 0, (arch, leaf.shape, spec)

        jax.tree_util.tree_map(check, struct, specs,
                               is_leaf=lambda x: isinstance(x, P))


def test_vocab_padding_only_when_needed():
    hub = get_config("hubert-xlarge")
    assert hub.padded_vocab == 512 and hub.vocab_size == 504
    llama = get_config("llama3.2-1b")
    assert llama.padded_vocab == llama.vocab_size    # 128256 % 256 == 0


def test_cell_map_counts():
    from repro.configs import run_cells, skipped_cells
    runs, skips = run_cells(), skipped_cells()
    assert len(runs) + len(skips) == 40
    assert len(runs) == 33
    assert ("hubert-xlarge", "decode_32k") in [(a, s) for a, s, _ in skips]
    assert ("rwkv6-3b", "long_500k") in runs
