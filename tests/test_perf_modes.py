"""Numerical equivalence of the SS Perf execution modes on real multi-device
meshes (subprocess with 8 host devices): ZeRO-1 vs FSDP training step and
chunked vs reference attention must produce the same model, within bf16
tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.test_distributed import run_sub


def test_zero1_step_matches_fsdp_step():
    res = run_sub("""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec
        import numpy as _np
        mesh = Mesh(_np.array(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        import repro.configs as C
        from repro.launch import steps
        orig = C.get_config
        steps.get_config = lambda name, smoke=False: orig(name, smoke=True)
        import repro.configs
        repro.configs.SHAPES["tiny_train"] = C.Shape("tiny_train", 64, 8,
                                                     "train")
        from repro.models import init_params, model_struct
        from repro.optim import adamw_init
        from repro.data import synthetic_batch

        cfg = orig("llama3.2-1b", smoke=True)
        struct = model_struct(cfg)
        params = init_params(struct, jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v)
                 for k, v in synthetic_batch(cfg, 8, 64).items()}

        outs = {}
        for mode in ("fsdp", "zero1"):
            cell = steps.build_cell("llama3.2-1b", "tiny_train", mesh,
                                    param_mode=mode, attn_dtype="f32")
            with mesh:
                jitted = jax.jit(cell.fn)
                if mode == "zero1":
                    p = jax.tree_util.tree_map(
                        lambda x: x.astype(jnp.bfloat16), params)
                    o = adamw_init(params)
                    o = {"m": o["m"], "v": o["v"], "step": o["step"],
                         "master": params}
                else:
                    p = params
                    o = adamw_init(params)
                new_p, new_o, metrics = jitted(p, o, batch)
            w = (new_o["master"] if mode == "zero1" else new_p)
            outs[mode] = (float(metrics["loss"]),
                          np.asarray(jax.tree_util.tree_leaves(w)[5],
                                     np.float32))
        l_f, w_f = outs["fsdp"]
        l_z, w_z = outs["zero1"]
        err = float(np.max(np.abs(w_f - w_z)) / (np.max(np.abs(w_f)) + 1e-9))
        print(json.dumps({"loss_fsdp": l_f, "loss_zero1": l_z, "err": err}))
    """)
    # zero1 computes grads in bf16 params; small relative deviation allowed
    assert abs(res["loss_fsdp"] - res["loss_zero1"]) < 0.05
    assert res["err"] < 0.05


def test_chunked_attention_under_mesh():
    res = run_sub("""
        from jax.sharding import Mesh
        import numpy as _np
        mesh = Mesh(_np.array(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        from repro.configs import get_config
        from repro.data import synthetic_batch
        from repro.models import forward, init_params, model_struct
        cfg = get_config("mixtral-8x7b", smoke=True).replace(
            batch_axes=("data",), act_shard="seq", score_shard="heads")
        params = init_params(model_struct(cfg), jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v)
                 for k, v in synthetic_batch(cfg, 8, 32).items()}
        with mesh:
            l_ref, _, _ = jax.jit(
                lambda p, b: forward(p, cfg, b))(params, batch)
            cfg2 = cfg.replace(attn_impl="chunked")
            l_chk, _, _ = jax.jit(
                lambda p, b: forward(p, cfg2, b))(params, batch)
        err = float(jnp.max(jnp.abs(l_ref - l_chk)))
        print(json.dumps({"err": err}))
    """)
    assert res["err"] < 5e-2


def test_shard_map_tp_mlp_matches_gspmd():
    """Explicit AG/RS TP combine == GSPMD lowering, numerically."""
    res = run_sub("""
        from jax.sharding import Mesh
        import numpy as _np
        mesh = Mesh(_np.array(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        from repro.configs import get_config
        from repro.models import init_params, model_struct
        from repro.models.layers import mlp
        from repro.models.shardmap_tp import mlp_tp
        cfg = get_config("llama3.2-1b", smoke=True).replace(
            batch_axes=("data",), act_shard="seq")
        d, ff = cfg.d_model, cfg.d_ff
        k = jax.random.PRNGKey(0)
        params = {
            "w_gate": jax.random.normal(k, (d, ff), jnp.float32) * 0.05,
            "w_up": jax.random.normal(k, (d, ff), jnp.float32) * 0.05,
            "w_down": jax.random.normal(k, (ff, d), jnp.float32) * 0.05,
        }
        x = jax.random.normal(k, (8, 32, d), jnp.float32)
        with jax.set_mesh(mesh):
            a = jax.jit(lambda p, x: mlp(p, x))(params, x)
            b = jax.jit(lambda p, x: mlp_tp(p, x, cfg))(params, x)
        err = float(jnp.max(jnp.abs(a - b)))
        print(json.dumps({"err": err}))
    """)
    assert res["err"] < 1e-4


def test_chunked_guard_falls_back_for_indivisible_heads():
    """gemma3 (8 heads, 16-way TP, score_shard=qseq): the chunked path must
    NOT engage under a mesh — the heads-TP pin would replicate q/k/v (the
    SS Perf gemma3 refutation); compute cost must match the dense path."""
    res = run_sub("""
        from jax.sharding import Mesh
        import numpy as _np
        mesh = Mesh(_np.array(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        from repro.configs import get_config
        from repro.data import synthetic_batch
        from repro.models import forward, init_params, model_struct
        cfg = get_config("gemma3-4b", smoke=True).replace(
            batch_axes=("data",), act_shard="seq", score_shard="qseq")
        params = init_params(model_struct(cfg), jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v)
                 for k, v in synthetic_batch(cfg, 8, 32).items()}
        with jax.set_mesh(mesh):
            f_dense = jax.jit(lambda p, b: forward(p, cfg, b)[0])
            cfg2 = cfg.replace(attn_impl="chunked")
            f_chunk = jax.jit(lambda p, b: forward(p, cfg2, b)[0])
            a = f_dense(params, batch)
            b_ = f_chunk(params, batch)
            c_dense = f_dense.lower(params, batch).compile().cost_analysis()
            c_chunk = f_chunk.lower(params, batch).compile().cost_analysis()
        err = float(jnp.max(jnp.abs(a - b_)))
        print(json.dumps({
            "err": err,
            "flops_ratio": c_chunk["flops"] / max(c_dense["flops"], 1.0)}))
    """)
    assert res["err"] < 1e-4
    assert 0.9 <= res["flops_ratio"] <= 1.1     # identical path taken
