"""Event-driven cycle-accurate timing engine (ISSUE 6).

Acceptance contract:

* **differential gate** — the cycle engine in trace-conservative,
  single-issue, fixed-latency mode reproduces the historical uniform-cost
  loop (kept verbatim as ``schedule_traces_reference``) **bit-for-bit**:
  same issue order, same cycle count, same thread-instruction total, over
  the paper suite and the progen distribution, for every policy;
* the cycle model is deterministic for a fixed seed (memory-latency
  distributions draw from a seeded rng in issue order);
* ``ipc_delta`` is exactly 0.0 on self-comparison and sign-antisymmetric;
* zero-instruction schedules report 0.0 ratios, never ZeroDivisionError —
  across ``TimingResult``, ``CycleResult`` and ``SmResult``;
* ``sm_interleave``'s policies are the shared :mod:`repro.timing.policies`
  layer: non-uniform latencies change *timing only* — warp traces are
  bit-identical (conformance is latency-independent);
* ``Simulator.compare(timing="cycle")`` reports the Fig 10 IPC delta with
  per-schedule stall breakdowns in ``report.timing_results``.
"""
import numpy as np
import pytest

# compat shim: without hypothesis only the @given tests skip, the
# example-based ones still run
from tests.hypothesis_compat import given, settings, st
from tests.progen import BASE_CFG, make_program

from repro.core import MachineConfig
from repro.core.programs import make_suite
from repro.core.timing import (TimingConfig, TimingResult, ipc_delta,
                               schedule_traces, schedule_traces_reference,
                               simulate)
from repro.engine import Simulator
from repro.engine.mechanisms.sm import SM_POLICIES, interleave_cycle
from repro.timing import (POLICY_NAMES, CycleConfig, CycleResult, Delay,
                          EventQueue, Scheduler, Signal, get_policy,
                          instr_deps, resolve_policy_name, schedule_cycle,
                          simulate_cycle)
from repro.timing.policies import GreedyThenOldest, OldestFirst, RoundRobin

CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
SUITE = make_suite(CFG, datasets=1)
SIM = Simulator("hanoi")

# the differential corpus: suite benches + progen (incl. the
# memory-latency-heavy shapes), traced under two mechanisms so the warp
# sets mix schedules of the same program
_DIFF_SEEDS = range(12)


def _trace(bench_or_prog, cfg=CFG, mech="hanoi", mem=None):
    r = SIM.run(bench_or_prog, cfg, mechanism=mech, init_mem=mem)
    return list(r.trace)


def _corpus():
    """(traces, programs) warp sets: heterogeneous programs per set."""
    sets = []
    for b in SUITE[:4]:
        prog = np.asarray(b.program)
        tr = [_trace(b, mech="hanoi"), _trace(b, mech="simt_stack")]
        sets.append((tr, [prog, prog]))
    pool = []
    for seed in _DIFF_SEEDS:
        out, cfg = make_program(seed, 8, mem_features=(seed % 2 == 0))
        if out is None:
            continue
        prog, mem = out
        pool.append((_trace(prog, cfg, "simt_stack", mem), np.asarray(prog)))
    for i in range(0, len(pool) - 2, 3):
        chunk = pool[i:i + 3]
        sets.append(([t for t, _ in chunk], [p for _, p in chunk]))
    return sets


# ---------------------------------------------------------------------------
# events.py: queue + coroutine scheduler
# ---------------------------------------------------------------------------

def test_event_queue_orders_by_time_then_fifo():
    q = EventQueue()
    q.push(5, "a")
    q.push(2, "b")
    q.push(5, "c")
    q.push(2, "d")
    assert len(q) == 4 and bool(q)
    assert q.peek_time() == 2
    # same-time entries pop in insertion order (stable ties)
    assert q.pop() == (2, "b")
    assert q.pop() == (2, "d")
    assert list(q.pop_until(5)) == ["a", "c"]     # payloads, time-ordered
    assert not q


def test_scheduler_delay_signal_completion_times():
    sched = Scheduler()
    done = {}

    def worker(name, wait):
        yield Delay(wait)
        done[name] = sched.now

    sig = Signal()

    def producer():
        yield Delay(3)
        sig.fire(sched)

    def consumer():
        yield sig
        done["consumer"] = sched.now

    sched.spawn(worker("fast", 2))
    sched.spawn(worker("slow", 7))
    sched.spawn(producer())
    sched.spawn(consumer())
    sched.run()
    assert done == {"fast": 2, "consumer": 3, "slow": 7}


def test_scheduler_parked_process_does_not_hang_run():
    """A process parked on a signal nobody fires must not keep run() alive."""
    sched = Scheduler()
    never = Signal()

    def parked():
        yield never
        raise AssertionError("unreachable")

    def active():
        yield Delay(4)

    sched.spawn(parked())
    sched.spawn(active())
    sched.run()
    assert sched.now == 4


# ---------------------------------------------------------------------------
# policies: the shared arbitration layer
# ---------------------------------------------------------------------------

def test_policy_registry_and_aliases():
    assert POLICY_NAMES == ("greedy_then_oldest", "round_robin",
                            "oldest_first")
    assert SM_POLICIES == POLICY_NAMES          # ONE policy layer
    assert resolve_policy_name("gto") == "greedy_then_oldest"
    assert resolve_policy_name("round_robin") == "round_robin"
    with pytest.raises(ValueError, match="unknown issue policy"):
        resolve_policy_name("fifo")
    assert isinstance(get_policy("gto", 4), GreedyThenOldest)
    assert isinstance(get_policy("oldest_first", 4), OldestFirst)


def test_gto_stickiness_and_stalled_reset():
    p = GreedyThenOldest(4)
    assert p.select([1, 2, 3]) == 1      # initial cur=0 not ready -> oldest
    p.issued(2)
    assert p.select([1, 2, 3]) == 2      # greedy on the granted warp
    assert p.select([0, 1, 3]) == 0      # granted warp gone -> oldest
    p.issued(2)
    p.stalled()                          # idle gap clears the stickiness
    assert p.select([1, 2, 3]) == 1      # oldest, NOT the old greedy warp


def test_round_robin_rotates():
    p = RoundRobin(4)
    order = []
    for _ in range(6):
        w = p.select([0, 1, 2, 3])
        p.issued(w)
        order.append(w)
    assert order == [0, 1, 2, 3, 0, 1]
    p2 = RoundRobin(4)
    p2.issued(1)
    assert p2.select([0, 3]) == 3        # closest at/after the cursor


# ---------------------------------------------------------------------------
# THE differential gate: cycle engine (unit mode) == legacy loop, bit-for-bit
# ---------------------------------------------------------------------------

# the historical loop implements exactly these two; ``oldest_first`` is
# new with the cycle engine (covered by the policy unit tests above)
@pytest.mark.parametrize("policy", ["greedy_then_oldest", "round_robin"])
def test_unit_latency_matches_reference_bit_for_bit(policy):
    cfgs = [TimingConfig(),
            TimingConfig(alu_latency=1, control_latency=1,
                         memory_latency=1, atomic_latency=1),
            TimingConfig(alu_latency=3, control_latency=2,
                         memory_latency=11, atomic_latency=17)]
    cases = 0
    for traces, progs in _corpus():
        ops = [p[:, 0] for p in progs]
        for cfg in cfgs:
            ref = schedule_traces_reference(traces, ops, policy, cfg)
            got = schedule_traces(traces, ops, policy, cfg)
            assert got == ref            # (order, cycles, tinstr) identical
            # full row tables route through the same path
            assert schedule_traces(traces, progs, policy, cfg) == ref
            cases += 1
    assert cases >= 15


def test_shim_simulate_matches_reference_ipc():
    b = SUITE[0]
    tr = _trace(b)
    prog = np.asarray(b.program)
    res = simulate([tr, tr], prog, CFG.n_threads)
    order, cycles, tinstr = schedule_traces_reference(
        [tr, tr], [prog[:, 0]] * 2)
    assert (res.cycles, res.issues, res.thread_instructions) == \
        (cycles, len(order), tinstr)
    assert res.warp_width == CFG.n_threads
    # the shim's result additionally partitions every cycle
    assert res.cycles == res.busy_cycles + res.scoreboard_stall_cycles + \
        res.memory_stall_cycles


# ---------------------------------------------------------------------------
# cycle-model properties: determinism, stall partition, scoreboard, dual issue
# ---------------------------------------------------------------------------

def _mem_case(seed=3):
    out, cfg = make_program(seed, 8, mem_features=True)
    assert out is not None
    prog, mem = out
    return _trace(prog, cfg, "simt_stack", mem), np.asarray(prog), cfg


def test_cycle_model_deterministic_for_fixed_seed():
    tr, prog, cfg = _mem_case()
    for model in ("uniform", "bimodal"):
        ccfg = CycleConfig(memory_model=model, seed=11, scoreboard=True)
        a = schedule_cycle([tr, tr, tr], [prog] * 3, "greedy_then_oldest",
                           ccfg)
        b = schedule_cycle([tr, tr, tr], [prog] * 3, "greedy_then_oldest",
                           ccfg)
        assert a == b                    # dataclass equality: every field


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       model=st.sampled_from(["fixed", "uniform", "bimodal"]),
       policy=st.sampled_from(["greedy_then_oldest", "round_robin"]))
def test_cycle_model_deterministic_property(seed, model, policy):
    tr, prog, _ = _mem_case()
    ccfg = CycleConfig(memory_model=model, seed=seed, scoreboard=True)
    a = schedule_cycle([tr, tr], [prog] * 2, policy, ccfg)
    b = schedule_cycle([tr, tr], [prog] * 2, policy, ccfg)
    assert a == b


def test_stall_partition_invariant():
    """Every cycle is busy, scoreboard-stalled, or memory-stalled — no
    unaccounted time, in every mode."""
    tr, prog, _ = _mem_case()
    for ccfg in (CycleConfig(),
                 CycleConfig(scoreboard=False),
                 CycleConfig(memory_model="bimodal", seed=5),
                 CycleConfig(issue_width=2),
                 CycleConfig(memory_latency=200)):
        for n in (1, 3):
            res = schedule_cycle([tr] * n, [prog] * n, "greedy_then_oldest",
                                 ccfg)
            assert res.cycles == (res.busy_cycles +
                                  res.scoreboard_stall_cycles +
                                  res.memory_stall_cycles)
            assert res.issues == len(res.order) == sum(res.per_warp_issues)


def test_memory_stalls_dominate_on_load_chains():
    """The progen mem_features shape exists to exercise exactly this:
    a long-latency load feeding a dependent chain must show up as memory
    stall cycles, and raising the latency must raise the cycle count."""
    tr, prog, _ = _mem_case()
    short = schedule_cycle([tr], [prog], "greedy_then_oldest",
                           CycleConfig(memory_latency=10))
    long = schedule_cycle([tr], [prog], "greedy_then_oldest",
                          CycleConfig(memory_latency=100))
    assert long.memory_stall_cycles > short.memory_stall_cycles
    assert long.cycles > short.cycles
    assert long.thread_instructions == short.thread_instructions


def test_scoreboard_never_slower_than_trace_conservative():
    """The scoreboard only *relaxes* the everything-depends-on-predecessor
    assumption; with identical latencies it cannot add cycles."""
    for traces, progs in _corpus()[:6]:
        base = CycleConfig(scoreboard=False)
        sb = CycleConfig(scoreboard=True)
        a = schedule_cycle(traces, progs, "greedy_then_oldest", base)
        b = schedule_cycle(traces, progs, "greedy_then_oldest", sb)
        assert b.cycles <= a.cycles
        assert b.thread_instructions == a.thread_instructions


def test_dual_issue_never_slower_and_helps_multiwarp():
    tr, prog, _ = _mem_case()
    one = schedule_cycle([tr] * 4, [prog] * 4, "greedy_then_oldest",
                         CycleConfig(issue_width=1))
    two = schedule_cycle([tr] * 4, [prog] * 4, "greedy_then_oldest",
                         CycleConfig(issue_width=2))
    assert two.cycles < one.cycles       # 4 identical warps: must overlap
    assert two.thread_instructions == one.thread_instructions


def test_memory_distribution_bounds():
    tr, prog, _ = _mem_case()
    lo, hi = 10, 60
    fixed = schedule_cycle([tr], [prog], "greedy_then_oldest",
                           CycleConfig(memory_latency=lo, scoreboard=False))
    slow = schedule_cycle([tr], [prog], "greedy_then_oldest",
                          CycleConfig(memory_latency=hi, scoreboard=False))
    uni = schedule_cycle([tr], [prog], "greedy_then_oldest",
                         CycleConfig(memory_model="uniform",
                                     memory_latency_lo=lo,
                                     memory_latency_hi=hi,
                                     scoreboard=False, seed=7))
    assert fixed.cycles <= uni.cycles <= slow.cycles


def test_cycle_config_validation():
    with pytest.raises(ValueError):
        CycleConfig(memory_model="gaussian")
    with pytest.raises(ValueError):
        CycleConfig(issue_width=0)
    with pytest.raises(ValueError):
        CycleConfig(memory_latency_lo=50, memory_latency_hi=10,
                    memory_model="uniform")
    # a CycleConfig passes through from_timing untouched (explicit config
    # wins over compare's scoreboard lift)
    c = CycleConfig(scoreboard=False, issue_width=2)
    assert CycleConfig.from_timing(c, scoreboard=True) is c
    t = CycleConfig.from_timing(TimingConfig(alu_latency=5))
    assert t.alu_latency == 5 and t.scoreboard is False


def test_instr_deps_isetp_and_memory_rows():
    from repro.core.asm import assemble
    prog = assemble("LDG R5, [R1+0]\nISETP.LT P1, R5, 3\n"
                    "@P1 IADD R6, R5, R2\nEXIT")
    reads, writes, preads, pwrites = instr_deps(np.asarray(prog)[0])
    assert reads == (1,) and writes == (5,)
    reads, writes, preads, pwrites = instr_deps(np.asarray(prog)[1])
    assert 5 in reads and not writes and pwrites == (1,)
    reads, writes, preads, pwrites = instr_deps(np.asarray(prog)[2])
    assert set(reads) == {5, 2} and writes == (6,) and preads == (1,)


# ---------------------------------------------------------------------------
# zero-instruction guards + ipc_delta algebra (the satellite bugfix)
# ---------------------------------------------------------------------------

def test_zero_instruction_schedule_reports_zero_ratios():
    empty = simulate([], np.zeros((1, 8), dtype=np.int32), 8)
    assert empty.cycles == 0 and empty.issues == 0
    assert empty.ipc == 0.0
    assert empty.warp_ipc == 0.0
    assert empty.simd_utilization == 0.0
    legacy = TimingResult(cycles=0, issues=0, thread_instructions=0,
                          warp_width=0)
    assert (legacy.ipc, legacy.warp_ipc, legacy.simd_utilization) == \
        (0.0, 0.0, 0.0)
    cyc = schedule_cycle([[]], [np.zeros((1, 8), dtype=np.int32)],
                         "greedy_then_oldest", CycleConfig())
    assert cyc.cycles == 0
    assert (cyc.ipc, cyc.warp_ipc, cyc.simd_utilization) == (0.0, 0.0, 0.0)
    # engine-level twin (SmResult) guards the same ratios
    from repro.engine.types import SimStatus, SmResult
    sm = SmResult(mechanism="sm_interleave", inner="hanoi",
                  policy="round_robin", warps=(), sm_trace=(),
                  status=SimStatus.OK, steps=0, cycles=0,
                  thread_instructions=0, utilization=0.0)
    assert sm.ipc == 0.0 and sm.warp_ipc == 0.0


def test_ipc_delta_zero_on_self_and_antisymmetric():
    b = SUITE[0]
    tr = _trace(b)
    prog = np.asarray(b.program)
    a = simulate([tr, tr], prog, CFG.n_threads)
    assert ipc_delta(a, a) == 0.0
    faster = simulate([tr], prog, CFG.n_threads)
    if faster.ipc != a.ipc:
        assert np.sign(ipc_delta(faster, a)) == -np.sign(ipc_delta(a, faster))
    # exact antisymmetry of the numerator: delta(a,b)*b.ipc == -delta(b,a)*a.ipc
    d_ab = ipc_delta(faster, a) * a.ipc
    d_ba = ipc_delta(a, faster) * faster.ipc
    assert d_ab == pytest.approx(-d_ba)


# ---------------------------------------------------------------------------
# integration: compare(timing="cycle"), shared-policy SM conformance, service
# ---------------------------------------------------------------------------

def test_compare_timing_cycle_reports_fig10_delta():
    benches = [b for b in SUITE if b.name in ("HOTS0", "DIAMOND")]
    rep = SIM.compare(["hanoi", "simt_stack"], benches, CFG, timing="cycle")
    assert rep.rows
    for row in rep.rows:
        assert np.isfinite(row.ipc_delta)
    # per-schedule stall breakdowns land in timing_results
    assert rep.timing_results
    for (prog, mech), tres in rep.timing_results.items():
        assert isinstance(prog, str) and mech in ("hanoi", "simt_stack")
        assert tres.cycles == (tres.busy_cycles +
                               tres.scoreboard_stall_cycles +
                               tres.memory_stall_cycles)
        assert tres.ipc > 0.0
    # self-pairs are exactly zero through the cache
    rep_self = SIM.compare(["hanoi"], benches, CFG,
                           pairs=[("hanoi", "hanoi")], timing="cycle")
    assert all(r.ipc_delta == 0.0 for r in rep_self.rows)


def test_compare_trace_and_cycle_modes_differ_only_in_timing():
    benches = [b for b in SUITE if b.name == "DIAMOND"]
    a = SIM.compare(["hanoi", "simt_stack"], benches, CFG, timing="trace")
    b = SIM.compare(["hanoi", "simt_stack"], benches, CFG, timing="cycle")
    for ra, rb in zip(a.rows, b.rows):
        assert ra.discrepancy == rb.discrepancy      # Fig 9 is timing-free


def test_sm_interleave_conformant_under_nonuniform_latencies():
    """Acceptance: sm_interleave through the shared policy layer stays
    trace-conformant when latencies change — only timing moves."""
    b = SUITE[0]
    base = SIM.run_sm(b, CFG, n_warps=3, policy="greedy_then_oldest")
    slow = SIM.run_sm(b, CFG, n_warps=3, policy="greedy_then_oldest",
                      timing_cfg=TimingConfig(memory_latency=300,
                                              alu_latency=7))
    cyc = SIM.run_sm(b, CFG, n_warps=3, policy="gto",
                     timing_cfg=CycleConfig(memory_latency=300))
    for w_base, w_slow, w_cyc in zip(base.warps, slow.warps, cyc.warps):
        assert w_base.trace == w_slow.trace == w_cyc.trace
    assert slow.cycles > base.cycles
    assert cyc.policy == base.policy == "greedy_then_oldest"   # canonical
    assert base.cycles == (base.busy_cycles + base.scoreboard_stall_cycles +
                           base.memory_stall_cycles)
    assert base.stall_breakdown.keys() == {"issue", "scoreboard", "memory"}


def test_interleave_cycle_policy_alias_and_result_shape():
    b = SUITE[0]
    tr = _trace(b)
    prog = np.asarray(b.program)
    res = interleave_cycle([tr, tr], [prog, prog], "gto", TimingConfig())
    assert isinstance(res, CycleResult)
    assert res.policy == "greedy_then_oldest"
    legacy = schedule_traces_reference([tr, tr], [prog[:, 0]] * 2)
    assert (res.order, res.cycles, res.thread_instructions) == legacy


def test_service_accumulates_sm_stall_counters():
    from repro.service import SimulationService
    b = SUITE[0]
    with SimulationService(default_mechanism="hanoi", workers=1) as svc:
        sm = svc.submit_sm(b, CFG, n_warps=3, inner="hanoi").result()
        stats = svc.stats()
    assert stats.sm_cycles == sm.cycles > 0
    assert stats.sm_busy_cycles == sm.busy_cycles
    assert stats.sm_cycles == (stats.sm_busy_cycles +
                               stats.sm_scoreboard_stall_cycles +
                               stats.sm_memory_stall_cycles)
    assert stats.sm_stall_breakdown == sm.stall_breakdown
