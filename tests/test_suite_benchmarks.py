"""Behavioral validation of the benchmark suite (Table II analogue) on all
three machines, plus the timing model's basic sanity.

All engine invocations go through the unified ``repro.engine`` API (the
canonical entry point); ``run_reference`` stays a direct import because the
per-thread scalar oracle is a correctness yardstick, not a mechanism.
"""
import numpy as np
import pytest

from repro.core import MachineConfig, run_reference
from repro.core.programs import make_suite
from repro.core.timing import TimingConfig, simulate
from repro.engine import SimStatus, Simulator

CFG = MachineConfig(n_threads=32, mem_size=256, max_steps=60_000)
SUITE = make_suite(CFG, datasets=1)
SIM = Simulator("hanoi")


@pytest.mark.parametrize("bench", SUITE, ids=lambda b: b.name)
def test_hanoi_completes(bench):
    r = SIM.run(bench, CFG)
    assert r.status is SimStatus.OK, f"{bench.name}: {r.status}"
    assert r.error is None
    assert r.finished == CFG.full_mask


@pytest.mark.parametrize("bench", [b for b in SUITE if b.race_free],
                         ids=lambda b: b.name)
def test_suite_matches_reference(bench):
    r = SIM.run(bench, CFG)
    ref = run_reference(bench.program, CFG, init_mem=bench.init_mem)
    np.testing.assert_array_equal(r.mem, ref.mem)
    assert r.finished == ref.finished


@pytest.mark.parametrize("bench", [b for b in SUITE if b.race_free],
                         ids=lambda b: b.name)
def test_suite_simt_stack_matches_reference(bench):
    """Race-free structured programs also complete pre-Volta (no SIMT-induced
    deadlock without locks)."""
    r = SIM.run(bench, CFG, mechanism="simt_stack")
    assert r.status is SimStatus.OK
    ref = run_reference(bench.program, CFG, init_mem=bench.init_mem)
    np.testing.assert_array_equal(r.mem, ref.mem)


def test_histogram_counts():
    bench = next(b for b in SUITE if b.name.startswith("HIST"))
    r = SIM.run(bench, CFG)
    assert r.status is SimStatus.OK
    vals = bench.init_mem[:32]
    expect = np.zeros(CFG.mem_size, np.int64)
    for v in vals:
        expect[(v + CFG.mem_size // 2) % CFG.mem_size] += 1
    got = r.mem[CFG.mem_size // 2:CFG.mem_size // 2 + 8]
    want = (bench.init_mem + expect)[CFG.mem_size // 2:CFG.mem_size // 2 + 8]
    np.testing.assert_array_equal(got, want)


def test_oracle_skip_changes_trace_not_results():
    """The BFSD benchmark: the Turing-oracle skips the loop BSYNC, producing
    a different trace (lower SIMD utilization) but identical results."""
    bench = next(b for b in SUITE if b.name == "BFSD")
    hanoi = SIM.run(bench, CFG)
    oracle = SIM.run(bench, CFG, mechanism="turing_oracle")
    assert hanoi.status is SimStatus.OK and oracle.status is SimStatus.OK
    np.testing.assert_array_equal(hanoi.mem, oracle.mem)
    assert hanoi.trace != oracle.trace, "heuristic must alter the schedule"
    assert hanoi.utilization >= oracle.utilization, (
        "enforcing reconvergence must not lower SIMD utilization "
        "(paper SS IX: +31.9%)")


def test_timing_model_prefers_reconvergence():
    """Fig 10 BFSD effect: Hanoi's reconvergence-enforcing trace yields
    higher thread-IPC than the skipping oracle trace."""
    bench = next(b for b in SUITE if b.name == "BFSD")
    report = SIM.compare(["hanoi", "turing_oracle"], [bench], CFG,
                         pairs=[("hanoi", "turing_oracle")], timing_warps=1)
    row = report.pair("hanoi", "turing_oracle")[0]
    assert row.util_a >= row.util_b
    assert row.ipc_a >= row.ipc_b
    assert row.ipc_delta >= 0.0


def test_timing_model_monotone_in_latency():
    bench = SUITE[0]
    r = SIM.run(bench, CFG)
    fast = simulate([list(r.trace)], bench.program, CFG.n_threads,
                    TimingConfig(memory_latency=2))
    slow = simulate([list(r.trace)], bench.program, CFG.n_threads,
                    TimingConfig(memory_latency=200))
    assert slow.cycles > fast.cycles
    assert slow.ipc < fast.ipc


def test_timing_multi_warp_hides_latency():
    """More warps per scheduler hide memory latency: cycles grow sublinearly
    with warp count."""
    bench = next(b for b in SUITE if b.name.startswith("RBFS"))
    r = SIM.run(bench, CFG)
    one = simulate([list(r.trace)], bench.program, CFG.n_threads)
    four = simulate([list(r.trace)] * 4, bench.program, CFG.n_threads)
    assert four.cycles < 4 * one.cycles
    assert four.ipc > one.ipc
