"""Behavioral validation of the benchmark suite (Table II analogue) on all
three machines, plus the timing model's basic sanity."""
import numpy as np
import pytest

from repro.core import (MachineConfig, run_hanoi, run_reference,
                        run_simt_stack, simd_utilization)
from repro.core.programs import make_suite
from repro.core.timing import TimingConfig, simulate

CFG = MachineConfig(n_threads=32, mem_size=256, max_steps=60_000)
SUITE = make_suite(CFG, datasets=1)


@pytest.mark.parametrize("bench", SUITE, ids=lambda b: b.name)
def test_hanoi_completes(bench):
    r = run_hanoi(bench.program, CFG, init_mem=bench.init_mem)
    assert not r.deadlocked, f"{bench.name} deadlocked on Hanoi"
    assert r.error is None
    assert r.finished == CFG.full_mask


@pytest.mark.parametrize("bench", [b for b in SUITE if b.race_free],
                         ids=lambda b: b.name)
def test_suite_matches_reference(bench):
    r = run_hanoi(bench.program, CFG, init_mem=bench.init_mem)
    ref = run_reference(bench.program, CFG, init_mem=bench.init_mem)
    np.testing.assert_array_equal(r.mem, ref.mem)
    assert r.finished == ref.finished


@pytest.mark.parametrize("bench", [b for b in SUITE if b.race_free],
                         ids=lambda b: b.name)
def test_suite_simt_stack_matches_reference(bench):
    """Race-free structured programs also complete pre-Volta (no SIMT-induced
    deadlock without locks)."""
    r = run_simt_stack(bench.program, CFG, init_mem=bench.init_mem)
    assert not r.deadlocked
    ref = run_reference(bench.program, CFG, init_mem=bench.init_mem)
    np.testing.assert_array_equal(r.mem, ref.mem)


def test_histogram_counts():
    bench = next(b for b in SUITE if b.name.startswith("HIST"))
    r = run_hanoi(bench.program, CFG, init_mem=bench.init_mem)
    assert not r.deadlocked
    vals = bench.init_mem[:32]
    expect = np.zeros(CFG.mem_size, np.int64)
    for v in vals:
        expect[(v + CFG.mem_size // 2) % CFG.mem_size] += 1
    got = r.mem[CFG.mem_size // 2:CFG.mem_size // 2 + 8]
    want = (bench.init_mem + expect)[CFG.mem_size // 2:CFG.mem_size // 2 + 8]
    np.testing.assert_array_equal(got, want)


def test_oracle_skip_changes_trace_not_results():
    """The BFSD benchmark: the Turing-oracle skips the loop BSYNC, producing
    a different trace (lower SIMD utilization) but identical results."""
    bench = next(b for b in SUITE if b.name == "BFSD")
    hanoi = run_hanoi(bench.program, CFG, init_mem=bench.init_mem)
    oracle = run_hanoi(bench.program, CFG, init_mem=bench.init_mem,
                       bsync_skip_pcs=bench.skip_bsync_pcs)
    assert not hanoi.deadlocked and not oracle.deadlocked
    np.testing.assert_array_equal(hanoi.mem, oracle.mem)
    assert hanoi.trace != oracle.trace, "heuristic must alter the schedule"
    util_h = simd_utilization(hanoi.trace, CFG.n_threads)
    util_o = simd_utilization(oracle.trace, CFG.n_threads)
    assert util_h >= util_o, ("enforcing reconvergence must not lower "
                              "SIMD utilization (paper SS IX: +31.9%)")


def test_timing_model_prefers_reconvergence():
    """Fig 10 BFSD effect: Hanoi's reconvergence-enforcing trace yields
    higher thread-IPC than the skipping oracle trace."""
    bench = next(b for b in SUITE if b.name == "BFSD")
    hanoi = run_hanoi(bench.program, CFG, init_mem=bench.init_mem)
    oracle = run_hanoi(bench.program, CFG, init_mem=bench.init_mem,
                       bsync_skip_pcs=bench.skip_bsync_pcs)
    t_h = simulate([hanoi.trace], bench.program, CFG.n_threads)
    t_o = simulate([oracle.trace], bench.program, CFG.n_threads)
    assert t_h.simd_utilization >= t_o.simd_utilization
    assert t_h.ipc >= t_o.ipc


def test_timing_model_monotone_in_latency():
    bench = SUITE[0]
    r = run_hanoi(bench.program, CFG, init_mem=bench.init_mem)
    fast = simulate([r.trace], bench.program, CFG.n_threads,
                    TimingConfig(memory_latency=2))
    slow = simulate([r.trace], bench.program, CFG.n_threads,
                    TimingConfig(memory_latency=200))
    assert slow.cycles > fast.cycles
    assert slow.ipc < fast.ipc


def test_timing_multi_warp_hides_latency():
    """More warps per scheduler hide memory latency: cycles grow sublinearly
    with warp count."""
    bench = next(b for b in SUITE if b.name.startswith("RBFS"))
    r = run_hanoi(bench.program, CFG, init_mem=bench.init_mem)
    one = simulate([r.trace], bench.program, CFG.n_threads)
    four = simulate([r.trace] * 4, bench.program, CFG.n_threads)
    assert four.cycles < 4 * one.cycles
    assert four.ipc > one.ipc
