"""Per-kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret=True executes the Pallas kernel bodies on CPU), plus the
divergence-tile census invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# compat shim: without hypothesis only the @given tests skip, the
# example-based kernel tests still run
from tests.hypothesis_compat import given, settings, st

from repro.kernels import ops, ref, tile_stats

K = jax.random.PRNGKey


def _qkv(key, B, S, H, Kh, hd, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, hd), dtype)
    k = jax.random.normal(k2, (B, S, Kh, hd), dtype)
    v = jax.random.normal(k3, (B, S, Kh, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("S,H,Kh,hd,causal,window", [
    (64, 4, 4, 32, True, 0),        # causal full
    (64, 4, 2, 32, True, 0),        # GQA
    (64, 4, 1, 32, True, 16),       # MQA + window (SWA)
    (96, 2, 2, 64, True, 32),       # non-multiple of block, window
    (64, 2, 2, 32, False, 0),       # encoder (bidirectional)
])
def test_flash_attention_matches_ref(S, H, Kh, hd, causal, window):
    q, k, v = _qkv(K(0), 2, S, H, Kh, hd, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=32, bk=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    q, k, v = _qkv(K(1), 1, 64, 4, 2, 32, dtype)
    out = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=12, deadline=None)
@given(s=st.sampled_from([32, 48, 64, 80]),
       hd=st.sampled_from([16, 32]),
       window=st.sampled_from([0, 8, 24]),
       causal=st.booleans())
def test_flash_attention_property_sweep(s, hd, window, causal):
    q, k, v = _qkv(K(s * 7 + hd), 1, s, 2, 2, hd, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=16, bk=16, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_tile_stats_census():
    """EMPTY/PARTIAL/FULL partition the grid; causal keeps ~half the tiles;
    windows make kept-work O(S*w) (the Hanoi path-skip saving)."""
    s = tile_stats(1024, 1024, causal=True, window=0, bq=128, bk=128)
    assert s["empty"] + s["full"] + s["partial"] == s["total"]
    assert 0.5 <= s["flops_kept_frac"] <= 0.7       # ~ (n+1)/2n + diag
    w = tile_stats(4096, 4096, causal=True, window=512, bq=128, bk=128)
    assert w["flops_kept_frac"] < 0.2               # window keeps O(S*w)
    f = tile_stats(512, 512, causal=False, window=0, bq=128, bk=128)
    assert f["empty"] == 0 and f["partial"] == 0    # all FULL, no mask cost


def test_rglru_scan_matches_ref():
    B, S, W = 2, 96, 64
    k1, k2 = jax.random.split(K(2))
    a = jax.random.uniform(k1, (B, S, W), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(k2, (B, S, W), jnp.float32)
    h = ops.rglru_scan(a, b, bs=32, bw=32, interpret=True)
    want = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([16, 40, 64]), w=st.sampled_from([8, 24]))
def test_rglru_scan_property_sweep(s, w):
    k1, k2 = jax.random.split(K(s + w))
    a = jax.random.uniform(k1, (1, s, w), jnp.float32, 0.0, 0.999)
    b = jax.random.normal(k2, (1, s, w), jnp.float32)
    h = ops.rglru_scan(a, b, bs=8, bw=8, interpret=True)
    want = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rwkv6_scan_matches_ref():
    B, S, H, hd = 2, 48, 2, 16
    ks = jax.random.split(K(3), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, hd), jnp.float32)
               for i in range(3))
    w = jax.random.uniform(ks[3], (B, S, H, hd), jnp.float32, 0.8, 0.999)
    u = jax.random.normal(ks[4], (H, hd), jnp.float32) * 0.1
    out, s_last = ops.rwkv6_scan(r, k, v, w, u, bs=16, interpret=True)
    want, s_want = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_last), np.asarray(s_want),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_scan_nonmultiple_tail():
    B, S, H, hd = 1, 24, 2, 8          # S not a multiple of bs=16
    ks = jax.random.split(K(4), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, hd), jnp.float32)
               for i in range(3))
    w = jax.random.uniform(ks[3], (B, S, H, hd), jnp.float32, 0.8, 0.999)
    u = jax.random.normal(ks[4], (H, hd), jnp.float32) * 0.1
    out, _ = ops.rwkv6_scan(r, k, v, w, u, bs=16, interpret=True)
    want, _ = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_model_attention_flash_path_matches_reference_impl():
    """End-to-end: a model layer with attn_impl='flash' must match the
    reference einsum attention."""
    from repro.configs import get_config
    from repro.data import synthetic_batch
    from repro.models import forward, init_params, model_struct
    cfg = get_config("llama3.2-1b", smoke=True).replace(n_layers=2)
    params = init_params(model_struct(cfg), K(0))
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(cfg, 2, 32).items()}
    l_ref, _, _ = forward(params, cfg, batch)
    l_flash, _, _ = forward(params, cfg.replace(attn_impl="flash"), batch)
    np.testing.assert_allclose(np.asarray(l_ref, np.float32),
                               np.asarray(l_flash, np.float32),
                               rtol=2e-4, atol=2e-4)
