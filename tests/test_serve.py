"""Serving-driver smoke: batched greedy decode across cache families, with
determinism (same seed -> same tokens)."""
import numpy as np
import pytest

from repro.launch.serve import serve


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x7b",
                                  "recurrentgemma-2b", "rwkv6-3b"])
def test_serve_generates(arch):
    res = serve(arch, smoke=True, batch=2, prompt_len=8, gen_len=8,
                max_len=64)
    assert res["generated"].shape == (2, 8)
    assert res["generated"].dtype == np.int32
    assert (res["generated"] >= 0).all()


def test_serve_deterministic():
    a = serve("llama3.2-1b", smoke=True, batch=2, prompt_len=8, gen_len=8,
              max_len=64, seed=7)
    b = serve("llama3.2-1b", smoke=True, batch=2, prompt_len=8, gen_len=8,
              max_len=64, seed=7)
    np.testing.assert_array_equal(a["generated"], b["generated"])
