"""``sm_jax`` conformance + the ISSUE 7 satellite bugfixes.

Acceptance contract:

* the lane-parallel ``sm_jax`` engine is **bit-identical** to the Python
  ``sm_interleave`` scheduler — ``(warp, pc, mask)`` SM trace, cycle
  count, stall taxonomy, instruction totals — for every issue policy,
  over the benchmark suite *and* randomized progen programs (sync and
  memory feature mixes), for homogeneous and heterogeneous cells;
* the argmin-vector policy formulation (``priority_keys``) can never
  drift from the stateful ``IssuePolicy`` classes (randomized drift
  test) — it is the contract ``sm_jax`` compiles against;
* ``sm_jax`` cells archive through the normal sink path and self-replay
  to exactly 0.0 discrepancy;
* satellite fixes stay fixed: ``sm_interleave`` dispatches its warps as
  ONE native batch through the planner (counting probe);
  ``hanoi_jax`` batch compilation is metered separately from execution
  wall time (``compile_time_s`` meta); ``warp_count`` accepts any sized
  sequence and raises on unsized iterables, and the service's warp-level
  stats agree with the façade's cell width for 3-D ndarray stacks.
"""
import numpy as np
import pytest

from repro.archive import ArchiveReader, Replayer
from repro.core import MachineConfig
from repro.core.programs import make_suite
from repro.engine import RotatingJsonlSink, SimRequest, Simulator
from repro.engine.mechanisms.sm import (DEFAULT_WARPS, per_warp_programs,
                                        warp_count)
from repro.engine.registry import (get_mechanism, register_mechanism,
                                   unregister_mechanism)
from repro.service import SimulationService
from repro.timing.policies import POLICY_NAMES, get_policy, priority_keys
from repro.timing.sm_model import CycleConfig
from tests.progen import make_program

# Same shape as the conformance CFG so the jit caches warm once per session.
CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=20_000)
SUITE = make_suite(CFG, datasets=1)
BENCH = {b.name: b for b in SUITE}
SIM = Simulator("hanoi")
BENCHES = ("GAUS0", "RBFS0", "DIAMOND", "HOTS0")


def _assert_sm_equal(j, p):
    """Bit-equality of two SmResults (jax cell vs Python interleaver)."""
    assert j.sm_trace == p.sm_trace
    assert j.steps == p.steps
    assert j.cycles == p.cycles
    assert j.thread_instructions == p.thread_instructions
    assert j.stall_breakdown == p.stall_breakdown
    assert j.utilization == pytest.approx(p.utilization)
    assert j.status == p.status
    assert j.policy == p.policy
    assert len(j.warps) == len(p.warps)
    for wj, wp in zip(j.warps, p.warps):
        assert wj.status == wp.status
        assert wj.trace == wp.trace
        assert np.array_equal(np.asarray(wj.regs), np.asarray(wp.regs))


def _cell_req(bench, *, warps, inner, policy, name=None):
    return SimRequest(program=bench.program, cfg=CFG,
                      init_mem=bench.init_mem, name=name or bench.name,
                      meta={"sm_warps": warps, "sm_inner": inner,
                            "sm_policy": policy})


# ---------------------------------------------------------------------------
# policy drift: priority_keys argmin == stateful select, always
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_priority_keys_never_drift_from_select(policy):
    """For random ready sets and issue/stall histories, the stateful
    ``select`` equals ``argmin over ready of priority_keys()`` — the exact
    formulation ``sm_jax`` compiles.  Injectivity makes ties impossible."""
    rng = np.random.default_rng(20260809)
    for n_warps in (1, 2, 3, 8):
        pol = get_policy(policy, n_warps)
        for _ in range(200):
            keys = pol.priority_keys()
            assert keys.shape == (n_warps,)
            assert len(set(int(k) for k in keys)) == n_warps  # injective
            k = int(rng.integers(1, n_warps + 1))
            ready = sorted(rng.choice(n_warps, size=k, replace=False))
            sel = pol.select(ready)
            assert sel == min(ready, key=lambda w: int(keys[w]))
            if rng.random() < 0.25:
                pol.stalled()
            else:
                pol.issued(sel)
    # the stateless module function agrees with the class methods
    assert list(priority_keys("oldest_first", 4)) == [0, 1, 2, 3]
    assert list(priority_keys("greedy_then_oldest", 4, last=2)) == \
        [1, 2, 0, 4]
    assert list(priority_keys("greedy_then_oldest", 4, last=None)) == \
        [1, 2, 3, 4]
    assert list(priority_keys("round_robin", 4, cursor=3)) == [1, 2, 3, 0]


# ---------------------------------------------------------------------------
# tentpole gate: sm_jax == sm_interleave, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_sm_jax_matches_interleave_on_suite(policy):
    """Every suite bench x warp widths {1, 3, 4}: identical SM schedules."""
    widths = {BENCHES[0]: 1, BENCHES[1]: 3}
    jax_reqs = [_cell_req(BENCH[n], warps=widths.get(n, 4),
                          inner="hanoi_jax", policy=policy)
                for n in BENCHES]
    py_reqs = [_cell_req(BENCH[n], warps=widths.get(n, 4), inner="hanoi",
                         policy=policy) for n in BENCHES]
    jax_res = SIM.run_batch(jax_reqs, mechanism="sm_jax")
    py_res = SIM.run_batch(py_reqs, mechanism="sm_interleave")
    for a, b in zip(jax_res, py_res):
        assert a.error is None and b.error is None
        sm_j, sm_p = a.meta["sm"], b.meta["sm"]
        assert sm_j.mechanism == "sm_jax"
        assert sm_p.mechanism == "sm_interleave"
        _assert_sm_equal(sm_j, sm_p)
        # top-level SimResult mirrors warp 0 + the interleaved (pc, mask)
        assert a.trace == tuple((pc, m) for _, pc, m in sm_j.sm_trace)
        assert np.array_equal(np.asarray(a.regs), np.asarray(b.regs))


@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_sm_jax_matches_interleave_on_progen(policy):
    """Randomized programs with sync and memory-latency features — the
    divergence/reconvergence + long-latency shapes the scheduler's stall
    taxonomy actually exercises."""
    pairs = []
    for seed in range(4):
        for sf, mf in ((True, False), (False, True)):
            built, cfg = make_program(seed, 8, sync_features=sf,
                                      mem_features=mf)
            if built is None:
                continue
            prog, mem = built
            pairs.append((prog, mem, cfg))
    assert pairs
    jax_reqs = [SimRequest(program=prog, cfg=cfg, init_mem=mem,
                           name=f"progen{i}",
                           meta={"sm_warps": 3, "sm_inner": "hanoi_jax",
                                 "sm_policy": policy})
                for i, (prog, mem, cfg) in enumerate(pairs)]
    py_reqs = [SimRequest(program=q.program, cfg=q.cfg, init_mem=q.init_mem,
                          name=q.name,
                          meta={**dict(q.meta), "sm_inner": "hanoi"})
               for q in jax_reqs]
    jax_res = SIM.run_batch(jax_reqs, mechanism="sm_jax")
    py_res = SIM.run_batch(py_reqs, mechanism="sm_interleave")
    for a, b in zip(jax_res, py_res):
        assert a.status == b.status
        _assert_sm_equal(a.meta["sm"], b.meta["sm"])


def test_run_sm_sm_jax_heterogeneous_and_ndarray_cells():
    """Facade routing: heterogeneous per-warp programs and a 3-D stacked
    ndarray both reach sm_jax and match the Python interleaver."""
    progs = [BENCH["DIAMOND"], BENCH["HOTS0"], BENCH["BFSD"]]
    j = SIM.run_sm(progs, CFG, inner="hanoi_jax",
                   policy="greedy_then_oldest", sm_mechanism="sm_jax")
    p = SIM.run_sm(progs, CFG, inner="hanoi", policy="greedy_then_oldest")
    assert j.mechanism == "sm_jax" and j.inner == "hanoi_jax"
    assert j.n_warps == 3 and len(j.requests) == 3
    _assert_sm_equal(j, p)

    stack = np.stack([BENCH["DIAMOND"].program] * 3)
    j = SIM.run_sm(stack, CFG, inner="hanoi_jax", policy="round_robin",
                   sm_mechanism="sm_jax")
    p = SIM.run_sm(stack, CFG, inner="hanoi", policy="round_robin")
    assert j.n_warps == p.n_warps == 3
    _assert_sm_equal(j, p)


def test_sm_jax_rejects_unsupported_inner_and_timing():
    b = BENCH["DIAMOND"]
    with pytest.raises(ValueError, match="jitted hanoi lane step"):
        SIM.run_sm(b, CFG, inner="volta_itps", sm_mechanism="sm_jax")
    with pytest.raises(ValueError, match="composite"):
        SIM.run_sm(b, CFG, inner="sm_interleave", sm_mechanism="sm_jax")
    with pytest.raises(ValueError, match="sm_mechanism"):
        SIM.run_sm(b, CFG, sm_mechanism="sm_vulkan")
    # trace-conservative cycle accounting only: no scoreboard lift, no
    # stochastic memory model
    with pytest.raises(ValueError, match="scoreboard"):
        SIM.run_sm(b, CFG, sm_mechanism="sm_jax",
                   timing_cfg=CycleConfig(scoreboard=True))
    with pytest.raises(ValueError, match="stochastic-memory"):
        SIM.run_sm(b, CFG, sm_mechanism="sm_jax",
                   timing_cfg=CycleConfig(scoreboard=False,
                                          memory_model="uniform"))


# ---------------------------------------------------------------------------
# archive round-trip: sm_jax cells replay to exactly 0.0
# ---------------------------------------------------------------------------

def test_sm_jax_archive_round_trip_self_replay(tmp_path):
    sink = RotatingJsonlSink(str(tmp_path))
    sm = Simulator("hanoi", sink=sink).run_sm(
        [BENCH["DIAMOND"], BENCH["HOTS0"]], CFG, inner="hanoi_jax",
        policy="greedy_then_oldest", sm_mechanism="sm_jax")
    sink.flush()
    sink.close()
    reader = ArchiveReader(str(tmp_path))
    runs = reader.runs()
    assert len(runs) == sm.n_warps == 2
    assert all(r.replayable for r in runs)
    for w, run in enumerate(runs):
        assert run.meta["sm_warp"] == w
        assert run.meta["sm_warps"] == 2
        assert run.meta["sm_policy"] == "greedy_then_oldest"
        assert run.meta["mechanism"] == "hanoi_jax"
        assert run.trace == sm.warps[w].trace
    report = Replayer().replay(reader)
    assert report.replayed == 2
    assert report.skipped_unreplayable == 0
    assert all(r.discrepancy == 0.0 for r in report.rows)


# ---------------------------------------------------------------------------
# satellite: sm_interleave routes warps through the planner as ONE batch
# ---------------------------------------------------------------------------

def test_sm_interleave_dispatches_warps_as_one_native_batch():
    """A homogeneous 5-warp cell through an inner with a batch_runner must
    hit it exactly once with all 5 warp requests — not 5 scalar calls."""
    hanoi = get_mechanism("hanoi")
    calls = {"batch": 0, "scalar": 0, "sizes": []}

    def probe_batch(reqs):
        calls["batch"] += 1
        calls["sizes"].append(len(reqs))
        return [hanoi(q) for q in reqs]

    try:
        @register_mechanism("probe_counter", backend="numpy",
                            batch_runner=probe_batch, overwrite=True,
                            description="counts native dispatches (test)")
        def _probe(req):
            calls["scalar"] += 1
            return hanoi(req)

        sm = SIM.run_sm(BENCH["DIAMOND"], CFG, n_warps=5,
                        inner="probe_counter")
    finally:
        unregister_mechanism("probe_counter")
    assert sm.ok and sm.n_warps == 5
    assert calls == {"batch": 1, "scalar": 0, "sizes": [5]}


# ---------------------------------------------------------------------------
# satellite: hanoi_jax batches meter compilation separately from wall
# ---------------------------------------------------------------------------

def test_hanoi_jax_compile_time_metered_separately():
    """First batch on a fresh executable shape stamps ``compile_time_s``
    meta and excludes it from ``wall_time_s``; a warm re-run of the same
    shape has no compile stamp at all."""
    cfg = MachineConfig(n_threads=8, mem_size=48, max_steps=4096)
    bench = next(b for b in make_suite(cfg, datasets=1)
                 if b.name == "DIAMOND")
    reqs = [SimRequest(program=bench.program, cfg=cfg,
                       init_mem=bench.init_mem, name=f"d{i}")
            for i in range(2)]
    cold = SIM.run_batch(reqs, mechanism="hanoi_jax")
    for r in cold:
        assert r.error is None
        assert r.meta.get("compile_time_s", 0.0) > 0.0
        # execution wall excludes the (much larger) trace-time compile
        assert 0.0 < r.wall_time_s < r.meta["compile_time_s"]
    warm = SIM.run_batch(reqs, mechanism="hanoi_jax")
    for r, c in zip(warm, cold):
        assert r.error is None
        assert "compile_time_s" not in r.meta
        assert r.trace == c.trace


# ---------------------------------------------------------------------------
# satellite: warp_count sized-sequence contract + service stats parity
# ---------------------------------------------------------------------------

def test_warp_count_accepts_any_sized_sequence():
    p = BENCH["DIAMOND"].program
    stack = np.stack([p, p, p])
    assert warp_count(stack, None) == 3
    assert [a.shape for a in per_warp_programs(stack, None)] == [p.shape] * 3
    assert warp_count([p, p], None) == 2
    assert warp_count(p, None) == DEFAULT_WARPS
    assert warp_count(p, 6) == 6
    assert warp_count(BENCH["DIAMOND"], None) == DEFAULT_WARPS

    class Deque:                       # sized, but not list/tuple/ndarray
        def __init__(self, items):
            self._items = list(items)

        def __len__(self):
            return len(self._items)

        def __iter__(self):
            return iter(self._items)

    assert warp_count(Deque([p, p]), None) == 2
    assert len(per_warp_programs(Deque([p, p]), None)) == 2
    with pytest.raises(TypeError, match="unsized iterable"):
        warp_count(iter([p, p]), None)
    with pytest.raises(TypeError, match="unsized iterable"):
        per_warp_programs((q for q in [p, p]), None)
    with pytest.raises(ValueError, match="conflicts"):
        per_warp_programs([p, p], 3)


def test_submit_sm_stats_count_ndarray_stack_warps():
    """The service's warp-level accounting uses the same warp_count as the
    façade: a 3-plane ndarray stack is 3 warps, not DEFAULT_WARPS."""
    stack = np.stack([BENCH["DIAMOND"].program] * 3)
    with SimulationService(default_mechanism="hanoi", workers=1) as svc:
        sm = svc.submit_sm(stack, CFG, policy="round_robin").result()
        stats = svc.stats()
    assert sm.n_warps == 3
    assert stats.sm_jobs == 1
    assert stats.submitted == stats.completed == 3
    assert stats.failed == 0
