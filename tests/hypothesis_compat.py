"""Degrade gracefully where hypothesis is absent.

``from tests.hypothesis_compat import given, settings, st`` works with or
without hypothesis installed: with it, these are the real objects; without
it, ``@given`` turns the test into an individually-skipped placeholder so
the *other* (example-based) tests in the same module still collect and run.
Modules that are 100% property-based can use ``pytest.importorskip``
instead; mixed modules should use this shim.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _REASON = ("hypothesis not installed "
               "(pip install -r requirements-dev.txt)")

    class _AnyStrategy:
        """Stand-in for hypothesis.strategies: any attribute is callable."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg placeholder: keeps pytest from trying to resolve the
            # strategy parameters as fixtures before honoring the skip
            @pytest.mark.skip(reason=_REASON)
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
