"""Process-tier service tests: cross-process bit-equality, per-shard
archives, warm start, shutdown semantics, and the bounded compile caches.

The spawn boundary is the point: every result that crosses it must be
bit-identical to the single-process façade, every shard's archive family
must self-replay to exactly 0.0, and a restarted warm-started service must
re-trace zero hot signatures.  ``_register_shard_probes`` is the shard
init hook — spawned shards import this module by reference (no
registration happens at import time, so collection never pollutes the
parent registry) and call it to install the probe mechanisms.
"""
from __future__ import annotations

import glob
import os
import time

import numpy as np
import pytest

from repro.archive import ArchiveReader, Replayer
from repro.archive.index import compact
from repro.core.isa import MachineConfig
from repro.core.programs import diamond_program, make_suite
from repro.engine import (RotatingJsonlSink, Simulator, adapters,
                          iter_mechanisms, register_mechanism,
                          unregister_mechanism)
from repro.engine.compile_cache import (CompileCache, affinity_token,
                                        shard_of_token,
                                        supports_serialization)
from repro.engine.simulator import as_request
from repro.service import ServiceStopped, SimulationService

CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=4096)
SUITE = make_suite(CFG, datasets=1)
SIM = Simulator("hanoi")


def _reqs(n=6, **kw):
    return [as_request(b, CFG, **kw) for b in SUITE[:n]]


def _same_outcome(a, b):
    """status / final regs / mem / fuel / trace equality."""
    assert a.status == b.status
    assert a.fuel_left == b.fuel_left
    assert a.finished == b.finished
    np.testing.assert_array_equal(a.regs, b.regs)
    np.testing.assert_array_equal(a.mem, b.mem)
    assert a.trace == b.trace


# ---------------------------------------------------------------------------
# shard init hook (pickled by reference into spawned shards)
# ---------------------------------------------------------------------------

def _register_shard_probes(shard: int) -> None:
    """Runs inside every spawned shard: install the probe mechanisms the
    tests below route to.  A parent-process ``register_mechanism`` call
    does not cross the spawn boundary — this hook is how plugins reach
    shard processes."""
    import time as _time

    from repro.engine import register_mechanism
    from repro.engine.types import SimStatus

    @register_mechanism("proc_probe", backend="numpy",
                        description="shard-side echo probe")
    def _probe(req):
        from repro.engine.adapters import result_from_runresult  # noqa: F401
        import dataclasses
        res = Simulator("hanoi").run(req)
        return dataclasses.replace(res, meta={**res.meta, "shard": shard})

    @register_mechanism("proc_sleeper", backend="numpy",
                        description="wedges the shard (shutdown tests)")
    def _sleeper(req):
        _time.sleep(120)
        raise RuntimeError("unreachable")


def _parent_stub(name):
    """Parent-side registration so signature_of/get_mechanism admit the
    request; execution happens in the shard."""
    def _never_runs(req):
        raise AssertionError(f"{name} must execute in a shard process")
    return register_mechanism(name, backend="numpy")(_never_runs)


# ---------------------------------------------------------------------------
# cross-process bit-equality
# ---------------------------------------------------------------------------

def test_every_mechanism_bit_equal_through_two_procs():
    """Every registered mechanism, run through a 2-process service, returns
    results bit-identical to the single-process ``Simulator.run_batch``."""
    names = sorted(m.name for m in iter_mechanisms())
    reqs = _reqs(3)
    with SimulationService(default_mechanism="hanoi", procs=2,
                           annotate=False) as svc:
        for name in names:
            got = svc.run(reqs, mechanism=name, timeout=600)
            want = Simulator(name).run_batch(reqs)
            for g, w in zip(got, want):
                _same_outcome(g, w)


def test_proc_results_annotated_with_shard():
    with SimulationService(default_mechanism="hanoi", procs=2) as svc:
        res = svc.run(_reqs(4), timeout=120)
    for r in res:
        svc_meta = r.meta["service"]
        assert svc_meta["shard"] in (0, 1)
        assert svc_meta["batch_size"] >= 1


def test_numpy_groups_spread_across_shards():
    """A homogeneous numpy group must NOT pin to one shard (that is the
    single-core ceiling the process tier exists to break)."""
    with SimulationService(default_mechanism="hanoi", procs=2,
                           max_batch=64) as svc:
        res = svc.run(_reqs(6), timeout=120)
        shards = {r.meta["service"]["shard"] for r in res}
        st = svc.stats()
    assert shards == {0, 1}
    assert {s.shard for s in st.shards if s.completed > 0} == {0, 1}


def test_jax_groups_route_affine_to_one_shard():
    """A signature-homogeneous jax group keeps its executable-cache
    locality: the whole group lands on its affinity shard."""
    with SimulationService(default_mechanism="hanoi_jax", procs=2) as svc:
        res = svc.run(_reqs(6), timeout=300)
        shards = {r.meta["service"]["shard"] for r in res}
    assert len(shards) == 1


def test_sm_grid_bit_equal_through_two_procs():
    progs = [b.program for b in SUITE[:4]]
    cells = [dict(programs=progs, cfg=CFG, n_warps=4, inner="hanoi",
                  policy=p) for p in ("round_robin", "greedy_then_oldest")]
    with SimulationService(default_mechanism="hanoi", procs=2) as svc:
        got = svc.run_sm_grid(cells, timeout=300)
        st = svc.stats()
    assert st.sm_jobs == 2
    for cell, sm in zip(cells, got):
        want = SIM.run_sm(progs, CFG, n_warps=4, inner="hanoi",
                          policy=cell["policy"])
        assert sm.sm_trace == want.sm_trace
        assert sm.cycles == want.cycles
        assert sm.stall_breakdown == want.stall_breakdown
        for g, w in zip(sm.warps, want.warps):
            _same_outcome(g, w)


def test_shard_init_registers_plugin_mechanisms_in_shards():
    _parent_stub("proc_probe")
    try:
        with SimulationService(default_mechanism="hanoi", procs=2,
                               shard_init=_register_shard_probes) as svc:
            got = svc.run(_reqs(4), mechanism="proc_probe", timeout=120)
        want = SIM.run_batch(_reqs(4))
        for g, w in zip(got, want):
            _same_outcome(g, w)
            assert g.meta["shard"] in (0, 1)
    finally:
        unregister_mechanism("proc_probe")


def test_shard_exception_rebuilt_parent_side():
    with SimulationService(default_mechanism="hanoi", procs=1) as svc:
        # a mechanism unknown to the shard raises there and crosses back
        _parent_stub("proc_parent_only")
        try:
            t2 = svc.submit(diamond_program(), CFG,
                            mechanism="proc_parent_only")
            svc.flush()
            with pytest.raises(Exception) as ei:
                t2.result(timeout=120)
            assert "proc_parent_only" in str(ei.value)
        finally:
            unregister_mechanism("proc_parent_only")
        st = svc.stats()
        assert st.failed >= 1


# ---------------------------------------------------------------------------
# per-shard archive families
# ---------------------------------------------------------------------------

def test_per_shard_archives_self_replay_to_zero(tmp_path):
    d = str(tmp_path)
    sink = RotatingJsonlSink(d, prefix="traces", max_bytes=1 << 20)
    with SimulationService(default_mechanism="hanoi", procs=2,
                           archive=sink) as svc:
        svc.run(_reqs(6), mechanism="hanoi", timeout=120)
        svc.run(_reqs(6), mechanism="hanoi_jax", timeout=300)
        svc.submit_sm([b.program for b in SUITE[:4]], CFG, n_warps=4,
                      inner="hanoi").result(120)
    sink.close()
    families = sorted(os.path.basename(p)
                      for p in glob.glob(os.path.join(d, "*.jsonl")))
    assert any("traces-shard0-" in f for f in families)
    assert any("traces-shard1-" in f for f in families)
    total = 0
    for k in range(2):
        reader = ArchiveReader(d, prefix=f"traces-shard{k}")
        runs = reader.runs()
        total += len(runs)
        rep = Replayer().replay(reader)
        assert rep.mean_discrepancy() == 0.0
        assert rep.replayed == len(runs)
        # archive stamps carry the shard id
        assert all(r.meta.get("shard") == k for r in runs)
    assert total == 16   # 6 hanoi + 6 hanoi_jax + 4 SM warps


def test_shard_family_index_and_compaction_still_work(tmp_path):
    d = str(tmp_path)
    sink = RotatingJsonlSink(d, prefix="traces", max_bytes=1 << 20)
    with SimulationService(default_mechanism="hanoi", procs=2,
                           archive=sink) as svc:
        svc.run(_reqs(6), mechanism="hanoi", timeout=120)
    sink.close()
    from repro.archive.index import ArchiveIndex
    for k in range(2):
        prefix = f"traces-shard{k}"
        reader = ArchiveReader(d, prefix=prefix)
        runs = reader.runs()
        if not runs:
            continue
        idx = ArchiveIndex.ensure(d, prefix=prefix)
        assert len(idx.entries) == len(runs)
        got = reader.get(idx.entries[0].run_id)  # sidecar index path
        assert got.meta == runs[0].meta and got.steps == runs[0].steps
        report = compact(d, prefix)
        assert report is not None
        after = ArchiveReader(d, prefix=prefix).runs()
        assert len(after) == len(runs)


def test_non_rotating_sink_fed_parent_side(tmp_path):
    from repro.engine import MemorySink
    sink = MemorySink()
    with SimulationService(default_mechanism="hanoi", procs=2,
                           archive=sink) as svc:
        svc.run(_reqs(4), timeout=120)
    assert len(sink.runs) == 4


# ---------------------------------------------------------------------------
# shutdown semantics
# ---------------------------------------------------------------------------

def test_stop_terminates_wedged_shard_and_resolves_tickets():
    _parent_stub("proc_sleeper")
    try:
        svc = SimulationService(default_mechanism="hanoi", procs=1,
                                shard_init=_register_shard_probes)
        svc.start()
        assert svc._pool.wait_ready(timeout=60.0)
        ticket = svc.submit(diamond_program(), CFG,
                            mechanism="proc_sleeper")
        svc.flush()
        time.sleep(0.5)                    # let the shard start sleeping
        t0 = time.monotonic()
        stragglers = svc.stop(timeout=1.0)
        elapsed = time.monotonic() - t0
        assert elapsed < 15.0
        assert "sim-shard-0" in stragglers
        with pytest.raises(ServiceStopped):
            ticket.result(timeout=5.0)
    finally:
        unregister_mechanism("proc_sleeper")


def test_clean_stop_reports_no_stragglers():
    svc = SimulationService(default_mechanism="hanoi", procs=2)
    svc.start()
    svc.run(_reqs(4), timeout=120)
    assert svc.stop(timeout=30.0) == []
    st = svc.stats()
    assert st.completed == 4 and st.inflight == 0


# ---------------------------------------------------------------------------
# warm start + compile-cache counters
# ---------------------------------------------------------------------------

def test_warm_start_restarted_service_retraces_zero(tmp_path):
    cache_dir = str(tmp_path / "ccache")
    svc1 = SimulationService(default_mechanism="hanoi_jax", procs=1,
                             warm_start=cache_dir)
    with svc1:
        svc1.run(_reqs(6), timeout=300)
        svc1.run(_reqs(3), timeout=300)    # second batch-class signature
    st1 = svc1.stats()
    assert st1.cache_misses >= 2           # cold compiles happened
    assert CompileCache(cache_dir).entries()   # manifest persisted

    svc2 = SimulationService(default_mechanism="hanoi_jax", procs=1,
                             warm_start=cache_dir)
    with svc2:
        svc2.run(_reqs(6), timeout=300)
        svc2.run(_reqs(3), timeout=300)
        st2 = svc2.stats()
    assert st2.warm_signatures >= 2
    # the warm-start contract: hot signatures never re-trace at serve time
    assert st2.cache_misses == st2.warm_retraced
    assert st2.cache_hits >= 2
    if supports_serialization():
        # this jaxlib deserializes AOT executables: zero re-trace anywhere
        assert st2.warm_retraced == 0
        assert st2.warm_loaded >= 2
        assert st2.cache_misses == 0


def test_thread_tier_warm_start(tmp_path):
    from repro.engine.compile_cache import uninstall_compile_cache
    cache_dir = str(tmp_path / "ccache")
    try:
        with SimulationService(default_mechanism="hanoi_jax",
                               warm_start=cache_dir) as svc:
            svc.run(_reqs(5), timeout=300)
        adapters.reset_batch_caches()      # simulate a process restart
        with SimulationService(default_mechanism="hanoi_jax",
                               warm_start=cache_dir) as svc2:
            before = svc2.stats()
            assert before.warm_signatures >= 1
            svc2.run(_reqs(5), timeout=300)
            after = svc2.stats()
        assert after.cache_misses == before.cache_misses   # zero re-trace
    finally:
        uninstall_compile_cache()
        adapters.reset_batch_caches()


# ---------------------------------------------------------------------------
# bounded in-memory caches (satellite: no more unbounded lru_cache)
# ---------------------------------------------------------------------------

def test_batch_caches_bounded_with_eviction_counters():
    adapters.reset_batch_caches()
    adapters.set_batch_cache_capacity(executables=2)
    try:
        sim = Simulator("hanoi_jax")
        for n in (1, 2, 3):
            sim.run_batch(_reqs(n))
        s = adapters.batch_cache_stats()
        assert s["entries"] <= 2
        assert s["evictions"] >= 1
        assert s["misses"] >= 3
        assert s["capacity"] == 2
        sim.run_batch(_reqs(3))            # most recent entry: a hit
        assert adapters.batch_cache_stats()["hits"] > s["hits"]
    finally:
        adapters.set_batch_cache_capacity(executables=256)
        adapters.reset_batch_caches()


def test_thread_tier_stats_surface_cache_counters():
    adapters.reset_batch_caches()
    with SimulationService(default_mechanism="hanoi_jax") as svc:
        svc.run(_reqs(4), timeout=300)
        st = svc.stats()
    assert st.procs == 0 and st.shards == ()
    assert st.cache_misses >= 1 or st.cache_hits >= 1
    assert st.cache_entries >= 1


# ---------------------------------------------------------------------------
# affinity hashing + envelope pickling
# ---------------------------------------------------------------------------

def test_affinity_token_stable_and_partitioning():
    tok = affinity_token("hanoi_jax", CFG, True, 32)
    assert tok == affinity_token("hanoi_jax", CFG, True, 32)
    assert tok != affinity_token("hanoi_jax", CFG, False, 32)
    assert tok != affinity_token("hanoi_jax", CFG, True, 64)
    for n in (1, 2, 3, 7):
        assert 0 <= shard_of_token(tok, n) < n
    assert shard_of_token(tok, 1) == 0


def test_request_result_pickle_roundtrip():
    import pickle
    import types as pytypes
    req = _reqs(1, meta={"k": 1})[0]
    r2 = pickle.loads(pickle.dumps(req))
    assert isinstance(r2.meta, pytypes.MappingProxyType)
    assert dict(r2.meta) == {"k": 1}
    np.testing.assert_array_equal(r2.program, req.program)
    res = SIM.run(req)
    res2 = pickle.loads(pickle.dumps(res))
    _same_outcome(res, res2)
    assert isinstance(res2.meta, pytypes.MappingProxyType)
    sm = SIM.run_sm([b.program for b in SUITE[:2]], CFG, n_warps=2,
                    inner="hanoi")
    sm2 = pickle.loads(pickle.dumps(sm))
    assert sm2.sm_trace == sm.sm_trace and sm2.cycles == sm.cycles
    for a, b in zip(sm.warps, sm2.warps):
        _same_outcome(a, b)
