"""The queue-fed simulation service: signatures, coalescing, dispatch,
order-preserving reassembly, native-batch routing, archival, metrics.

Acceptance contract (ISSUE 3): for a mixed batch spanning >= 3 mechanisms,
heterogeneous configs/shapes, and an SM job, the service returns results
identical (status / final regs / mem / fuel) to sequential
``Simulator.run`` / ``run_sm`` calls, in submission order, while routing
every homogeneous ``hanoi_jax`` group through the native vmap
``batch_runner``.
"""
import json
import threading
import time

import numpy as np
import pytest

from repro.core import MachineConfig
from repro.core.programs import make_suite
from repro.engine import (RotatingJsonlSink, SimRequest, Simulator,
                          as_request, available_mechanisms, get_mechanism,
                          iter_mechanisms, register_mechanism,
                          unregister_mechanism)
from repro.service import (BatchCoalescer, SimulationService, execute_plan,
                           plan_dispatch, signature_of)

CFG = MachineConfig(n_threads=8, mem_size=64, max_steps=8192)
SUITE = make_suite(CFG, datasets=1)
SIM = Simulator("hanoi")


def _bench(name):
    return next(b for b in SUITE if b.name == name)


def _same_outcome(a, b):
    """status / final regs / mem / fuel equality — the acceptance fields."""
    assert a.status == b.status
    assert a.fuel_left == b.fuel_left
    assert a.finished == b.finished
    np.testing.assert_array_equal(a.regs, b.regs)
    np.testing.assert_array_equal(a.mem, b.mem)
    assert a.trace == b.trace


# ---------------------------------------------------------------------------
# execution signatures
# ---------------------------------------------------------------------------

def test_signature_groups_compatible_requests():
    a = signature_of("hanoi_jax", as_request(_bench("DIAMOND"), CFG))
    b = signature_of("hanoi_jax", as_request(_bench("GAUS0"), CFG))
    # different programs and memory images, same execution signature
    assert a == b and hash(a) == hash(b)
    assert a.batchable


@pytest.mark.parametrize("override,field", [
    (dict(fuel=17), "cfg"),                      # fuel folds into the cfg
    (dict(cfg=CFG._replace(n_threads=4)), "cfg"),
    (dict(majority_first=False), "majority_first"),
    (dict(active0=0b0011), "batchable"),
    (dict(record_trace=False), "record_trace"),
    (dict(bsync_skip_pcs=(3,)), "skip_pcs"),
    (dict(meta={"itps_patience": 1}), "meta"),
])
def test_signature_splits_on(override, field):
    base = signature_of("hanoi", as_request(_bench("DIAMOND"), CFG))
    cfg = override.pop("cfg", CFG)
    changed = signature_of("hanoi", as_request(_bench("DIAMOND"), cfg,
                                               **override))
    assert base != changed
    assert getattr(base, field) != getattr(changed, field)


def test_signature_pad_class():
    short = signature_of("hanoi_jax", as_request(
        np.asarray(_bench("DIAMOND").program), CFG))
    assert short.pad_len % 32 == 0
    long_prog = np.concatenate([_bench("DIAMOND").program] * 8, axis=0)
    longer = signature_of("hanoi_jax", as_request(long_prog, CFG))
    assert longer.pad_len > short.pad_len     # different padding class


# ---------------------------------------------------------------------------
# coalescer flush rules (pure bookkeeping, fake clock)
# ---------------------------------------------------------------------------

def test_coalescer_size_flush():
    now = [0.0]
    c = BatchCoalescer(max_batch=3, max_wait_s=10.0, clock=lambda: now[0])
    sig = signature_of("hanoi", as_request(_bench("DIAMOND"), CFG))
    assert c.add(sig, "a") == (None, True)       # new bucket created
    assert c.add(sig, "b") == (None, False)      # joins the existing bucket
    full, created = c.add(sig, "c")
    assert not created
    assert full is not None and full.cause == "size"
    assert [e.payload for e in full.entries] == ["a", "b", "c"]
    assert c.depth() == 0


def test_coalescer_deadline_flush_only_when_due():
    now = [100.0]
    c = BatchCoalescer(max_batch=64, max_wait_s=0.5, clock=lambda: now[0])
    sig_a = signature_of("hanoi", as_request(_bench("DIAMOND"), CFG))
    sig_b = signature_of("simt_stack", as_request(_bench("DIAMOND"), CFG))
    c.add(sig_a, "a1")
    now[0] = 100.3
    c.add(sig_b, "b1")
    assert c.due() == []                          # nothing aged out yet
    assert c.next_deadline() == pytest.approx(100.5)
    now[0] = 100.6                                # only sig_a is due
    due = c.due()
    assert [g.signature for g in due] == [sig_a]
    assert due[0].cause == "deadline"
    assert c.depth() == 1                         # b1 still pending
    now[0] = 101.0
    assert [g.signature for g in c.due()] == [sig_b]


def test_coalescer_manual_flush_and_validation():
    c = BatchCoalescer(max_batch=4, max_wait_s=60.0)
    sig = signature_of("hanoi", as_request(_bench("DIAMOND"), CFG))
    c.add(sig, "x")
    groups = c.flush_all()
    assert len(groups) == 1 and groups[0].cause == "manual"
    assert c.depth() == 0 and c.next_deadline() is None
    with pytest.raises(ValueError):
        BatchCoalescer(max_batch=0)
    with pytest.raises(ValueError):
        BatchCoalescer(max_wait_s=-1)


# ---------------------------------------------------------------------------
# planner: the shared dispatch path
# ---------------------------------------------------------------------------

def test_plan_routes_homogeneous_subgroups_natively():
    mech = get_mechanism("hanoi_jax")
    reqs = [as_request(_bench("DIAMOND"), CFG),
            as_request(_bench("GAUS0"), CFG),
            as_request(_bench("DIAMOND"), CFG, fuel=64),    # different fuel
            as_request(_bench("DIAMOND"), CFG, active0=0b1)]  # masked entry
    plan = plan_dispatch(mech, reqs)
    routed = {i: g.native for g in plan for i in g.indices}
    assert routed == {0: True, 1: True, 2: True, 3: False}
    sizes = sorted(g.size for g in plan)
    assert sizes == [1, 1, 2]                    # mixed batch, 3 groups


def test_execute_plan_preserves_order_and_matches_singles():
    mech = get_mechanism("hanoi")
    names = ["HOTS0", "GAUS0", "RBFS0", "DIAMOND"]
    reqs = [as_request(_bench(n), CFG) for n in names]
    out = execute_plan(mech, reqs)
    for req, res in zip(reqs, out):
        _same_outcome(res, SIM.run(req))


def test_run_batch_mixed_jax_batch_still_uses_native_groups():
    """The façade regression the planner fixes: a heterogeneous batch no
    longer forfeits native execution for its homogeneous sub-groups."""
    reqs = [as_request(_bench("DIAMOND"), CFG),
            as_request(_bench("GAUS0"), CFG),
            as_request(_bench("DIAMOND"), CFG, fuel=64)]
    mech = get_mechanism("hanoi_jax")
    plan = plan_dispatch(mech, reqs)
    assert all(g.native for g in plan) and len(plan) == 2
    out = SIM.run_batch(reqs, mechanism="hanoi_jax")
    for req, res in zip(reqs, out):
        _same_outcome(res, SIM.run(req, mechanism="hanoi_jax"))


# ---------------------------------------------------------------------------
# service: equivalence across every registered mechanism
# ---------------------------------------------------------------------------

def test_service_matches_per_request_run_for_every_mechanism():
    bench = _bench("DIAMOND")
    mechs = [m.name for m in iter_mechanisms()]
    assert len(mechs) >= 6
    with SimulationService(default_mechanism="hanoi", max_batch=8,
                           max_wait_s=0.01, workers=2) as svc:
        tickets = [(name, svc.submit(bench, CFG, mechanism=name))
                   for name in mechs]
        svc.flush()
        for name, t in tickets:
            _same_outcome(t.result(120), SIM.run(bench, CFG, mechanism=name))


# ---------------------------------------------------------------------------
# service: the acceptance-criterion mixed batch
# ---------------------------------------------------------------------------

def test_service_mixed_batch_order_and_equivalence():
    """>= 3 mechanisms, heterogeneous cfgs/shapes, an SM job: identical to
    sequential run()/run_sm(), in submission order, with every homogeneous
    hanoi_jax group natively batched."""
    small = MachineConfig(n_threads=4, mem_size=64, max_steps=4096)
    jobs = [
        ("hanoi_jax", as_request(_bench("DIAMOND"), CFG)),
        ("hanoi", as_request(_bench("GAUS0"), CFG)),
        ("hanoi_jax", as_request(_bench("GAUS0"), CFG)),
        ("simt_stack", as_request(_bench("HOTS0"), CFG)),
        ("hanoi_jax", as_request(_bench("RBFS0"), small)),   # other cfg
        ("volta_itps", as_request(_bench("DIAMOND"), CFG)),
        ("hanoi_jax", as_request(_bench("HOTS0"), CFG)),
        ("dualpath", as_request(_bench("DIAMOND"), small)),
    ]
    expected = [SIM.run(req, mechanism=name) for name, req in jobs]
    sm_expected = SIM.run_sm(_bench("RBFS0"), CFG, n_warps=4, inner="hanoi",
                             policy="greedy_then_oldest")
    # max_wait_s is deliberately long: grouping assertions below depend on
    # the deadline flusher NOT firing mid-submission; flush() drives dispatch
    with SimulationService(default_mechanism="hanoi_jax", max_batch=16,
                           max_wait_s=30.0, workers=3) as svc:
        tickets = [svc.submit(req, mechanism=name) for name, req in jobs]
        sm_ticket = svc.submit_sm(_bench("RBFS0"), CFG, n_warps=4,
                                  inner="hanoi",
                                  policy="greedy_then_oldest")
        svc.flush()
        results = [t.result(180) for t in tickets]
        sm = sm_ticket.result(180)
        stats = svc.stats()
    # submission order and architectural equivalence
    for res, exp in zip(results, expected):
        assert res.mechanism == exp.mechanism
        _same_outcome(res, exp)
    # the instrumentation assert: homogeneous hanoi_jax groups (3 CFG warps
    # in one group; the small-cfg one alone) actually hit the batch_runner
    for i, (name, _) in enumerate(jobs):
        if name == "hanoi_jax":
            assert results[i].meta["service"]["native"] is True
    cfg_group = [results[i].meta["service"] for i, (n, _) in enumerate(jobs)
                 if n == "hanoi_jax"
                 and results[i].meta["service"]["batch_size"] == 3]
    assert len(cfg_group) == 3                   # coalesced into ONE batch
    assert stats.native_batches >= 2
    assert stats.native_warps == 4
    # the SM cell: one sharded run_sm call, identical aggregate
    assert sm.policy == sm_expected.policy and sm.inner == sm_expected.inner
    assert sm.sm_trace == sm_expected.sm_trace
    assert sm.cycles == sm_expected.cycles
    assert sm.status == sm_expected.status
    for w_res, w_exp in zip(sm.warps, sm_expected.warps):
        _same_outcome(w_res, w_exp)
    assert stats.sm_jobs == 1
    # the SM cell counts per warp into the warp-level counters
    assert stats.completed == len(jobs) + sm.n_warps
    assert stats.failed == 0 and stats.inflight == 0


def test_service_native_batch_instrumented_probe():
    """White-box routing proof: a probe mechanism whose batch_runner counts
    invocations — the service must execute a homogeneous group through it
    exactly once and never fall back to the per-request runner."""
    calls = {"batch": 0, "single": 0, "sizes": []}

    def probe_batch(reqs):
        calls["batch"] += 1
        calls["sizes"].append(len(reqs))
        return [SIM.run(r) for r in reqs]

    @register_mechanism("probe_native", backend="numpy",
                        batch_runner=probe_batch,
                        description="test probe: counting batch_runner")
    def probe_single(req):
        calls["single"] += 1
        return SIM.run(req)

    try:
        with SimulationService(default_mechanism="probe_native",
                               max_batch=4, max_wait_s=5.0,
                               workers=1) as svc:
            tickets = svc.submit_many([_bench("DIAMOND")] * 4, CFG)
            results = [t.result(60) for t in tickets]   # size-flush: no wait
            stats = svc.stats()
    finally:
        unregister_mechanism("probe_native")
    assert calls == {"batch": 1, "single": 0, "sizes": [4]}
    assert stats.flush_size == 1 and stats.native_batches == 1
    assert all(r.meta["service"]["flush"] == "size" for r in results)
    assert dict(stats.batch_fill) == {4: 1}


# ---------------------------------------------------------------------------
# service: flush rules end to end, stats, failure path
# ---------------------------------------------------------------------------

def test_service_deadline_flush_resolves_without_manual_flush():
    with SimulationService(default_mechanism="hanoi", max_batch=64,
                           max_wait_s=0.05, workers=1) as svc:
        t = svc.submit(_bench("DIAMOND"), CFG)
        res = t.result(timeout=30)               # deadline flush must fire
        stats = svc.stats()
    assert res.ok
    assert stats.flush_deadline == 1 and stats.flush_size == 0
    assert res.meta["service"]["flush"] == "deadline"


def test_service_stats_shape_and_latency():
    with SimulationService(default_mechanism="hanoi", max_batch=2,
                           max_wait_s=30.0, workers=2) as svc:
        svc.run([_bench("DIAMOND")] * 4, CFG)   # two size-flushes of 2
        stats = svc.stats()
    assert stats.submitted == stats.completed == 4
    assert stats.queue_depth == 0 and stats.inflight == 0
    assert stats.latency_p50_s <= stats.latency_p99_s
    assert stats.warps_per_s > 0
    assert stats.mean_fill == pytest.approx(2.0)
    assert stats.uptime_s > 0


def test_service_failure_resolves_ticket_with_exception():
    @register_mechanism("probe_boom", backend="numpy",
                        description="test probe: always raises")
    def _boom(req):
        raise RuntimeError("probe exploded")

    try:
        with SimulationService(default_mechanism="probe_boom",
                               max_batch=2, max_wait_s=0.01,
                               workers=1) as svc:
            t = svc.submit(_bench("DIAMOND"), CFG)
            svc.flush()
            with pytest.raises(RuntimeError, match="probe exploded"):
                t.result(30)
            stats = svc.stats()
    finally:
        unregister_mechanism("probe_boom")
    assert stats.failed == 1 and stats.completed == 0
    assert stats.inflight == 0                    # accounting stays balanced


def test_short_batch_runner_is_an_error_not_a_hang():
    """A plugin batch_runner that drops results must resolve every ticket
    with a diagnosable error — never leave the tail hanging."""
    @register_mechanism("probe_short", backend="numpy",
                        batch_runner=lambda reqs:
                            [SIM.run(r) for r in reqs[:-1]],
                        description="test probe: drops the last result")
    def _probe_short(req):
        return SIM.run(req)

    try:
        with pytest.raises(RuntimeError, match="returned 1 results for 2"):
            SIM.run_batch([_bench("DIAMOND")] * 2, CFG,
                          mechanism="probe_short")
        with SimulationService(default_mechanism="probe_short", max_batch=2,
                               max_wait_s=5.0, workers=1) as svc:
            tickets = svc.submit_many([_bench("DIAMOND")] * 2, CFG)
            for t in tickets:
                with pytest.raises(RuntimeError, match="batch_runner"):
                    t.result(30)
            assert svc.stats().failed == 2
    finally:
        unregister_mechanism("probe_short")


def test_service_restarts_after_stop():
    """stop() drains and joins; a later submit transparently restarts the
    service (lazy start is the same path first use takes)."""
    svc = SimulationService(default_mechanism="hanoi", max_batch=1,
                            workers=1)
    assert svc.run([_bench("DIAMOND")], CFG)[0].ok
    svc.stop()
    t = svc.submit(_bench("DIAMOND"), CFG)      # auto-restart
    svc.flush()
    assert t.result(30).ok
    svc.stop()


def test_run_sm_grid_shards_cells():
    cells = [dict(programs=_bench("RBFS0"), cfg=CFG, n_warps=w,
                  inner="hanoi", policy=p)
             for w in (2, 4) for p in ("round_robin", "greedy_then_oldest")]
    with SimulationService(default_mechanism="hanoi", workers=3) as svc:
        grid = svc.run_sm_grid(cells, timeout=120)
        stats = svc.stats()
    assert stats.sm_jobs == len(cells)
    for cell, sm in zip(cells, grid):
        exp = SIM.run_sm(cell["programs"], CFG, n_warps=cell["n_warps"],
                         inner="hanoi", policy=cell["policy"])
        assert sm.n_warps == cell["n_warps"] and sm.policy == cell["policy"]
        assert sm.sm_trace == exp.sm_trace and sm.cycles == exp.cycles


def test_sm_cell_stats_count_per_warp():
    """ISSUE 5 satellite regression: an SM cell used to bump submitted/
    completed by 1 regardless of width, undercounting warps_per_s by
    n_warps x.  Fixed samples: a 3-warp replicated cell + a 2-warp
    heterogeneous cell = 5 warps, 2 cells, 2 latency samples."""
    with SimulationService(default_mechanism="hanoi", workers=1) as svc:
        rep = svc.submit_sm(_bench("DIAMOND"), CFG, n_warps=3,
                            inner="hanoi").result(120)
        het = svc.submit_sm([_bench("DIAMOND"), _bench("HOTS0")], CFG,
                            inner="hanoi").result(120)
        stats = svc.stats()
    assert rep.n_warps == 3 and het.n_warps == 2
    assert stats.submitted == stats.completed == 5    # warps, not cells
    assert stats.sm_jobs == 2
    assert stats.failed == 0 and stats.inflight == 0
    assert stats.warps_per_s == pytest.approx(5 / stats.uptime_s)
    assert len(svc._latencies) == 2                   # cell latency: once


def test_sm_cell_failure_counts_per_warp():
    with SimulationService(default_mechanism="hanoi", workers=1) as svc:
        # 2 per-warp programs conflicting with n_warps=3 -> run_sm raises
        t = svc.submit_sm([_bench("DIAMOND"), _bench("HOTS0")], CFG,
                          n_warps=3, inner="hanoi")
        with pytest.raises(ValueError, match="conflicts"):
            t.result(120)
        stats = svc.stats()
    assert stats.failed == 2 and stats.completed == 0
    assert stats.inflight == 0                        # accounting balanced


def test_stop_shared_deadline_reports_stragglers():
    """ISSUE 5 satellite: stop(timeout=T) must be ONE deadline across all
    joins — per-thread budgets made worst-case shutdown (workers+1) x T —
    and must report the threads still alive at expiry."""
    svc = SimulationService(default_mechanism="hanoi", workers=2)
    svc.start()
    assert svc.run([_bench("DIAMOND")], CFG)[0].ok
    sleepers = [threading.Thread(target=time.sleep, args=(30,),
                                 daemon=True, name=f"wedged-{i}")
                for i in range(3)]
    for t in sleepers:
        t.start()
        svc._threads.append(t)                       # simulate wedged threads
    t0 = time.monotonic()
    stragglers = svc.stop(timeout=0.5)
    elapsed = time.monotonic() - t0
    # per-thread budgets would take >= 3 x 0.5s on the sleepers alone
    assert elapsed < 1.2, elapsed
    assert sorted(stragglers) == [f"wedged-{i}" for i in range(3)]
    # a clean stop reports no stragglers
    with SimulationService(default_mechanism="hanoi", workers=1) as svc2:
        svc2.run([_bench("DIAMOND")], CFG)
    assert svc2.stop() == []                         # idempotent, clean


# ---------------------------------------------------------------------------
# durable archival: rotating buffered sink
# ---------------------------------------------------------------------------

def test_rotating_sink_rotates_and_preserves_runs(tmp_path):
    sink = RotatingJsonlSink(str(tmp_path), prefix="t", max_bytes=2000)
    r = SIM.run(_bench("DIAMOND"), CFG)
    for i in range(12):
        from repro.engine import feed_result
        feed_result(sink, r, {"mechanism": "hanoi", "program": f"p{i}"})
    sink.flush()
    sink.close()
    assert len(sink.paths) > 1                   # rotation happened
    assert sink.runs_written == 12
    begins, ends = [], []
    for path in sink.paths:
        state = None
        for line in open(path, encoding="utf-8"):
            ev = json.loads(line)
            if ev["event"] == "begin":
                assert state in (None, "end")    # runs never interleave
                state = "begin"
                begins.append(ev["program"])
            elif ev["event"] == "end":
                state = "end"
                ends.append(ev["status"])
    assert sorted(begins) == sorted(f"p{i}" for i in range(12))
    assert len(ends) == 12 and set(ends) == {"ok"}
    with pytest.raises(RuntimeError):
        sink.begin({})                           # closed sink refuses events


def test_rotating_sink_survives_io_failure(tmp_path, monkeypatch):
    """A writer-side IO error must degrade (drop + record), never wedge
    producers in end() or flush() — the failure mode is a dead archive,
    not a hung service."""
    from repro.engine import feed_result
    sink = RotatingJsonlSink(str(tmp_path), max_bytes=1 << 20)
    r = SIM.run(_bench("DIAMOND"), CFG)
    feed_result(sink, r, {"mechanism": "hanoi", "program": "ok"})
    sink.flush()
    assert sink.runs_written == 1 and sink.write_error is None
    monkeypatch.setattr(sink, "_rotate",
                        lambda: (_ for _ in ()).throw(OSError("disk full")))
    sink._fh.close()                             # force the rotate path
    sink._fh = None
    for i in range(3):                           # producers never block
        feed_result(sink, r, {"mechanism": "hanoi", "program": f"bad{i}"})
    sink.flush()                                 # returns: queue fully acked
    assert isinstance(sink.write_error, OSError)
    assert sink.runs_dropped == 3 and sink.runs_written == 1
    sink.close()


def test_service_archives_whole_runs_concurrently(tmp_path):
    sink = RotatingJsonlSink(str(tmp_path), max_bytes=1 << 20)
    names = ["HOTS0", "GAUS0", "RBFS0", "DIAMOND"] * 2
    with SimulationService(default_mechanism="hanoi", max_batch=2,
                           max_wait_s=0.01, workers=3,
                           archive=sink) as svc:
        svc.run([_bench(n) for n in names], CFG)
    sink.flush()
    sink.close()
    assert sink.runs_written == len(names)
    events = [json.loads(l) for p in sink.paths
              for l in open(p, encoding="utf-8")]
    assert sum(e["event"] == "begin" for e in events) == len(names)
    assert sum(e["event"] == "end" for e in events) == len(names)
    # every run's events are contiguous (begin ... end with no foreign run)
    depth = 0
    for e in events:
        if e["event"] == "begin":
            depth += 1
        elif e["event"] == "end":
            depth -= 1
        assert depth in (0, 1)


# ---------------------------------------------------------------------------
# serve_simulations: the thin client keeps its contract
# ---------------------------------------------------------------------------

def test_serve_simulations_thin_client():
    from repro.launch.serve import serve_simulations
    reqs = [SimRequest(program=_bench("DIAMOND").program, cfg=CFG,
                       name=f"req{i}") for i in range(4)]
    out = serve_simulations(reqs, mechanism="hanoi", max_workers=2)
    assert out["mechanism"] == "hanoi"
    assert out["ok"] == 4 and out["failed"] == 0
    assert len(out["results"]) == 4 and out["warps_per_s"] > 0
    assert out["stats"].completed == 4
    for res, req in zip(out["results"], reqs):
        _same_outcome(res, SIM.run(req))


# ---------------------------------------------------------------------------
# regressions (ISSUE 4 satellites): percentile indexing + sink accounting
# ---------------------------------------------------------------------------

def test_stats_percentiles_nearest_rank():
    """pct() must be ceil(p*n)-1 nearest-rank: int(p*n) was one-off-high
    (p50 of 2 samples returned the max; index 500 instead of 499 at
    n=1000)."""
    svc = SimulationService(default_mechanism="hanoi")
    svc._latencies.extend([0.2, 0.1])
    s = svc.stats()
    assert s.latency_p50_s == 0.1                  # the lower sample
    assert s.latency_p99_s == 0.2
    svc._latencies.clear()
    svc._latencies.extend(float(i) for i in range(1, 1001))
    s = svc.stats()
    assert s.latency_p50_s == 500.0                # index 499, not 500
    assert s.latency_p99_s == 990.0                # ceil(990)-1 = 989
    svc._latencies.clear()
    svc._latencies.append(5.0)
    s = svc.stats()
    assert s.latency_p50_s == 5.0 and s.latency_p99_s == 5.0
    svc._latencies.clear()
    assert np.isnan(svc.stats().latency_p50_s)


def test_rotating_sink_measures_encoded_bytes(tmp_path):
    """max_bytes rotation and bytes_written must count encoded UTF-8
    bytes; len(chunk) (characters) undercounts multi-byte meta."""
    import os
    from repro.engine import feed_result
    meta = {"mechanism": "hanoi", "program": "é" * 120}   # 2-byte chars
    r = SIM.run(_bench("DIAMOND"), CFG)
    probe = RotatingJsonlSink(str(tmp_path / "probe"))
    feed_result(probe, r, meta)
    probe.flush()
    probe.close()
    chunk_bytes = os.path.getsize(probe.paths[0])
    chunk_chars = len(open(probe.paths[0], encoding="utf-8").read())
    assert chunk_bytes > chunk_chars                      # multi-byte meta
    # character accounting would pack 2 runs per file; byte accounting
    # rotates after every run
    max_bytes = 2 * chunk_chars
    assert max_bytes < 2 * chunk_bytes
    sink = RotatingJsonlSink(str(tmp_path / "real"), max_bytes=max_bytes)
    for _ in range(4):
        feed_result(sink, r, meta)
    sink.flush()
    sink.close()
    assert len(sink.paths) == 4                           # crossed per run
    sizes = [os.path.getsize(p) for p in sink.paths]
    assert all(s <= max_bytes for s in sizes)             # never overshoot
    assert sink.bytes_written == sum(sizes)               # on-disk truth
    for path in sink.paths:                               # still valid JSONL
        for line in open(path, encoding="utf-8"):
            json.loads(line)


def test_rotating_sink_guards_protocol_violations(tmp_path):
    """end() without begin() and emit() outside a run are dropped and
    counted (an enqueued chunk with no begin event would be unreadable by
    ArchiveReader); a begin() over a stale unfinished buffer discards it."""
    from repro.engine import feed_result
    sink = RotatingJsonlSink(str(tmp_path))
    r = SIM.run(_bench("DIAMOND"), CFG)
    sink.end(r)                                    # no begin: drop + count
    sink.emit(1, 3)                                # orphan emit: drop + count
    assert sink.runs_malformed == 1
    assert sink.events_orphaned == 1
    feed_result(sink, r, {"mechanism": "hanoi", "program": "good"})
    # producer that errored between begin and end leaves a stale buffer...
    sink.begin({"mechanism": "hanoi", "program": "halfdone"})
    sink.emit(0, 1)
    # ...which the next begin() on that thread discards
    sink.begin({"mechanism": "hanoi", "program": "fresh"})
    sink.emit(0, 1)
    sink.end(r)
    sink.flush()
    sink.close()
    assert sink.runs_stale == 1
    assert sink.runs_written == 2                  # "good" and "fresh" only
    events = [json.loads(l) for p in sink.paths
              for l in open(p, encoding="utf-8")]
    begins = [e["program"] for e in events if e["event"] == "begin"]
    assert begins == ["good", "fresh"]             # no "halfdone" on disk
    assert sum(e["event"] == "end" for e in events) == 2
