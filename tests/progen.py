"""Random structured-program generator shared by the property suites.

Lives outside the test modules (and imports no hypothesis) so that
benchmark/property consumers can build the same If/While/BREAK program
distribution regardless of whether hypothesis is installed.

Three distributions:

* ``make_program(seed, n_bx)`` — the original If/While/BREAK nest
  distribution, unchanged (bit-identical rng stream) so the long-standing
  property suites keep exercising exactly the same programs;
* ``make_program(seed, n_bx, sync_features=True)`` — additionally weaves in
  the synchronization-heavy shapes the multi-mechanism conformance suite
  needs: top-level WARPSYNC joins, a Fig 3/7-style spinlock region (CAS
  acquire loop + YIELD + observable critical section + EXCH release), and a
  BREAK loop with a nested inner While (divergence-region depth >= 2).
  These programs deadlock pre-Volta by design (simt_stack has no YIELD),
  which is exactly what the differential suite's "agree wherever both
  finish" contract is for.  Memory is widened so the lock/counter cells sit
  above every lane-private address.
* ``make_program(seed, n_bx, mem_features=True)`` — additionally weaves in
  the memory-latency-heavy shapes the cycle-accurate timing suite needs:
  long-latency loads feeding dependent ALU chains (the scoreboard must
  stall the consumer, not the whole warp) and loads inside divergent
  branches (only part of the warp is behind the miss).  Drawn from an
  independent rng stream, so base shapes per seed are unchanged.

Feature flags compose: each draws from its own seeded rng, and none of
them perturbs the historical base stream.

Orthogonally, ``unannotated=True`` strips the compiler-planted
BSSY/BSYNC/BMOV (and spin-loop YIELDs) from any of the three
distributions after compilation — the same shapes, presented the way the
annotation synthesizer (:mod:`repro.analysis.transform`) receives them.
Rng streams are untouched: stripping is a post-pass on the encoded
program.
"""
import numpy as np

from repro.core import MachineConfig, compile_structured
from repro.core.structured import If, Raw, Seq, While

W = 8
MEM = 64
BASE_CFG = MachineConfig(n_threads=W, n_regs=16, n_preds=4, n_bx=8,
                         mem_size=MEM, max_steps=20_000)

# sync-feature programs get a widened memory so the spinlock's shared cells
# cannot collide with lane-private reads (cells < 4W) or writes (< 8W)
SYNC_MEM = 96
LOCK_CELL = 8 * W              # 64: the mutex
COUNTER_CELL = 8 * W + 1       # 65: the observable critical-section counter

# lane-private address offsets: lower half of memory is read-only input,
# upper half is written at lane-private cells
_RD_OFFS = [0, W, 2 * W, 3 * W]
_WR_OFFS = [4 * W, 5 * W, 6 * W, 7 * W]


def _raw(rng) -> Raw:
    ops = []
    for _ in range(rng.integers(1, 4)):
        k = rng.integers(0, 6)
        if k == 0:
            ops.append(f"IADDI R2, R2, {int(rng.integers(-3, 4))}")
        elif k == 1:
            ops.append("IADD R5, R2, R1")
        elif k == 2:
            ops.append("XOR R6, R5, R2")
        elif k == 3:
            ops.append(f"LDG R5, [R1+{int(rng.choice(_RD_OFFS))}]")
        elif k == 4:
            ops.append(f"STG [R1+{int(rng.choice(_WR_OFFS))}], R5")
        else:
            ops.append("IADD R2, R2, R5")
    return Raw(ops)


def _cond(rng, pred: int) -> list[str]:
    reg = rng.choice(["R2", "R5", "R6", "R1"])
    cmp = rng.choice(["LT", "GT", "EQ", "NE", "GE", "LE"])
    return [f"ISETP.{cmp} P{pred}, {reg}, {int(rng.integers(-2, 5))}"]


def _node(rng, depth: int, loop_level: int) -> "Seq | If | While | Raw":
    choices = ["raw", "seq"]
    if depth < 3:
        choices += ["if", "if", "while"]
    kind = rng.choice(choices)
    if kind == "raw":
        return _raw(rng)
    if kind == "seq":
        return Seq([_node(rng, depth, loop_level)
                    for _ in range(rng.integers(1, 3))])
    pred = int(rng.integers(0, 2))
    if kind == "if":
        has_else = bool(rng.integers(0, 2))
        return If(cond=_cond(rng, pred), pred=pred,
                  then_=_node(rng, depth + 1, loop_level),
                  else_=_node(rng, depth + 1, loop_level) if has_else else None)
    # while: bounded counter in R{8+loop_level}
    rc = 8 + loop_level
    bound = int(rng.integers(1, 4))
    body = Seq([Raw([f"IADDI R{rc}, R{rc}, 1"]),
                _node(rng, depth + 1, loop_level + 1)])
    brk = None
    if rng.integers(0, 3) == 0:
        body = Seq([Raw(["ISETP.GT P2, R5, 6"]), body])
        brk = 2
    return Seq([Raw([f"MOV R{rc}, 0"]),
                While(cond=[f"ISETP.LT P{pred}, R{rc}, {bound}"], pred=pred,
                      body=body, break_pred=brk)])


_SYNC_UID = [0]    # unique label suffixes across spinlock regions


def _spinlock_node() -> Raw:
    """A Fig 3/7-style spinlock region with an *observable* critical section.

    Mirrors ``programs.SPINLOCK_ASM`` (BSSY bracket, YIELD at the loop head
    so Hanoi's sibling switch can reach the lock holder, CAS acquire,
    non-atomic counter increment, EXCH release) on dedicated shared cells
    above the lane-private range.  The final state is schedule-invariant:
    the lock cell ends 0, the counter ends W (mutual exclusion), every
    lane's last CAS returned 0 and its EXCH returned 1 — only the *transit*
    registers R14/R15 (not in CHECK_REGS) ever hold schedule-dependent
    values.  Top-level only: R14/R15 double as Bx spill registers inside
    deeply nested regions, and no spill is live between top-level regions.

    The lock cell is freed by ``make_program``'s init-mem, NOT by a runtime
    store: on a per-thread-PC machine a straggler lane reaching a runtime
    "zero the lock" store while another lane holds the lock would break
    mutual exclusion — the schedule-invariance argument above needs the
    protocol to be self-contained.
    """
    uid = _SYNC_UID[0]
    _SYNC_UID[0] += 1
    return Raw([
        "MOV R12, 0",
        "MOV R13, 1",
        f"BSSY B0, slk_end_{uid}",
        f"slk_loop_{uid}:",
        "YIELD",
        f"ATOMCAS R14, [R12+{LOCK_CELL}], R12, R13",
        "ISETP.NE P3, R14, 0",
        f"@P3 BRA slk_loop_{uid}",
        f"LDG R15, [R12+{COUNTER_CELL}]",    # critical section: counter++
        "IADDI R15, R15, 1",
        f"STG [R12+{COUNTER_CELL}], R15",
        f"ATOMEXCH R14, [R12+{LOCK_CELL}], R12",
        f"slk_end_{uid}:",
        "BSYNC B0",
    ])


def _break_nested_while(rng) -> Seq:
    """A BREAK loop whose body contains a nested While: divergence-region
    depth >= 2 under an early-exit-past-BSYNC region (the Fig 6 shape the
    compiler dedicates a Bx register to)."""
    inner = Seq([Raw(["MOV R10, 0"]),
                 While(cond=["ISETP.LT P1, R10, 2"], pred=1,
                       body=Seq([Raw(["IADDI R10, R10, 1"]), _raw(rng)]))])
    bound = int(rng.integers(2, 5))
    body = Seq([Raw([f"ISETP.GT P2, R5, {int(rng.integers(4, 9))}"]),
                Raw(["IADDI R9, R9, 1"]), inner])
    return Seq([Raw(["MOV R9, 0"]),
                While(cond=[f"ISETP.LT P0, R9, {bound}"], pred=0,
                      body=body, break_pred=2)])


def _load_use_chain(mrng) -> Raw:
    """A long-latency load feeding a dependent ALU chain.

    The first consumer (``IADD R6, R5, R6``) has a RAW hazard on the load
    destination: under the cycle model the scoreboard must park the warp
    for the full memory latency before the chain can start, while the
    trace-conservative model charges only the issue slot.  The chain then
    alternates R5/R6 so every instruction depends on its predecessor —
    no independent work for dual-issue to hide the miss behind.
    """
    ops = [f"LDG R5, [R1+{int(mrng.choice(_RD_OFFS))}]"]
    for _ in range(int(mrng.integers(3, 7))):
        ops.append("IADD R6, R5, R6")
        ops.append("XOR R5, R6, R2")
    return Raw(ops)


def _divergent_load(mrng) -> If:
    """A load inside a divergent branch (the load-behind-divergence shape).

    Only the lanes that take the branch are behind the miss; the timing
    model still stalls the whole warp (per-warp scoreboard), which is the
    behaviour the stall-taxonomy tests pin down.
    """
    then_ = Raw([f"LDG R5, [R1+{int(mrng.choice(_RD_OFFS))}]",
                 "IADD R6, R6, R5"])
    else_ = Raw([f"LDG R5, [R1+{int(mrng.choice(_RD_OFFS))}]",
                 "XOR R6, R5, R2"])
    return If(cond=[f"ISETP.LT P0, R1, {int(mrng.integers(1, W))}"], pred=0,
              then_=then_, else_=else_ if mrng.integers(0, 2) else None)


def make_program(seed: int, n_bx: int, *, sync_features: bool = False,
                 mem_features: bool = False, unannotated: bool = False):
    """Build one random program; returns ``((prog, mem), cfg)`` or
    ``(None, cfg)`` for legitimately rejected shapes.

    All flags off reproduces the historical distribution exactly (same rng
    stream, same MachineConfig).  ``sync_features=True`` draws the
    synchronization constructs from an independent rng so the base shape
    for a given seed stays recognizable, and widens ``mem_size`` for the
    shared cells.  ``mem_features=True`` appends memory-latency-heavy
    shapes (load→dependent-ALU chains, loads in divergent branches) drawn
    from another independent rng; it composes with ``sync_features``.

    ``unannotated=True`` compiles the *same* shape (identical rng
    streams), then strips the compiler-planted BSSY/BSYNC/BMOV (and
    spin-loop YIELDs) via :func:`repro.analysis.strip_annotations` — the
    synthesizer's input distribution.  Annotations the stripper must
    conservatively retain (WARPSYNC joins, non-canonical regions) stay.
    """
    rng = np.random.default_rng(seed)
    base = [Raw(["LANEID R1", "MOVR R2, R1"]),
            _node(rng, 0, 0),
            _node(rng, 0, 0)]
    cfg = BASE_CFG._replace(n_bx=n_bx)
    mem_nodes: "list[Raw | If]" = []
    if mem_features:
        mrng = np.random.default_rng(seed ^ 0x9E3779B9)
        mem_nodes.append(_load_use_chain(mrng))
        mem_nodes.append(_divergent_load(mrng))
        if mrng.integers(0, 2):
            mem_nodes.append(_load_use_chain(mrng))
    if sync_features:
        srng = np.random.default_rng(seed ^ 0x5F3759DF)
        full = (1 << W) - 1
        items = base[:2]
        if srng.integers(0, 2):
            items.append(Raw([f"WARPSYNC {full}"]))   # top-level full join
        items.append(_spinlock_node())
        items.append(base[2])
        if srng.integers(0, 2):
            items.append(_break_nested_while(srng))
        if srng.integers(0, 2):
            items.append(Raw([f"WARPSYNC {full}"]))
        ast = Seq(items + mem_nodes)
        cfg = cfg._replace(mem_size=SYNC_MEM)
    else:
        ast = Seq(base + mem_nodes)
    try:
        prog = compile_structured(ast, cfg)
    except ValueError:   # BREAK under spill pressure: legitimately rejected
        return None, cfg
    mem = rng.integers(0, 8, size=cfg.mem_size).astype(np.int32)
    if sync_features:
        mem[LOCK_CELL] = 0          # the mutex must start free
        mem[COUNTER_CELL] = 0       # counter starts 0 -> must end W
    if unannotated:
        from repro.analysis import strip_annotations   # lazy: optional dep
        prog = strip_annotations(prog, cfg).program
    return (prog, mem), cfg


CHECK_REGS = [1, 2, 5, 6, 8, 9, 10]


def corpus(n_seeds: int = 40, n_bx: int = 8, *, unannotated: bool = False):
    """Every distribution's programs for ``n_seeds`` seeds, as
    ``(label, program, cfg)`` triples — the shared walk the static-analysis
    conformance gate, the analyzer benchmark, and CI smoke all iterate
    (rejected seeds are skipped, exactly as the property suites skip them).
    ``unannotated=True`` passes through to :func:`make_program`.
    """
    out = []
    for tag, kw in (("base", {}), ("sync", {"sync_features": True}),
                    ("mem", {"mem_features": True})):
        for seed in range(n_seeds):
            made, cfg = make_program(seed, n_bx, unannotated=unannotated,
                                     **kw)
            if made is not None:
                out.append((f"{tag}-{seed}", made[0], cfg))
    return out
