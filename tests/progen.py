"""Random structured-program generator shared by the property suites.

Lives outside the test modules (and imports no hypothesis) so that
benchmark/property consumers can build the same If/While/BREAK program
distribution regardless of whether hypothesis is installed.
"""
import numpy as np

from repro.core import MachineConfig, compile_structured
from repro.core.structured import If, Raw, Seq, While

W = 8
MEM = 64
BASE_CFG = MachineConfig(n_threads=W, n_regs=16, n_preds=4, n_bx=8,
                         mem_size=MEM, max_steps=20_000)

# lane-private address offsets: lower half of memory is read-only input,
# upper half is written at lane-private cells
_RD_OFFS = [0, W, 2 * W, 3 * W]
_WR_OFFS = [4 * W, 5 * W, 6 * W, 7 * W]


def _raw(rng) -> Raw:
    ops = []
    for _ in range(rng.integers(1, 4)):
        k = rng.integers(0, 6)
        if k == 0:
            ops.append(f"IADDI R2, R2, {int(rng.integers(-3, 4))}")
        elif k == 1:
            ops.append("IADD R5, R2, R1")
        elif k == 2:
            ops.append("XOR R6, R5, R2")
        elif k == 3:
            ops.append(f"LDG R5, [R1+{int(rng.choice(_RD_OFFS))}]")
        elif k == 4:
            ops.append(f"STG [R1+{int(rng.choice(_WR_OFFS))}], R5")
        else:
            ops.append("IADD R2, R2, R5")
    return Raw(ops)


def _cond(rng, pred: int) -> list[str]:
    reg = rng.choice(["R2", "R5", "R6", "R1"])
    cmp = rng.choice(["LT", "GT", "EQ", "NE", "GE", "LE"])
    return [f"ISETP.{cmp} P{pred}, {reg}, {int(rng.integers(-2, 5))}"]


def _node(rng, depth: int, loop_level: int) -> "Seq | If | While | Raw":
    choices = ["raw", "seq"]
    if depth < 3:
        choices += ["if", "if", "while"]
    kind = rng.choice(choices)
    if kind == "raw":
        return _raw(rng)
    if kind == "seq":
        return Seq([_node(rng, depth, loop_level)
                    for _ in range(rng.integers(1, 3))])
    pred = int(rng.integers(0, 2))
    if kind == "if":
        has_else = bool(rng.integers(0, 2))
        return If(cond=_cond(rng, pred), pred=pred,
                  then_=_node(rng, depth + 1, loop_level),
                  else_=_node(rng, depth + 1, loop_level) if has_else else None)
    # while: bounded counter in R{8+loop_level}
    rc = 8 + loop_level
    bound = int(rng.integers(1, 4))
    body = Seq([Raw([f"IADDI R{rc}, R{rc}, 1"]),
                _node(rng, depth + 1, loop_level + 1)])
    brk = None
    if rng.integers(0, 3) == 0:
        body = Seq([Raw(["ISETP.GT P2, R5, 6"]), body])
        brk = 2
    return Seq([Raw([f"MOV R{rc}, 0"]),
                While(cond=[f"ISETP.LT P{pred}, R{rc}, {bound}"], pred=pred,
                      body=body, break_pred=brk)])


def make_program(seed: int, n_bx: int):
    rng = np.random.default_rng(seed)
    ast = Seq([Raw(["LANEID R1", "MOVR R2, R1"]),
               _node(rng, 0, 0),
               _node(rng, 0, 0)])
    cfg = BASE_CFG._replace(n_bx=n_bx)
    try:
        prog = compile_structured(ast, cfg)
    except ValueError:   # BREAK under spill pressure: legitimately rejected
        return None, cfg
    mem = rng.integers(0, 8, size=MEM).astype(np.int32)
    return (prog, mem), cfg


CHECK_REGS = [1, 2, 5, 6, 8, 9, 10]
