"""Dual-Path baseline (paper SS X comparison): what it can and cannot do.

The paper argues Hanoi beats Dual-Path because Dual-Path cannot support the
Turing control-flow instructions.  Reproducing the comparison turned up a
sharper picture than the test author first assumed (see EXPERIMENTS.md):

* Dual-Path's two-path interleaving does NOT rescue the spinlock — the
  critical-section path is *born at* the IPDom reconvergence point, so it
  is parked immediately; only Hanoi's YIELD + later-than-IPDom BSYNC works;
* Dual-Path "survives" unstructured flows like Fig 6 by never synchronizing
  at all (BSYNC/BREAK are inexpressible -> NOPs) — it cannot represent the
  deadlock Hanoi's BREAK exists to prevent, nor the early reconvergence;
* BREAK's early exit is a genuine TRADE-OFF: on the BFSW loop Hanoi's
  escaped threads run ahead with small masks (lower SIMD utilization,
  better thread latency) while Dual-Path's forced-IPDom merge packs lanes.
"""
import numpy as np
import pytest

from repro.core import MachineConfig, run_reference, simd_utilization
from repro.core.programs import (fig6_no_break_program, fig6_program,
                                 make_suite, spinlock_program,
                                 warpsync_program)
from repro.engine import Simulator
from tests.progen import make_program

CFG = MachineConfig(n_threads=32, mem_size=256, max_steps=60_000)
# all three mechanisms run through the canonical engine façade (the
# interp/dualpath run_* entry points are deprecated shims)
SIM = Simulator("hanoi")


def run_hanoi(prog, cfg, **kw):
    return SIM.run(prog, cfg, **kw)


def run_simt_stack(prog, cfg, **kw):
    return SIM.run(prog, cfg, mechanism="simt_stack", **kw)


def run_dual_path(prog, cfg, **kw):
    return SIM.run(prog, cfg, mechanism="dualpath", **kw)


def test_dual_path_matches_reference_on_structured_programs():
    checked = 0
    for seed in range(60):
        built, cfg = make_program(seed, 8)
        if built is None:
            continue
        prog, mem = built
        d = run_dual_path(prog, cfg, init_mem=mem)
        if d.deadlocked:
            continue
        ref = run_reference(prog, cfg, init_mem=mem)
        np.testing.assert_array_equal(d.mem, ref.mem)
        checked += 1
    assert checked >= 20


def test_spinlock_only_hanoi_completes():
    """The CS path starts AT the IPDom, so Dual-Path parks it instantly and
    the spinners starve it — two schedulable paths are useless when one is
    already 'reconverging'.  Only the YIELD + late-BSYNC mechanism works."""
    cfg = MachineConfig(n_threads=4, max_steps=20_000)
    assert run_simt_stack(spinlock_program(), cfg).deadlocked
    assert run_dual_path(spinlock_program(), cfg).deadlocked
    h = run_hanoi(spinlock_program(), cfg)
    assert not h.deadlocked and h.mem[1] == 4


def test_fig6_dual_path_cannot_express_the_break_distinction():
    """On Hanoi, removing the BREAK turns Fig 6 into a deadlock (the BSYNC
    waits for thread 0 forever).  Dual-Path cannot express either behavior:
    BSYNC and BREAK are NOPs, so both variants run identically — the
    reconvergence guarantee the compiler asked for silently disappears."""
    cfg = MachineConfig(n_threads=4, max_steps=4096)
    assert not run_hanoi(fig6_program(), cfg).deadlocked
    assert run_hanoi(fig6_no_break_program(), cfg).deadlocked
    d1 = run_dual_path(fig6_program(), cfg)
    d2 = run_dual_path(fig6_no_break_program(), cfg)
    assert not d1.deadlocked and not d2.deadlocked
    assert d1.trace == d2.trace          # BREAK changes nothing: unsupported


def test_warpsync_dual_path_interleaves_hanoi_serializes():
    """Pre-sync paths ALTERNATE on Dual-Path (its scheduling freedom); Hanoi
    executes one WS-stack path to its sync point before switching (the
    paper's coarse, cheap policy).  Both reunite here only because a shared
    WARPSYNC site is topologically an IPDom."""
    cfg = MachineConfig(n_threads=4, max_steps=4096)
    prog = warpsync_program(4)
    h = run_hanoi(prog, cfg)
    d = run_dual_path(prog, cfg)
    sync_pc = next(pc for pc in range(prog.shape[0]) if prog[pc, 0] == 8)
    post = sync_pc + 1

    def mask_switches(trace):
        pre = [m for p, m in trace if p < sync_pc and p > 2]
        return sum(1 for a, b in zip(pre, pre[1:]) if a != b)

    assert [m for p, m in h.trace if p == post] == [0b1111]
    assert [m for p, m in d.trace if p == post] == [0b1111]
    assert mask_switches(d.trace) > mask_switches(h.trace)


def test_break_is_a_latency_vs_utilization_tradeoff():
    """BFSW (loop + BREAK early exit): Hanoi's escaped threads run ahead in
    small groups; Dual-Path's forced-IPDom merge packs lanes.  Results agree;
    utilizations differ in opposite directions per program — recorded in
    EXPERIMENTS.md rather than asserted as a universal ordering."""
    suite = [b for b in make_suite(CFG, datasets=1)
             if b.name.startswith("BFSW")]
    assert suite
    for bench in suite:
        h = run_hanoi(bench.program, CFG, init_mem=bench.init_mem)
        d = run_dual_path(bench.program, CFG, init_mem=bench.init_mem)
        assert not h.deadlocked and not d.deadlocked
        np.testing.assert_array_equal(h.mem, d.mem)
        assert 0 < simd_utilization(d.trace, 32) <= 1
        assert 0 < simd_utilization(h.trace, 32) <= 1
