"""Gate on the recorded dry-run sweep (results/dryrun.json).

These tests validate the DELIVERABLE artifact rather than re-compiling 80
cells (the sweep takes ~2h; `python -m repro.launch.dryrun --all` refreshes
it).  Skipped when the artifact is absent."""
import json
import os

import pytest

PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "results", "dryrun.json")

pytestmark = pytest.mark.skipif(not os.path.exists(PATH),
                                reason="run repro.launch.dryrun --all first")


def _load():
    return json.load(open(PATH))


def test_all_cells_accounted():
    rs = _load()
    for mesh in ("single", "multi"):
        cells = [r for r in rs if r["mesh"] == mesh]
        assert len(cells) == 40, f"{mesh}: {len(cells)}/40 cells recorded"
        ok = [r for r in cells if r["status"] == "ok"]
        skipped = [r for r in cells if r["status"] == "skipped"]
        assert len(ok) == 33, f"{mesh}: {len(ok)} ok"
        assert len(skipped) == 7
        assert not [r for r in cells if r["status"] == "error"]


def test_skips_match_design_doc():
    rs = _load()
    skips = {(r["arch"], r["shape"]) for r in rs
             if r["mesh"] == "single" and r["status"] == "skipped"}
    assert skips == {
        ("hubert-xlarge", "decode_32k"), ("hubert-xlarge", "long_500k"),
        ("minitron-4b", "long_500k"), ("internlm2-20b", "long_500k"),
        ("llama3.2-1b", "long_500k"), ("internvl2-2b", "long_500k"),
        ("deepseek-moe-16b", "long_500k"),
    }


def test_roofline_terms_present_and_positive():
    rs = _load()
    for r in rs:
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        assert ro["memory_s"] > 0, r["arch"]
        assert ro["dominant"] in ("compute", "memory", "collective")
        assert r["memory"]["temp_bytes"] > 0
        if r["shape"] == "train_4k":
            assert ro["flops"] > 1e11, (r["arch"], "train flops too low")
            assert ro["coll_bytes"] > 0


def test_train_cells_fit_hbm():
    rs = _load()
    for r in rs:
        if r["status"] == "ok" and r["shape"] == "train_4k":
            assert r["memory"]["temp_bytes"] <= 15 * 2**30, \
                (r["arch"], r["memory"]["temp_bytes"] / 2**30)


def test_multi_pod_weak_scaling():
    """Pod axis = data parallel: per-device compute must halve (+/-20%)."""
    rs = _load()
    by = {(r["arch"], r["shape"], r["mesh"]): r for r in rs
          if r["status"] == "ok"}
    checked = 0
    for (a, s, m), r in by.items():
        if m != "single" or s != "train_4k":
            continue
        r2 = by.get((a, s, "multi"))
        if r2 is None:
            continue
        c1, c2 = r["roofline"]["compute_s"], r2["roofline"]["compute_s"]
        if c1 > 1e-4:
            assert 0.4 <= c2 / c1 <= 0.75, (a, s, c2 / c1)
            checked += 1
    assert checked >= 8
