"""Exact-equivalence tests: vectorized JAX engine vs. numpy reference.

The JAX engine must produce bit-identical architectural state AND the exact
same control-flow trace (the paper's comparison object) for every program.
"""
import numpy as np
import pytest

from repro.core import MachineConfig
from repro.core.hanoi import (run_hanoi_jax, run_warps_jax, state_deadlocked,
                              state_trace)
from repro.engine import Simulator
from repro.core.programs import (fig5_program, fig6_program, make_suite,
                                 spinlock_program, warpsync_program)
# compat shim: without hypothesis only the @given tests skip, the
# example-based equivalence tests below still run
from tests.hypothesis_compat import given, settings, st
from tests.progen import BASE_CFG, MEM, W, make_program

CFG = MachineConfig(n_threads=4, max_steps=2048)
PAD = 128
SIM = Simulator("hanoi")


def run_ref(prog, cfg, *, init_mem=None, init_regs=None, skips=()):
    """The numpy Hanoi reference through the canonical ``repro.engine`` API
    (``interp.run_hanoi`` is a deprecated shim); a non-empty oracle skip set
    selects the ``turing_oracle`` mechanism, which is Hanoi + skips."""
    mech = "turing_oracle" if skips else "hanoi"
    return SIM.run(prog, cfg, mechanism=mech, init_mem=init_mem,
                   init_regs=init_regs, bsync_skip_pcs=tuple(skips))


def assert_equiv(prog, cfg, *, init_mem=None, skips=()):
    ref = run_ref(prog, cfg, init_mem=init_mem, skips=skips)
    st_ = run_hanoi_jax(prog, cfg, init_mem=init_mem, bsync_skip_pcs=skips,
                        pad_to=PAD)
    assert state_deadlocked(st_, cfg) == ref.deadlocked
    np.testing.assert_array_equal(np.asarray(st_.regs), ref.regs)
    np.testing.assert_array_equal(np.asarray(st_.preds), ref.preds)
    np.testing.assert_array_equal(np.asarray(st_.mem), ref.mem)
    assert int(st_.finished) == ref.finished
    assert tuple(state_trace(st_)) == ref.trace


@pytest.mark.parametrize("mk", [fig5_program, fig6_program,
                                lambda: warpsync_program(4)])
def test_jax_matches_numpy_on_figures(mk):
    assert_equiv(mk(), CFG)


def test_jax_matches_numpy_on_spinlock():
    assert_equiv(spinlock_program(), MachineConfig(n_threads=4,
                                                   max_steps=2048))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 5_000), n_bx=st.sampled_from([2, 8]))
def test_jax_matches_numpy_on_random_programs(seed, n_bx):
    built, cfg = make_program(seed, n_bx)
    if built is None:
        return
    prog, mem = built
    if prog.shape[0] > 256:
        return
    cfg = cfg._replace(max_steps=4096)
    ref = run_ref(prog, cfg, init_mem=mem)
    st_ = run_hanoi_jax(prog, cfg, init_mem=mem, pad_to=256)
    np.testing.assert_array_equal(np.asarray(st_.regs), ref.regs)
    np.testing.assert_array_equal(np.asarray(st_.mem), ref.mem)
    assert int(st_.finished) == ref.finished
    assert tuple(state_trace(st_)) == ref.trace


def test_vmapped_warps_match_sequential():
    """The vectorized simulator's selling point: many warps in one XLA call,
    each bit-identical to a solo run."""
    cfg = MachineConfig(n_threads=8, mem_size=64, max_steps=4096)
    built, _ = make_program(1234, 8)
    prog, _ = built
    n_warps = 4
    rng = np.random.default_rng(0)
    regs = np.zeros((n_warps, cfg.n_threads, cfg.n_regs), np.int32)
    mems = rng.integers(0, 8, size=(n_warps, cfg.mem_size)).astype(np.int32)
    batched = run_warps_jax(prog, cfg, regs, mems)
    for i in range(n_warps):
        ref = run_ref(prog, cfg, init_regs=regs[i], init_mem=mems[i])
        np.testing.assert_array_equal(np.asarray(batched.regs[i]), ref.regs)
        np.testing.assert_array_equal(np.asarray(batched.mem[i]), ref.mem)
        assert int(batched.finished[i]) == ref.finished


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 5_000), fuel=st.sampled_from([7, 23, 61]))
def test_fuel_exhaustion_equivalence(seed, fuel):
    """Out-of-fuel normalization: when the scheduler-slot budget expires
    mid-execution (including mid-split), the numpy and JAX engines must
    agree on the truncated trace, step count, remaining fuel, AND the
    normalized SimStatus — fuel exhaustion is flagged, never silently
    truncated differently per engine."""
    from repro.engine import classify_status
    built, cfg = make_program(seed, 2)
    if built is None:
        return
    prog, mem = built
    if prog.shape[0] > 256:
        return
    cfg = cfg._replace(max_steps=fuel)
    ref = run_ref(prog, cfg, init_mem=mem)
    st_ = run_hanoi_jax(prog, cfg, init_mem=mem, pad_to=256)
    assert tuple(state_trace(st_)) == ref.trace
    assert int(st_.steps) == ref.steps
    assert int(st_.fuel) == ref.fuel_left
    assert int(st_.finished) == ref.finished
    s_np = classify_status(finished=ref.finished, full_mask=cfg.full_mask,
                           fuel_left=ref.fuel_left, error=ref.error)
    s_jx = classify_status(finished=int(st_.finished),
                           full_mask=cfg.full_mask,
                           fuel_left=int(st_.fuel), error=None)
    assert s_np == s_jx


def test_oracle_skip_on_jax_engine():
    from repro.core.isa import Op
    built = None
    for seed in range(77, 120):
        built, cfg = make_program(seed, 8)
        if built is not None:
            break
    prog, mem = built
    cfg = cfg._replace(max_steps=4096)
    skips = ()
    bsyncs = [pc for pc in range(prog.shape[0]) if prog[pc, 0] == Op.BSYNC]
    if bsyncs:
        skips = (bsyncs[-1],)
    ref = run_ref(prog, cfg, init_mem=mem, skips=skips)
    st_ = run_hanoi_jax(prog, cfg, init_mem=mem, bsync_skip_pcs=skips)
    np.testing.assert_array_equal(np.asarray(st_.regs), ref.regs)
    assert tuple(state_trace(st_)) == ref.trace
