"""Assembler + ISA unit tests: syntax coverage, label resolution, predicate
encoding, error paths, disassembly smoke."""
import numpy as np
import pytest

from repro.core import AsmError, Instr, Op, assemble, disassemble
from repro.core.isa import F_IMM, F_OP, F_PRED1, F_PRED2, encode_program


def test_labels_forward_and_backward():
    prog = assemble("""
    top:
        IADDI R1, R1, 1
        ISETP.LT P0, R1, 3
        @P0 BRA top
        BRA end
        MOV R2, 99
    end:
        EXIT
    """)
    assert prog[2, F_OP] == Op.BRA and prog[2, F_IMM] == 0
    assert prog[3, F_IMM] == 5


def test_predicate_encoding():
    prog = assemble("@!P2 BRA P1, 0")
    assert prog[0, F_PRED1] == -3
    assert prog[0, F_PRED2] == 2
    prog = assemble("@P0 BREAK !P1, B3")
    assert prog[0, F_PRED1] == 1
    assert prog[0, F_PRED2] == -2


def test_memory_operand_forms():
    prog = assemble("""
        LDG R1, [R2]
        LDG R1, [R2+8]
        STG [R3 + 4], R1
        ATOMCAS R5, [R0], R6, R7
    """)
    assert prog[0, F_IMM] == 0 and prog[1, F_IMM] == 8
    assert prog[2, F_IMM] == 4
    assert prog[3, F_OP] == Op.ATOMCAS


def test_bmov_direction_inference():
    prog = assemble("BMOV R5, B2\nBMOV B2, R5")
    assert prog[0, F_OP] == Op.BMOV_B2R
    assert prog[1, F_OP] == Op.BMOV_R2B


@pytest.mark.parametrize("bad", [
    "FROB R1, R2",            # unknown mnemonic
    "BRA nowhere",            # unresolved label
    "LDG R1, R2",             # malformed memory operand
    "BSSY R0, 5",             # wrong register class
])
def test_assembler_rejects(bad):
    with pytest.raises(AsmError):
        assemble(bad)


def test_disassemble_smoke():
    from repro.core.programs import spinlock_program
    text = disassemble(spinlock_program())
    assert "ATOMCAS" in text and "YIELD" in text and "BSYNC" in text


def test_encode_decode_roundtrip():
    from repro.core.isa import decode_program
    instrs = [Instr(Op.MOV, dst=3, imm=-7), Instr(Op.EXIT, pred1=-1)]
    table = encode_program(instrs)
    out = decode_program(table)
    assert out[0].imm == -7 and out[1].pred1 == -1
